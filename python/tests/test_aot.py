"""AOT contract tests: every variant lowers to HLO text the 0.5.1 XLA
parser accepts structurally, and the manifest matches the lowered
signatures."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = []
    for build in aot.variants():
        name, hlo, entry = build()
        (out / entry["hlo_file"]).write_text(hlo)
        entries.append(entry)
    (out / "manifest.json").write_text(json.dumps({"artifacts": entries}))
    return out, entries


def test_all_variants_lower(built):
    out, entries = built
    assert len(entries) >= 13
    for e in entries:
        text = (out / e["hlo_file"]).read_text()
        assert text.startswith("HloModule"), e["name"]
        assert "ENTRY" in text, e["name"]


def test_manifest_signatures_consistent(built):
    _, entries = built
    for e in entries:
        # params lead the input list, in param_names order
        for i, pname in enumerate(e["param_names"]):
            assert e["inputs"][i]["name"] == pname, e["name"]
        assert len(e["param_init"]) == len(e["param_names"]), e["name"]
        # train artifacts: one grad per param + loss
        if "_train_" in e["name"]:
            assert len(e["outputs"]) == len(e["param_names"]) + 1, e["name"]
            assert e["outputs"][-1]["name"] == "loss"
            for i, pname in enumerate(e["param_names"]):
                assert e["outputs"][i]["dims"] == e["inputs"][i]["dims"], (
                    f"{e['name']}: grad {pname} shape mismatch"
                )


def test_hlo_text_has_no_64bit_id_issue(built):
    """The text format is the interchange: it must parse as HLO text
    (heuristic: no 'id=' attributes that trip xla_extension 0.5.1)."""
    out, entries = built
    for e in entries:
        text = (out / e["hlo_file"]).read_text()
        # serialized protos would be binary; text must be ASCII
        assert text.isascii(), e["name"]


def test_train_variants_cover_precisions(built):
    _, entries = built
    names = {e["name"] for e in entries}
    assert "resnet_mini_train_f32_b16" in names
    assert "resnet_mini_train_bf16_b16" in names
    assert "resnet_mini_train_jnpref_b16" in names  # Table 1 baseline
    assert "tfmr_lm_train_f32_b8" in names
    assert "matmul_f32_256" in names and "matmul_bf16_256" in names


def test_bf16_graph_contains_bf16_ops(built):
    out, entries = built
    bf16 = next(e for e in entries if e["name"] == "matmul_bf16_256")
    f32 = next(e for e in entries if e["name"] == "matmul_f32_256")
    assert "bf16" in (out / bf16["hlo_file"]).read_text()
    assert "bf16" not in (out / f32["hlo_file"]).read_text()
