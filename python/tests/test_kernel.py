"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle,
swept across shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mmk
from compile.kernels.ref import im2col_ref, matmul_ref


def rand(shape, seed):
    return np.random.RandomState(seed).randn(*shape).astype(np.float32)


# ------------------------------------------------------------- hypothesis


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**16),
)
def test_matmul_f32_matches_ref(m, k, n, seed):
    a, b = rand((m, k), seed), rand((k, n), seed + 1)
    got = np.asarray(mmk.matmul(a, b))
    want = np.asarray(matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 100),
    k=st.integers(1, 100),
    n=st.integers(1, 100),
    seed=st.integers(0, 2**16),
)
def test_matmul_bf16_matches_ref(m, k, n, seed):
    a, b = rand((m, k), seed), rand((k, n), seed + 1)
    got = np.asarray(mmk.matmul(a, b, half=True))
    want = np.asarray(matmul_ref(a, b, half=True))
    # same storage-cast + f32-accumulate contract: near-exact agreement
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_matmul_grad_matches_ref_grad(seed):
    a, b = rand((17, 23), seed), rand((23, 9), seed + 1)

    def f_kernel(a, b):
        return mmk.matmul(a, b).sum()

    def f_ref(a, b):
        return matmul_ref(a, b).sum()

    ga_k, gb_k = jax.grad(f_kernel, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_k), np.asarray(ga_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_k), np.asarray(gb_r), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- point tests


def test_block_tiled_path_exact_sizes():
    # shapes exactly on the 128-block grid exercise the multi-block path
    a, b = rand((256, 384), 0), rand((384, 128), 1)
    got = np.asarray(mmk.matmul(a, b))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-3)


def test_bf16_actually_quantizes():
    a = np.full((8, 8), 1.0 + 2.0**-9, np.float32)  # not bf16-representable
    b = np.eye(8, dtype=np.float32)
    exact = np.asarray(mmk.matmul(a, b))
    half = np.asarray(mmk.matmul(a, b, half=True))
    assert not np.allclose(exact, half), "half path did not quantize"


def test_identity_and_zeros():
    a = rand((33, 33), 2)
    eye = np.eye(33, dtype=np.float32)
    np.testing.assert_allclose(np.asarray(mmk.matmul(a, eye)), a, rtol=1e-5, atol=1e-5)
    z = np.zeros((33, 7), np.float32)
    assert np.abs(np.asarray(mmk.matmul(a, z))).max() == 0.0


def test_vmem_estimate_within_budget():
    # DESIGN.md §9: the TPU-target 128^3 tiles stay far under 16 MiB VMEM
    assert mmk.estimate_vmem_bytes(128, 128, 128) <= 256 * 1024
    assert mmk.estimate_vmem_bytes(128, 128, 128, half=True) < mmk.estimate_vmem_bytes(
        128, 128, 128
    )


def test_mxu_utilization_model():
    kw = dict(bm=128, bn=128, bk=128)
    assert mmk.estimate_mxu_utilization(128, 128, 128, **kw) == 1.0
    assert 0.4 < mmk.estimate_mxu_utilization(300, 300, 300, **kw) < 0.5
    assert mmk.estimate_mxu_utilization(1, 1, 1, **kw) < 0.01


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 4),
    hw=st.integers(4, 10),
    k=st.sampled_from([1, 3]),
    seed=st.integers(0, 2**16),
)
def test_im2col_matches_lax_conv(n, c, hw, k, seed):
    """conv-via-im2col+kernel == jax.lax conv (the L2 lowering is right)."""
    x = rand((n, c, hw, hw), seed)
    w = rand((5, c, k, k), seed + 1)
    pad = k // 2
    cols, (oh, ow) = im2col_ref(x, k, k, 1, pad)
    got = np.asarray(mmk.matmul(cols, w.reshape(5, -1).T)).reshape(n, oh, ow, 5)
    got = got.transpose(0, 3, 1, 2)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), [(pad, pad), (pad, pad)], dimension_numbers=("NCHW", "OIHW", "NCHW")
    )
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-3, atol=1e-3)
