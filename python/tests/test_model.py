"""L2 correctness: model shapes, gradient flow, pallas-vs-jnpref parity
and the loss-scaling contract of every train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def init_params(model, cfg, seed=0):
    rng = np.random.RandomState(seed)
    params = {}
    for name, shape, kind, scale in M.MODELS[model]["param_specs"](cfg):
        if kind == "zeros":
            params[name] = jnp.zeros(shape)
        elif kind == "ones":
            params[name] = jnp.ones(shape)
        elif kind == "uniform":
            params[name] = jnp.asarray(rng.uniform(-scale, scale, shape), jnp.float32)
        else:
            params[name] = jnp.asarray(rng.randn(*shape) * scale, jnp.float32)
    return params


def data_for(model, cfg, batch, seed=1):
    rng = np.random.RandomState(seed)
    if model == "tfmr_lm":
        x = rng.randint(0, cfg["vocab"], (batch, cfg["seq"])).astype(np.float32)
        y = rng.randint(0, cfg["vocab"], (batch, cfg["seq"])).astype(np.float32)
    elif model == "mlp":
        x = rng.randn(batch, cfg["d_in"]).astype(np.float32)
        y = rng.randint(0, cfg["classes"], batch).astype(np.float32)
    else:
        x = rng.randn(batch, cfg["c_in"], cfg["img"], cfg["img"]).astype(np.float32)
        y = rng.randint(0, cfg["classes"], batch).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


ALL_MODELS = ["mlp", "lenet", "resnet_mini", "tfmr_lm"]


@pytest.mark.parametrize("model", ALL_MODELS)
def test_train_step_shapes_and_finite(model):
    cfg = M.MODELS[model]["default_cfg"]
    params = init_params(model, cfg)
    x, y = data_for(model, cfg, 4)
    step = jax.jit(M.make_train_step(model, cfg))
    grads, loss = step(params, x, y, jnp.float32(1.0))
    assert np.isfinite(float(loss)), f"{model} loss not finite"
    for name, g in grads.items():
        assert g.shape == params[name].shape, f"{model}:{name} grad shape"
        assert np.isfinite(np.asarray(g)).all(), f"{model}:{name} grad has nan/inf"


@pytest.mark.parametrize("model", ["mlp", "lenet", "resnet_mini"])
def test_initial_loss_near_log_classes(model):
    cfg = M.MODELS[model]["default_cfg"]
    params = init_params(model, cfg)
    x, y = data_for(model, cfg, 8)
    step = M.make_train_step(model, cfg)
    _, loss = step(params, x, y, jnp.float32(1.0))
    assert abs(float(loss) - np.log(cfg["classes"])) < 1.0


@pytest.mark.parametrize("model", ["mlp", "resnet_mini"])
def test_loss_scaling_contract(model):
    """grads scale linearly with loss_scale; loss is returned unscaled."""
    cfg = M.MODELS[model]["default_cfg"]
    params = init_params(model, cfg)
    x, y = data_for(model, cfg, 4)
    step = jax.jit(M.make_train_step(model, cfg))
    g1, l1 = step(params, x, y, jnp.float32(1.0))
    g8, l8 = step(params, x, y, jnp.float32(8.0))
    assert abs(float(l1) - float(l8)) < 1e-5
    k = next(iter(g1))
    np.testing.assert_allclose(np.asarray(g1[k]) * 8, np.asarray(g8[k]), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("model", ["mlp", "resnet_mini"])
def test_pallas_and_jnpref_agree(model):
    """The pallas-kernel graph and the pure-jnp graph compute the same
    function (Table 1's comparator baseline is honest)."""
    cfg = M.MODELS[model]["default_cfg"]
    params = init_params(model, cfg)
    x, y = data_for(model, cfg, 4)
    s_pallas = M.make_train_step(model, cfg, use_pallas=True)
    s_ref = M.make_train_step(model, cfg, use_pallas=False)
    gp, lp = s_pallas(params, x, y, jnp.float32(1.0))
    gr, lr = s_ref(params, x, y, jnp.float32(1.0))
    assert abs(float(lp) - float(lr)) < 1e-3
    for k in gp:
        np.testing.assert_allclose(
            np.asarray(gp[k]), np.asarray(gr[k]), rtol=5e-3, atol=5e-3, err_msg=k
        )


def test_mlp_short_training_converges():
    cfg = M.MODELS["mlp"]["default_cfg"]
    params = init_params("mlp", cfg)
    rng = np.random.RandomState(3)
    # separable: class mean shift
    y = np.arange(32) % 10
    x = rng.randn(32, cfg["d_in"]).astype(np.float32)
    for i, c in enumerate(y):
        x[i, c] += 3.0
    x, y = jnp.asarray(x), jnp.asarray(y.astype(np.float32))
    step = jax.jit(M.make_train_step("mlp", cfg))
    losses = []
    for _ in range(40):
        grads, loss = step(params, x, y, jnp.float32(1.0))
        params = {k: params[k] - 0.1 * grads[k] for k in params}
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.3, f"{losses[0]} -> {losses[-1]}"


def test_bf16_step_close_to_f32_step():
    cfg = M.MODELS["mlp"]["default_cfg"]
    params = init_params("mlp", cfg)
    x, y = data_for("mlp", cfg, 8)
    _, l32 = M.make_train_step("mlp", cfg, half=False)(params, x, y, jnp.float32(1.0))
    _, l16 = M.make_train_step("mlp", cfg, half=True)(params, x, y, jnp.float32(1.0))
    assert abs(float(l32) - float(l16)) < 0.05


def test_tfmr_causality():
    """Changing a future token must not affect earlier logits."""
    cfg = dict(M.MODELS["tfmr_lm"]["default_cfg"])
    params = init_params("tfmr_lm", cfg)
    rng = np.random.RandomState(5)
    ids = rng.randint(0, cfg["vocab"], (1, cfg["seq"])).astype(np.float32)
    ids2 = ids.copy()
    ids2[0, -1] = (ids2[0, -1] + 1) % cfg["vocab"]
    la = M.tfmr_apply(params, jnp.asarray(ids), cfg)
    lb = M.tfmr_apply(params, jnp.asarray(ids2), cfg)
    np.testing.assert_allclose(
        np.asarray(la[0, : cfg["seq"] - 1]), np.asarray(lb[0, : cfg["seq"] - 1]),
        rtol=1e-4, atol=1e-4,
    )
    assert not np.allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]))


def test_param_counts_documented():
    """Parameter counts are what DESIGN.md claims (zoo footprints)."""
    counts = {}
    for model in ALL_MODELS:
        cfg = M.MODELS[model]["default_cfg"]
        specs = M.MODELS[model]["param_specs"](cfg)
        counts[model] = sum(int(np.prod(s[1])) for s in specs)
    assert 10_000 < counts["mlp"] < 30_000
    assert 10_000 < counts["lenet"] < 50_000
    assert 15_000 < counts["resnet_mini"] < 100_000
    assert 400_000 < counts["tfmr_lm"] < 2_000_000
