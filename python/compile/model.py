"""L2 — JAX model/train-step definitions, AOT-lowered by `aot.py`.

Every dense contraction (affine layers, im2col'd convolutions,
attention) routes through the L1 Pallas kernel (`kernels.matmul`), so
the lowered HLO exercises the paper's compute hot-spot end to end.

Each model is described by:
- ``param_specs(cfg)`` — ordered ``(name, shape, init_kind, scale)``
  (the manifest contract: Rust materializes identical initial params);
- ``apply(params, x, half)`` — forward pass to logits;
- a generic ``make_train_step`` building
  ``(params..., x, y, loss_scale) -> (scaled grads..., loss)``,
  which is exactly Listing 6's ``loss.backward(loss_scale)`` contract:
  the solver-side unscale/update stays in Rust (L3).
"""

import functools
import math

import jax
import jax.numpy as jnp

from .kernels import matmul as mmk
from .kernels.ref import im2col_ref, matmul_ref


# --------------------------------------------------------------------- ops


def dense(x, w, b=None, *, half=False, use_pallas=True):
    """x [B, in] @ w [in, out] + b through the L1 kernel."""
    mm = mmk.matmul if use_pallas else matmul_ref
    y = mm(x, w, half=half)
    if b is not None:
        y = y + b
    return y


def conv2d(x, w, b=None, *, stride=1, pad=0, half=False, use_pallas=True):
    """NCHW conv through im2col + the L1 matmul kernel."""
    oc, c, kh, kw = w.shape
    n = x.shape[0]
    cols, (oh, ow) = im2col_ref(x, kh, kw, stride, pad)  # [n*oh*ow, c*kh*kw]
    wr = w.reshape(oc, c * kh * kw).T
    mm = mmk.matmul if use_pallas else matmul_ref
    y = mm(cols, wr, half=half)  # [n*oh*ow, oc]
    if b is not None:
        y = y + b
    return y.reshape(n, oh, ow, oc).transpose(0, 3, 1, 2)


def max_pool(x, k=2, stride=2):
    n, c, h, w = x.shape
    x = x.reshape(n, c, h // stride, stride, w // stride, stride)
    return x.max(axis=(3, 5)) if k == stride else x.max(axis=(3, 5))


def global_avg_pool(x):
    return x.mean(axis=(2, 3))


def batch_norm_stats(x, gamma, beta, eps=1e-5):
    """Batch-stat normalization (training graph; running stats live on
    the dynamic path — documented substitution in DESIGN.md). Always
    f32, per the paper's §3.3 rule."""
    x32 = x.astype(jnp.float32)
    axes = (0, 2, 3) if x.ndim == 4 else (0,)
    mu = x32.mean(axis=axes, keepdims=True)
    var = x32.var(axis=axes, keepdims=True)
    shape = (1, -1, 1, 1) if x.ndim == 4 else (1, -1)
    return gamma.reshape(shape) * (x32 - mu) / jnp.sqrt(var + eps) + beta.reshape(shape)


def layer_norm(x, gamma, beta, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(axis=-1, keepdims=True)
    var = x32.var(axis=-1, keepdims=True)
    return gamma * (x32 - mu) / jnp.sqrt(var + eps) + beta


def softmax_cross_entropy(logits, labels):
    """Mean CE over the batch; labels are int32 indices."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    return nll.mean()


def _glorot(shape):
    fan_in, fan_out = shape[0], shape[-1]
    if len(shape) == 4:  # conv [oc, c, kh, kw]
        rcf = shape[1] * shape[2] * shape[3]
        fan_in, fan_out = rcf, shape[0] * shape[2] * shape[3]
    return ("uniform", math.sqrt(6.0 / (fan_in + fan_out)))


# --------------------------------------------------------------------- MLP


def mlp_param_specs(cfg):
    d_in, hidden, classes = cfg["d_in"], cfg["hidden"], cfg["classes"]
    specs = []
    last = d_in
    for i, h in enumerate(hidden):
        kind, scale = _glorot((last, h))
        specs.append((f"fc{i}/W", (last, h), kind, scale))
        specs.append((f"fc{i}/b", (h,), "zeros", 0.0))
        last = h
    kind, scale = _glorot((last, classes))
    specs.append(("out/W", (last, classes), kind, scale))
    specs.append(("out/b", (classes,), "zeros", 0.0))
    return specs


def mlp_apply(params, x, cfg, *, half=False, use_pallas=True):
    h = x
    for i in range(len(cfg["hidden"])):
        h = dense(h, params[f"fc{i}/W"], params[f"fc{i}/b"], half=half, use_pallas=use_pallas)
        h = jax.nn.relu(h)
    return dense(h, params["out/W"], params["out/b"], half=half, use_pallas=use_pallas)


# -------------------------------------------------------------------- LeNet
# Listing 4 verbatim: conv16-5x5 / pool / relu / conv16-5x5 / pool /
# relu / affine50 / relu / affine10.


def lenet_param_specs(cfg):
    c_in, img, classes = cfg["c_in"], cfg["img"], cfg["classes"]
    specs = []
    for i, (ic, oc) in enumerate([(c_in, 16), (16, 16)]):
        kind, scale = _glorot((oc, ic, 5, 5))
        specs.append((f"conv{i + 1}/W", (oc, ic, 5, 5), kind, scale))
        specs.append((f"conv{i + 1}/b", (oc,), "zeros", 0.0))
    # spatial size after two (conv5x5 valid + pool2) stages
    s = img
    for _ in range(2):
        s = (s - 4) // 2
    flat = 16 * s * s
    for name, (i_, o_) in [("affine3", (flat, 50)), ("affine4", (50, classes))]:
        kind, scale = _glorot((i_, o_))
        specs.append((f"{name}/W", (i_, o_), kind, scale))
        specs.append((f"{name}/b", (o_,), "zeros", 0.0))
    return specs


def lenet_apply(params, x, cfg, *, half=False, use_pallas=True):
    h = conv2d(x, params["conv1/W"], params["conv1/b"], half=half, use_pallas=use_pallas)
    h = jax.nn.relu(max_pool(h))
    h = conv2d(h, params["conv2/W"], params["conv2/b"], half=half, use_pallas=use_pallas)
    h = jax.nn.relu(max_pool(h))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(dense(h, params["affine3/W"], params["affine3/b"], half=half,
                          use_pallas=use_pallas))
    return dense(h, params["affine4/W"], params["affine4/b"], half=half,
                 use_pallas=use_pallas)


# -------------------------------------------------------------- ResNet-mini


def resnet_param_specs(cfg):
    """Scaled-down ResNet: stem conv + `blocks` residual blocks per
    stage over `widths`, GAP, classifier."""
    widths, blocks, c_in, classes = cfg["widths"], cfg["blocks"], cfg["c_in"], cfg["classes"]
    specs = []

    def conv(name, oc, ic, k):
        kind, scale = _glorot((oc, ic, k, k))
        specs.append((f"{name}/W", (oc, ic, k, k), kind, scale))
        specs.append((f"{name}/gamma", (oc,), "ones", 0.0))
        specs.append((f"{name}/beta", (oc,), "zeros", 0.0))

    conv("stem", widths[0], c_in, 3)
    ic = widths[0]
    for s, w in enumerate(widths):
        for b in range(blocks):
            conv(f"s{s}b{b}/c1", w, ic, 3)
            conv(f"s{s}b{b}/c2", w, w, 3)
            if ic != w:
                conv(f"s{s}b{b}/proj", w, ic, 1)
            ic = w
    kind, scale = _glorot((ic, classes))
    specs.append(("head/W", (ic, classes), kind, scale))
    specs.append(("head/b", (classes,), "zeros", 0.0))
    return specs


def resnet_apply(params, x, cfg, *, half=False, use_pallas=True):
    widths, blocks = cfg["widths"], cfg["blocks"]

    def cbr(name, h, stride=1, relu=True):
        k = params[f"{name}/W"].shape[2]
        h = conv2d(h, params[f"{name}/W"], stride=stride, pad=k // 2, half=half,
                   use_pallas=use_pallas)
        h = batch_norm_stats(h, params[f"{name}/gamma"], params[f"{name}/beta"])
        return jax.nn.relu(h) if relu else h

    h = cbr("stem", x)
    ic = widths[0]
    for s, w in enumerate(widths):
        for b in range(blocks):
            stride = 2 if (s > 0 and b == 0) else 1
            r = cbr(f"s{s}b{b}/c1", h, stride=stride)
            r = cbr(f"s{s}b{b}/c2", r, relu=False)
            sc = h
            if ic != w or stride != 1:
                if f"s{s}b{b}/proj/W" in params:
                    sc = cbr(f"s{s}b{b}/proj", h, stride=stride, relu=False)
                else:
                    sc = h[:, :, ::stride, ::stride]
            h = jax.nn.relu(r + sc)
            ic = w
    h = global_avg_pool(h)
    return dense(h, params["head/W"], params["head/b"], half=half, use_pallas=use_pallas)


# ---------------------------------------------------------- TransformerLM


def tfmr_param_specs(cfg):
    v, d, l, ff = cfg["vocab"], cfg["d"], cfg["layers"], cfg["ff"]
    specs = [("embed/W", (v, d), "normal", 0.02), ("pos/W", (cfg["seq"], d), "normal", 0.02)]
    for i in range(l):
        for nm, shape in [
            (f"l{i}/qkv/W", (d, 3 * d)),
            (f"l{i}/proj/W", (d, d)),
            (f"l{i}/ff1/W", (d, ff)),
            (f"l{i}/ff2/W", (ff, d)),
        ]:
            kind, scale = _glorot(shape)
            specs.append((nm, shape, kind, scale))
        specs += [
            (f"l{i}/ln1/gamma", (d,), "ones", 0.0),
            (f"l{i}/ln1/beta", (d,), "zeros", 0.0),
            (f"l{i}/ln2/gamma", (d,), "ones", 0.0),
            (f"l{i}/ln2/beta", (d,), "zeros", 0.0),
        ]
    specs += [("lnf/gamma", (d,), "ones", 0.0), ("lnf/beta", (d,), "zeros", 0.0)]
    kind, scale = _glorot((d, v))
    specs.append(("head/W", (d, v), kind, scale))
    return specs


def tfmr_apply(params, ids, cfg, *, half=False, use_pallas=True):
    """ids [B, T] int32 -> logits [B, T, V]; causal self-attention."""
    b, t = ids.shape
    d, heads = cfg["d"], cfg["heads"]
    hd = d // heads
    h = params["embed/W"][ids.astype(jnp.int32)] + params["pos/W"][None, :t, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    mm = mmk.matmul if use_pallas else matmul_ref
    for i in range(cfg["layers"]):
        x = layer_norm(h, params[f"l{i}/ln1/gamma"], params[f"l{i}/ln1/beta"])
        qkv = mm(x.reshape(b * t, d), params[f"l{i}/qkv/W"], half=half).reshape(b, t, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att.astype(jnp.float32), axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b * t, d)
        h = h + mm(o, params[f"l{i}/proj/W"], half=half).reshape(b, t, d)
        x = layer_norm(h, params[f"l{i}/ln2/gamma"], params[f"l{i}/ln2/beta"])
        f = mm(x.reshape(b * t, d), params[f"l{i}/ff1/W"], half=half)
        f = jax.nn.gelu(f)
        h = h + mm(f, params[f"l{i}/ff2/W"], half=half).reshape(b, t, d)
    h = layer_norm(h, params["lnf/gamma"], params["lnf/beta"])
    return mm(h.reshape(b * t, d), params["head/W"], half=half).reshape(b, t, cfg["vocab"])


def tfmr_loss(params, ids, targets, cfg, *, half=False, use_pallas=True):
    logits = tfmr_apply(params, ids, cfg, half=half, use_pallas=use_pallas)
    b, t, v = logits.shape
    return softmax_cross_entropy(logits.reshape(b * t, v), targets.reshape(b * t))


# ---------------------------------------------------------------- registry

MODELS = {
    "mlp": {
        "param_specs": mlp_param_specs,
        "apply": mlp_apply,
        "default_cfg": {"d_in": 64, "hidden": [128, 64], "classes": 10},
        "input": lambda cfg, b: [("x", (b, cfg["d_in"]), "float32"), ("y", (b,), "float32")],
    },
    "lenet": {
        "param_specs": lenet_param_specs,
        "apply": lenet_apply,
        "default_cfg": {"c_in": 1, "img": 28, "classes": 10},
        "input": lambda cfg, b: [
            ("x", (b, cfg["c_in"], cfg["img"], cfg["img"]), "float32"),
            ("y", (b,), "float32"),
        ],
    },
    "resnet_mini": {
        "param_specs": resnet_param_specs,
        "apply": resnet_apply,
        "default_cfg": {"widths": [8, 16, 32], "blocks": 1, "c_in": 3, "classes": 10, "img": 16},
        "input": lambda cfg, b: [
            ("x", (b, cfg["c_in"], cfg["img"], cfg["img"]), "float32"),
            ("y", (b,), "float32"),
        ],
    },
    "tfmr_lm": {
        "param_specs": tfmr_param_specs,
        "apply": None,  # language model: uses tfmr_loss directly
        "default_cfg": {"vocab": 96, "d": 128, "layers": 2, "heads": 4, "ff": 512, "seq": 64},
        "input": lambda cfg, b: [
            ("x", (b, cfg["seq"]), "float32"),
            ("y", (b, cfg["seq"]), "float32"),
        ],
    },
}


def classifier_loss(model, params, x, y, cfg, *, half=False, use_pallas=True):
    logits = MODELS[model]["apply"](params, x, cfg, half=half, use_pallas=use_pallas)
    return softmax_cross_entropy(logits, y)


def make_train_step(model, cfg, *, half=False, use_pallas=True):
    """Build `(params_dict, x, y, loss_scale) -> (grads_dict, loss)`.

    The returned grads are *scaled* by `loss_scale` (Listing 6:
    `loss.backward(loss_scale)`); loss is returned unscaled. The
    unscale + update happens in the Rust solver.
    """
    if model == "tfmr_lm":
        def loss_fn(params, x, y):
            return tfmr_loss(params, x, y, cfg, half=half, use_pallas=use_pallas)
    else:
        def loss_fn(params, x, y):
            return classifier_loss(model, params, x, y, cfg, half=half, use_pallas=use_pallas)

    def step(params, x, y, loss_scale):
        def scaled(params):
            return loss_fn(params, x, y) * loss_scale

        sloss, grads = jax.value_and_grad(scaled)(params)
        return grads, sloss / loss_scale

    return step


def make_infer(model, cfg, *, half=False, use_pallas=True):
    """Build `(params_dict, x) -> logits` for Executor artifacts."""
    def infer(params, x):
        return MODELS[model]["apply"](params, x, cfg, half=half, use_pallas=use_pallas)

    return infer
