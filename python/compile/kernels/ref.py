"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
signal (pytest compares kernel vs ref across shapes/dtypes)."""

import jax.numpy as jnp


def matmul_ref(a, b, *, half: bool = False):
    """Reference matmul with the same precision contract as the kernel:
    optional bf16 storage of the operands, f32 accumulation."""
    if half:
        a = a.astype(jnp.bfloat16)
        b = b.astype(jnp.bfloat16)
    else:
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def im2col_ref(x, kh, kw, stride, pad):
    """NCHW im2col -> [n*oh*ow, c*kh*kw]; mirrors the Rust lowering so
    conv-through-matmul agrees across all three layers."""
    n, c, h, w = x.shape
    x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, :, ky : ky + oh * stride : stride, kx : kx + ow * stride : stride]
            cols.append(patch)  # [n, c, oh, ow]
    # -> [n, c, kh*kw, oh, ow] -> [n, oh, ow, c, kh*kw]
    stacked = jnp.stack(cols, axis=2)
    out = stacked.transpose(0, 3, 4, 1, 2).reshape(n * oh * ow, c * kh * kw)
    return out, (oh, ow)
