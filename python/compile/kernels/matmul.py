"""L1 — the Pallas matmul kernel.

This is the compute hot-spot of the whole stack: affine layers call it
directly and convolutions reach it through im2col, so one kernel serves
the paper's entire model zoo (the same lowering the Rust dynamic engine
uses, keeping the two backends structurally identical).

TPU mapping (DESIGN.md §Hardware-Adaptation): 128x128x128 blocks sized
for VMEM (<=192 KiB resident per grid step vs ~16 MiB VMEM), bf16
inputs with f32 accumulation (`preferred_element_type`) to hit the
MXU's native mode — the TensorCore analogue the paper's mixed precision
(§3.3) relies on. Run under `interpret=True` here because the CPU PJRT
plugin cannot execute Mosaic custom-calls; the lowered HLO is what the
Rust runtime compiles and runs.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block sizes are a compile-target knob (§Perf in EXPERIMENTS.md):
# - `tpu`: 128x128x128, MXU-systolic-array-shaped, ~192 KiB VMEM/step —
#   the paper-faithful structure this kernel is designed for;
# - `cpu` (default here): large blocks. Interpret-mode lowering turns
#   each grid step into a while-loop iteration of dynamic-slice +
#   dynamic-update-slice HLO; on the CPU PJRT backend that overhead
#   (~0.15 ms/step) dwarfs the matmul, so fewer/larger blocks win
#   (measured 54x on conv-shaped matmuls — see EXPERIMENTS.md §Perf).
KERNEL_TARGET = os.environ.get("NNL_KERNEL_TARGET", "cpu")
if KERNEL_TARGET == "tpu":
    BLOCK_M, BLOCK_N, BLOCK_K = 128, 128, 128
else:
    BLOCK_M, BLOCK_N, BLOCK_K = 4096, 512, 512


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The f32 accumulator lives in scratch (`acc_ref`) so bf16 inputs
    accumulate at full precision across the K loop — the mixed
    precision contract of paper §3.3.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_padded(a, b, bm: int, bn: int, bk: int):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    grid = (m // bm, n // bn, k // bk)
    # f32 accumulator tile in scratch memory (VMEM on a real TPU)
    acc = pl.MemoryRef(jax.core.ShapedArray((bm, bn), jnp.float32), pl.ANY)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[acc],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)


def _matmul_core(a, b, half: bool):
    """Cast to the storage dtype, pad to block multiples, run the
    kernel, slice the result back."""
    m, k = a.shape
    _, n = b.shape
    bm, bn, bk = (min(BLOCK_M, _ceil_to(m, 8)),
                  min(BLOCK_N, _ceil_to(n, 8)),
                  min(BLOCK_K, _ceil_to(k, 8)))
    a = a.astype(jnp.bfloat16) if half else a.astype(jnp.float32)
    b = b.astype(jnp.bfloat16) if half else b.astype(jnp.float32)
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    if (mp, kp) != (m, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = _matmul_padded(a, b, bm, bn, bk)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


# Pallas calls with scratch refs are not AD-traceable; give the matmul
# an explicit VJP whose backward *also* runs on the Pallas kernel —
# so fwd and bwd of every dense layer hit the same MXU path (exactly
# how the paper's TensorCore mixed precision works, Fig. 3-left).
@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _matmul_vjp(a, b, half):
    return _matmul_core(a, b, half)


def _matmul_fwd(a, b, half):
    return _matmul_core(a, b, half), (a, b)


def _matmul_bwd(half, res, g):
    a, b = res
    ga = _matmul_core(g, b.T, half).astype(a.dtype)
    gb = _matmul_core(a.T, g, half).astype(b.dtype)
    return ga, gb


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(a, b, *, half: bool = False):
    """`a [m,k] @ b [k,n] -> f32 [m,n]` through the Pallas kernel.

    With `half=True`, inputs are stored/fed to the MXU as bf16 while
    accumulation stays f32 (mixed precision, §3.3). Operands are padded
    to block multiples and the result sliced back, so any shape works.
    Differentiable: backward runs the same kernel on (g·bᵀ, aᵀ·g).
    """
    return _matmul_vjp(a, b, half)


def estimate_vmem_bytes(bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K,
                        half: bool = False) -> int:
    """Per-grid-step VMEM residency estimate (DESIGN.md §9)."""
    in_bytes = 2 if half else 4
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * 4  # + f32 acc


def estimate_mxu_utilization(m: int, n: int, k: int,
                             bm: int = BLOCK_M, bn: int = BLOCK_N,
                             bk: int = BLOCK_K) -> float:
    """Useful MACs / issued MACs given tile padding (DESIGN.md §9)."""
    issued = (-(-m // bm) * bm) * (-(-n // bn) * bn) * (-(-k // bk) * bk)
    return (m * n * k) / issued
