"""AOT driver: lower every (model x precision x batch) variant to HLO
*text* + a manifest, consumed by the Rust runtime (`rust/src/runtime`).

HLO text — NOT `lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()`
— because the image's xla_extension 0.5.1 rejects jax>=0.5 protos
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`. Python never runs after this step.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import matmul as mmk

SEED = 20260710


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_entry(name, dims, dtype="float32"):
    return {"name": name, "dims": list(dims), "dtype": dtype}


def lower_train(model, cfg, batch, *, half, use_pallas, tag):
    """Lower a train-step variant; return (name, hlo_text, manifest)."""
    specs = M.MODELS[model]["param_specs"](cfg)
    data_inputs = M.MODELS[model]["input"](cfg, batch)
    step = M.make_train_step(model, cfg, half=half, use_pallas=use_pallas)
    names = [s[0] for s in specs]

    def flat_step(*args):
        params = dict(zip(names, args[: len(names)]))
        x, y, loss_scale = args[len(names) :]
        grads, loss = step(params, x, y, loss_scale)
        return tuple(grads[n] for n in names) + (loss,)

    arg_specs = [jax.ShapeDtypeStruct(s[1], jnp.float32) for s in specs]
    arg_specs += [jax.ShapeDtypeStruct(d[1], jnp.float32) for d in data_inputs]
    arg_specs += [jax.ShapeDtypeStruct((), jnp.float32)]  # loss_scale
    lowered = jax.jit(flat_step).lower(*arg_specs)

    name = f"{model}_train_{tag}_b{batch}"
    manifest = {
        "name": name,
        "hlo_file": f"{name}.hlo.txt",
        "seed": SEED,
        "param_names": names,
        "param_init": [{"kind": s[2], "scale": s[3]} for s in specs],
        "inputs": [spec_entry(s[0], s[1]) for s in specs]
        + [spec_entry(d[0], d[1]) for d in data_inputs]
        + [spec_entry("loss_scale", ())],
        "outputs": [spec_entry(f"g:{n}", s[1]) for n, s in zip(names, specs)]
        + [spec_entry("loss", ())],
    }
    return name, to_hlo_text(lowered), manifest


def lower_infer(model, cfg, batch, *, half, use_pallas, tag):
    specs = M.MODELS[model]["param_specs"](cfg)
    data_inputs = M.MODELS[model]["input"](cfg, batch)[:1]  # x only
    infer = M.make_infer(model, cfg, half=half, use_pallas=use_pallas)
    names = [s[0] for s in specs]

    def flat_infer(*args):
        params = dict(zip(names, args[: len(names)]))
        (x,) = args[len(names) :]
        return (infer(params, x),)

    arg_specs = [jax.ShapeDtypeStruct(s[1], jnp.float32) for s in specs]
    arg_specs += [jax.ShapeDtypeStruct(d[1], jnp.float32) for d in data_inputs]
    lowered = jax.jit(flat_infer).lower(*arg_specs)
    out_shape = jax.eval_shape(flat_infer, *arg_specs)[0]

    name = f"{model}_infer_{tag}_b{batch}"
    manifest = {
        "name": name,
        "hlo_file": f"{name}.hlo.txt",
        "seed": SEED,
        "param_names": names,
        "param_init": [{"kind": s[2], "scale": s[3]} for s in specs],
        "inputs": [spec_entry(s[0], s[1]) for s in specs]
        + [spec_entry(d[0], d[1]) for d in data_inputs],
        "outputs": [spec_entry("logits", out_shape.shape)],
    }
    return name, to_hlo_text(lowered), manifest


def lower_matmul(size, *, half, tag):
    """Micro-artifact: the raw L1 kernel (kernel benches + tests)."""
    def f(a, b):
        return (mmk.matmul(a, b, half=half),)

    spec = jax.ShapeDtypeStruct((size, size), jnp.float32)
    lowered = jax.jit(f).lower(spec, spec)
    name = f"matmul_{tag}_{size}"
    manifest = {
        "name": name,
        "hlo_file": f"{name}.hlo.txt",
        "seed": SEED,
        "param_names": [],
        "param_init": [],
        "inputs": [spec_entry("a", (size, size)), spec_entry("b", (size, size))],
        "outputs": [spec_entry("c", (size, size))],
    }
    return name, to_hlo_text(lowered), manifest


def variants():
    mlp_cfg = M.MODELS["mlp"]["default_cfg"]
    lenet_cfg = M.MODELS["lenet"]["default_cfg"]
    rn_cfg = M.MODELS["resnet_mini"]["default_cfg"]
    lm_cfg = M.MODELS["tfmr_lm"]["default_cfg"]
    out = []
    # MLP: all four precision/backend combos (Table 1 micro-scale)
    out.append(lambda: lower_train("mlp", mlp_cfg, 32, half=False, use_pallas=True, tag="f32"))
    out.append(lambda: lower_train("mlp", mlp_cfg, 32, half=True, use_pallas=True, tag="bf16"))
    out.append(
        lambda: lower_train("mlp", mlp_cfg, 32, half=False, use_pallas=False, tag="jnpref")
    )
    out.append(lambda: lower_infer("mlp", mlp_cfg, 32, half=False, use_pallas=True, tag="f32"))
    # LeNet (Listing 4/5)
    out.append(lambda: lower_train("lenet", lenet_cfg, 16, half=False, use_pallas=True, tag="f32"))
    # ResNet-mini (Tables 1/2, Figure 3)
    out.append(
        lambda: lower_train("resnet_mini", rn_cfg, 16, half=False, use_pallas=True, tag="f32")
    )
    out.append(
        lambda: lower_train("resnet_mini", rn_cfg, 16, half=True, use_pallas=True, tag="bf16")
    )
    out.append(
        lambda: lower_train("resnet_mini", rn_cfg, 16, half=False, use_pallas=False, tag="jnpref")
    )
    out.append(
        lambda: lower_infer("resnet_mini", rn_cfg, 16, half=False, use_pallas=True, tag="f32")
    )
    # TransformerLM (end-to-end driver)
    out.append(lambda: lower_train("tfmr_lm", lm_cfg, 8, half=False, use_pallas=True, tag="f32"))
    out.append(lambda: lower_train("tfmr_lm", lm_cfg, 8, half=True, use_pallas=True, tag="bf16"))
    # raw kernel micro-artifacts
    out.append(lambda: lower_matmul(256, half=False, tag="f32"))
    out.append(lambda: lower_matmul(256, half=True, tag="bf16"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for build in variants():
        name, hlo, entry = build()
        path = os.path.join(args.out, entry["hlo_file"])
        with open(path, "w") as f:
            f.write(hlo)
        manifest.append(entry)
        print(f"  wrote {name}: {len(hlo) / 1024:.0f} KiB")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=1)
    print(f"manifest: {len(manifest)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
