//! Table 3 — "Training time and validation error for lightweight
//! models": MobileNetV3-small/large, EfficientNet-B0..B3, regenerated
//! at mini scale.
//!
//! Paper shape: time grows with compound scaling (B0 < B1 < B2 < B3),
//! error tends to shrink.

use nnl::data::SyntheticImages;
use nnl::trainer::{train_dynamic, TrainConfig};

const MODELS: [&str; 6] = [
    "mobilenet_v3_small",
    "mobilenet_v3_large",
    "efficientnet_b0",
    "efficientnet_b1",
    "efficientnet_b2",
    "efficientnet_b3",
];

fn main() {
    let steps = 30;
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps, lr: 0.05, val_batches: 6, ..Default::default() };
    println!("Table 3 (regenerated): {steps} steps, batch 8, synthetic ImageNet-mini\n");
    println!(
        "{:<22} {:>12} {:>14} {:>10} {:>12}",
        "architecture", "time (s)", "ms/step", "val error", "params"
    );
    let mut eff_times = Vec::new();
    for model in MODELS {
        let report = train_dynamic(model, &data, &cfg);
        println!(
            "{:<22} {:>12.2} {:>14.1} {:>9.1}% {:>12}",
            model,
            report.wall_secs,
            report.wall_secs * 1e3 / steps as f64,
            report.val_error * 100.0,
            report.n_params
        );
        if model.starts_with("efficientnet") {
            eff_times.push(report.wall_secs);
        }
    }
    let monotone = eff_times.windows(2).filter(|w| w[1] > w[0]).count();
    println!("\nEfficientNet compound-scaling time ordering: {monotone}/3 increase (paper: 3/3)");
    println!("table3_table OK");
}
