//! Neural Network Console, headless (paper §5.1): automatic structure
//! search optimizing accuracy *and* multiply-adds, trial records with
//! a comparison table, and a confusion matrix for the winner.

use nnl::console::{structure_search, ConfusionMatrix, SearchSpace, TrialStore};
use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::models::Gb;
use nnl::parametric as PF;
use nnl::trainer::{train_dynamic, TrainConfig};

fn main() {
    let data = SyntheticImages::new(4, 1, 8, 16, 21);

    // --- automatic structure search (bi-objective Pareto front)
    println!("structure search over MLP plans (error vs MACs)...");
    let space = SearchSpace { steps: 40, widths: vec![16, 32, 64], max_layers: 3, lr: 0.1 };
    let front = structure_search(&data, &space, 2, 4, 7);
    println!("Pareto front ({} candidates):", front.len());
    for c in &front {
        println!(
            "  plan {:?}: val_error {:.3}  MACs {:>8}  params {:>7}",
            c.plan, c.val_error, c.macs, c.n_params
        );
    }

    // --- trial records: train two baselines, compare
    let dir = std::env::temp_dir().join("nnl_console_demo");
    std::fs::remove_dir_all(&dir).ok();
    let store = TrialStore::open(&dir).unwrap();
    for model in ["resnet18", "mobilenet_v3_small"] {
        let imgs = SyntheticImages::imagenet_mini(8);
        let cfg = TrainConfig { steps: 25, ..Default::default() };
        let report = train_dynamic(model, &imgs, &cfg);
        store.record(&report).unwrap();
    }
    println!("\ntrial comparison:");
    print!("{}", store.comparison_table().unwrap());
    let best = store.best().unwrap().unwrap();
    println!("best so far: {} (val error {:.3})", best.model, best.val_error);

    // --- confusion matrix of the best searched structure
    println!("\nconfusion matrix for the best searched plan:");
    PF::clear_parameters();
    PF::seed_parameter_rng(3);
    let plan = &front[0].plan;
    let mut g = Gb::new("winner", true);
    let x = g.input("x", &[16, 64]);
    let mut h = x.clone();
    for (i, &w) in plan.iter().enumerate() {
        h = g.affine(&h, w, &format!("fc{i}"));
        h = g.relu(&h);
    }
    let logits = g.affine(&h, 4, "out");
    let y = nnl::Variable::new(&[16, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
    let mut solver = nnl::solvers::Solver::momentum(0.1, 0.9);
    solver.set_parameters(&PF::get_parameters());
    for step in 0..60 {
        let (bx, by) = data.batch(step, 0, 1);
        x.var.set_data(bx.reshape(&[16, 64]));
        y.set_data(by.reshape(&[16, 1]));
        loss.forward();
        solver.zero_grad();
        loss.backward();
        solver.update();
    }
    let mut cm = ConfusionMatrix::new(4);
    for i in 0..4 {
        let (bx, by) = data.val_batch(i);
        x.var.set_data(bx.reshape(&[16, 64]));
        logits.var.forward();
        cm.record_batch(&logits.var.data(), &by);
    }
    print!("{}", cm.render());
    assert!(cm.accuracy() > 0.3, "winner accuracy {:.3}", cm.accuracy());
    std::fs::remove_dir_all(&dir).ok();
    println!("console_search OK");
}
