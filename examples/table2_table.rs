//! Table 2 — "Training time and validation error for variations of
//! ResNet architecture": ResNet-18/50, ResNeXt-50, SE-ResNet-50,
//! SE-ResNeXt-50, regenerated at mini scale on synthetic ImageNet.
//!
//! Paper shape to reproduce: training time strictly increases down the
//! table (18 < 50 < X50 < SE-50 < SE-X50); error roughly decreases.

use nnl::data::SyntheticImages;
use nnl::trainer::{train_dynamic, TrainConfig};

const MODELS: [&str; 5] =
    ["resnet18", "resnet50", "resnext50", "se_resnet50", "se_resnext50"];

fn main() {
    let steps = 40;
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps, lr: 0.05, val_batches: 6, ..Default::default() };
    println!("Table 2 (regenerated): {steps} steps, batch 8, synthetic ImageNet-mini\n");
    println!(
        "{:<16} {:>12} {:>14} {:>10} {:>12}",
        "architecture", "time (s)", "ms/step", "val error", "params"
    );
    let mut times = Vec::new();
    for model in MODELS {
        let report = train_dynamic(model, &data, &cfg);
        println!(
            "{:<16} {:>12.2} {:>14.1} {:>9.1}% {:>12}",
            model,
            report.wall_secs,
            report.wall_secs * 1e3 / steps as f64,
            report.val_error * 100.0,
            report.n_params
        );
        times.push(report.wall_secs);
    }
    // the paper's monotone-time shape
    let monotone = times.windows(2).filter(|w| w[1] > w[0]).count();
    println!("\ntime ordering: {monotone}/4 adjacent pairs increase (paper: 4/4)");
    println!("table2_table OK");
}
