//! Table 1 — "Training time of ResNet-50 ... FP-32 | Mixed precision |
//! Speedup": regenerated on this testbed. The comparator frameworks
//! (paper: PyTorch, TensorFlow) are replaced by in-repo baselines
//! running the *same* workload on the same hardware:
//!
//! - `jnpref-static` — the XLA graph built from plain `jnp.matmul`
//!   (no Pallas kernel), the "other framework" baseline;
//! - `nnl-dynamic`   — the native define-by-run engine;
//! - `nnl-static`    — the Pallas-kernel AOT path (the headline row),
//!   in FP-32 and bf16 mixed precision.
//!
//! The paper's *shape*: mixed precision speeds training up (x2.3–3.1
//! on Volta); the framework is competitive with comparators.

use nnl::data::SyntheticImages;
use nnl::runtime::Manifest;
use nnl::trainer::{train_dynamic, train_static, LossScalerKind, TrainConfig};
use nnl::utils::bench::Measurement;

const STEPS: usize = 30;

fn row(name: &str, secs: f64) -> Measurement {
    Measurement { name: name.into(), iters: STEPS, mean_secs: secs / STEPS as f64, min_secs: secs / STEPS as f64 }
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())
        .expect("run `make artifacts` first");
    let data = SyntheticImages::imagenet_mini(16);
    let cfg = TrainConfig { steps: STEPS, val_batches: 0, ..Default::default() };
    let mut half_cfg = cfg.clone();
    half_cfg.loss_scale = Some(LossScalerKind::Fixed(8.0));

    println!("Table 1 (regenerated): ResNet-mini training, {STEPS} steps, batch 16\n");

    let dyn_rep = train_dynamic("resnet18", &data, &cfg);
    let jnp_rep = train_static(&manifest, "resnet_mini_train_jnpref_b16", &data, &cfg)?;
    let f32_rep = train_static(&manifest, "resnet_mini_train_f32_b16", &data, &cfg)?;
    let bf16_rep = train_static(&manifest, "resnet_mini_train_bf16_b16", &data, &half_cfg)?;

    let rows = vec![
        row("nnl-dynamic (define-by-run, FP-32)", dyn_rep.wall_secs),
        row("jnpref-static (comparator, FP-32)", jnp_rep.wall_secs),
        row("nnl-static (Pallas AOT, FP-32)", f32_rep.wall_secs),
        row("nnl-static (Pallas AOT, mixed bf16)", bf16_rep.wall_secs),
    ];
    println!("{}", nnl::utils::bench::table("Table 1", &rows));
    println!(
        "mixed-precision speedup over FP-32 (static): x{:.2}",
        f32_rep.wall_secs / bf16_rep.wall_secs
    );
    println!(
        "static speedup over dynamic: x{:.2}",
        dyn_rep.wall_secs / f32_rep.wall_secs
    );
    // losses all in the same regime (training is real in every row)
    println!(
        "final losses: dynamic {:.3}, jnpref {:.3}, f32 {:.3}, bf16 {:.3}",
        dyn_rep.final_loss(),
        jnp_rep.final_loss(),
        f32_rep.final_loss(),
        bf16_rep.final_loss()
    );
    println!("table1_table OK");
    Ok(())
}
