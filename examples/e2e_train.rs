//! END-TO-END DRIVER — exercises every layer of the stack on a real
//! small workload:
//!
//!   L1 Pallas matmul kernel → L2 JAX TransformerLM train-step, AOT
//!   to HLO → L3 Rust: PJRT execution, Adam solver, dynamic loss
//!   scaling (mixed precision), 2-worker data parallelism via the
//!   communicator — training a byte-level language model on a tiny
//!   English corpus for a few hundred steps and logging the loss curve.
//!
//! Recorded in EXPERIMENTS.md §End-to-end. Run: `make artifacts &&
//! cargo run --release --example e2e_train`

use nnl::comm::{Collective, CommHub};
use nnl::data::TinyCorpus;
use nnl::mixed_precision::LossScaler;
use nnl::monitor::MonitorSeries;
use nnl::runtime::{Manifest, StaticExecutable};
use nnl::solvers::Solver;
use nnl::tensor::NdArray;
use nnl::Variable;

const STEPS: usize = 300;
const WORLD: usize = 2;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())
        .expect("artifacts missing — run `make artifacts` first");
    let artifact = "tfmr_lm_train_bf16_b8"; // mixed-precision variant
    let spec = manifest.get(artifact).unwrap().clone();
    let corpus = TinyCorpus::default_corpus(64, 8);
    println!(
        "e2e: TransformerLM ({} params) on {}-token corpus, {} workers, artifact {artifact}",
        spec.init_params().iter().map(|(_, a)| a.size()).sum::<usize>(),
        corpus.len_tokens(),
        WORLD,
    );
    println!("uniform baseline loss: {:.3}", corpus.uniform_loss());

    let mut hub = CommHub::new(WORLD);
    let mut handles = Vec::new();
    for rank in 0..WORLD {
        let mut comm = hub.communicator(rank)?;
        let manifest = manifest.clone();
        let corpus = corpus.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<MonitorSeries> {
            let exe = StaticExecutable::load(&manifest, artifact)?;
            let params: Vec<(String, Variable)> = exe
                .spec()
                .init_params()
                .into_iter()
                .map(|(n, a)| (n, Variable::from_array(a, true)))
                .collect();
            let mut solver = Solver::adam(3e-3, 0.9, 0.999, 1e-8);
            solver.set_parameters(&params);
            // Listing 6: dynamic loss scaling
            let mut scaler = LossScaler::dynamic(256.0, 2.0, 500);
            let mut losses = MonitorSeries::new("loss");
            for step in 0..STEPS {
                let (x, y) = corpus.batch(step, comm.rank(), comm.size());
                let mut inputs: Vec<NdArray> =
                    params.iter().map(|(_, v)| v.data()).collect();
                inputs.push(x);
                inputs.push(y);
                inputs.push(NdArray::scalar(scaler.scale()));
                let out = exe.execute(&inputs)?;
                // per-worker backward done; all-reduce grads (Listing 3)
                let mut grads: Vec<NdArray> = out[..params.len()].to_vec();
                comm.all_reduce(&mut grads, true)?;
                for ((_, v), g) in params.iter().zip(grads) {
                    v.set_grad(g);
                }
                scaler.step(&mut solver);
                let mean_loss =
                    comm.all_gather_scalar(out.last().unwrap().item())?.iter().sum::<f32>()
                        / comm.size() as f32;
                losses.add(step, mean_loss);
                if comm.rank() == 0 && step % 25 == 0 {
                    println!(
                        "  step {step:>4}: loss {mean_loss:.4} (scale {})",
                        scaler.scale()
                    );
                }
            }
            Ok(losses)
        }));
    }
    let mut curves: Vec<MonitorSeries> = handles
        .into_iter()
        .map(|h| h.join().expect("worker panicked"))
        .collect::<anyhow::Result<_>>()?;
    let losses = curves.remove(0);

    let first = losses.points()[0].1;
    let last = losses.tail_mean(20);
    println!("\nloss: {first:.3} -> {last:.3} (uniform baseline {:.3})", corpus.uniform_loss());
    losses.save_csv(std::path::Path::new("e2e_loss_curve.csv")).ok();
    println!("curve written to e2e_loss_curve.csv");
    assert!(
        last < corpus.uniform_loss() * 0.75,
        "LM did not learn below baseline: {last}"
    );
    println!("e2e_train OK");
    Ok(())
}
