//! Figure 3 (right): the training curve of ResNet-mini under
//! 4-worker distributed data-parallel training (the paper trained
//! ResNet-50 on 4 Voltas). Writes `fig3_loss_curve.csv`.

use nnl::data::SyntheticImages;
use nnl::trainer::{train_distributed, TrainConfig};

fn main() {
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps: 60, lr: 0.05, val_batches: 4, ..Default::default() };
    println!("Figure 3: resnet18-mini, 4 simulated devices, data-parallel SGD+momentum");
    let report = train_distributed("resnet18", data, &cfg, 4);
    for (step, loss) in report.losses.points().iter().step_by(10) {
        println!("  step {step:>3}: loss {loss:.4}");
    }
    println!(
        "final loss {:.4}, val error {:.3} ({} params, {:.1} steps/s aggregate)",
        report.final_loss(),
        report.val_error,
        report.n_params,
        report.steps as f64 / report.wall_secs
    );
    report.losses.save_csv(std::path::Path::new("fig3_loss_curve.csv")).ok();
    println!("curve written to fig3_loss_curve.csv");
    let first = report.losses.points()[0].1;
    assert!(report.final_loss() < first, "distributed training did not learn");
    println!("fig3_distributed OK");
}
