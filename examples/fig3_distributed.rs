//! Figure 3 (right): the training curve of ResNet-mini under
//! 4-worker distributed data-parallel training (the paper trained
//! ResNet-50 on 4 Voltas). Writes `fig3_loss_curve.csv`.
//!
//! Runs on either communicator backend: the in-process thread hub by
//! default, or the real TCP ring over loopback with `--net` — both
//! compute the same rank-order fold, so the curves are bit-identical.
//! Comm failures surface as typed errors through `main`, not panics.

use nnl::comm::{CommError, NetCommunicator, NetOptions};
use nnl::data::SyntheticImages;
use nnl::trainer::{train_distributed_opts, train_worker, DistConfig, TrainConfig, TrainReport};

const WORLD: usize = 4;

/// The same 4-rank job over loopback TCP: rank 0 in this thread via
/// the pre-bound listener, ranks 1..4 dialing it from worker threads.
fn run_tcp(
    data: &SyntheticImages,
    cfg: &TrainConfig,
    dist: &DistConfig,
) -> Result<TrainReport, CommError> {
    let listener = NetCommunicator::rendezvous_bind("127.0.0.1:0").map_err(CommError::from)?;
    let addr = listener.local_addr().map_err(CommError::from)?.to_string();
    let mut handles = Vec::new();
    for rank in 1..WORLD {
        let addr = addr.clone();
        let data = data.clone();
        let cfg = cfg.clone();
        let dist = dist.clone();
        handles.push(std::thread::spawn(move || {
            let comm = NetCommunicator::connect(rank, WORLD, &addr, NetOptions::default())?;
            train_worker("resnet18", &data, &cfg, &dist, comm, "cpu:tcp")
        }));
    }
    let comm = NetCommunicator::connect_with_listener(listener, WORLD, NetOptions::default())?;
    let mut result = train_worker("resnet18", data, cfg, dist, comm, "cpu:tcp");
    for h in handles {
        let r = h.join().expect("worker thread panicked");
        if result.is_ok() {
            if let Err(e) = r {
                result = Err(e);
            }
        }
    }
    result
}

fn main() -> Result<(), CommError> {
    let net = std::env::args().any(|a| a == "--net");
    let data = SyntheticImages::imagenet_mini(8);
    let cfg = TrainConfig { steps: 60, lr: 0.05, val_batches: 4, ..Default::default() };
    let dist = DistConfig::default();
    println!(
        "Figure 3: resnet18-mini, {WORLD} {} devices, data-parallel SGD+momentum",
        if net { "TCP-ring" } else { "simulated" }
    );
    let report = if net {
        run_tcp(&data, &cfg, &dist)?
    } else {
        train_distributed_opts("resnet18", data.clone(), &cfg, WORLD, &dist)?
    };
    for (step, loss) in report.losses.points().iter().step_by(10) {
        println!("  step {step:>3}: loss {loss:.4}");
    }
    println!(
        "final loss {:.4}, val error {:.3} ({} params, {:.1} steps/s aggregate)",
        report.final_loss(),
        report.val_error,
        report.n_params,
        report.steps as f64 / report.wall_secs
    );
    report.losses.save_csv(std::path::Path::new("fig3_loss_curve.csv")).ok();
    println!("curve written to fig3_loss_curve.csv");
    let first = report.losses.points()[0].1;
    assert!(report.final_loss() < first, "distributed training did not learn");
    println!("fig3_distributed OK");
    Ok(())
}
