//! Figure 2 tour: train a model, save NNP, convert through every
//! target (ONNX-lite, NNB, frozen graph, Rust source), run each
//! runnable format and verify identical inference — the paper's
//! compatibility fabric end to end.

use std::collections::HashMap;

use nnl::converters::{frozen, nnb, onnx_lite, query, rs_source};
use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::models::{build_model, Gb};
use nnl::nnp::Nnp;
use nnl::parametric as PF;
use nnl::solvers::Solver;
use nnl::tensor::NdArray;

fn main() {
    // 1. build + briefly train LeNet (eval-mode graph for export)
    PF::clear_parameters();
    PF::seed_parameter_rng(9);
    let data = SyntheticImages::new(10, 1, 28, 8, 3);
    {
        let mut g = Gb::new("lenet", true);
        let x = g.input("x", &[8, 1, 28, 28]);
        let logits = build_model(&mut g, "lenet", &x, 10);
        let y = nnl::Variable::new(&[8, 1], false);
        let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));
        let mut solver = Solver::momentum(0.02, 0.9);
        solver.set_parameters(&PF::get_parameters());
        for step in 0..20 {
            let (bx, by) = data.batch(step, 0, 1);
            x.var.set_data(bx);
            y.set_data(by.reshape(&[8, 1]));
            loss.forward();
            solver.zero_grad();
            loss.backward();
            solver.update();
        }
        println!("trained lenet, final loss {:.3}", loss.item());
    }
    // 2. export eval-mode definition + params to NNP
    let mut g = Gb::new("lenet", false);
    let x = g.input("x", &[8, 1, 28, 28]);
    let logits = build_model(&mut g, "lenet", &x, 10);
    let def = g.finish(&[&logits]);
    let params: Vec<(String, NdArray)> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let nnp = Nnp::from_network(def.clone(), params.clone());

    let dir = std::env::temp_dir().join("nnl_convert_tour");
    std::fs::create_dir_all(&dir).unwrap();
    let nnp_path = dir.join("lenet.nnp");
    nnp.save(&nnp_path).unwrap();
    println!("saved {} ({} bytes)", nnp_path.display(), std::fs::metadata(&nnp_path).unwrap().len());

    // reference output through the NNP executor
    let (bx, _) = data.val_batch(0);
    let mut inputs = HashMap::new();
    inputs.insert("x".to_string(), bx.clone());
    let reference = nnp.execute("lenet_executor", &inputs).unwrap().remove(0);

    // 3. support query (the paper's pre-conversion check)
    print!("\n{}", query::support_report(&def));

    // 4. ONNX round trip
    let onnx = onnx_lite::to_onnx(&def, &nnp.param_map()).unwrap();
    let onnx_path = dir.join("lenet.onnxl");
    std::fs::write(&onnx_path, onnx_lite::save_bytes(&onnx)).unwrap();
    let onnx2 = onnx_lite::load_bytes(&std::fs::read(&onnx_path).unwrap()).unwrap();
    let (net2, params2) = onnx_lite::from_onnx(&onnx2).unwrap();
    let pm2: HashMap<String, NdArray> = params2.into_iter().collect();
    let via_onnx = nnl::nnp::interpreter::run(&net2, &inputs, &pm2).unwrap().remove(0);
    assert!(reference.allclose(&via_onnx, 1e-5, 1e-5), "ONNX roundtrip diverged");
    println!("NNP -> ONNX -> NNP: outputs identical ✓ ({} bytes)", std::fs::metadata(&onnx_path).unwrap().len());

    // 5. NNB (C-runtime analogue) executes identically
    let nnb_bytes = nnb::to_nnb(&def, &params);
    let via_nnb = nnb::run_nnb(&nnb_bytes, &inputs).unwrap().remove(0);
    assert!(reference.allclose(&via_nnb, 1e-5, 1e-5), "NNB diverged");
    println!("NNP -> NNB (runtime executed): outputs identical ✓ ({} bytes)", nnb_bytes.len());

    // 6. frozen graph
    let fg = frozen::freeze(&def, &nnp.param_map()).unwrap();
    let via_frozen = frozen::run(&fg, &inputs).unwrap().remove(0);
    assert!(reference.allclose(&via_frozen, 1e-5, 1e-5), "frozen diverged");
    println!(
        "NNP -> frozen graph: outputs identical ✓ ({} layers after folding)",
        fg.net.layers.len()
    );

    // 7. Rust source generation — works for dense nets; conv nets
    //    report the documented limitation
    match rs_source::generate(&def, &nnp.param_map()) {
        Ok(_) => println!("NNP -> Rust source: generated"),
        Err(e) => println!("NNP -> Rust source: {e} (dense-only target, as documented)"),
    }
    // generate for a dense sub-model instead
    PF::clear_parameters();
    let mut g = Gb::new("mlp", false);
    let x = g.input("x", &[1, 64]);
    let y = build_model(&mut g, "mlp", &x, 10);
    let dense_def = g.finish(&[&y]);
    let dense_params: HashMap<String, NdArray> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let src = rs_source::generate(&dense_def, &dense_params).unwrap();
    std::fs::write(dir.join("mlp_gen.rs"), &src).unwrap();
    println!("NNP(mlp) -> Rust source: {} lines ✓", src.lines().count());

    std::fs::remove_dir_all(&dir).ok();
    println!("convert_tour OK");
}
