//! Quickstart — the paper's Listing 1, line for line, in Rust:
//!
//! ```python
//! x = nn.Variable((16, 10), need_grad=True)
//! y = PF.affine(x, 5)
//! x.d = np.random.random(x.shape)
//! y.forward()
//! y.backward()
//! nn.get_parameters()
//! ```

use nnl::parametric as PF;
use nnl::tensor::Rng;
use nnl::Variable;

fn main() {
    PF::seed_parameter_rng(0);
    let mut rng = Rng::new(0);

    // Define input variable and computational graph
    let x = Variable::new(&[16, 10], true);
    let y = PF::affine(&x, 5, "affine1");

    // Compute output for some random input
    x.set_data(rng.rand(&[16, 10], 0.0, 1.0));
    y.forward();

    // Compute gradient with respect to input and parameters
    y.backward();

    // Show all the trainable parameters assigned to the existing layers
    println!("parameters:");
    for (name, p) in PF::get_parameters() {
        println!(
            "  {name:<22} shape {:?}  need_grad={}  |grad|={:.4}",
            p.dims(),
            p.need_grad(),
            p.grad().norm2()
        );
    }
    println!("\noutput shape: {:?}", y.dims());
    println!("input grad norm: {:.4}", x.grad().norm2());
    println!("quickstart OK");
}
