//! LeNet — the paper's Listing 4 (Python) / Listing 5 (Python-like C++
//! API), reproduced as the Python-like *Rust* API with the same number
//! of lines, then trained briefly on synthetic digits to prove it
//! learns.
//!
//! Python (Listing 4)                         | Rust (this file)
//! ------------------------------------------|--------------------------------------------------
//! h = PF.convolution(x, 16, (5,5), "conv1") | let h = g.conv(&x, 16, (5,5), (1,1), (0,0), "conv1");
//! h = F.max_pooling(h, (2,2))               | let h = g.max_pool(&h, (2,2), (2,2));
//! h = F.relu(h, inplace=False)              | let h = g.relu(&h);
//! ... (same for conv2/affine3/affine4)      | ...

use nnl::data::{DataSource, SyntheticImages};
use nnl::functions as F;
use nnl::graph::Variable;
use nnl::models::Gb;
use nnl::parametric as PF;
use nnl::solvers::Solver;

fn main() {
    PF::seed_parameter_rng(42);
    let data = SyntheticImages::new(10, 1, 28, 16, 7);

    let mut g = Gb::new("lenet", true);
    let x = g.input("x", &[16, 1, 28, 28]);
    // Listing 4, line for line:
    let h = g.conv(&x, 16, (5, 5), (1, 1), (0, 0), "conv1");
    let h = g.max_pool(&h, (2, 2), (2, 2));
    let h = g.relu(&h);
    let h = g.conv(&h, 16, (5, 5), (1, 1), (0, 0), "conv2");
    let h = g.max_pool(&h, (2, 2), (2, 2));
    let h = g.relu(&h);
    let h = g.affine(&h, 50, "affine3");
    let h = g.relu(&h);
    let logits = g.affine(&h, 10, "affine4");

    let y = Variable::new(&[16, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let mut solver = Solver::momentum(0.02, 0.9);
    solver.set_parameters(&PF::get_parameters());

    println!("training LeNet ({} params)...", PF::get_parameters().iter().map(|(_, v)| v.size()).sum::<usize>());
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..60 {
        let (bx, by) = data.batch(step, 0, 1);
        x.var.set_data(bx);
        y.set_data(by.reshape(&[16, 1]));
        loss.forward();
        solver.zero_grad();
        loss.backward();
        solver.update();
        if step == 0 {
            first = loss.item();
        }
        last = loss.item();
        if step % 15 == 0 {
            println!("  step {step:>3}: loss {:.4}", loss.item());
        }
    }
    println!("loss {first:.3} -> {last:.3}");
    assert!(last < first, "LeNet did not learn");
    println!("lenet_api OK");
}
