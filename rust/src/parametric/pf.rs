//! The parametric functions themselves. Each takes a layer `name`
//! (the scope under which its parameters live) and applies Glorot/He
//! initialization on first use — NNabla's defaults.

use crate::context::{Context, TypeConfig};
use crate::functions as F;
use crate::graph::Variable;
use crate::tensor::{DType, NdArray, Rng};

use super::registry::{get_or_create_parameter, with_parameter_scope};

/// Glorot-uniform limit for a (fan_in, fan_out) pair.
fn glorot_limit(fan_in: usize, fan_out: usize) -> f32 {
    (6.0 / (fan_in + fan_out) as f32).sqrt()
}

/// Under `type_config = half`, parameters are *stored* in bf16
/// (quantized on init and on every solver write via dtype tag); under
/// `float` they stay f32. Paper §3.3 storage rule.
fn storage_dtype() -> DType {
    match Context::default().type_config {
        TypeConfig::Float => DType::F32,
        TypeConfig::Half => DType::BF16,
    }
}

fn uniform_init(rng: &mut Rng, dims: &[usize], limit: f32) -> NdArray {
    let mut a = rng.rand(dims, -limit, limit);
    a.set_dtype(storage_dtype());
    a
}

/// `PF.affine(x, n_out, name)` — fully connected layer with bias.
pub fn affine(x: &Variable, n_out: usize, name: &str) -> Variable {
    let fan_in: usize = x.dims()[1..].iter().product();
    with_parameter_scope(name, || {
        with_parameter_scope("affine", || {
            let lim = glorot_limit(fan_in, n_out);
            let w = get_or_create_parameter(
                "W",
                &[fan_in, n_out],
                |rng| uniform_init(rng, &[fan_in, n_out], lim),
                true,
            );
            let b = get_or_create_parameter("b", &[n_out], |_| NdArray::zeros(&[n_out]), true);
            F::affine(x, &w, Some(&b))
        })
    })
}

/// `PF.convolution(x, outmaps, kernel, name, ...)` — 2-D convolution
/// with bias.
pub fn convolution(
    x: &Variable,
    outmaps: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    name: &str,
) -> Variable {
    let inmaps = x.dims()[1];
    with_parameter_scope(name, || {
        with_parameter_scope("conv", || {
            let fan_in = inmaps * kernel.0 * kernel.1;
            let fan_out = outmaps * kernel.0 * kernel.1;
            let dims = [outmaps, inmaps, kernel.0, kernel.1];
            let lim = glorot_limit(fan_in, fan_out);
            let w = get_or_create_parameter("W", &dims, |rng| uniform_init(rng, &dims, lim), true);
            let b = get_or_create_parameter("b", &[outmaps], |_| NdArray::zeros(&[outmaps]), true);
            F::convolution(x, &w, Some(&b), stride, pad, (1, 1))
        })
    })
}

/// Transposed convolution; weight layout `[inmaps, outmaps, kh, kw]`.
pub fn deconvolution(
    x: &Variable,
    outmaps: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    name: &str,
) -> Variable {
    let inmaps = x.dims()[1];
    with_parameter_scope(name, || {
        with_parameter_scope("deconv", || {
            let fan_in = inmaps * kernel.0 * kernel.1;
            let fan_out = outmaps * kernel.0 * kernel.1;
            let dims = [inmaps, outmaps, kernel.0, kernel.1];
            let lim = glorot_limit(fan_in, fan_out);
            let w = get_or_create_parameter("W", &dims, |rng| uniform_init(rng, &dims, lim), true);
            let b = get_or_create_parameter("b", &[outmaps], |_| NdArray::zeros(&[outmaps]), true);
            F::deconvolution(x, &w, Some(&b), stride, pad)
        })
    })
}

/// `PF.batch_normalization(x, batch_stat, name)`. Creates
/// `beta/gamma/mean/var` of size `[C]`; per the paper's §3.3 rule BN
/// statistics stay FP-32 even under the half config.
pub fn batch_normalization(x: &Variable, batch_stat: bool, name: &str) -> Variable {
    let c = x.dims()[1];
    with_parameter_scope(name, || {
        with_parameter_scope("bn", || {
            let beta = get_or_create_parameter("beta", &[c], |_| NdArray::zeros(&[c]), true);
            let gamma = get_or_create_parameter("gamma", &[c], |_| NdArray::ones(&[c]), true);
            let mean = get_or_create_parameter("mean", &[c], |_| NdArray::zeros(&[c]), false);
            let var = get_or_create_parameter("var", &[c], |_| NdArray::ones(&[c]), false);
            F::batch_normalization(x, &beta, &gamma, &mean, &var, 0.9, 1e-5, batch_stat)
        })
    })
}

/// Layer normalization over the last axis with learnable scale/shift.
pub fn layer_normalization(x: &Variable, name: &str) -> Variable {
    let d = *x.dims().last().unwrap();
    with_parameter_scope(name, || {
        with_parameter_scope("ln", || {
            let beta = get_or_create_parameter("beta", &[d], |_| NdArray::zeros(&[d]), true);
            let gamma = get_or_create_parameter("gamma", &[d], |_| NdArray::ones(&[d]), true);
            F::layer_normalization(x, &beta, &gamma, 1e-5)
        })
    })
}

/// `PF.embed(ids, vocab, dim, name)` — embedding table lookup.
pub fn embed(ids: &Variable, vocab: usize, dim: usize, name: &str) -> Variable {
    with_parameter_scope(name, || {
        with_parameter_scope("embed", || {
            let w = get_or_create_parameter(
                "W",
                &[vocab, dim],
                |rng| {
                    let mut a = rng.randn(&[vocab, dim], 0.02);
                    a.set_dtype(storage_dtype());
                    a
                },
                true,
            );
            F::embed(ids, &w)
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parametric::registry::{clear_parameters, get_parameters, seed_parameter_rng};
    use crate::context::Backend;

    fn reset() {
        clear_parameters();
        seed_parameter_rng(7);
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
    }

    #[test]
    fn affine_registers_w_and_b() {
        reset();
        let x = Variable::from_array(NdArray::zeros(&[4, 10]), false);
        let y = affine(&x, 5, "fc1");
        assert_eq!(y.dims(), vec![4, 5]);
        let names: Vec<String> = get_parameters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["fc1/affine/W", "fc1/affine/b"]);
    }

    #[test]
    fn second_call_reuses_parameters() {
        reset();
        let x = Variable::from_array(NdArray::zeros(&[4, 10]), false);
        let _ = affine(&x, 5, "fc1");
        let n = get_parameters().len();
        let _ = affine(&x, 5, "fc1"); // weight sharing
        assert_eq!(get_parameters().len(), n);
    }

    #[test]
    fn conv_shapes_and_registry() {
        reset();
        let x = Variable::from_array(NdArray::zeros(&[2, 3, 8, 8]), false);
        let y = convolution(&x, 16, (5, 5), (1, 1), (0, 0), "conv1");
        assert_eq!(y.dims(), vec![2, 16, 4, 4]);
        let names: Vec<String> = get_parameters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["conv1/conv/W", "conv1/conv/b"]);
    }

    #[test]
    fn bn_registers_four_params_two_trainable() {
        reset();
        let x = Variable::from_array(NdArray::zeros(&[2, 3, 4, 4]), false);
        let _ = batch_normalization(&x, true, "bn1");
        let ps = get_parameters();
        assert_eq!(ps.len(), 4);
        let trainable = ps.iter().filter(|(_, v)| v.need_grad()).count();
        assert_eq!(trainable, 2); // beta, gamma
    }

    #[test]
    fn half_config_stores_bf16() {
        reset();
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Half));
        let x = Variable::from_array(NdArray::zeros(&[1, 4]), false);
        let _ = affine(&x, 3, "h");
        let (_, w) = &get_parameters()[0];
        assert_eq!(w.data().dtype(), DType::BF16);
        reset();
    }

    #[test]
    fn embed_param_shape() {
        reset();
        let ids = Variable::from_array(NdArray::from_slice(&[2], &[0., 1.]), false);
        let y = embed(&ids, 10, 4, "tok");
        assert_eq!(y.dims(), vec![2, 4]);
        assert_eq!(get_parameters()[0].0, "tok/embed/W");
    }

    #[test]
    fn deterministic_across_resets() {
        reset();
        let x = Variable::from_array(NdArray::ones(&[1, 6]), false);
        let a = affine(&x, 2, "f").data();
        reset();
        let b = affine(&x, 2, "f").data();
        assert_eq!(a.data(), b.data());
    }
}
