//! The global (thread-ambient) parameter registry with name scopes —
//! `nn.get_parameters()` / `nn.parameter_scope()` semantics.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::graph::Variable;
use crate::tensor::{NdArray, Rng};

struct Registry {
    params: BTreeMap<String, Variable>,
    scope: Vec<String>,
    rng: Rng,
}

thread_local! {
    static REGISTRY: RefCell<Registry> = RefCell::new(Registry {
        params: BTreeMap::new(),
        scope: Vec::new(),
        rng: Rng::new(313),
    });
}

fn scoped_name(scope: &[String], name: &str) -> String {
    if scope.is_empty() {
        name.to_string()
    } else {
        format!("{}/{}", scope.join("/"), name)
    }
}

/// Get-or-create a parameter under the current scope. `init` runs only
/// on creation and receives the registry RNG (deterministic under
/// [`seed_parameter_rng`]).
pub fn get_or_create_parameter(
    name: &str,
    dims: &[usize],
    init: impl FnOnce(&mut Rng) -> NdArray,
    need_grad: bool,
) -> Variable {
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        let full = scoped_name(&reg.scope, name);
        if let Some(v) = reg.params.get(&full) {
            assert_eq!(
                v.dims(),
                dims,
                "parameter '{full}' exists with different shape"
            );
            return v.clone();
        }
        let data = init(&mut reg.rng);
        assert_eq!(data.dims(), dims);
        let v = Variable::from_array(data, need_grad);
        v.set_name(&full);
        reg.params.insert(full, v.clone());
        v
    })
}

/// Look up an existing parameter by fully-qualified name.
pub fn get_parameter(full_name: &str) -> Option<Variable> {
    REGISTRY.with(|r| r.borrow().params.get(full_name).cloned())
}

/// Insert/overwrite a parameter by fully-qualified name (NNP load path).
pub fn set_parameter(full_name: &str, v: Variable) {
    v.set_name(full_name);
    REGISTRY.with(|r| {
        r.borrow_mut().params.insert(full_name.to_string(), v);
    });
}

/// All registered parameters, sorted by name —
/// `nn.get_parameters()` (Listing 1, last line).
pub fn get_parameters() -> Vec<(String, Variable)> {
    REGISTRY.with(|r| r.borrow().params.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
}

/// Number of registered parameter *tensors*.
pub fn parameter_count() -> usize {
    REGISTRY.with(|r| r.borrow().params.len())
}

/// Clear the registry (between experiments / Console trials).
pub fn clear_parameters() {
    REGISTRY.with(|r| {
        let mut reg = r.borrow_mut();
        reg.params.clear();
        reg.scope.clear();
    });
}

/// Reseed the parameter-initializer RNG.
pub fn seed_parameter_rng(seed: u64) {
    REGISTRY.with(|r| r.borrow_mut().rng = Rng::new(seed));
}

/// Run `f` inside a named parameter scope
/// (`with nn.parameter_scope("block1"): ...`). Scopes nest.
pub fn with_parameter_scope<R>(name: &str, f: impl FnOnce() -> R) -> R {
    REGISTRY.with(|r| r.borrow_mut().scope.push(name.to_string()));
    let out = f();
    REGISTRY.with(|r| {
        r.borrow_mut().scope.pop();
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reset() {
        clear_parameters();
        seed_parameter_rng(0);
    }

    #[test]
    fn create_then_reuse() {
        reset();
        let a = get_or_create_parameter("w", &[2, 3], |rng| rng.randn(&[2, 3], 1.0), true);
        let b = get_or_create_parameter("w", &[2, 3], |rng| rng.randn(&[2, 3], 1.0), true);
        assert_eq!(a.data().data(), b.data().data()); // same variable
        assert_eq!(parameter_count(), 1);
    }

    #[test]
    #[should_panic(expected = "different shape")]
    fn shape_conflict_panics() {
        reset();
        let _ = get_or_create_parameter("w", &[2], |rng| rng.randn(&[2], 1.0), true);
        let _ = get_or_create_parameter("w", &[3], |rng| rng.randn(&[3], 1.0), true);
    }

    #[test]
    fn scopes_nest_and_pop() {
        reset();
        with_parameter_scope("outer", || {
            let _ = get_or_create_parameter("a", &[1], |_| NdArray::zeros(&[1]), true);
            with_parameter_scope("inner", || {
                let _ = get_or_create_parameter("b", &[1], |_| NdArray::zeros(&[1]), true);
            });
        });
        let _ = get_or_create_parameter("c", &[1], |_| NdArray::zeros(&[1]), true);
        let names: Vec<String> = get_parameters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["c", "outer/a", "outer/inner/b"]);
    }

    #[test]
    fn deterministic_init_under_seed() {
        reset();
        let a = get_or_create_parameter("w", &[4], |rng| rng.randn(&[4], 1.0), true).data();
        reset();
        let b = get_or_create_parameter("w", &[4], |rng| rng.randn(&[4], 1.0), true).data();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn set_parameter_overwrites() {
        reset();
        let v = Variable::from_array(NdArray::full(&[2], 7.0), true);
        set_parameter("loaded/w", v);
        assert_eq!(get_parameter("loaded/w").unwrap().data().data(), &[7., 7.]);
        assert!(get_parameter("missing").is_none());
    }
}
