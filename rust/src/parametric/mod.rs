//! `PF::*` — parametric functions, the paper's third building block:
//! "functions accompanied with additional trainable parameters" (§2.1).
//!
//! The defining usability feature reproduced here is the **global
//! parameter registry**: `PF::affine(&x, 5, "fc")` creates (or reuses)
//! `fc/affine/W` and `fc/affine/b` in a globally accessible dictionary —
//! no manual parameter plumbing, and `get_parameters()` returns
//! everything, exactly as the last line of Listing 1.

pub mod pf;
pub mod registry;

pub use pf::{
    affine, batch_normalization, convolution, deconvolution, embed, layer_normalization,
};
pub use registry::{
    clear_parameters, get_or_create_parameter, get_parameter, get_parameters, parameter_count,
    seed_parameter_rng, set_parameter, with_parameter_scope,
};
