//! Extension context — the paper's one-line backend switch (§2.3,
//! Listing 2):
//!
//! ```text
//! nn.set_default_context(get_extension_context('cudnn'))
//! ```
//!
//! becomes
//!
//! ```no_run
//! # // no_run: doctest binaries bypass the workspace rpath to
//! # // libxla_extension's bundled libstdc++ in this offline image
//! use nnl::context::{Context, Backend, TypeConfig};
//! Context::set_default(Context::new(Backend::Xla, TypeConfig::Half));
//! ```
//!
//! Everything downstream (trainer, parametric initializers, runtime)
//! reads the ambient context; no per-tensor device placement is ever
//! written by the user — matching the paper's claim that "all
//! Variables are automatically assigned to the chosen device".

use std::cell::RefCell;

/// Compute backend, the analogue of `'cpu' | 'cudnn'`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Native Rust tape engine (dynamic graphs, flexible).
    Cpu,
    /// AOT-compiled XLA executables via PJRT (static graphs, fast).
    Xla,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Cpu => "cpu",
            Backend::Xla => "xla",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "cpu" | "cpu:float" => Some(Backend::Cpu),
            "xla" | "cudnn" => Some(Backend::Xla), // accept the paper's name
            _ => None,
        }
    }
}

/// Storage precision config, the analogue of `type_config='float'|'half'`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TypeConfig {
    /// FP-32 everywhere.
    Float,
    /// Mixed precision: half storage/compute, f32 master weights +
    /// updates (paper §3.3 / Fig. 3-left).
    Half,
}

impl TypeConfig {
    pub fn name(self) -> &'static str {
        match self {
            TypeConfig::Float => "float",
            TypeConfig::Half => "half",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "float" | "f32" => Some(TypeConfig::Float),
            "half" | "bf16" | "f16" => Some(TypeConfig::Half),
            _ => None,
        }
    }
}

/// The extension context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Context {
    pub backend: Backend,
    pub type_config: TypeConfig,
    /// Device ordinal (worker rank in data-parallel runs).
    pub device_id: usize,
}

impl Context {
    pub fn new(backend: Backend, type_config: TypeConfig) -> Self {
        Context { backend, type_config, device_id: 0 }
    }

    pub fn with_device(mut self, device_id: usize) -> Self {
        self.device_id = device_id;
        self
    }

    /// `get_extension_context(name)` — parse "backend[:type_config]".
    pub fn get_extension_context(spec: &str) -> Option<Self> {
        let mut parts = spec.splitn(2, ':');
        let backend = Backend::from_name(parts.next()?)?;
        let type_config = match parts.next() {
            Some(t) => TypeConfig::from_name(t)?,
            None => TypeConfig::Float,
        };
        Some(Context::new(backend, type_config))
    }

    /// Set the thread-ambient default context (Listing 2).
    pub fn set_default(ctx: Context) {
        DEFAULT.with(|d| *d.borrow_mut() = ctx);
    }

    /// Read the thread-ambient default context.
    pub fn default() -> Context {
        DEFAULT.with(|d| *d.borrow())
    }
}

thread_local! {
    static DEFAULT: RefCell<Context> =
        const { RefCell::new(Context { backend: Backend::Cpu, type_config: TypeConfig::Float, device_id: 0 }) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_cpu_float() {
        let c = Context::default();
        assert_eq!(c.backend, Backend::Cpu);
        assert_eq!(c.type_config, TypeConfig::Float);
    }

    #[test]
    fn one_line_switch() {
        Context::set_default(Context::get_extension_context("xla:half").unwrap());
        let c = Context::default();
        assert_eq!(c.backend, Backend::Xla);
        assert_eq!(c.type_config, TypeConfig::Half);
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
    }

    #[test]
    fn accepts_paper_spelling() {
        // the paper's Listing 2 uses 'cudnn'; we map it to the fast backend
        let c = Context::get_extension_context("cudnn").unwrap();
        assert_eq!(c.backend, Backend::Xla);
    }

    #[test]
    fn rejects_unknown() {
        assert!(Context::get_extension_context("tpu").is_none());
        assert!(Context::get_extension_context("cpu:int8").is_none());
    }

    #[test]
    fn thread_local_isolation() {
        Context::set_default(Context::new(Backend::Xla, TypeConfig::Half));
        let handle = std::thread::spawn(|| Context::default().backend);
        assert_eq!(handle.join().unwrap(), Backend::Cpu); // fresh thread = default
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
    }
}
