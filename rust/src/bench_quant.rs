//! Quantization benchmark harness — shared by `nnl bench-quant` and
//! `benches/quant_inference.rs`, emitting `BENCH_quant.json`.
//!
//! Measures the int8 subsystem's acceptance numbers: fp32-vs-int8 GEMM
//! throughput at equal thread counts (the int8 side runs exactly as
//! serving does — weights prepacked at load, activations quantized per
//! call), per-model fp32-vs-int8 top-1 agreement, NNB1-vs-NNB2
//! artifact bytes, and per-request serving throughput on both plans.

use crate::converters::nnb;
use crate::models::zoo;
use crate::nnp::passes::{self, OptLevel};
use crate::nnp::plan::{CompiledNet, InferencePlan};
use crate::nnp::NetworkDef;
use crate::quant::{self, referenced_params, QTensor, QuantConfig};
use crate::tensor::kernels::int8::{self, ActQuant, QEpilogue, QMatA, QMatB};
use crate::tensor::{ops, parallel, NdArray, Rng};
use crate::utils::bench::{bench, table, Measurement};
use crate::utils::json::Json;

/// Everything one run produces: the human table and the JSON payload.
pub struct QuantBenchReport {
    pub text: String,
    pub json: Json,
}

fn gflops(flops: f64, m: &Measurement) -> f64 {
    flops / m.mean_secs / 1e9
}

/// Batch-1 random positional inputs for `net` — shared by the bench,
/// `nnl quantize`'s calibration, and the parity tests so input
/// synthesis cannot drift between them.
pub fn random_inputs(net: &NetworkDef, n: usize, rng: &mut Rng) -> Vec<Vec<NdArray>> {
    (0..n)
        .map(|_| {
            net.inputs
                .iter()
                .map(|t| {
                    let mut d = t.dims.clone();
                    if !d.is_empty() {
                        d[0] = 1;
                    }
                    rng.rand(&d, -1.0, 1.0)
                })
                .collect()
        })
        .collect()
}

/// Run the suite. `quick` shrinks sizes/iterations for CI smoke use.
pub fn run(quick: bool) -> QuantBenchReport {
    let mut rows: Vec<Measurement> = Vec::new();
    let mut rng = Rng::new(13);
    let nt = parallel::num_threads();

    // --- GEMM: f32 tiled (per-call B pack, as serving runs it) vs
    //     int8 (B prepacked at load, A quantized per call)
    let mm = if quick { 128 } else { 256 };
    let iters = if quick { 3 } else { 10 };
    let a = rng.rand(&[mm, mm], -1.0, 1.0);
    let b = rng.randn(&[mm, mm], 0.5);
    let flops = 2.0 * (mm as f64).powi(3);
    let f32_mt = bench(&format!("matmul f32 tiled, {nt} threads {mm}^3"), 1, iters, || {
        std::hint::black_box(ops::matmul(&a, &b));
    });
    let f32_1t = bench(&format!("matmul f32 tiled, 1 thread {mm}^3"), 1, iters, || {
        parallel::with_thread_limit(1, || std::hint::black_box(ops::matmul(&a, &b)));
    });
    let act = ActQuant::from_range(-1.0, 1.0);
    let qt = QTensor::quantize(&b, 1).expect("bench weight axis in range");
    let wq = QMatB::from_i8_kn(&qt.data, &qt.scales, mm, mm);
    let combined: Vec<f32> = wq.scales().iter().map(|s| s * act.scale).collect();
    let int8_call = || {
        let mut xq = Vec::new();
        int8::quantize_slice(&act, a.data(), &mut xq);
        let mut out = vec![0.0f32; mm * mm];
        int8::qgemm(
            &mut out,
            &QMatA::Dense { d: &xq, ld: mm },
            act.zero_point,
            &wq,
            mm,
            &QEpilogue { scales: &combined, bias: None, relu: false },
        );
        std::hint::black_box(&out);
    };
    let int8_mt = bench(&format!("matmul int8, {nt} threads {mm}^3"), 1, iters, int8_call);
    let int8_1t = bench(&format!("matmul int8, 1 thread {mm}^3"), 1, iters, || {
        parallel::with_thread_limit(1, int8_call);
    });
    rows.push(f32_mt.clone());
    rows.push(int8_mt.clone());
    rows.push(f32_1t.clone());
    rows.push(int8_1t.clone());

    // --- zoo models: agreement, artifact bytes, per-request throughput
    let mut model_names = vec!["mlp", "lenet"];
    if !quick {
        model_names.push("resnet18");
    }
    let n_eval = if quick { 64 } else { 256 };
    let mut model_rows: Vec<Json> = Vec::new();
    let mut all_ratios_ok = true;
    for name in model_names {
        let (net, params) = zoo::export_eval(name, 11);
        let calib = random_inputs(&net, 16, &mut rng);
        // explicit pipeline (not quantize_net): agreement below must be
        // measured against the very plan calibration ran on — the
        // graph is optimized first, exactly as `nnl quantize` does
        let (onet, oparams, _) = passes::optimize(&net, &params, OptLevel::default())
            .expect("zoo model optimizes");
        let plan = CompiledNet::compile(&onet, &oparams).expect("zoo model compiles");
        let ranges = quant::calibrate(&plan, &calib, &QuantConfig::default())
            .expect("zoo model calibrates");
        let model =
            quant::quantize_model(&onet, &oparams, &ranges).expect("zoo model quantizes");
        let qnet = quant::QuantizedNet::compile(&model).expect("quantized plan compiles");
        let evals = random_inputs(&net, n_eval, &mut rng);
        let agree = evals
            .iter()
            .filter(|s| {
                let f = plan.execute_positional(s.as_slice()).expect("fp32 run");
                let q = qnet.execute_positional(s.as_slice()).expect("int8 run");
                f[0].argmax_flat() == q[0].argmax_flat()
            })
            .count();
        let agreement = agree as f64 / n_eval as f64;
        let v1_bytes = nnb::to_nnb(&net, &referenced_params(&net, &params)).len();
        let v2_bytes = nnb::to_nnb2(&model).len();
        let ratio = v1_bytes as f64 / v2_bytes as f64;
        all_ratios_ok &= ratio >= 3.0;
        let f32_m = bench(&format!("{name} fp32 x{n_eval} requests"), 1, 3, || {
            for s in &evals {
                plan.execute_positional(s).expect("fp32 serve");
            }
        });
        let int8_m = bench(&format!("{name} int8 x{n_eval} requests"), 1, 3, || {
            for s in &evals {
                qnet.execute_positional(s).expect("int8 serve");
            }
        });
        let f32_rps = n_eval as f64 / f32_m.mean_secs;
        let int8_rps = n_eval as f64 / int8_m.mean_secs;
        rows.push(f32_m);
        rows.push(int8_m);
        model_rows.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("quantized_layers", Json::num(qnet.n_quantized() as f64)),
            ("top1_agreement", Json::num(agreement)),
            ("nnb1_bytes", Json::num(v1_bytes as f64)),
            ("nnb2_bytes", Json::num(v2_bytes as f64)),
            ("size_ratio", Json::num(ratio)),
            ("fp32_rps", Json::num(f32_rps)),
            ("int8_rps", Json::num(int8_rps)),
        ]));
    }

    let json = Json::obj(vec![
        ("nnl_threads", Json::num(nt as f64)),
        (
            "gemm",
            Json::obj(vec![
                ("size", Json::num(mm as f64)),
                ("f32_gflops", Json::num(gflops(flops, &f32_mt))),
                ("f32_1thread_gflops", Json::num(gflops(flops, &f32_1t))),
                ("int8_gops", Json::num(gflops(flops, &int8_mt))),
                ("int8_1thread_gops", Json::num(gflops(flops, &int8_1t))),
                (
                    "speedup_int8_vs_f32",
                    Json::num(f32_mt.mean_secs / int8_mt.mean_secs),
                ),
                (
                    "speedup_int8_vs_f32_1thread",
                    Json::num(f32_1t.mean_secs / int8_1t.mean_secs),
                ),
            ]),
        ),
        ("models", Json::Arr(model_rows)),
        ("nnb2_smaller", Json::Bool(all_ratios_ok)),
    ]);

    let mut text = table(
        &format!("Int8 quantized inference vs fp32 (NNL_THREADS = {nt})"),
        &rows,
    );
    text.push_str(&format!(
        "GEMM {mm}^3 x{nt} threads: f32 {:.2} GF/s | int8 {:.2} GOP/s \
         => {:.2}x; x1 thread: f32 {:.2} | int8 {:.2} => {:.2}x\n\
         NNB2 artifacts >=3x smaller than NNB1 across models: {}\n",
        gflops(flops, &f32_mt),
        gflops(flops, &int8_mt),
        f32_mt.mean_secs / int8_mt.mean_secs,
        gflops(flops, &f32_1t),
        gflops(flops, &int8_1t),
        f32_1t.mean_secs / int8_1t.mean_secs,
        all_ratios_ok,
    ));
    QuantBenchReport { text, json }
}

/// Write the JSON payload where the acceptance tooling expects it.
pub fn write_json(path: &std::path::Path, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_string_pretty())
}
