//! Parameter blob — the HDF5-analogue (`parameter.h5b`). Values are
//! stored at their native dtype width: f32 params at 4 B/elem,
//! bf16/f16 at 2 B/elem, so a half-precision checkpoint really is half
//! the size (paper §3.3 "nearly halves the memory usage").

use crate::tensor::{DType, NdArray};
use crate::utils::half;

const MAGIC: &[u8; 4] = b"H5B1";

/// Serialize named parameters.
pub fn save_params(params: &[(String, NdArray)]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, arr) in params {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        out.extend_from_slice(nb);
        let dt = arr.dtype().name().as_bytes();
        out.extend_from_slice(&(dt.len() as u32).to_le_bytes());
        out.extend_from_slice(dt);
        out.extend_from_slice(&(arr.rank() as u32).to_le_bytes());
        for &d in arr.dims() {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        match arr.dtype() {
            DType::F32 => {
                for &v in arr.data() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            DType::BF16 => {
                for &v in arr.data() {
                    out.extend_from_slice(&half::f32_to_bf16_bits(v).to_le_bytes());
                }
            }
            DType::F16 => {
                for &v in arr.data() {
                    out.extend_from_slice(&half::f32_to_f16_bits(v).to_le_bytes());
                }
            }
        }
    }
    out
}

/// Deserialize named parameters.
pub fn load_params(blob: &[u8]) -> Result<Vec<(String, NdArray)>, String> {
    if blob.len() < 8 || &blob[0..4] != MAGIC {
        return Err("bad parameter blob magic".into());
    }
    let mut pos = 4usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
        // `n` is untrusted and may be huge: compare against the
        // remaining length (never `pos + n`, which could overflow)
        if n > blob.len() - *pos {
            return Err("truncated parameter blob".into());
        }
        let s = &blob[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    // every entry costs at least its 4-byte name length: reject
    // implausible counts before allocating
    if count > blob.len() / 4 {
        return Err("truncated parameter blob".into());
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
            .map_err(|_| "bad param name".to_string())?;
        let dlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let dt_name = String::from_utf8(take(&mut pos, dlen)?.to_vec())
            .map_err(|_| "bad dtype".to_string())?;
        let dtype = DType::from_name(&dt_name).ok_or(format!("unknown dtype '{dt_name}'"))?;
        let rank = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        // dims, size, and byte length are untrusted: bound-check every
        // arithmetic step *before* any allocation, so bit-flipped blobs
        // fail with a clean Err instead of an overflow panic / OOM
        let mut dims = Vec::new();
        for _ in 0..rank {
            dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        let n = dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or("parameter size overflows")?;
        let width = match dtype {
            DType::F32 => 4usize,
            DType::BF16 | DType::F16 => 2,
        };
        let byte_len = n.checked_mul(width).ok_or("parameter size overflows")?;
        let raw = take(&mut pos, byte_len)?;
        let data: Vec<f32> = match dtype {
            DType::F32 => raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect(),
            DType::BF16 => raw
                .chunks_exact(2)
                .map(|c| half::bf16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
            DType::F16 => raw
                .chunks_exact(2)
                .map(|c| half::f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect(),
        };
        let mut arr = NdArray::from_vec(&dims, data);
        arr.set_dtype(dtype);
        out.push((name, arr));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_exact() {
        let params = vec![
            ("a/W".to_string(), NdArray::arange(&[2, 3])),
            ("a/b".to_string(), NdArray::from_slice(&[1], &[-1.5e-30])),
            ("scalar".to_string(), NdArray::scalar(7.0)),
        ];
        let blob = save_params(&params);
        let back = load_params(&blob).unwrap();
        assert_eq!(back.len(), 3);
        for ((n1, a1), (n2, a2)) in params.iter().zip(&back) {
            assert_eq!(n1, n2);
            assert_eq!(a1.dims(), a2.dims());
            assert_eq!(a1.data(), a2.data());
        }
    }

    #[test]
    fn bf16_stored_at_2_bytes() {
        let w = NdArray::arange(&[100]).cast(DType::BF16);
        let f32_blob = save_params(&[("w".into(), NdArray::arange(&[100]))]);
        let bf_blob = save_params(&[("w".into(), w.clone())]);
        assert!(bf_blob.len() < f32_blob.len() - 150); // ~200 bytes saved
        let back = load_params(&bf_blob).unwrap();
        assert_eq!(back[0].1.dtype(), DType::BF16);
        assert_eq!(back[0].1.data(), w.data()); // lossless for bf16-grid values
    }

    #[test]
    fn f16_roundtrip_preserves_grid_values() {
        let w = NdArray::from_slice(&[4], &[1.0, -2.5, 65504.0, 0.0]).cast(DType::F16);
        let back = load_params(&save_params(&[("w".into(), w.clone())])).unwrap();
        assert_eq!(back[0].1.data(), w.data());
    }

    #[test]
    fn rejects_truncated() {
        let blob = save_params(&[("w".into(), NdArray::arange(&[10]))]);
        assert!(load_params(&blob[..blob.len() - 3]).is_err());
        assert!(load_params(b"XXXX").is_err());
    }
}
