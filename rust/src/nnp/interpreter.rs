//! Execute a [`NetworkDef`] for inference — the deployment runtime the
//! paper's NNB / C-runtime targets exist for.
//!
//! There is **no per-op re-implementation here**: every layer is
//! executed through [`Op::execute`](super::ir::Op::execute), the same
//! registry dispatch the training tape records its nodes with — so
//! converted models are bit-identical to the source graph by
//! construction.
//!
//! [`run`] is the convenience one-shot entry point: it compiles a
//! [`CompiledNet`] at **O0** (lower + schedule + allocate only — the
//! graph-optimizer passes are skipped) and executes it once, so it
//! shares every validation and dispatch path with the planned runtime
//! while executing the graph *exactly as written*. That pins the
//! reference semantics: converter round-trips, gradcheck-style
//! comparisons and trace tests stay bit-identical to the tape no
//! matter what the optimizer learns to rewrite. Services that run the
//! same network repeatedly should call [`CompiledNet::compile`] (full
//! O2 pipeline) once and `execute` per request instead — that is the
//! whole point of the compiled plan (see `nnp::plan` and
//! `nnp::passes`).

use std::collections::HashMap;

use crate::tensor::NdArray;

use super::ir::NetworkDef;
use super::plan::CompiledNet;

/// Run `net` on named inputs with a parameter map. Returns the
/// network's declared outputs in order. The batch axis (axis 0) of each
/// input is free; feature dims must match the declaration.
///
/// This is compile-then-execute: all structural/arity/parameter errors
/// surface exactly as they would from [`CompiledNet::compile`].
pub fn run(
    net: &NetworkDef,
    inputs: &HashMap<String, NdArray>,
    params: &HashMap<String, NdArray>,
) -> Result<Vec<NdArray>, String> {
    CompiledNet::compile_with(net, params, crate::nnp::OptLevel::O0)?.execute(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, Op, TensorDef};

    fn affine_relu_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("W".into(), NdArray::from_slice(&[2, 2], &[1., -1., 1., 1.]));
        params.insert("b".into(), NdArray::from_slice(&[2], &[0., -10.]));
        (net, params)
    }

    #[test]
    fn runs_affine_relu() {
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::from_slice(&[1, 2], &[3., 4.]));
        let out = run(&net, &inputs, &params).unwrap();
        // h = [3+4, -3+4-10] = [7, -9]; relu -> [7, 0]
        assert_eq!(out[0].data(), &[7., 0.]);
    }

    #[test]
    fn batch_size_flexible() {
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[5, 2]));
        let out = run(&net, &inputs, &params).unwrap();
        assert_eq!(out[0].dims(), &[5, 2]);
    }

    #[test]
    fn rank0_input_is_error_not_panic() {
        // regression: this used to panic slicing `dims()[1..]`
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::scalar(1.0));
        let err = run(&net, &inputs, &params).unwrap_err();
        assert!(err.contains("incompatible"), "{err}");
    }

    #[test]
    fn rank_mismatch_is_error_not_panic() {
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[2])); // rank 1, declared rank 2
        let err = run(&net, &inputs, &params).unwrap_err();
        assert!(err.contains("incompatible"), "{err}");
    }

    #[test]
    fn missing_param_reported() {
        let (net, mut params) = affine_relu_net();
        params.remove("b");
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[1, 2]));
        let err = run(&net, &inputs, &params).unwrap_err();
        assert!(err.contains("missing parameter 'b'"), "{err}");
    }

    #[test]
    fn missing_input_reported() {
        let (net, params) = affine_relu_net();
        let err = run(&net, &HashMap::new(), &params).unwrap_err();
        assert!(err.contains("missing input 'x'"), "{err}");
    }

    #[test]
    fn bad_arity_is_layer_error() {
        let (mut net, params) = affine_relu_net();
        net.layers[0].params.clear(); // Affine with no W
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[1, 2]));
        let err = run(&net, &inputs, &params).unwrap_err();
        assert!(err.contains("layer 'fc'"), "{err}");
    }

    #[test]
    fn dropout_is_identity_at_inference() {
        let net = NetworkDef {
            name: "d".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "drop".into(),
                op: Op::Dropout { p: 0.9 },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::from_slice(&[1, 3], &[1., 2., 3.]));
        let out = run(&net, &inputs, &HashMap::new()).unwrap();
        assert_eq!(out[0].data(), &[1., 2., 3.]);
    }

    #[test]
    fn reshape_keep_batch_and_infer() {
        let net = NetworkDef {
            name: "r".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![2, 3, 4] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "reshape".into(),
                op: Op::Reshape { dims: vec![0, -1] },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[2, 3, 4]));
        let out = run(&net, &inputs, &HashMap::new()).unwrap();
        assert_eq!(out[0].dims(), &[2, 12]);
    }

    #[test]
    fn slice_layer_executes() {
        let net = NetworkDef {
            name: "s".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "sl".into(),
                op: Op::Slice { axis: 1, start: 1, stop: 3 },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::from_slice(&[1, 4], &[0., 1., 2., 3.]));
        let out = run(&net, &inputs, &HashMap::new()).unwrap();
        assert_eq!(out[0].data(), &[1., 2.]);
    }
}
