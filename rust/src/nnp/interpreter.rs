//! Execute a [`NetworkDef`] for inference — the deployment runtime the
//! paper's NNB / C-runtime targets exist for. Built on the same tested
//! `F::*` kernels as the training engine, so converted models are
//! bit-identical to the source graph.

use std::collections::HashMap;

use crate::functions as F;
use crate::graph::Variable;
use crate::tensor::NdArray;

use super::ir::{NetworkDef, Op};

/// Run `net` on named inputs with a parameter map. Returns the
/// network's declared outputs in order.
pub fn run(
    net: &NetworkDef,
    inputs: &HashMap<String, NdArray>,
    params: &HashMap<String, NdArray>,
) -> Result<Vec<NdArray>, String> {
    net.validate()?;
    let mut env: HashMap<String, Variable> = HashMap::new();
    for t in &net.inputs {
        let a = inputs
            .get(&t.name)
            .ok_or_else(|| format!("missing input '{}'", t.name))?;
        if a.dims()[1..] != t.dims[1..] {
            return Err(format!(
                "input '{}' feature dims {:?} != declared {:?}",
                t.name,
                a.dims(),
                t.dims
            ));
        }
        env.insert(t.name.clone(), Variable::from_array(a.clone(), false));
    }
    let p = |name: &str| -> Result<Variable, String> {
        params
            .get(name)
            .map(|a| Variable::from_array(a.clone(), false))
            .ok_or_else(|| format!("missing parameter '{name}'"))
    };
    for l in &net.layers {
        let ins: Vec<Variable> = l
            .inputs
            .iter()
            .map(|n| env.get(n).cloned().ok_or_else(|| format!("missing tensor '{n}'")))
            .collect::<Result<_, _>>()?;
        let y = match &l.op {
            Op::Affine => {
                let w = p(&l.params[0])?;
                let b = if l.params.len() > 1 { Some(p(&l.params[1])?) } else { None };
                F::affine(&ins[0], &w, b.as_ref())
            }
            Op::Convolution { stride, pad, dilation } => {
                let w = p(&l.params[0])?;
                let b = if l.params.len() > 1 { Some(p(&l.params[1])?) } else { None };
                F::convolution(&ins[0], &w, b.as_ref(), *stride, *pad, *dilation)
            }
            Op::MaxPool { kernel, stride, pad } => F::max_pooling(&ins[0], *kernel, *stride, *pad),
            Op::AvgPool { kernel, stride, pad, including_pad } => {
                F::average_pooling(&ins[0], *kernel, *stride, *pad, *including_pad)
            }
            Op::GlobalAvgPool => F::global_average_pooling(&ins[0]),
            Op::ReLU => F::relu(&ins[0]),
            Op::LeakyReLU { alpha } => F::leaky_relu(&ins[0], *alpha),
            Op::Sigmoid => F::sigmoid(&ins[0]),
            Op::Tanh => F::tanh(&ins[0]),
            Op::Elu { alpha } => F::elu(&ins[0], *alpha),
            Op::Swish => F::swish(&ins[0]),
            Op::Gelu => F::gelu(&ins[0]),
            Op::Softplus => F::softplus(&ins[0]),
            Op::Softmax => F::softmax(&ins[0]),
            Op::LogSoftmax => F::log_softmax(&ins[0]),
            Op::BatchNorm { eps } => {
                let beta = p(&l.params[0])?;
                let gamma = p(&l.params[1])?;
                let mean = p(&l.params[2])?;
                let var = p(&l.params[3])?;
                F::batch_normalization(&ins[0], &beta, &gamma, &mean, &var, 0.9, *eps, false)
            }
            Op::LayerNorm { eps } => {
                let beta = p(&l.params[0])?;
                let gamma = p(&l.params[1])?;
                F::layer_normalization(&ins[0], &beta, &gamma, *eps)
            }
            Op::Add2 => F::add(&ins[0], &ins[1]),
            Op::Mul2 => F::mul(&ins[0], &ins[1]),
            Op::Concat { axis } => {
                let refs: Vec<&Variable> = ins.iter().collect();
                F::concat(&refs, *axis)
            }
            Op::Reshape { dims } => {
                let batch = ins[0].dims()[0];
                let resolved: Vec<usize> = dims
                    .iter()
                    .enumerate()
                    .map(|(i, &d)| {
                        if d == -1 {
                            usize::MAX
                        } else if d == 0 && i == 0 {
                            batch // 0 in dim 0 = "keep batch"
                        } else {
                            d as usize
                        }
                    })
                    .collect();
                F::reshape(&ins[0], &resolved)
            }
            Op::Dropout { .. } => ins[0].clone(), // inference no-op
            Op::Embed => {
                let w = p(&l.params[0])?;
                F::embed(&ins[0], &w)
            }
            Op::Identity => ins[0].clone(),
        };
        // register outputs (ops here are all single-output)
        env.insert(l.outputs[0].clone(), y);
    }
    net.outputs
        .iter()
        .map(|o| env.get(o).map(|v| v.data()).ok_or_else(|| format!("missing output '{o}'")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, TensorDef};

    fn affine_relu_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("W".into(), NdArray::from_slice(&[2, 2], &[1., -1., 1., 1.]));
        params.insert("b".into(), NdArray::from_slice(&[2], &[0., -10.]));
        (net, params)
    }

    #[test]
    fn runs_affine_relu() {
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::from_slice(&[1, 2], &[3., 4.]));
        let out = run(&net, &inputs, &params).unwrap();
        // h = [3+4, -3+4-10] = [7, -9]; relu -> [7, 0]
        assert_eq!(out[0].data(), &[7., 0.]);
    }

    #[test]
    fn batch_size_flexible() {
        let (net, params) = affine_relu_net();
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[5, 2]));
        let out = run(&net, &inputs, &params).unwrap();
        assert_eq!(out[0].dims(), &[5, 2]);
    }

    #[test]
    fn missing_param_reported() {
        let (net, mut params) = affine_relu_net();
        params.remove("b");
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[1, 2]));
        let err = run(&net, &inputs, &params).unwrap_err();
        assert!(err.contains("missing parameter 'b'"), "{err}");
    }

    #[test]
    fn missing_input_reported() {
        let (net, params) = affine_relu_net();
        let err = run(&net, &HashMap::new(), &params).unwrap_err();
        assert!(err.contains("missing input 'x'"), "{err}");
    }

    #[test]
    fn dropout_is_identity_at_inference() {
        let net = NetworkDef {
            name: "d".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "drop".into(),
                op: Op::Dropout { p: 0.9 },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::from_slice(&[1, 3], &[1., 2., 3.]));
        let out = run(&net, &inputs, &HashMap::new()).unwrap();
        assert_eq!(out[0].data(), &[1., 2., 3.]);
    }

    #[test]
    fn reshape_keep_batch_and_infer() {
        let net = NetworkDef {
            name: "r".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![2, 3, 4] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "reshape".into(),
                op: Op::Reshape { dims: vec![0, -1] },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let mut inputs = HashMap::new();
        inputs.insert("x".into(), NdArray::zeros(&[2, 3, 4]));
        let out = run(&net, &inputs, &HashMap::new()).unwrap();
        assert_eq!(out[0].dims(), &[2, 12]);
    }
}
