//! Identity / Dropout / StopGradient elision.
//!
//! At inference these ops forward their input unchanged, so the layer
//! can be removed and every later reader rewired to the producer —
//! bit-identical by construction (the value object is literally the
//! same). A no-op whose output is a declared network output is kept:
//! the name is part of the serving contract.

use std::collections::HashMap;

use crate::nnp::ir::Op;

use super::{Module, Pass};

pub struct ElideNoops;

fn resolve(alias: &HashMap<String, String>, name: &str) -> String {
    // aliases always point at already-resolved names, so one hop wins;
    // the loop only guards against pathological hand-built chains
    let mut cur = name.to_string();
    let mut hops = 0;
    while let Some(next) = alias.get(&cur) {
        cur = next.clone();
        hops += 1;
        if hops > alias.len() {
            break;
        }
    }
    cur
}

impl Pass for ElideNoops {
    fn name(&self) -> &'static str {
        "elide-noops"
    }

    fn run(&self, m: &mut Module) -> Result<usize, String> {
        let mut alias: HashMap<String, String> = HashMap::new();
        let mut kept = Vec::with_capacity(m.net.layers.len());
        let mut removed = 0usize;
        for mut l in std::mem::take(&mut m.net.layers) {
            for i in l.inputs.iter_mut() {
                *i = resolve(&alias, i);
            }
            let noop = matches!(l.op, Op::Identity | Op::Dropout { .. } | Op::StopGradient)
                && l.inputs.len() == 1
                && l.params.is_empty()
                && !m.net.outputs.iter().any(|o| o == &l.outputs[0]);
            if noop {
                alias.insert(l.outputs[0].clone(), l.inputs[0].clone());
                removed += 1;
            } else {
                kept.push(l);
            }
        }
        m.net.layers = kept;
        Ok(removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, TensorDef};

    #[test]
    fn elides_chains_but_keeps_output_noops() {
        // x -> id -> drop -> y(out via Identity kept)
        let net = NetworkDef {
            name: "e".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "i1".into(),
                    op: Op::Identity,
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["a".into()],
                },
                Layer {
                    name: "d1".into(),
                    op: Op::Dropout { p: 0.3 },
                    inputs: vec!["a".into()],
                    params: vec![],
                    outputs: vec!["b".into()],
                },
                Layer {
                    name: "i2".into(),
                    op: Op::Identity,
                    inputs: vec!["b".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut m = Module { net, params: Default::default() };
        let n = ElideNoops.run(&mut m).unwrap();
        assert_eq!(n, 2);
        assert_eq!(m.net.layers.len(), 1);
        // the kept output-producing Identity reads the original input
        assert_eq!(m.net.layers[0].name, "i2");
        assert_eq!(m.net.layers[0].inputs, vec!["x".to_string()]);
        assert!(m.net.validate().is_ok());
    }
}
