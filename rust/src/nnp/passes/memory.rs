//! Static memory planning — the **allocate** phase of the compile
//! pipeline.
//!
//! Given each activation slot's byte size and live interval (producing
//! step → last reading step, in schedule order), a greedy interval
//! coloring assigns every slot a fixed offset in one shared arena:
//! slots are placed in definition order at the lowest offset where they
//! overlap no other live slot. The resulting `peak_bytes` is the exact
//! arena high-water mark of one request at the declared input shape —
//! reported by the plan, compared O0-vs-O2 by `nnl bench-plan`, and
//! bounded by the pass-parity suite (`planned ≤ naive`, i.e. never
//! worse than giving every slot its own allocation).

/// One slot's placement in the arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotAlloc {
    /// Byte offset in the shared arena.
    pub offset: usize,
    /// Slot size in bytes.
    pub bytes: usize,
    /// First step index at which the slot is live (inclusive).
    pub start: usize,
    /// Last step index at which the slot is live (inclusive).
    pub end: usize,
}

/// A slot's live range + size, the planner's input.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SlotInterval {
    pub slot: usize,
    pub start: usize,
    pub end: usize,
    pub bytes: usize,
}

/// The compile-time memory plan of one `CompiledNet`.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Per-slot placement (`None` for slots never materialized).
    pub slots: Vec<Option<SlotAlloc>>,
    /// Exact arena high-water mark, in bytes.
    pub peak_bytes: usize,
    /// Sum of all slot sizes — what "every slot owns its buffer"
    /// would cost. `peak_bytes <= naive_bytes` always holds.
    pub naive_bytes: usize,
}

/// Greedy interval coloring: place each slot (in start order) at the
/// lowest arena offset where it fits beside every overlapping-in-time
/// slot already placed.
pub(crate) fn plan_memory(intervals: &[SlotInterval], n_slots: usize) -> MemoryPlan {
    let mut order: Vec<usize> = (0..intervals.len()).collect();
    order.sort_by_key(|&i| (intervals[i].start, std::cmp::Reverse(intervals[i].bytes)));
    let mut slots: Vec<Option<SlotAlloc>> = vec![None; n_slots];
    let mut placed: Vec<SlotAlloc> = Vec::with_capacity(intervals.len());
    let mut peak = 0usize;
    let mut naive = 0usize;
    for &i in &order {
        let iv = intervals[i];
        naive += iv.bytes;
        // offsets of every time-overlapping placement, in offset order
        let mut busy: Vec<(usize, usize)> = placed
            .iter()
            .filter(|p| p.start <= iv.end && iv.start <= p.end)
            .map(|p| (p.offset, p.bytes))
            .collect();
        busy.sort_unstable();
        let mut offset = 0usize;
        for (boff, bbytes) in busy {
            if offset + iv.bytes <= boff {
                break; // fits in the gap before this block
            }
            offset = offset.max(boff + bbytes);
        }
        let alloc = SlotAlloc { offset, bytes: iv.bytes, start: iv.start, end: iv.end };
        peak = peak.max(offset + iv.bytes);
        slots[iv.slot] = Some(alloc);
        placed.push(alloc);
    }
    MemoryPlan { slots, peak_bytes: peak, naive_bytes: naive }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(slot: usize, start: usize, end: usize, bytes: usize) -> SlotInterval {
        SlotInterval { slot, start, end, bytes }
    }

    #[test]
    fn disjoint_intervals_share_one_offset() {
        // a [0,1], b [2,3]: b reuses a's storage
        let p = plan_memory(&[iv(0, 0, 1, 64), iv(1, 2, 3, 64)], 2);
        assert_eq!(p.peak_bytes, 64);
        assert_eq!(p.naive_bytes, 128);
        assert_eq!(p.slots[0].unwrap().offset, p.slots[1].unwrap().offset);
    }

    #[test]
    fn overlapping_intervals_stack() {
        let p = plan_memory(&[iv(0, 0, 2, 32), iv(1, 1, 3, 16), iv(2, 2, 4, 8)], 3);
        assert_eq!(p.peak_bytes, 32 + 16 + 8);
        // and a later disjoint slot falls back into the gap
        let p2 = plan_memory(&[iv(0, 0, 2, 32), iv(1, 3, 5, 16)], 2);
        assert_eq!(p2.peak_bytes, 32);
        assert_eq!(p2.slots[1].unwrap().offset, 0);
    }

    #[test]
    fn boundary_sharing_counts_as_overlap() {
        // producer at step 2 must not reuse memory freed at step 2:
        // the dying slot is still read while the new one is written
        let p = plan_memory(&[iv(0, 0, 2, 16), iv(1, 2, 4, 16)], 2);
        assert_eq!(p.peak_bytes, 32);
    }

    #[test]
    fn never_worse_than_naive() {
        let ivs: Vec<SlotInterval> =
            (0..20).map(|i| iv(i, i / 3, i / 3 + (i % 4), 8 * (1 + i % 5))).collect();
        let p = plan_memory(&ivs, 20);
        assert!(p.peak_bytes <= p.naive_bytes);
        assert!(p.peak_bytes >= ivs.iter().map(|v| v.bytes).max().unwrap());
    }
}
