//! Constant folding of parameter-only subtrees.
//!
//! A layer whose every activation input is itself constant (and whose
//! parameters are bound) computes the same value on every request —
//! evaluate it once at compile time through the same [`Op::execute`]
//! dispatch the interpreter uses and bind the result as a parameter.
//!
//! Rewiring is order-sensitive: an operator consumes its activation
//! inputs first, parameters second, so a constant input can only move
//! into the parameter list when every activation *after* it moves too
//! (the constant suffix of the input list is prepended to the params).
//! Constants consumed mid-list keep their producing layer.

use std::collections::{HashMap, HashSet};

use crate::nnp::ir::Op;
use crate::tensor::NdArray;

use super::{Module, Pass};

pub struct ConstFold;

/// Upper bound on folded tensor elements. Graphs come from untrusted
/// files and folding *executes* ops at load time — without a bound, a
/// tiny param feeding a `BroadcastTo { dims: [huge] }` subtree would
/// turn `nnl serve --in model.nnb` into an OOM at load (the same
/// reason the memory planner's dry run refuses absurd declared shapes).
const FOLD_LIMIT: usize = 1 << 24;

/// A cheap upper bound on `op`'s output element count given its
/// operands, or `None` when no cheap bound exists (attr-driven output
/// geometry: conv/deconv/pool/embed) — those stay on the runtime path.
fn output_bound(op: &Op, xs: &[&NdArray]) -> Option<usize> {
    let max_in = xs.iter().map(|a| a.size()).max().unwrap_or(0);
    match op {
        // output no larger than the largest operand
        Op::ReLU
        | Op::LeakyReLU { .. }
        | Op::Sigmoid
        | Op::Tanh
        | Op::Elu { .. }
        | Op::Swish
        | Op::Gelu
        | Op::Softplus
        | Op::Softmax
        | Op::LogSoftmax
        | Op::Neg
        | Op::AddScalar { .. }
        | Op::MulScalar { .. }
        | Op::PowScalar { .. }
        | Op::Exp
        | Op::Log
        | Op::StopGradient
        | Op::Reshape { .. }
        | Op::Transpose { .. }
        | Op::Slice { .. }
        | Op::Dropout { .. }
        | Op::Identity
        | Op::SumAll
        | Op::MeanAll
        | Op::Sum { .. }
        | Op::Mean { .. }
        | Op::BatchNorm { .. }
        | Op::LayerNorm { .. } => Some(max_in),
        // right-aligned broadcast of the two operands
        Op::Add2
        | Op::Sub2
        | Op::Mul2
        | Op::Div2
        | Op::SquaredError
        | Op::SigmoidCrossEntropy
        | Op::SoftmaxCrossEntropy => {
            broadcast_bound(xs.first()?.dims(), xs.get(1)?.dims())
        }
        Op::Concat { .. } => xs.iter().try_fold(0usize, |s, a| s.checked_add(a.size())),
        Op::BroadcastTo { dims } => dims.iter().try_fold(1usize, |p, &d| p.checked_mul(d)),
        Op::Affine => {
            let w = xs.get(1)?;
            if w.rank() == 2 && !xs[0].dims().is_empty() {
                xs[0].dims()[0].checked_mul(w.dims()[1])
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Element count of the right-aligned elementwise broadcast of two
/// shapes (missing leading axes count as 1; mismatches overestimate).
fn broadcast_bound(a: &[usize], b: &[usize]) -> Option<usize> {
    let rank = a.len().max(b.len());
    let mut p = 1usize;
    for i in 0..rank {
        let ad = if i + a.len() >= rank { a[i + a.len() - rank] } else { 1 };
        let bd = if i + b.len() >= rank { b[i + b.len() - rank] } else { 1 };
        p = p.checked_mul(ad.max(bd))?;
    }
    Some(p)
}

impl Pass for ConstFold {
    fn name(&self) -> &'static str {
        "const-fold"
    }

    fn run(&self, m: &mut Module) -> Result<usize, String> {
        // 1. discover constant tensors, walking in topological order
        let mut const_vals: HashMap<String, NdArray> = HashMap::new();
        for l in &m.net.layers {
            if m.net.outputs.iter().any(|o| o == &l.outputs[0]) {
                continue; // declared outputs keep their producing layer
            }
            if !l.inputs.iter().all(|n| const_vals.contains_key(n)) {
                continue;
            }
            let mut xs: Vec<&NdArray> = Vec::with_capacity(l.inputs.len() + l.params.len());
            for n in &l.inputs {
                xs.push(&const_vals[n]);
            }
            let mut bound = true;
            for p in &l.params {
                match m.params.get(p.as_str()) {
                    Some(a) => xs.push(a),
                    None => {
                        bound = false;
                        break;
                    }
                }
            }
            if !bound {
                continue;
            }
            // refuse to instantiate absurd shapes from untrusted files
            let safe = xs.iter().all(|a| a.size() <= FOLD_LIMIT)
                && matches!(output_bound(&l.op, &xs), Some(n) if n <= FOLD_LIMIT);
            if !safe {
                continue;
            }
            let result = l.op.execute(&xs);
            drop(xs);
            if let Ok(v) = result {
                const_vals.insert(l.outputs[0].clone(), v);
            }
            // evaluation errors leave the layer for the runtime path,
            // which reports them with full layer context
        }
        if const_vals.is_empty() {
            return Ok(0);
        }

        // 2. move constant input suffixes into parameter lists
        let mut pname_of: HashMap<String, String> = HashMap::new();
        let mut rewired = 0usize;
        for l in &mut m.net.layers {
            if const_vals.contains_key(&l.outputs[0]) {
                continue; // the subtree itself; may be removed below
            }
            let mut cut = l.inputs.len();
            while cut > 0 && const_vals.contains_key(&l.inputs[cut - 1]) {
                cut -= 1;
            }
            if cut == l.inputs.len() {
                continue;
            }
            let moved: Vec<String> = l.inputs.split_off(cut);
            rewired += moved.len();
            let mut new_params = Vec::with_capacity(moved.len() + l.params.len());
            for tname in moved {
                let pname = match pname_of.get(&tname) {
                    Some(p) => p.clone(),
                    None => {
                        let p = super::fresh_name(&m.params, &format!("{tname}.const"));
                        m.params.insert(p.clone(), const_vals[&tname].clone());
                        pname_of.insert(tname.clone(), p.clone());
                        p
                    }
                };
                new_params.push(pname);
            }
            new_params.append(&mut l.params);
            l.params = new_params;
        }

        // 3. drop constant layers nothing reads any more (in reverse,
        //    so a chain collapses in one pass)
        let mut removed = 0usize;
        loop {
            let read: HashSet<&str> = m
                .net
                .layers
                .iter()
                .flat_map(|l| l.inputs.iter().map(String::as_str))
                .collect();
            let dead = m.net.layers.iter().rposition(|l| {
                const_vals.contains_key(&l.outputs[0]) && !read.contains(l.outputs[0].as_str())
            });
            let Some(i) = dead else { break };
            m.net.layers.remove(i);
            removed += 1;
        }
        // a rewrite is any graph change: a constant wired into a
        // parameter list, or a subtree layer removed — counting only
        // removals would report 0 for a compile that did rewrite
        Ok(rewired + removed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
    use crate::nnp::passes::OptLevel;
    use crate::nnp::plan::CompiledNet;

    #[test]
    fn folds_param_only_chain_into_a_bound_constant() {
        // c = exp(w); y = x + c   — the exp chain runs at compile time
        let net = NetworkDef {
            name: "cf".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "e".into(),
                    op: Op::Exp,
                    inputs: vec![],
                    params: vec!["w".into()],
                    outputs: vec!["c".into()],
                },
                Layer {
                    name: "add".into(),
                    op: Op::Add2,
                    inputs: vec!["x".into(), "c".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("w".to_string(), NdArray::from_slice(&[1, 3], &[0.0, 1.0, 2.0]));
        let mut m = Module { net: net.clone(), params: params.clone() };
        // one input rewired into a param + one layer removed
        assert_eq!(ConstFold.run(&mut m).unwrap(), 2);
        assert_eq!(m.net.layers.len(), 1);
        assert_eq!(m.net.layers[0].inputs, vec!["x".to_string()]);
        assert_eq!(m.net.layers[0].params.len(), 1);
        assert!(m.net.validate().is_ok());
        // folded == unfolded, bit-identical (same dispatch, same values)
        let x = NdArray::from_slice(&[1, 3], &[1., 2., 3.]);
        let a = CompiledNet::compile_with(&net, &params, OptLevel::O0)
            .unwrap()
            .execute_positional(&[x.clone()])
            .unwrap();
        let b = CompiledNet::compile_with(&m.net, &m.params, OptLevel::O0)
            .unwrap()
            .execute_positional(&[x])
            .unwrap();
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn refuses_to_fold_absurd_output_shapes() {
        // c = exp(w); big = broadcast(c) to 2^26 elements; t = sum(big)
        // — the broadcast is const-reachable but must never be
        // evaluated at compile/load time (untrusted files would turn
        // that into an OOM); the exp still folds and rewires
        let net = NetworkDef {
            name: "cap".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["t".into()],
            layers: vec![
                Layer {
                    name: "e".into(),
                    op: Op::Exp,
                    inputs: vec![],
                    params: vec!["w".into()],
                    outputs: vec!["c".into()],
                },
                Layer {
                    name: "bc".into(),
                    op: Op::BroadcastTo { dims: vec![1 << 13, 1 << 13] },
                    inputs: vec!["c".into()],
                    params: vec![],
                    outputs: vec!["big".into()],
                },
                Layer {
                    name: "s".into(),
                    op: Op::SumAll,
                    inputs: vec!["big".into()],
                    params: vec![],
                    outputs: vec!["t".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("w".to_string(), NdArray::from_slice(&[1, 3], &[0.0, 1.0, 2.0]));
        let mut m = Module { net, params };
        // exp folded into a param wired into the broadcast (+ removal)
        assert_eq!(ConstFold.run(&mut m).unwrap(), 2);
        assert_eq!(m.net.layers.len(), 2);
        assert_eq!(m.net.layers[0].name, "bc");
        assert!(m.net.layers[0].inputs.is_empty());
        assert_eq!(m.net.layers[0].params.len(), 1);
        assert!(m.net.validate().is_ok());
    }

    #[test]
    fn const_consumed_mid_list_keeps_its_producer() {
        // y = c - x: the const is input 0 with a live input after it,
        // so moving it to params would reorder Sub2's operands
        let net = NetworkDef {
            name: "cf2".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "n".into(),
                    op: Op::Neg,
                    inputs: vec![],
                    params: vec!["w".into()],
                    outputs: vec!["c".into()],
                },
                Layer {
                    name: "sub".into(),
                    op: Op::Sub2,
                    inputs: vec!["c".into(), "x".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("w".to_string(), NdArray::from_slice(&[1, 2], &[1., -2.]));
        let mut m = Module { net, params };
        assert_eq!(ConstFold.run(&mut m).unwrap(), 0);
        assert_eq!(m.net.layers.len(), 2);
        assert!(m.net.validate().is_ok());
    }
}
