//! Dead-op elimination: remove layers whose outputs nothing reads.
//!
//! One backward sweep marks every layer reachable from the declared
//! network outputs; everything else is dropped. Bit-identical — dead
//! layers cannot influence any output value.

use std::collections::HashSet;

use super::{Module, Pass};

pub struct DeadOpElimination;

impl Pass for DeadOpElimination {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, m: &mut Module) -> Result<usize, String> {
        let mut live: HashSet<String> = m.net.outputs.iter().cloned().collect();
        let mut keep = vec![false; m.net.layers.len()];
        for (i, l) in m.net.layers.iter().enumerate().rev() {
            if l.outputs.iter().any(|o| live.contains(o)) {
                keep[i] = true;
                for inp in &l.inputs {
                    live.insert(inp.clone());
                }
            }
        }
        let before = m.net.layers.len();
        let mut it = keep.into_iter();
        m.net.layers.retain(|_| it.next().unwrap_or(false));
        Ok(before - m.net.layers.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};

    #[test]
    fn removes_transitively_dead_branches() {
        // y = neg(x); dead: a = exp(x), b = log(a)
        let net = NetworkDef {
            name: "d".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "dead1".into(),
                    op: Op::Exp,
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["a".into()],
                },
                Layer {
                    name: "dead2".into(),
                    op: Op::Log,
                    inputs: vec!["a".into()],
                    params: vec![],
                    outputs: vec!["b".into()],
                },
                Layer {
                    name: "keep".into(),
                    op: Op::Neg,
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut m = Module { net, params: Default::default() };
        assert_eq!(DeadOpElimination.run(&mut m).unwrap(), 2);
        assert_eq!(m.net.layers.len(), 1);
        assert_eq!(m.net.layers[0].name, "keep");
    }
}
