//! Step-level ReLU fusion.
//!
//! Runs after lowering (names are slots, dense ops are explicit
//! [`StepKernel`] entries): an `Affine`/`Conv2d` step whose output is
//! read by exactly one `Relu` step — and is not a declared network
//! output — absorbs the rectification into its epilogue, and the ReLU
//! step disappears. The fused step computes the same kernel output and
//! then applies the same elementwise `max(0)`, so results are
//! bit-identical to the unfused pair; one intermediate slot is never
//! materialized, which is also what lets the int8 lowering fold the
//! rectification into its requantize epilogue for free.

use std::collections::{HashMap, HashSet};

use crate::nnp::plan::{Src, Step, StepKernel};

/// Fuse dense→ReLU chains in place; returns the number of fusions.
pub(crate) fn fuse_relu(steps: &mut Vec<Step>, output_slots: &[usize]) -> usize {
    let outs: HashSet<usize> = output_slots.iter().copied().collect();
    // slot -> indices of steps reading it (one entry per read)
    let mut readers: HashMap<usize, Vec<usize>> = HashMap::new();
    for (i, st) in steps.iter().enumerate() {
        for a in &st.args {
            if let Src::Act(s) = a {
                readers.entry(*s).or_default().push(i);
            }
        }
    }
    let mut dead = vec![false; steps.len()];
    let mut fused = 0usize;
    for i in 0..steps.len() {
        if dead[i] {
            continue;
        }
        if !matches!(
            steps[i].kernel,
            StepKernel::Affine { relu: false } | StepKernel::Conv2d { relu: false, .. }
        ) {
            continue;
        }
        let o = steps[i].out;
        if outs.contains(&o) {
            continue;
        }
        let Some(rs) = readers.get(&o) else { continue };
        if rs.len() != 1 {
            continue;
        }
        let j = rs[0];
        if dead[j] || !matches!(steps[j].kernel, StepKernel::Relu) {
            continue;
        }
        let relu_out = steps[j].out;
        match &mut steps[i].kernel {
            StepKernel::Affine { relu } | StepKernel::Conv2d { relu, .. } => *relu = true,
            _ => unreachable!("fusable kernels checked above"),
        }
        steps[i].out = relu_out;
        dead[j] = true;
        fused += 1;
    }
    let mut it = dead.into_iter();
    steps.retain(|_| !it.next().unwrap_or(false));
    fused
}
