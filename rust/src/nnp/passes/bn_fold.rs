//! BatchNorm folding (inference mode, running statistics).
//!
//! `BatchNormalization` after a Convolution/Affine computes, per
//! output channel `c`:
//!
//! ```text
//!   y = gamma[c] · (dense(x) − mean[c]) / sqrt(var[c] + eps) + beta[c]
//! ```
//!
//! With `s[c] = gamma[c] / sqrt(var[c] + eps)` this is an affine
//! rewrite of the dense layer's own parameters:
//!
//! ```text
//!   W'[c] = s[c] · W[c]          b'[c] = s[c]·(b[c] − mean[c]) + beta[c]
//! ```
//!
//! so the BN layer disappears entirely — the dominant layer-count and
//! peak-memory win on every zoo CNN, and what makes BN-sandwiched
//! convolutions *quantizable* (the int8 path only lowers plain dense
//! layers). Float re-association makes this exact only to ≤ ~1e-4
//! relative, which is why it lives at O2, not O1.
//!
//! A fold is applied only when it is provably safe:
//! - the BN input is produced by an Affine/Convolution layer with a
//!   single activation input and owned (unshared) W/b parameters,
//! - the BN layer is the *only* reader of that output, which is not a
//!   declared network output,
//! - every parameter involved exists with per-channel sizes matching
//!   the dense layer's output-channel count.

use crate::nnp::ir::Op;
use crate::tensor::NdArray;

use super::{Module, Pass};

pub struct BnFold;

/// Everything one fold needs, gathered immutably before mutating.
struct Fold {
    dense: usize,
    bn: usize,
    new_w: (String, NdArray),
    new_b: (String, NdArray),
}

fn find_fold(m: &Module) -> Option<Fold> {
    let net = &m.net;
    // tensor-name read counts and parameter-name reference counts
    let mut reads = std::collections::HashMap::<&str, usize>::new();
    let mut prefs = std::collections::HashMap::<&str, usize>::new();
    for l in &net.layers {
        for i in &l.inputs {
            *reads.entry(i.as_str()).or_insert(0) += 1;
        }
        for p in &l.params {
            *prefs.entry(p.as_str()).or_insert(0) += 1;
        }
    }
    for (j, bn) in net.layers.iter().enumerate() {
        let Op::BatchNorm { eps } = &bn.op else { continue };
        if bn.inputs.len() != 1 || bn.params.len() != 4 {
            continue;
        }
        let src = bn.inputs[0].as_str();
        if net.outputs.iter().any(|o| o == src) || reads.get(src).copied() != Some(1) {
            continue;
        }
        let Some(i) = net.layers.iter().position(|p| p.outputs[0] == src) else { continue };
        let dense = &net.layers[i];
        if dense.inputs.len() != 1 || dense.params.is_empty() || dense.params.len() > 2 {
            continue;
        }
        // folding rewrites W/b in place (under new names); a weight
        // shared with any other layer must stay untouched
        if dense.params.iter().any(|p| prefs.get(p.as_str()).copied() != Some(1)) {
            continue;
        }
        let Some(w) = m.params.get(dense.params[0].as_str()) else { continue };
        // output-channel count and the contiguous per-channel block
        let (c, layout) = match &dense.op {
            Op::Affine if w.rank() == 2 => (w.dims()[1], AffineCols),
            Op::Convolution { .. } if w.rank() == 4 => (w.dims()[0], ConvRows),
            _ => continue,
        };
        if c == 0 {
            continue;
        }
        let bias = match dense.params.get(1) {
            Some(bname) => match m.params.get(bname.as_str()) {
                Some(b) if b.size() == c => Some(b),
                _ => continue,
            },
            None => None,
        };
        // BN params in Op-defined order: beta, gamma, mean, var
        let mut bnp = Vec::with_capacity(4);
        for pname in &bn.params {
            match m.params.get(pname.as_str()) {
                Some(a) if a.size() == c => bnp.push(a),
                _ => break,
            }
        }
        if bnp.len() != 4 {
            continue;
        }
        let (beta, gamma, mean, var) = (bnp[0], bnp[1], bnp[2], bnp[3]);
        // s[c] = gamma / sqrt(var + eps), t[c] = beta - mean*s
        let mut s = vec![0.0f32; c];
        let mut t = vec![0.0f32; c];
        for ci in 0..c {
            let inv = 1.0 / (var.data()[ci] + eps).sqrt();
            s[ci] = gamma.data()[ci] * inv;
            t[ci] = beta.data()[ci] - mean.data()[ci] * s[ci];
        }
        if s.iter().chain(&t).any(|v| !v.is_finite()) {
            continue; // degenerate running stats: leave the BN in place
        }
        let mut wd = w.data().to_vec();
        match layout {
            AffineCols => {
                // W [in, out]: scale column c
                let out = c;
                for row in wd.chunks_mut(out) {
                    for (ci, v) in row.iter_mut().enumerate() {
                        *v *= s[ci];
                    }
                }
            }
            ConvRows => {
                // W [oc, ic, kh, kw]: scale the block of channel c
                let inner = w.size() / c;
                for (ci, block) in wd.chunks_mut(inner).enumerate() {
                    for v in block {
                        *v *= s[ci];
                    }
                }
            }
        }
        let nb: Vec<f32> = match bias {
            Some(b) => (0..c).map(|ci| s[ci] * (b.data()[ci] - mean.data()[ci]) + beta.data()[ci]).collect(),
            None => t,
        };
        let wname = m.fresh_param_name(&format!("{}.bnfold", dense.params[0]));
        let bname = m.fresh_param_name(&format!("{}.bnfold.b", dense.params[0]));
        return Some(Fold {
            dense: i,
            bn: j,
            new_w: (wname, NdArray::from_vec(w.dims(), wd)),
            new_b: (bname, NdArray::from_vec(&[c], nb)),
        });
    }
    None
}

/// Marker for the per-channel weight layout.
use Layout::{AffineCols, ConvRows};
enum Layout {
    AffineCols,
    ConvRows,
}

impl Pass for BnFold {
    fn name(&self) -> &'static str {
        "bn-fold"
    }

    fn run(&self, m: &mut Module) -> Result<usize, String> {
        let mut folded = 0usize;
        while let Some(f) = find_fold(m) {
            let bn_out = m.net.layers[f.bn].outputs[0].clone();
            {
                let dense = &mut m.net.layers[f.dense];
                dense.params = vec![f.new_w.0.clone(), f.new_b.0.clone()];
                dense.outputs[0] = bn_out;
            }
            m.params.insert(f.new_w.0, f.new_w.1);
            m.params.insert(f.new_b.0, f.new_b.1);
            m.net.layers.remove(f.bn);
            folded += 1;
        }
        Ok(folded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, NetworkDef, TensorDef};
    use crate::nnp::plan::CompiledNet;
    use crate::nnp::passes::OptLevel;
    use crate::tensor::Rng;
    use std::collections::HashMap;

    fn conv_bn_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "cb".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2, 5, 5] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "conv".into(),
                    op: Op::Convolution { stride: (1, 1), pad: (1, 1), dilation: (1, 1) },
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "bn".into(),
                    op: Op::BatchNorm { eps: 1e-5 },
                    inputs: vec!["h".into()],
                    params: vec!["beta".into(), "gamma".into(), "mean".into(), "var".into()],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut rng = Rng::new(21);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[3, 2, 3, 3], 0.5));
        params.insert("b".to_string(), rng.randn(&[3], 0.2));
        params.insert("beta".to_string(), rng.randn(&[3], 0.3));
        params.insert("gamma".to_string(), rng.rand(&[3], 0.5, 1.5));
        params.insert("mean".to_string(), rng.randn(&[3], 0.4));
        params.insert("var".to_string(), rng.rand(&[3], 0.2, 1.2));
        (net, params)
    }

    #[test]
    fn conv_bn_folds_and_matches_within_tolerance() {
        let (net, params) = conv_bn_net();
        let mut m = Module { net: net.clone(), params: params.clone() };
        assert_eq!(BnFold.run(&mut m).unwrap(), 1);
        assert_eq!(m.net.layers.len(), 1);
        assert_eq!(m.net.layers[0].outputs, vec!["y".to_string()]);
        assert!(m.net.validate().is_ok());
        // folded output ≈ original output
        let x = Rng::new(3).randn(&[2, 2, 5, 5], 1.0);
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        let pf = CompiledNet::compile_with(&m.net, &m.params, OptLevel::O0).unwrap();
        let a = p0.execute_positional(&[x.clone()]).unwrap();
        let b = pf.execute_positional(&[x]).unwrap();
        assert!(
            a[0].allclose(&b[0], 1e-4, 1e-4),
            "fold drifted: {}",
            a[0].max_abs_diff(&b[0])
        );
    }

    #[test]
    fn bn_with_second_reader_is_not_folded() {
        let (mut net, params) = conv_bn_net();
        net.layers.push(Layer {
            name: "side".into(),
            op: Op::Neg,
            inputs: vec!["h".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        net.outputs.push("z".into());
        let mut m = Module { net, params };
        assert_eq!(BnFold.run(&mut m).unwrap(), 0);
        assert_eq!(m.net.layers.len(), 3);
    }

    #[test]
    fn affine_bn_folds_per_output_column() {
        let net = NetworkDef {
            name: "ab".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "bn".into(),
                    op: Op::BatchNorm { eps: 1e-5 },
                    inputs: vec!["h".into()],
                    params: vec!["beta".into(), "gamma".into(), "mean".into(), "var".into()],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut rng = Rng::new(9);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[4, 3], 1.0));
        params.insert("beta".to_string(), rng.randn(&[3], 0.3));
        params.insert("gamma".to_string(), rng.rand(&[3], 0.5, 1.5));
        params.insert("mean".to_string(), rng.randn(&[3], 0.4));
        params.insert("var".to_string(), rng.rand(&[3], 0.2, 1.2));
        let mut m = Module { net: net.clone(), params: params.clone() };
        assert_eq!(BnFold.run(&mut m).unwrap(), 1);
        // bias was absent: the fold must add one
        assert_eq!(m.net.layers[0].params.len(), 2);
        let x = Rng::new(5).randn(&[3, 4], 1.0);
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        let pf = CompiledNet::compile_with(&m.net, &m.params, OptLevel::O0).unwrap();
        let a = p0.execute_positional(&[x.clone()]).unwrap();
        let b = pf.execute_positional(&[x]).unwrap();
        assert!(a[0].allclose(&b[0], 1e-4, 1e-4));
    }
}
