//! Compile-time graph optimizer: a pass pipeline over the NNP IR.
//!
//! The paper's "speedy computation" pillar rests on optimizing the
//! *graph* before execution, not just on fast kernels. Until this
//! module existed, every optimization the framework had (Affine/Conv +
//! ReLU fusion, dropout elision) was pattern-matched at **runtime**
//! inside the plan executor on every request, and BatchNorm — which
//! dominates every zoo model — was never folded at all. The pass
//! pipeline moves all of it to compile time:
//!
//! ```text
//!   NetworkDef + params
//!        │ optimize      graph-level [`Pass`]es (this module)
//!        ▼
//!   NetworkDef + params  (fewer layers, folded weights)
//!        │ lower          names → slots, ops → step kernels
//!        ▼
//!   steps                 [`fuse_relu`] rewrites dense→ReLU chains
//!        │ schedule       liveness → eager frees
//!        │ allocate       static memory plan (interval coloring)
//!        ▼
//!   CompiledNet           a dumb step loop over `tensor::kernels`
//! ```
//!
//! Graph-level passes rewrite a [`Module`] (a [`NetworkDef`] plus its
//! parameter map) and report how many rewrites they applied. They run
//! under a [`PassManager`] built for an [`OptLevel`]:
//!
//! - **O0** — no rewrites at all: lower + schedule + allocate only.
//!   This is what [`crate::nnp::interpreter::run`] and the training /
//!   gradcheck paths use, so tape semantics are provably untouched.
//! - **O1** — semantics-preserving, **bit-identical** rewrites:
//!   Identity/Dropout elision ([`ElideNoops`]), dead-op elimination
//!   ([`DeadOpElimination`]) and the step-level ReLU fusion
//!   ([`fuse_relu`]). The rewritten plan calls the exact same kernels
//!   in the same order on the same values.
//! - **O2** (default for serving) — adds numeric folds that are exact
//!   up to float re-association (≤ 1e-4 relative in practice):
//!   BatchNorm folding into the preceding Conv/Affine weights
//!   ([`BnFold`], inference mode, running statistics) and constant
//!   folding of parameter-only subtrees ([`ConstFold`]).
//!
//! # Authoring a new pass
//!
//! A pass is a unit struct implementing [`Pass`]: inspect and rewrite
//! `m.net` / `m.params`, return how many rewrites you applied. Passes
//! may assume the module has already passed [`NetworkDef::validate`] —
//! in particular that tensor names are unique (no shadowing) and that
//! layers are topologically ordered, so name-based rewiring is safe.
//!
//! ```ignore
//! struct FoldMulOne; // y = x * 1.0  ->  y = x
//! impl Pass for FoldMulOne {
//!     fn name(&self) -> &'static str { "fold-mul-one" }
//!     fn run(&self, m: &mut Module) -> Result<usize, String> {
//!         let mut n = 0;
//!         for l in &mut m.net.layers {
//!             if matches!(l.op, Op::MulScalar { val } if val == 1.0) {
//!                 l.op = Op::Identity; // ElideNoops removes it next
//!                 n += 1;
//!             }
//!         }
//!         Ok(n)
//!     }
//! }
//! ```
//!
//! Then register it in [`PassManager::for_level`] at the right level:
//! O1 if the rewrite is bit-identical, O2 if it re-associates floats.
//!
//! While developing a pass, run the pipeline through
//! [`PassManager::run_verified`] instead of [`PassManager::run`]: after
//! *each* pass it re-validates the IR contract and re-runs the full
//! static verifier ([`crate::nnp::verify::verify_network`]) over the
//! rewritten module, so the first pass that breaks an invariant —
//! dangling tensor reads, arity violations, shape disagreements — is
//! named in the error instead of surfacing later as a mysterious
//! compile or runtime failure. `nnl optimize --verify` and the debug
//! translation-validation hook in [`crate::nnp::CompiledNet`] lean on
//! the same machinery.

mod bn_fold;
mod const_fold;
mod dce;
mod elide;
mod fuse;
mod memory;

pub use bn_fold::BnFold;
pub use const_fold::ConstFold;
pub use dce::DeadOpElimination;
pub use elide::ElideNoops;
pub(crate) use fuse::fuse_relu;
pub use memory::{MemoryPlan, SlotAlloc};
pub(crate) use memory::{plan_memory, SlotInterval};

use std::collections::HashMap;

use crate::nnp::ir::NetworkDef;
use crate::tensor::NdArray;

/// The unit the graph-level passes rewrite: a network definition plus
/// the parameter map it binds against. Passes may add parameters (BN
/// folding, constant folding) or leave orphans behind — orphans are
/// simply never bound by the plan.
pub struct Module {
    pub net: NetworkDef,
    pub params: HashMap<String, NdArray>,
}

impl Module {
    /// A parameter name not yet taken, derived from `base`.
    pub(crate) fn fresh_param_name(&self, base: &str) -> String {
        fresh_name(&self.params, base)
    }
}

/// A name not yet present in `params`, derived from `base` — the free
/// form of [`Module::fresh_param_name`] for passes that hold a
/// conflicting borrow on the module's layers.
pub(crate) fn fresh_name(params: &HashMap<String, NdArray>, base: &str) -> String {
    if !params.contains_key(base) {
        return base.to_string();
    }
    let mut i = 1usize;
    loop {
        let cand = format!("{base}.{i}");
        if !params.contains_key(&cand) {
            return cand;
        }
        i += 1;
    }
}

/// One graph-level rewrite over a [`Module`]. See the module docs for
/// how to author and register a new pass.
pub trait Pass {
    /// Stable pass name (reported in stats / `nnl optimize`).
    fn name(&self) -> &'static str;
    /// Apply the rewrite; returns the number of rewrites performed.
    fn run(&self, m: &mut Module) -> Result<usize, String>;
}

/// How many rewrites one pass applied during a compile.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassStat {
    pub pass: &'static str,
    pub rewrites: usize,
}

/// Optimization level of the compile pipeline (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum OptLevel {
    /// Lower + schedule + allocate only — no rewrites. The interpreter
    /// and training/gradcheck paths run here.
    O0,
    /// Bit-identical rewrites only (elision, DCE, ReLU fusion).
    O1,
    /// All passes, including numeric folds (BN fold, const fold).
    #[default]
    O2,
}

impl OptLevel {
    /// Parse a `--opt 0|1|2` CLI flag.
    pub fn from_flag(s: &str) -> Option<OptLevel> {
        match s.trim() {
            "0" | "O0" | "o0" => Some(OptLevel::O0),
            "1" | "O1" | "o1" => Some(OptLevel::O1),
            "2" | "O2" | "o2" => Some(OptLevel::O2),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptLevel::O0 => "O0",
            OptLevel::O1 => "O1",
            OptLevel::O2 => "O2",
        }
    }
}

/// Runs an ordered pass list over a [`Module`], collecting per-pass
/// rewrite stats.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    /// The standard pipeline for `level`. Elision runs first (it can
    /// expose dense→BN adjacency hidden behind a Dropout), DCE runs
    /// last to sweep anything the folds orphaned.
    pub fn for_level(level: OptLevel) -> PassManager {
        let mut passes: Vec<Box<dyn Pass>> = Vec::new();
        if level >= OptLevel::O1 {
            passes.push(Box::new(ElideNoops));
            passes.push(Box::new(DeadOpElimination));
        }
        if level >= OptLevel::O2 {
            passes.push(Box::new(BnFold));
            passes.push(Box::new(ConstFold));
            passes.push(Box::new(DeadOpElimination));
        }
        PassManager { passes }
    }

    /// An empty manager (O0 behaviour) — useful for custom pipelines.
    pub fn empty() -> PassManager {
        PassManager { passes: Vec::new() }
    }

    /// Append a custom pass to the pipeline.
    pub fn push(&mut self, pass: Box<dyn Pass>) {
        self.passes.push(pass);
    }

    /// Validate the module, then run every pass in order.
    pub fn run(&self, m: &mut Module) -> Result<Vec<PassStat>, String> {
        // passes assume unique tensor names + topological order
        m.net.validate()?;
        let mut stats = Vec::with_capacity(self.passes.len());
        for p in &self.passes {
            let rewrites = p
                .run(m)
                .map_err(|e| format!("pass '{}' failed: {e}", p.name()))?;
            stats.push(PassStat { pass: p.name(), rewrites });
        }
        Ok(stats)
    }

    /// [`PassManager::run`] with per-pass translation validation: after
    /// each pass the module is re-checked against the IR contract
    /// ([`NetworkDef::validate`]) *and* the full static verifier
    /// ([`crate::nnp::verify::verify_network`]). The first pass that
    /// breaks an invariant is named in the error — this is the
    /// bisection mode for debugging a new or misbehaving pass.
    pub fn run_verified(&self, m: &mut Module) -> Result<Vec<PassStat>, String> {
        m.net.validate()?;
        let baseline = crate::nnp::verify::verify_network(&m.net, &m.params);
        if baseline.has_errors() {
            return Err(format!(
                "module fails verification before any pass runs:\n{}",
                baseline.render_human()
            ));
        }
        let mut stats = Vec::with_capacity(self.passes.len());
        for p in &self.passes {
            let rewrites = p
                .run(m)
                .map_err(|e| format!("pass '{}' failed: {e}", p.name()))?;
            stats.push(PassStat { pass: p.name(), rewrites });
            if let Err(e) = m.net.validate() {
                return Err(format!("pass '{}' broke the IR contract: {e}", p.name()));
            }
            let report = crate::nnp::verify::verify_network(&m.net, &m.params);
            if report.has_errors() {
                return Err(format!(
                    "pass '{}' broke a graph invariant:\n{}",
                    p.name(),
                    report.render_human()
                ));
            }
        }
        Ok(stats)
    }
}

/// Run the standard pipeline for `level` on a copy of `net`/`params`.
/// Returns the optimized definition, its (possibly extended) parameter
/// map and the per-pass stats. This is the entry the quantization
/// pipeline uses so NNB2 artifacts carry the *optimized* graph and
/// BN-folded convolutions become quantizable dense layers.
pub fn optimize(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    level: OptLevel,
) -> Result<(NetworkDef, HashMap<String, NdArray>, Vec<PassStat>), String> {
    let mut m = Module { net: net.clone(), params: params.clone() };
    let stats = PassManager::for_level(level).run(&mut m)?;
    Ok((m.net, m.params, stats))
}

/// [`optimize`] under [`PassManager::run_verified`]: every pass is
/// followed by a full re-verification of the rewritten module, and an
/// invariant-breaking pass is named in the error. Slower — meant for
/// `nnl optimize --verify` and pass development, not the serving path.
pub fn optimize_verified(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    level: OptLevel,
) -> Result<(NetworkDef, HashMap<String, NdArray>, Vec<PassStat>), String> {
    let mut m = Module { net: net.clone(), params: params.clone() };
    let stats = PassManager::for_level(level).run_verified(&mut m)?;
    Ok((m.net, m.params, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, Op, TensorDef};

    fn chain_net() -> (NetworkDef, HashMap<String, NdArray>) {
        // x -> fc -> drop -> relu -> y, plus a dead Neg branch
        let net = NetworkDef {
            name: "p".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "drop".into(),
                    op: Op::Dropout { p: 0.5 },
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["hd".into()],
                },
                Layer {
                    name: "act".into(),
                    op: Op::ReLU,
                    inputs: vec!["hd".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
                Layer {
                    name: "dead".into(),
                    op: Op::Neg,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["unused".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("W".to_string(), NdArray::from_slice(&[3, 2], &[1., 0., 0., 1., 1., 1.]));
        params.insert("b".to_string(), NdArray::from_slice(&[2], &[0.5, -0.5]));
        (net, params)
    }

    #[test]
    fn o1_elides_noops_and_sweeps_dead_ops() {
        let (net, params) = chain_net();
        let (onet, _, stats) = optimize(&net, &params, OptLevel::O1).unwrap();
        assert_eq!(onet.layers.len(), 2, "{:?}", onet.layers);
        assert_eq!(onet.layers[0].name, "fc");
        assert_eq!(onet.layers[1].name, "act");
        // the relu now reads the affine output directly
        assert_eq!(onet.layers[1].inputs, vec!["h".to_string()]);
        let by_name: HashMap<_, _> = stats.iter().map(|s| (s.pass, s.rewrites)).collect();
        assert_eq!(by_name["elide-noops"], 1);
        assert_eq!(by_name["dce"], 1);
        assert!(onet.validate().is_ok());
    }

    #[test]
    fn o0_is_a_no_op() {
        let (net, params) = chain_net();
        let (onet, oparams, stats) = optimize(&net, &params, OptLevel::O0).unwrap();
        assert_eq!(onet, net);
        assert_eq!(oparams.len(), params.len());
        assert!(stats.is_empty());
    }

    #[test]
    fn optimize_rejects_invalid_graphs() {
        let (mut net, params) = chain_net();
        net.layers[0].inputs[0] = "ghost".into();
        assert!(optimize(&net, &params, OptLevel::O2).is_err());
    }

    #[test]
    fn pipeline_is_idempotent() {
        let (net, params) = chain_net();
        let (once, p1, _) = optimize(&net, &params, OptLevel::O2).unwrap();
        let (twice, _, stats) = optimize(&once, &p1, OptLevel::O2).unwrap();
        assert_eq!(once, twice);
        assert!(stats.iter().all(|s| s.rewrites == 0), "{stats:?}");
    }

    #[test]
    fn run_verified_matches_run_on_sound_passes() {
        let (net, params) = chain_net();
        let (plain, _, _) = optimize(&net, &params, OptLevel::O2).unwrap();
        let (checked, _, stats) = optimize_verified(&net, &params, OptLevel::O2).unwrap();
        assert_eq!(plain, checked);
        assert!(!stats.is_empty());
    }

    #[test]
    fn run_verified_names_the_breaking_pass() {
        // a pass that rewires a layer to read a tensor that no longer
        // exists — validate() catches it right after this pass runs
        struct BreakGraph;
        impl Pass for BreakGraph {
            fn name(&self) -> &'static str {
                "break-graph"
            }
            fn run(&self, m: &mut Module) -> Result<usize, String> {
                m.net.layers[0].inputs[0] = "ghost".into();
                Ok(1)
            }
        }
        let (net, params) = chain_net();
        let mut m = Module { net, params };
        let mut pm = PassManager::empty();
        pm.push(Box::new(ElideNoops));
        pm.push(Box::new(BreakGraph));
        let err = pm.run_verified(&mut m).unwrap_err();
        assert!(err.contains("break-graph"), "{err}");
        // the sound pass before it is not blamed
        assert!(!err.contains("elide-noops"), "{err}");
    }

    #[test]
    fn run_verified_catches_shape_invariant_breaks() {
        // validate() cannot see shapes — a pass that resizes a weight
        // is only caught by the static verifier layer
        struct ShrinkWeight;
        impl Pass for ShrinkWeight {
            fn name(&self) -> &'static str {
                "shrink-weight"
            }
            fn run(&self, m: &mut Module) -> Result<usize, String> {
                m.params.insert("W".to_string(), NdArray::zeros(&[2, 2]));
                Ok(1)
            }
        }
        let (net, params) = chain_net();
        let mut m = Module { net, params };
        let mut pm = PassManager::empty();
        pm.push(Box::new(ShrinkWeight));
        let err = pm.run_verified(&mut m).unwrap_err();
        assert!(err.contains("shrink-weight"), "{err}");
        assert!(err.contains("NNL-E006"), "{err}");
    }

    #[test]
    fn opt_level_flag_parses() {
        assert_eq!(OptLevel::from_flag("0"), Some(OptLevel::O0));
        assert_eq!(OptLevel::from_flag("O1"), Some(OptLevel::O1));
        assert_eq!(OptLevel::from_flag("2"), Some(OptLevel::O2));
        assert_eq!(OptLevel::from_flag("9"), None);
        assert!(OptLevel::O0 < OptLevel::O1 && OptLevel::O1 < OptLevel::O2);
        assert_eq!(OptLevel::default(), OptLevel::O2);
    }
}
