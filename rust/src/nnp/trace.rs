//! `nnp::trace` — export any define-by-run graph directly to the NNP
//! IR, with **zero dual bookkeeping**.
//!
//! Because every tape node carries its [`Op`] descriptor (see
//! [`crate::graph::Variable::from_function`]) and every `PF::*`
//! parameter is registered under a canonical name, the tape is
//! self-describing: walking it from the outputs yields the complete
//! [`NetworkDef`] — layers with typed attributes, activation tensors,
//! parameter references, and network inputs. A graph built purely from
//! `F::*` / `PF::*` calls (Listing 1 style, no builder) therefore
//! exports to NNP / ONNX / NNB exactly like one built through
//! [`crate::models::Gb`] — which is itself now a thin convenience
//! wrapper over this function.

use std::collections::{HashMap, HashSet};

use crate::graph::Variable;
use crate::parametric;

use super::ir::{Layer, NetworkDef, TensorDef};

/// Walk the tape backwards from `outputs` and emit the network IR.
///
/// - **Parameters** are recognized by identity against the global
///   parameter registry and recorded by their registry names (in the
///   op-defined input order: `W[, b]`, `beta, gamma, mean, var`, …).
/// - **Network inputs** are the remaining leaf variables, named by
///   their [`Variable::name`] (set one with `set_name`) or `in<N>`.
/// - **Activations** are named by their variable name or `t<N>`.
/// - **Layer names** derive from the parameter scope (`c1/conv/W` →
///   layer `c1`) or `<op>_<index>` for parameter-free functions.
///
/// Dropout recorded via `F::dropout_inference` (eval graphs) traces to
/// an [`super::ir::Op::Dropout`] layer that the interpreter treats as a
/// no-op; train-mode graphs (sampled dropout, batch-stat BN) trace to
/// the same descriptors with deployment semantics, so trace eval-mode
/// graphs when you need bit-identical round-trips.
pub fn trace(name: &str, outputs: &[&Variable]) -> Result<NetworkDef, String> {
    // parameter identity -> registry name
    let mut param_names: HashMap<usize, String> = HashMap::new();
    for (pname, v) in parametric::get_parameters() {
        param_names.insert(v.uid(), pname);
    }

    // topological order over every function node reachable from the
    // outputs (iterative DFS — tapes can be very deep)
    enum Step {
        Visit(Variable),
        Emit(Variable),
    }
    let mut order: Vec<Variable> = Vec::new();
    let mut seen: HashSet<usize> = HashSet::new();
    let mut stack: Vec<Step> =
        outputs.iter().rev().map(|v| Step::Visit((*v).clone())).collect();
    while let Some(step) = stack.pop() {
        match step {
            Step::Visit(v) => {
                if !seen.insert(v.uid()) {
                    continue;
                }
                if !v.is_leaf() {
                    stack.push(Step::Emit(v.clone()));
                    for inp in v.creator_inputs().into_iter().rev() {
                        stack.push(Step::Visit(inp));
                    }
                }
            }
            Step::Emit(v) => order.push(v),
        }
    }

    let mut def = NetworkDef { name: name.to_string(), ..Default::default() };
    let mut tensor_names: HashMap<usize, String> = HashMap::new();
    let mut used: HashSet<String> = HashSet::new();
    fn unique(used: &mut HashSet<String>, base: String) -> String {
        if used.insert(base.clone()) {
            return base;
        }
        let mut i = 2;
        loop {
            let cand = format!("{base}_{i}");
            if used.insert(cand.clone()) {
                return cand;
            }
            i += 1;
        }
    }

    // Gb's auto-assigned tensor names (`t<N>`) are not meaningful as
    // layer names; anything else the user chose is.
    fn is_auto_name(n: &str) -> bool {
        n.len() > 1 && n.starts_with('t') && n[1..].chars().all(|c| c.is_ascii_digit())
    }

    let mut input_count = 0usize;
    let mut act_count = 0usize;
    for (idx, v) in order.iter().enumerate() {
        let op = v.creator_op().expect("topo order yields non-leaves");
        let mut layer_inputs: Vec<String> = Vec::new();
        let mut layer_params: Vec<String> = Vec::new();
        for inp in v.creator_inputs() {
            if let Some(pname) = param_names.get(&inp.uid()) {
                layer_params.push(pname.clone());
                continue;
            }
            // The IR stores a layer's operands as activations followed
            // by parameters (the order Op::apply re-applies them in).
            // A parameter *preceding* an activation (e.g. Sub2(param, x))
            // cannot be represented without silently reordering the
            // operands — reject it instead of exporting a different
            // function.
            if !layer_params.is_empty() {
                return Err(format!(
                    "trace: '{}' has an activation input after a parameter input; \
                     parameter-leading operand orders are not representable in the IR \
                     (wrap the parameter in F::identity to lift it to an activation)",
                    op.name()
                ));
            }
            let tname = match tensor_names.get(&inp.uid()) {
                Some(t) => t.clone(),
                None => {
                    if !inp.is_leaf() {
                        return Err(format!(
                            "trace: tape ordering error at '{}' (non-leaf input unseen)",
                            op.name()
                        ));
                    }
                    // a fresh leaf: this is a network input
                    let base = if inp.name().is_empty() {
                        input_count += 1;
                        format!("in{}", input_count - 1)
                    } else {
                        inp.name()
                    };
                    let t = unique(&mut used, base);
                    tensor_names.insert(inp.uid(), t.clone());
                    def.inputs.push(TensorDef { name: t.clone(), dims: inp.dims() });
                    t
                }
            };
            layer_inputs.push(tname);
        }
        // output tensor name
        let base = if v.name().is_empty() {
            act_count += 1;
            format!("t{act_count}")
        } else {
            v.name()
        };
        let out_name = unique(&mut used, base);
        tensor_names.insert(v.uid(), out_name.clone());
        // layer name: parameter scope, else the user-chosen output
        // tensor name (Gb's named ops / set_name), else op + topo index
        let layer_name = match layer_params.first() {
            Some(first) => {
                let parts: Vec<&str> = first.split('/').collect();
                if parts.len() >= 3 {
                    parts[..parts.len() - 2].join("/")
                } else if parts.len() == 2 {
                    parts[0].to_string()
                } else {
                    format!("{}_{idx}", op.name().to_lowercase())
                }
            }
            None => {
                let n = v.name();
                if !n.is_empty() && !is_auto_name(&n) {
                    n
                } else {
                    format!("{}_{idx}", op.name().to_lowercase())
                }
            }
        };
        def.layers.push(Layer {
            name: layer_name,
            op,
            inputs: layer_inputs,
            params: layer_params,
            outputs: vec![out_name],
        });
    }

    for o in outputs {
        let t = tensor_names.get(&o.uid()).ok_or_else(|| {
            "trace: output variable is a leaf (no function ever produced it)".to_string()
        })?;
        def.outputs.push(t.clone());
    }
    def.validate()?;
    Ok(def)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions as F;
    use crate::nnp::interpreter;
    use crate::nnp::ir::Op;
    use crate::parametric as PF;
    use crate::tensor::{NdArray, Rng};
    use std::collections::HashMap;

    fn reset() {
        PF::clear_parameters();
        PF::seed_parameter_rng(11);
    }

    #[test]
    fn traces_pure_functional_graph() {
        reset();
        let x = Variable::new(&[2, 6], false);
        x.set_name("x");
        let h = PF::affine(&x, 4, "fc1");
        let h = F::relu(&h);
        let y = PF::affine(&h, 3, "fc2");
        let def = trace("mlp", &[&y]).unwrap();
        assert_eq!(def.inputs.len(), 1);
        assert_eq!(def.inputs[0].name, "x");
        assert_eq!(def.inputs[0].dims, vec![2, 6]);
        assert_eq!(def.layers.len(), 3);
        assert_eq!(def.layers[0].name, "fc1");
        assert_eq!(def.layers[0].op, Op::Affine);
        assert_eq!(def.layers[0].params, vec!["fc1/affine/W", "fc1/affine/b"]);
        assert_eq!(def.layers[1].op, Op::ReLU);
        assert_eq!(def.layers[2].name, "fc2");
        assert_eq!(def.outputs.len(), 1);
        assert!(def.validate().is_ok());
    }

    #[test]
    fn traced_graph_runs_bit_identical_in_interpreter() {
        reset();
        let mut rng = Rng::new(21);
        let x = Variable::from_array(rng.randn(&[3, 8], 1.0), false);
        x.set_name("x");
        let h = PF::affine(&x, 5, "l1");
        let h = F::tanh(&h);
        let y = PF::affine(&h, 2, "l2");
        let def = trace("net", &[&y]).unwrap();

        let params: HashMap<String, NdArray> =
            PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), x.data());
        let out = interpreter::run(&def, &inputs, &params).unwrap();
        assert_eq!(out[0].data(), y.data().data(), "interpreter must be bit-identical");
    }

    #[test]
    fn shared_input_traces_once() {
        reset();
        let x = Variable::from_array(NdArray::full(&[1, 2], 2.0), false);
        x.set_name("x");
        let a = F::mul(&x, &x);
        let y = F::add(&a, &x);
        let def = trace("shared", &[&y]).unwrap();
        assert_eq!(def.inputs.len(), 1); // x appears once
        assert_eq!(def.layers.len(), 2);
        assert_eq!(def.layers[0].inputs, vec!["x", "x"]);
    }

    #[test]
    fn unnamed_inputs_get_generated_names() {
        reset();
        let x = Variable::new(&[1, 3], false);
        let y = F::relu(&x);
        let def = trace("anon", &[&y]).unwrap();
        assert_eq!(def.inputs[0].name, "in0");
    }

    #[test]
    fn leaf_output_is_an_error() {
        reset();
        let x = Variable::new(&[1], false);
        assert!(trace("bad", &[&x]).is_err());
    }

    #[test]
    fn multi_output_graphs_trace() {
        reset();
        let x = Variable::new(&[2, 4], false);
        x.set_name("x");
        let h = PF::affine(&x, 4, "body");
        let y1 = F::relu(&h);
        let y2 = F::sigmoid(&h);
        let def = trace("two_heads", &[&y1, &y2]).unwrap();
        assert_eq!(def.outputs.len(), 2);
        assert_eq!(def.layers.len(), 3);
    }

    #[test]
    fn param_before_activation_is_rejected_not_reordered() {
        // Sub2(param, x) cannot be stored as activations-first without
        // changing the computed function — trace must refuse.
        reset();
        let s = PF::get_or_create_parameter("s", &[1, 2], |_| NdArray::ones(&[1, 2]), true);
        let x = Variable::new(&[1, 2], false);
        x.set_name("x");
        let y = F::sub(&s, &x);
        let err = trace("bad_order", &[&y]).unwrap_err();
        assert!(err.contains("parameter-leading"), "{err}");
        // the representable order traces fine
        let y2 = F::sub(&x, &s);
        assert!(trace("good_order", &[&y2]).is_ok());
    }

    #[test]
    fn batch_norm_params_in_op_order() {
        reset();
        let x = Variable::new(&[2, 3, 4, 4], false);
        x.set_name("x");
        let y = PF::batch_normalization(&x, false, "bn1");
        let def = trace("bn", &[&y]).unwrap();
        assert_eq!(def.layers[0].name, "bn1");
        assert_eq!(
            def.layers[0].params,
            vec!["bn1/bn/beta", "bn1/bn/gamma", "bn1/bn/mean", "bn1/bn/var"]
        );
    }
}
