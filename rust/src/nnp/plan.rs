//! Compiled execution plans — the deployment hot path (ROADMAP: serve
//! heavy traffic as fast as the hardware allows).
//!
//! [`crate::nnp::interpreter::run`] is correct but pays a per-call tax
//! no server can afford: it re-validates the graph, re-resolves every
//! tensor name through a `HashMap`, and re-binds every parameter on
//! every single request. [`CompiledNet`] moves all of that to load
//! time: compile a [`NetworkDef`] + parameter map **once** into a
//! topologically-ordered, slot-indexed plan —
//!
//! - parameters bound up front (missing ones fail at load);
//! - tensor names resolved to integer slot ids (no hashing per call);
//! - per-layer arity and pooling/slice/transpose attributes validated
//!   at compile time (malformed files fail at load, not mid-request);
//! - last-use liveness precomputed, so intermediate buffers are
//!   dropped eagerly and peak memory tracks liveness, not depth.
//!
//! [`CompiledNet::execute`] is `&self` and `CompiledNet` is
//! `Send + Sync`: one plan serves any number of threads concurrently
//! (see `serve::Server`). Execution still flows through [`Op::execute`]
//! — the same registry dispatch the training tape records — so compiled
//! outputs are bit-identical to the interpreter and to the live graph.

use std::collections::{HashMap, HashSet};

use crate::tensor::ops::Conv2dGeom;
use crate::tensor::{kernels, ops, NdArray};

use super::ir::{self, NetworkDef, Op, TensorDef};

/// Where one operand of a step comes from. `pub(crate)` so the int8
/// quantizer ([`crate::quant`]) can walk a compiled plan's dataflow.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Src {
    /// Activation slot in the per-call environment.
    Act(usize),
    /// Parameter index, bound once at compile time.
    Param(usize),
}

/// One executable step of the plan.
#[derive(Debug, Clone)]
pub(crate) struct Step {
    /// Layer name, kept for error reporting only.
    pub(crate) name: String,
    pub(crate) op: Op,
    /// Activations first, then parameters — the order [`Op::apply`]
    /// defines.
    pub(crate) args: Vec<Src>,
    /// Output activation slot (fresh per layer).
    pub(crate) out: usize,
    /// Activation slots whose last read is this step; dropped eagerly
    /// after it runs.
    pub(crate) free_after: Vec<usize>,
}

/// A network compiled against a fixed parameter set, ready for
/// repeated, concurrent inference. Build with [`CompiledNet::compile`];
/// run with [`CompiledNet::execute`] (named inputs) or
/// [`CompiledNet::execute_positional`] (declared input order, the
/// serving hot path).
pub struct CompiledNet {
    name: String,
    /// Declared inputs; input `i` lives in slot `i`.
    inputs: Vec<TensorDef>,
    output_names: Vec<String>,
    output_slots: Vec<usize>,
    steps: Vec<Step>,
    n_slots: usize,
    /// Tensor name of each slot (inputs first, then each layer's
    /// output in step order; shadowed names repeat). Calibration and
    /// quantization key activation statistics by these names.
    slot_names: Vec<String>,
    /// Parameters bound at compile time (COW handles — O(1) to hold,
    /// never copied per request).
    params: Vec<NdArray>,
}

impl CompiledNet {
    /// Compile `net` against `params`. Validates structure, arity and
    /// parameter availability so that a successfully compiled plan can
    /// only fail at run time on input-shape mismatches or kernel-level
    /// shape errors.
    pub fn compile(
        net: &NetworkDef,
        params: &HashMap<String, NdArray>,
    ) -> Result<CompiledNet, String> {
        net.validate()?;
        let n_inputs = net.inputs.len();
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        let mut slot_names: Vec<String> = Vec::new();
        let mut n_slots = 0usize;
        for t in &net.inputs {
            slot_of.insert(t.name.clone(), n_slots);
            slot_names.push(t.name.clone());
            n_slots += 1;
        }

        let mut bound: Vec<NdArray> = Vec::new();
        let mut param_idx: HashMap<String, usize> = HashMap::new();
        let mut steps: Vec<Step> = Vec::with_capacity(net.layers.len());
        for l in &net.layers {
            let mut args = Vec::with_capacity(l.inputs.len() + l.params.len());
            for tname in &l.inputs {
                let s = *slot_of
                    .get(tname.as_str())
                    .ok_or_else(|| format!("layer '{}' reads undefined tensor '{tname}'", l.name))?;
                args.push(Src::Act(s));
            }
            for pname in &l.params {
                let idx = match param_idx.get(pname.as_str()) {
                    Some(&i) => i,
                    None => {
                        let a = params
                            .get(pname.as_str())
                            .ok_or_else(|| format!("missing parameter '{pname}'"))?;
                        bound.push(a.clone());
                        param_idx.insert(pname.clone(), bound.len() - 1);
                        bound.len() - 1
                    }
                };
                args.push(Src::Param(idx));
            }
            // a layer output always gets a fresh slot; re-defining an
            // existing name shadows it for later readers, exactly like
            // the interpreter's env overwrite
            let out = n_slots;
            n_slots += 1;
            slot_of.insert(l.outputs[0].clone(), out);
            slot_names.push(l.outputs[0].clone());
            steps.push(Step {
                name: l.name.clone(),
                op: l.op.clone(),
                args,
                out,
                free_after: Vec::new(),
            });
        }

        let output_slots = net
            .outputs
            .iter()
            .map(|o| {
                slot_of
                    .get(o.as_str())
                    .copied()
                    .ok_or_else(|| format!("network output '{o}' never produced"))
            })
            .collect::<Result<Vec<usize>, String>>()?;

        // liveness: find each slot's last reader; a slot that is not a
        // network output dies right after that step. Slots written but
        // never read die at their producing step (slot s >= n_inputs is
        // produced by step s - n_inputs, since each layer allocates
        // exactly one fresh slot in order).
        let mut last_read: Vec<Option<usize>> = vec![None; n_slots];
        for (i, st) in steps.iter().enumerate() {
            for a in &st.args {
                if let Src::Act(s) = a {
                    last_read[*s] = Some(i);
                }
            }
        }
        let keep: HashSet<usize> = output_slots.iter().copied().collect();
        for s in 0..n_slots {
            if keep.contains(&s) {
                continue;
            }
            match last_read[s] {
                Some(i) => steps[i].free_after.push(s),
                None if s >= n_inputs => steps[s - n_inputs].free_after.push(s),
                None => {} // unread network input: held by the caller anyway
            }
        }

        Ok(CompiledNet {
            name: net.name.clone(),
            inputs: net.inputs.clone(),
            output_names: net.outputs.clone(),
            output_slots,
            steps,
            n_slots,
            slot_names,
            params: bound,
        })
    }

    // ------------------------------------------------ quantizer access

    /// The compiled steps, in execution order (one per source layer).
    pub(crate) fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// A bound parameter by compile-time index.
    pub(crate) fn param(&self, i: usize) -> &NdArray {
        &self.params[i]
    }

    /// Number of activation slots a call environment needs.
    pub(crate) fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Slots holding the declared outputs, in output order.
    pub(crate) fn output_slots(&self) -> &[usize] {
        &self.output_slots
    }

    /// Network name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared inputs, in positional order.
    pub fn inputs(&self) -> &[TensorDef] {
        &self.inputs
    }

    /// Declared output names, in order.
    pub fn outputs(&self) -> &[String] {
        &self.output_names
    }

    /// Number of executable steps (layers) in the plan.
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Validate a positional input set against the declared signature
    /// (rank must match; dims past the batch axis must agree; axis 0 is
    /// free). Returns the batch-row count of the first input (1 for
    /// rank-0 / input-less nets).
    pub fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        if inputs.len() != self.inputs.len() {
            return Err(format!(
                "network '{}' expects {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            ));
        }
        for (t, a) in self.inputs.iter().zip(inputs) {
            if a.dims().len() != t.dims.len() || a.dims().get(1..) != t.dims.get(1..) {
                return Err(format!(
                    "input '{}' shape {:?} incompatible with declared {:?} (batch axis free)",
                    t.name,
                    a.dims(),
                    t.dims
                ));
            }
        }
        Ok(inputs.first().and_then(|a| a.dims().first().copied()).unwrap_or(1))
    }

    /// Run the plan on named inputs. Thin wrapper over
    /// [`CompiledNet::execute_positional`].
    pub fn execute(&self, inputs: &HashMap<String, NdArray>) -> Result<Vec<NdArray>, String> {
        let mut positional = Vec::with_capacity(self.inputs.len());
        for t in &self.inputs {
            positional.push(
                inputs
                    .get(&t.name)
                    .ok_or_else(|| format!("missing input '{}'", t.name))?
                    .clone(),
            );
        }
        self.execute_positional(&positional)
    }

    /// Run the plan on inputs given in declared order. `&self`: any
    /// number of threads may execute one plan concurrently; each call
    /// owns its buffer environment.
    ///
    /// The hot ops (Affine, Convolution, plus the trivial
    /// ReLU/Identity/Dropout) run *fused*: the same
    /// [`crate::tensor::kernels`] entry points the training tape
    /// records — so outputs stay bit-identical to the live graph —
    /// but with no tape node, no column materialization, and all
    /// intermediates drawn from this thread's scratch arena. Freed
    /// activation slots are recycled back into that arena, so a
    /// long-lived serving thread reaches a steady state with no heap
    /// allocation per request for conv columns or plan intermediates.
    pub fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        self.execute_inner(inputs, None)
    }

    /// [`CompiledNet::execute_positional`] plus a hook: `observe` is
    /// called with `(tensor_name, value)` for every declared input and
    /// every layer output, in execution order. This is the calibration
    /// entry the int8 quantizer ([`crate::quant::calibrate`]) runs its
    /// sample set through.
    pub fn execute_observed(
        &self,
        inputs: &[NdArray],
        observe: &mut dyn FnMut(&str, &NdArray),
    ) -> Result<Vec<NdArray>, String> {
        self.execute_inner(inputs, Some(observe))
    }

    fn execute_inner(
        &self,
        inputs: &[NdArray],
        mut observe: Option<&mut dyn FnMut(&str, &NdArray)>,
    ) -> Result<Vec<NdArray>, String> {
        self.check_inputs(inputs)?;
        let mut env: Vec<Option<NdArray>> = vec![None; self.n_slots];
        for (i, a) in inputs.iter().enumerate() {
            if let Some(obs) = observe.as_deref_mut() {
                obs(&self.slot_names[i], a);
            }
            env[i] = Some(a.clone());
        }
        for st in &self.steps {
            let mut xs: Vec<&NdArray> = Vec::with_capacity(st.args.len());
            for a in &st.args {
                match a {
                    Src::Act(s) => {
                        xs.push(env[*s].as_ref().expect("plan liveness invariant broken"))
                    }
                    Src::Param(i) => xs.push(&self.params[*i]),
                }
            }
            let y = execute_step(&st.op, &xs).map_err(|e| format!("layer '{}': {e}", st.name))?;
            drop(xs);
            if let Some(obs) = observe.as_deref_mut() {
                obs(&self.slot_names[st.out], &y);
            }
            env[st.out] = Some(y);
            for &s in &st.free_after {
                if let Some(dead) = env[s].take() {
                    kernels::recycle(dead);
                }
            }
        }
        self.output_slots
            .iter()
            .map(|&s| {
                env[s]
                    .as_ref()
                    .cloned()
                    .ok_or_else(|| "plan output slot empty (liveness invariant broken)".into())
            })
            .collect()
    }

    /// Conservative static check that rows are independent under this
    /// plan: concatenating several requests along axis 0, executing
    /// once, and splitting the outputs back is equivalent to executing
    /// each request alone. The batching server falls back to
    /// per-request execution when this is `false`.
    ///
    /// Soundness without shape inference: last-axis ops (Softmax,
    /// LayerNorm, …) are row-independent only while every activation
    /// keeps rank ≥ 2 (axis 0 stays a pure batch axis). So all inputs
    /// must declare rank ≥ 2 and every rank-reducing op is excluded:
    /// global reductions and `BroadcastTo` outright, axis reductions
    /// unless `keepdims` on a non-batch axis, `Reshape` unless it keeps
    /// the batch axis and rank ≥ 2. Everything else in the registry
    /// preserves "rank ≥ 2 with batch axis 0" — so the last axis a
    /// normalisation sees is never the batch axis.
    pub fn batch_invariant(&self) -> bool {
        if self.inputs.is_empty() || self.inputs.iter().any(|t| t.dims.len() < 2) {
            return false;
        }
        self.steps.iter().all(|st| match &st.op {
            Op::SumAll | Op::MeanAll | Op::BroadcastTo { .. } => false,
            Op::Sum { axis, keepdims } | Op::Mean { axis, keepdims } => *axis != 0 && *keepdims,
            Op::Concat { axis } | Op::Slice { axis, .. } => *axis != 0,
            Op::Transpose { axes } => axes.first() == Some(&0),
            Op::Reshape { dims } => dims.len() >= 2 && dims[0] == 0,
            _ => true,
        })
    }
}

/// The contract a serving plan exposes, whatever executes underneath —
/// the f32 [`CompiledNet`] or the int8 [`crate::quant::QuantizedNet`].
/// Object-safe: [`crate::serve::Server`] hosts an
/// `Arc<dyn InferencePlan>` so one worker pool serves either backend.
pub trait InferencePlan: Send + Sync {
    /// Network name.
    fn name(&self) -> &str;
    /// Declared inputs, in positional order.
    fn inputs(&self) -> &[TensorDef];
    /// Declared output names, in order.
    fn outputs(&self) -> &[String];
    /// Number of executable steps (layers).
    fn n_steps(&self) -> usize;
    /// Validate positional inputs; returns the batch-row count.
    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String>;
    /// Run on inputs given in declared order (`&self`: thread-shared).
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String>;
    /// Whether rows are provably independent (micro-batching safety).
    fn batch_invariant(&self) -> bool;

    /// Run on named inputs (declared-order resolution).
    fn execute_named(&self, inputs: &HashMap<String, NdArray>) -> Result<Vec<NdArray>, String> {
        let mut positional = Vec::with_capacity(self.inputs().len());
        for t in self.inputs() {
            positional.push(
                inputs
                    .get(&t.name)
                    .ok_or_else(|| format!("missing input '{}'", t.name))?
                    .clone(),
            );
        }
        self.execute_positional(&positional)
    }
}

impl InferencePlan for CompiledNet {
    fn name(&self) -> &str {
        CompiledNet::name(self)
    }

    fn inputs(&self) -> &[TensorDef] {
        CompiledNet::inputs(self)
    }

    fn outputs(&self) -> &[String] {
        CompiledNet::outputs(self)
    }

    fn n_steps(&self) -> usize {
        CompiledNet::n_steps(self)
    }

    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        CompiledNet::check_inputs(self, inputs)
    }

    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        CompiledNet::execute_positional(self, inputs)
    }

    fn batch_invariant(&self) -> bool {
        CompiledNet::batch_invariant(self)
    }
}

/// One plan step. The fused arms call the very kernels the tape's
/// `F::*` closures call (bit-identical outputs) while skipping the
/// per-op `Variable` construction `Op::execute` pays; everything else
/// falls through to the registry dispatch. Guards mirror `Op::apply`'s
/// validation so malformed shapes stay clean errors.
pub(crate) fn execute_step(op: &Op, xs: &[&NdArray]) -> Result<NdArray, String> {
    match op {
        Op::Affine if (2..=3).contains(&xs.len()) && xs[0].rank() >= 1 && xs[1].rank() == 2 => {
            let feat: usize = xs[0].dims()[1..].iter().product();
            if feat != xs[1].dims()[0] {
                return Err(format!(
                    "Affine: input features {feat} do not match weight rows {}",
                    xs[1].dims()[0]
                ));
            }
            Ok(kernels::affine_forward(xs[0], xs[1], xs.get(2).copied()))
        }
        Op::Convolution { stride, pad, dilation } if (2..=3).contains(&xs.len()) => {
            ir::check_conv_geometry(xs[0].dims(), xs[1].dims(), *stride, *pad, *dilation)?;
            let g = Conv2dGeom {
                kernel: (xs[1].dims()[2], xs[1].dims()[3]),
                stride: *stride,
                pad: *pad,
                dilation: *dilation,
            };
            Ok(kernels::conv2d_forward(xs[0], xs[1], xs.get(2).copied(), &g))
        }
        Op::ReLU if xs.len() == 1 => Ok(ops::map(xs[0], |v| v.max(0.0))),
        Op::Identity | Op::Dropout { .. } if xs.len() == 1 => Ok(xs[0].clone()),
        _ => op.execute(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::interpreter;
    use crate::nnp::ir::Layer;

    fn affine_relu_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("W".into(), NdArray::from_slice(&[2, 2], &[1., -1., 1., 1.]));
        params.insert("b".into(), NdArray::from_slice(&[2], &[0., -10.]));
        (net, params)
    }

    #[test]
    fn compile_once_execute_many() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        assert_eq!(plan.n_steps(), 2);
        // repeated calls, varying batch size, all matching the interpreter
        for bs in [1usize, 3, 8] {
            let x = NdArray::arange(&[bs, 2]);
            let mut inputs = HashMap::new();
            inputs.insert("x".to_string(), x);
            let got = plan.execute(&inputs).unwrap();
            let want = interpreter::run(&net, &inputs, &params).unwrap();
            assert_eq!(got[0].dims(), want[0].dims());
            assert_eq!(got[0].data(), want[0].data());
        }
    }

    #[test]
    fn positional_matches_named() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let x = NdArray::from_slice(&[1, 2], &[3., 4.]);
        let mut named = HashMap::new();
        named.insert("x".to_string(), x.clone());
        assert_eq!(
            plan.execute(&named).unwrap()[0].data(),
            plan.execute_positional(&[x]).unwrap()[0].data()
        );
    }

    #[test]
    fn missing_param_fails_at_compile() {
        let (net, mut params) = affine_relu_net();
        params.remove("b");
        let err = CompiledNet::compile(&net, &params).unwrap_err();
        assert!(err.contains("missing parameter 'b'"), "{err}");
    }

    #[test]
    fn bad_arity_fails_at_compile() {
        let (mut net, params) = affine_relu_net();
        net.layers[0].params.clear();
        let err = CompiledNet::compile(&net, &params).unwrap_err();
        assert!(err.contains("layer 'fc'"), "{err}");
    }

    #[test]
    fn bad_pool_geometry_fails_at_run_with_clean_error() {
        let net = NetworkDef {
            name: "p".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 1, 2, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "pool".into(),
                op: Op::MaxPool { kernel: (7, 7), stride: (1, 1), pad: (0, 0) },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::zeros(&[1, 1, 2, 2]));
        let err = plan.execute(&inputs).unwrap_err();
        assert!(err.contains("layer 'pool'"), "{err}");
        assert!(err.contains("kernel"), "{err}");
    }

    #[test]
    fn intermediates_freed_at_last_use() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        // slot 0 = x (dies after fc), slot 1 = h (dies after relu),
        // slot 2 = y (network output, kept)
        assert_eq!(plan.steps[0].free_after, vec![0]);
        assert_eq!(plan.steps[1].free_after, vec![1]);
        assert_eq!(plan.output_slots, vec![2]);
    }

    #[test]
    fn shadowed_tensor_names_match_interpreter() {
        // h is defined twice; later readers must see the newest value
        let net = NetworkDef {
            name: "s".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "a".into(),
                    op: Op::MulScalar { val: 2.0 },
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "b".into(),
                    op: Op::AddScalar { val: 1.0 },
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "c".into(),
                    op: Op::Identity,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let params = HashMap::new();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[1., 2., 3.]));
        let got = plan.execute(&inputs).unwrap();
        assert_eq!(got[0].data(), &[3., 5., 7.]);
        let want = interpreter::run(&net, &inputs, &params).unwrap();
        assert_eq!(got[0].data(), want[0].data());
    }

    #[test]
    fn output_that_is_also_input_survives() {
        // passthrough output: the input slot must never be freed
        let net = NetworkDef {
            name: "pass".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["x".into(), "y".into()],
            layers: vec![Layer {
                name: "neg".into(),
                op: Op::Neg,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 2], &[1., -2.]));
        let out = plan.execute(&inputs).unwrap();
        assert_eq!(out[0].data(), &[1., -2.]);
        assert_eq!(out[1].data(), &[-1., 2.]);
    }

    #[test]
    fn batch_invariance_classification() {
        let (net, params) = affine_relu_net();
        assert!(CompiledNet::compile(&net, &params).unwrap().batch_invariant());
        let mut reducing = net.clone();
        reducing.layers.push(Layer {
            name: "s".into(),
            op: Op::SumAll,
            inputs: vec!["y".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        reducing.outputs = vec!["z".into()];
        assert!(!CompiledNet::compile(&reducing, &params).unwrap().batch_invariant());
    }

    #[test]
    fn rank1_last_axis_net_is_not_batch_invariant() {
        // on a rank-1 activation the "last axis" IS the batch axis:
        // micro-batching a softmax over it would mix requests
        let net = NetworkDef {
            name: "sm1".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "sm".into(),
                op: Op::Softmax,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        assert!(!plan.batch_invariant());
        // rank-reducing reductions are excluded too
        let mut reduced = affine_relu_net().0;
        reduced.layers.push(Layer {
            name: "m".into(),
            op: Op::Mean { axis: 1, keepdims: false },
            inputs: vec!["y".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        reduced.outputs = vec!["z".into()];
        let params = affine_relu_net().1;
        assert!(!CompiledNet::compile(&reduced, &params).unwrap().batch_invariant());
        // but keepdims on a non-batch axis stays batchable
        reduced.layers.last_mut().unwrap().op = Op::Mean { axis: 1, keepdims: true };
        assert!(CompiledNet::compile(&reduced, &params).unwrap().batch_invariant());
    }

    #[test]
    fn compiled_net_is_send_and_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<CompiledNet>();
    }

    #[test]
    fn execute_observed_sees_every_tensor_once_and_matches_execute() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let x = NdArray::from_slice(&[2, 2], &[1., -1., 3., 4.]);
        let mut seen: Vec<(String, usize)> = Vec::new();
        let got = plan
            .execute_observed(&[x.clone()], &mut |name, a| {
                seen.push((name.to_string(), a.size()));
            })
            .unwrap();
        // input + both layer outputs, in execution order
        assert_eq!(
            seen.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["x", "h", "y"]
        );
        assert!(seen.iter().all(|&(_, sz)| sz == 4));
        let want = plan.execute_positional(&[x]).unwrap();
        assert_eq!(got[0].data(), want[0].data());
    }
}
