//! Compiled execution plans — the deployment hot path (ROADMAP: serve
//! heavy traffic as fast as the hardware allows).
//!
//! [`crate::nnp::interpreter::run`] is correct but pays a per-call tax
//! no server can afford. [`CompiledNet`] moves everything to load time
//! through an explicit four-phase pipeline (see [`crate::nnp::passes`]
//! for the optimizer half):
//!
//! 1. **optimize** — graph-level passes over the NNP IR at the chosen
//!    [`OptLevel`]: no-op elision, dead-op elimination, BatchNorm
//!    folding, constant folding. O0 skips this phase entirely, which
//!    is what the interpreter and the training/gradcheck paths use.
//! 2. **lower** — tensor names become integer slots, parameters are
//!    bound (missing ones fail at load), and every layer becomes a
//!    [`Step`] with an explicit [`StepKernel`]: dense ops lower
//!    directly onto [`crate::tensor::kernels`] entry points, everything
//!    else onto the registry dispatch. At O1+ the ReLU-fusion pass
//!    then rewrites Affine/Conv → ReLU chains into single fused steps.
//! 3. **schedule** — last-use liveness is precomputed, so intermediate
//!    buffers are released eagerly at their planned death step.
//! 4. **allocate** — a liveness-based static memory plan (greedy
//!    interval coloring over the slots' live ranges at the declared
//!    input shape) assigns every slot an arena offset and reports the
//!    exact peak arena bytes ([`CompiledNet::peak_arena_bytes`]).
//!    Slot sizes come from a one-off dry run, so the plan is computed
//!    lazily on first inspection — hot compile paths (interpreter
//!    one-shots, serve loads) never pay it.
//!
//! The executor itself is a dumb step loop: no pattern matching, no
//! name resolution, no revalidation per request — each step already
//! knows its kernel. Fused steps call the very kernels the training
//! tape records (then the same elementwise `max(0)`), so O1 plans are
//! bit-identical to the interpreter; O2 folds are exact up to float
//! re-association (≤ ~1e-4 relative).
//!
//! [`CompiledNet::execute`] is `&self` and `CompiledNet` is
//! `Send + Sync`: one plan serves any number of threads concurrently
//! (see `serve::Server`).

use std::collections::{HashMap, HashSet};

use crate::tensor::ops::Conv2dGeom;
use crate::tensor::{kernels, ops, NdArray};

use super::ir::{self, NetworkDef, Op, TensorDef};
use super::passes::{self, MemoryPlan, OptLevel, PassStat, SlotInterval};

/// Where one operand of a step comes from. `pub(crate)` so the int8
/// quantizer ([`crate::quant`]) can walk a compiled plan's dataflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Src {
    /// Activation slot in the per-call environment.
    Act(usize),
    /// Parameter index, bound once at compile time.
    Param(usize),
}

/// What a step executes. Decided once at compile time — the executor
/// never pattern-matches ops or shapes per request.
#[derive(Debug, Clone)]
pub(crate) enum StepKernel {
    /// Registry dispatch through [`Op::execute`] (the long tail).
    Registry(Op),
    /// `kernels::affine_forward`, optionally with a fused ReLU.
    Affine { relu: bool },
    /// `kernels::conv2d_forward`, optionally with a fused ReLU.
    Conv2d { geom: Conv2dGeom, relu: bool },
    /// Standalone elementwise rectification.
    Relu,
    /// Inference no-op (Identity / Dropout / StopGradient): O(1) COW
    /// clone of the input.
    Copy,
}

impl StepKernel {
    /// Display name for op histograms and plan inspection.
    pub(crate) fn display(&self) -> &'static str {
        match self {
            StepKernel::Registry(op) => op.name(),
            StepKernel::Affine { relu: false } => "Affine",
            StepKernel::Affine { relu: true } => "Affine+ReLU",
            StepKernel::Conv2d { relu: false, .. } => "Convolution",
            StepKernel::Conv2d { relu: true, .. } => "Convolution+ReLU",
            StepKernel::Relu => "ReLU",
            StepKernel::Copy => "Copy",
        }
    }
}

/// One executable step of the plan.
#[derive(Debug, Clone)]
pub(crate) struct Step {
    /// Originating layer name, kept for error reporting (a fused step
    /// keeps the dense layer's name).
    pub(crate) name: String,
    pub(crate) kernel: StepKernel,
    /// Activations first, then parameters — the order [`Op::apply`]
    /// defines.
    pub(crate) args: Vec<Src>,
    /// Output activation slot.
    pub(crate) out: usize,
    /// Activation slots whose planned death is this step; released
    /// eagerly after it runs.
    pub(crate) free_after: Vec<usize>,
}

/// A network compiled against a fixed parameter set, ready for
/// repeated, concurrent inference. Build with [`CompiledNet::compile`]
/// (full O2 pipeline) or [`CompiledNet::compile_with`] (explicit
/// [`OptLevel`]); run with [`CompiledNet::execute`] (named inputs) or
/// [`CompiledNet::execute_positional`] (declared input order, the
/// serving hot path).
pub struct CompiledNet {
    name: String,
    /// Declared inputs; input `i` lives in slot `i`.
    inputs: Vec<TensorDef>,
    output_names: Vec<String>,
    output_slots: Vec<usize>,
    steps: Vec<Step>,
    n_slots: usize,
    /// Tensor name of each slot (inputs first, then each layer's
    /// output in lowering order). Calibration and quantization key
    /// activation statistics by these names; slots elided or fused
    /// away keep their name but are never materialized or observed.
    slot_names: Vec<String>,
    /// Parameters bound at compile time (COW handles — O(1) to hold,
    /// never copied per request).
    params: Vec<NdArray>,
    /// Registry name of each bound parameter (quantizer lookups).
    param_names: Vec<String>,
    opt: OptLevel,
    pass_stats: Vec<PassStat>,
    /// Static memory plan, computed lazily on first inspection
    /// (requires a dry run at the declared shape; hot compile paths
    /// never pay it). `Some(None)` caches an inference failure.
    memory: std::sync::OnceLock<Option<MemoryPlan>>,
}

/// Output of the lowering phase, threaded through schedule/allocate.
struct Lowered {
    steps: Vec<Step>,
    n_slots: usize,
    slot_names: Vec<String>,
    output_slots: Vec<usize>,
    params: Vec<NdArray>,
    param_names: Vec<String>,
}

impl CompiledNet {
    /// Compile `net` against `params` through the full (O2) pipeline —
    /// the serving default. Validates structure, arity and parameter
    /// availability so that a successfully compiled plan can only fail
    /// at run time on input-shape mismatches or kernel-level shape
    /// errors.
    pub fn compile(
        net: &NetworkDef,
        params: &HashMap<String, NdArray>,
    ) -> Result<CompiledNet, String> {
        Self::compile_with(net, params, OptLevel::default())
    }

    /// Compile at an explicit optimization level. `O0` is lower +
    /// schedule + allocate only — the graph executes exactly as
    /// written, which is what [`crate::nnp::interpreter::run`] and the
    /// training-side paths rely on.
    pub fn compile_with(
        net: &NetworkDef,
        params: &HashMap<String, NdArray>,
        opt: OptLevel,
    ) -> Result<CompiledNet, String> {
        // ---- phase 1: optimize (graph-level passes; O0 skips)
        let (optimized, mut pass_stats) = if opt == OptLevel::O0 {
            net.validate()?;
            (None, Vec::new())
        } else {
            let (onet, oparams, stats) = passes::optimize(net, params, opt)?;
            (Some((onet, oparams)), stats)
        };
        let (net_ref, params_ref): (&NetworkDef, &HashMap<String, NdArray>) = match &optimized {
            Some((n, p)) => (n, p),
            None => (net, params),
        };

        // ---- phase 2: lower (names -> slots, ops -> kernels)
        let mut low = lower(net_ref, params_ref)?;
        if opt >= OptLevel::O1 {
            let rewrites = passes::fuse_relu(&mut low.steps, &low.output_slots);
            pass_stats.push(PassStat { pass: "fuse-relu", rewrites });
        }

        // ---- phase 3: schedule (liveness -> eager frees)
        schedule(&mut low.steps, low.n_slots, &low.output_slots);

        // ---- phase 4: allocate — deferred to the first
        // memory_plan()/peak_arena_bytes() call (needs a dry run)

        let plan = CompiledNet {
            name: net_ref.name.clone(),
            inputs: net_ref.inputs.clone(),
            output_names: net_ref.outputs.clone(),
            output_slots: low.output_slots,
            steps: low.steps,
            n_slots: low.n_slots,
            slot_names: low.slot_names,
            params: low.params,
            param_names: low.param_names,
            opt,
            pass_stats,
            memory: std::sync::OnceLock::new(),
        };

        // ---- translation validation (debug builds): an independent
        // verifier re-derives liveness from the scheduled steps and
        // cross-checks the step order and memory plan. A failure here
        // is a compiler bug, never a user error — release builds skip
        // the check (and its dry run) entirely.
        #[cfg(debug_assertions)]
        {
            let report = super::verify::verify_plan(&plan);
            if report.has_errors() {
                return Err(format!(
                    "translation validation failed (compiler bug, not a model error):\n{}",
                    report.render_human()
                ));
            }
        }

        Ok(plan)
    }

    /// Test-only: mutate the scheduled steps in place (invalidates the
    /// cached memory plan). The mutation suite uses this to prove the
    /// verifier rejects corrupted plans.
    #[cfg(test)]
    pub(crate) fn mutate_steps(&mut self, f: impl FnOnce(&mut Vec<Step>)) {
        f(&mut self.steps);
        self.memory = std::sync::OnceLock::new();
    }

    /// Test-only: replace the cached memory plan wholesale (seeded
    /// arena-overlap / out-of-bounds mutants).
    #[cfg(test)]
    pub(crate) fn inject_memory_plan(&mut self, m: MemoryPlan) {
        self.memory = std::sync::OnceLock::new();
        let _ = self.memory.set(Some(m));
    }

    // ------------------------------------------------ quantizer access

    /// The compiled steps, in execution order.
    pub(crate) fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// A bound parameter by compile-time index.
    pub(crate) fn param(&self, i: usize) -> &NdArray {
        &self.params[i]
    }

    /// The registry name of a bound parameter.
    pub(crate) fn param_name(&self, i: usize) -> &str {
        &self.param_names[i]
    }

    /// The tensor name living in a slot.
    pub(crate) fn slot_name(&self, s: usize) -> &str {
        &self.slot_names[s]
    }

    /// Number of activation slots a call environment needs.
    pub(crate) fn n_slots(&self) -> usize {
        self.n_slots
    }

    /// Slots holding the declared outputs, in output order.
    pub(crate) fn output_slots(&self) -> &[usize] {
        &self.output_slots
    }

    // ------------------------------------------------- plan inspection

    /// Network name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Declared inputs, in positional order.
    pub fn inputs(&self) -> &[TensorDef] {
        &self.inputs
    }

    /// Declared output names, in order.
    pub fn outputs(&self) -> &[String] {
        &self.output_names
    }

    /// Number of executable steps in the plan (≤ source layers once
    /// the optimizer has run).
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// The optimization level this plan was compiled at.
    pub fn opt_level(&self) -> OptLevel {
        self.opt
    }

    /// Per-pass rewrite counts from the compile pipeline.
    pub fn pass_stats(&self) -> &[PassStat] {
        &self.pass_stats
    }

    /// Step-kernel histogram (`name -> count`), name-sorted — the
    /// `nnl optimize` before/after readout.
    pub fn op_histogram(&self) -> Vec<(String, usize)> {
        let mut counts: std::collections::BTreeMap<&'static str, usize> =
            std::collections::BTreeMap::new();
        for st in &self.steps {
            *counts.entry(st.kernel.display()).or_insert(0) += 1;
        }
        counts.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
    }

    /// The static memory plan, if shape inference succeeds at the
    /// declared input shape. Computed (and cached) on first call.
    pub fn memory_plan(&self) -> Option<&MemoryPlan> {
        self.memory
            .get_or_init(|| {
                allocate(&self.steps, &self.params, &self.inputs, self.n_slots, &self.output_slots)
            })
            .as_ref()
    }

    /// Exact arena high-water mark of one request's *intermediates* at
    /// the declared input shape, per the static memory plan (network
    /// inputs are caller-held and never arena-backed).
    pub fn peak_arena_bytes(&self) -> Option<usize> {
        self.memory_plan().map(|m| m.peak_bytes)
    }

    /// Validate a positional input set against the declared signature
    /// (rank must match; dims past the batch axis must agree; axis 0 is
    /// free). Returns the batch-row count of the first input (1 for
    /// rank-0 / input-less nets).
    pub fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        if inputs.len() != self.inputs.len() {
            return Err(format!(
                "network '{}' expects {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            ));
        }
        for (t, a) in self.inputs.iter().zip(inputs) {
            if a.dims().len() != t.dims.len() || a.dims().get(1..) != t.dims.get(1..) {
                return Err(format!(
                    "input '{}' shape {:?} incompatible with declared {:?} (batch axis free)",
                    t.name,
                    a.dims(),
                    t.dims
                ));
            }
        }
        Ok(inputs.first().and_then(|a| a.dims().first().copied()).unwrap_or(1))
    }

    /// Run the plan on named inputs. Thin wrapper over
    /// [`CompiledNet::execute_positional`].
    pub fn execute(&self, inputs: &HashMap<String, NdArray>) -> Result<Vec<NdArray>, String> {
        let mut positional = Vec::with_capacity(self.inputs.len());
        for t in &self.inputs {
            positional.push(
                inputs
                    .get(&t.name)
                    .ok_or_else(|| format!("missing input '{}'", t.name))?
                    .clone(),
            );
        }
        self.execute_positional(&positional)
    }

    /// Run the plan on inputs given in declared order. `&self`: any
    /// number of threads may execute one plan concurrently; each call
    /// owns its buffer environment.
    ///
    /// This is a dumb loop over precompiled steps: each step dispatches
    /// straight to its [`StepKernel`] — the same
    /// [`crate::tensor::kernels`] entry points the training tape
    /// records — and slots freed at their planned death step are
    /// recycled into this thread's scratch arena, so a long-lived
    /// serving thread reaches a steady state with no heap allocation
    /// per request for conv columns or plan intermediates.
    pub fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        self.execute_inner(inputs, None)
    }

    /// [`CompiledNet::execute_positional`] plus a hook: `observe` is
    /// called with `(tensor_name, value)` for every declared input and
    /// every step output the plan actually materializes, in execution
    /// order. Tensors the optimizer elided, folded, or fused away are
    /// never observed — so int8 calibration
    /// ([`crate::quant::calibrate`]) records ranges for exactly the
    /// tensors the optimized plan produces.
    pub fn execute_observed(
        &self,
        inputs: &[NdArray],
        observe: &mut dyn FnMut(&str, &NdArray),
    ) -> Result<Vec<NdArray>, String> {
        self.execute_inner(inputs, Some(observe))
    }

    fn execute_inner(
        &self,
        inputs: &[NdArray],
        mut observe: Option<&mut dyn FnMut(&str, &NdArray)>,
    ) -> Result<Vec<NdArray>, String> {
        self.check_inputs(inputs)?;
        let mut env: Vec<Option<NdArray>> = vec![None; self.n_slots];
        for (i, a) in inputs.iter().enumerate() {
            if let Some(obs) = observe.as_deref_mut() {
                obs(&self.slot_names[i], a);
            }
            env[i] = Some(a.clone());
        }
        for st in &self.steps {
            let mut xs: Vec<&NdArray> = Vec::with_capacity(st.args.len());
            for a in &st.args {
                match a {
                    Src::Act(s) => match env[*s].as_ref() {
                        Some(v) => xs.push(v),
                        None => {
                            return Err(format!(
                                "layer '{}': [NNL-P002] slot '{}' read after its planned free (plan liveness invariant broken)",
                                st.name, self.slot_names[*s]
                            ))
                        }
                    },
                    Src::Param(i) => xs.push(&self.params[*i]),
                }
            }
            let y =
                execute_kernel(&st.kernel, &xs).map_err(|e| format!("layer '{}': {e}", st.name))?;
            drop(xs);
            if let Some(obs) = observe.as_deref_mut() {
                obs(&self.slot_names[st.out], &y);
            }
            env[st.out] = Some(y);
            for &s in &st.free_after {
                if let Some(dead) = env[s].take() {
                    kernels::recycle(dead);
                }
            }
        }
        self.output_slots
            .iter()
            .map(|&s| {
                env[s]
                    .as_ref()
                    .cloned()
                    .ok_or_else(|| {
                        format!(
                            "[NNL-P003] output slot '{}' empty (plan liveness invariant broken)",
                            self.slot_names[s]
                        )
                    })
            })
            .collect()
    }

    /// Conservative static check that rows are independent under this
    /// plan: concatenating several requests along axis 0, executing
    /// once, and splitting the outputs back is equivalent to executing
    /// each request alone. The batching server falls back to
    /// per-request execution when this is `false`.
    ///
    /// Soundness without shape inference: last-axis ops (Softmax,
    /// LayerNorm, …) are row-independent only while every activation
    /// keeps rank ≥ 2 (axis 0 stays a pure batch axis). So all inputs
    /// must declare rank ≥ 2 and every rank-reducing op is excluded:
    /// global reductions and `BroadcastTo` outright, axis reductions
    /// unless `keepdims` on a non-batch axis, `Reshape` unless it keeps
    /// the batch axis and rank ≥ 2. The lowered kernels (dense, ReLU,
    /// Copy) are row-independent by construction.
    pub fn batch_invariant(&self) -> bool {
        if self.inputs.is_empty() || self.inputs.iter().any(|t| t.dims.len() < 2) {
            return false;
        }
        self.steps.iter().all(|st| match &st.kernel {
            StepKernel::Registry(op) => match op {
                Op::SumAll | Op::MeanAll | Op::BroadcastTo { .. } => false,
                Op::Sum { axis, keepdims } | Op::Mean { axis, keepdims } => {
                    *axis != 0 && *keepdims
                }
                Op::Concat { axis } | Op::Slice { axis, .. } => *axis != 0,
                Op::Transpose { axes } => axes.first() == Some(&0),
                Op::Reshape { dims } => dims.len() >= 2 && dims[0] == 0,
                _ => true,
            },
            StepKernel::Affine { .. }
            | StepKernel::Conv2d { .. }
            | StepKernel::Relu
            | StepKernel::Copy => true,
        })
    }
}

// --------------------------------------------------------------- phases

/// Lowering: resolve names to slots, bind parameters, pick a
/// [`StepKernel`] per layer.
fn lower(net: &NetworkDef, params: &HashMap<String, NdArray>) -> Result<Lowered, String> {
    let mut slot_of: HashMap<String, usize> = HashMap::new();
    let mut slot_names: Vec<String> = Vec::new();
    let mut n_slots = 0usize;
    for t in &net.inputs {
        slot_of.insert(t.name.clone(), n_slots);
        slot_names.push(t.name.clone());
        n_slots += 1;
    }

    let mut bound: Vec<NdArray> = Vec::new();
    let mut bound_names: Vec<String> = Vec::new();
    let mut param_idx: HashMap<String, usize> = HashMap::new();
    let mut steps: Vec<Step> = Vec::with_capacity(net.layers.len());
    for l in &net.layers {
        let mut args = Vec::with_capacity(l.inputs.len() + l.params.len());
        for tname in &l.inputs {
            let s = *slot_of
                .get(tname.as_str())
                .ok_or_else(|| format!("layer '{}' reads undefined tensor '{tname}'", l.name))?;
            args.push(Src::Act(s));
        }
        for pname in &l.params {
            let idx = match param_idx.get(pname.as_str()) {
                Some(&i) => i,
                None => {
                    let a = params
                        .get(pname.as_str())
                        .ok_or_else(|| format!("missing parameter '{pname}'"))?;
                    bound.push(a.clone());
                    bound_names.push(pname.clone());
                    param_idx.insert(pname.clone(), bound.len() - 1);
                    bound.len() - 1
                }
            };
            args.push(Src::Param(idx));
        }
        let kernel = select_kernel(&l.op, &args, &bound);
        let out = n_slots;
        n_slots += 1;
        slot_of.insert(l.outputs[0].clone(), out);
        slot_names.push(l.outputs[0].clone());
        steps.push(Step {
            name: l.name.clone(),
            kernel,
            args,
            out,
            free_after: Vec::new(),
        });
    }

    let output_slots = net
        .outputs
        .iter()
        .map(|o| {
            slot_of
                .get(o.as_str())
                .copied()
                .ok_or_else(|| format!("network output '{o}' never produced"))
        })
        .collect::<Result<Vec<usize>, String>>()?;

    Ok(Lowered {
        steps,
        n_slots,
        slot_names,
        output_slots,
        params: bound,
        param_names: bound_names,
    })
}

/// Pick the executable form of one layer. Dense ops lower onto the
/// tiled kernels only when their weight (and bias) are compile-time
/// parameters with coherent shapes; anything else takes the registry
/// dispatch, whose `Op::apply` validation produces clean errors.
fn select_kernel(op: &Op, args: &[Src], bound: &[NdArray]) -> StepKernel {
    let pdims = |a: Option<&Src>| match a {
        Some(Src::Param(i)) => Some(bound[*i].dims()),
        _ => None,
    };
    match op {
        Op::Affine => {
            if let Some(wd) = pdims(args.get(1)) {
                if (2..=3).contains(&args.len()) && wd.len() == 2 {
                    let bias_ok = match args.get(2) {
                        None => true,
                        Some(Src::Param(i)) => bound[*i].size() == wd[1],
                        Some(Src::Act(_)) => false,
                    };
                    if bias_ok {
                        return StepKernel::Affine { relu: false };
                    }
                }
            }
            StepKernel::Registry(op.clone())
        }
        Op::Convolution { stride, pad, dilation } => {
            if let Some(wd) = pdims(args.get(1)) {
                if (2..=3).contains(&args.len()) && wd.len() == 4 && wd[2] > 0 && wd[3] > 0 {
                    let bias_ok = match args.get(2) {
                        None => true,
                        Some(Src::Param(i)) => bound[*i].size() == wd[0],
                        Some(Src::Act(_)) => false,
                    };
                    if bias_ok {
                        return StepKernel::Conv2d {
                            geom: Conv2dGeom {
                                kernel: (wd[2], wd[3]),
                                stride: *stride,
                                pad: *pad,
                                dilation: *dilation,
                            },
                            relu: false,
                        };
                    }
                }
            }
            StepKernel::Registry(op.clone())
        }
        Op::ReLU => StepKernel::Relu,
        Op::Identity | Op::Dropout { .. } | Op::StopGradient => StepKernel::Copy,
        other => StepKernel::Registry(other.clone()),
    }
}

/// Scheduling: find each slot's last use; a slot that is not a network
/// output dies right after that step (or at its producer, if written
/// but never read).
fn schedule(steps: &mut [Step], n_slots: usize, output_slots: &[usize]) {
    let mut last_read: Vec<Option<usize>> = vec![None; n_slots];
    let mut producer: Vec<Option<usize>> = vec![None; n_slots];
    for (i, st) in steps.iter().enumerate() {
        for a in &st.args {
            if let Src::Act(s) = a {
                last_read[*s] = Some(i);
            }
        }
        producer[st.out] = Some(i);
    }
    for st in steps.iter_mut() {
        st.free_after.clear();
    }
    let keep: HashSet<usize> = output_slots.iter().copied().collect();
    for s in 0..n_slots {
        if keep.contains(&s) {
            continue;
        }
        match (last_read[s], producer[s]) {
            (Some(i), _) => steps[i].free_after.push(s),
            (None, Some(i)) => steps[i].free_after.push(s),
            // unread network input (caller-held) or a slot the
            // optimizer fused away (never materialized)
            (None, None) => {}
        }
    }
}

/// Allocation: infer every materialized slot's size by a one-off dry
/// run at the declared input shape, then color live intervals into
/// arena offsets. Network inputs are caller-held COW handles that
/// never draw from the arena, so only step-produced slots get
/// intervals — `peak_bytes` is the intermediates' high-water mark.
/// Returns `None` when inference fails (e.g. geometry errors only
/// reachable at other batch sizes, or inputs too large to instantiate
/// at compile time) — execution does not depend on it.
fn allocate(
    steps: &[Step],
    params: &[NdArray],
    inputs: &[TensorDef],
    n_slots: usize,
    output_slots: &[usize],
) -> Option<MemoryPlan> {
    let sizes = dry_run_sizes(steps, params, inputs, n_slots).ok()?;
    let mut start: Vec<Option<usize>> = vec![None; n_slots];
    let mut end: Vec<usize> = vec![0; n_slots];
    for (i, st) in steps.iter().enumerate() {
        for a in &st.args {
            if let Src::Act(s) = a {
                end[*s] = end[*s].max(i);
            }
        }
        start[st.out] = Some(i);
        end[st.out] = end[st.out].max(i);
    }
    for &o in output_slots {
        if start[o].is_some() {
            end[o] = steps.len();
        }
    }
    let intervals: Vec<SlotInterval> = (0..n_slots)
        .filter_map(|s| {
            start[s].map(|st0| SlotInterval {
                slot: s,
                start: st0,
                end: end[s],
                bytes: sizes[s] * std::mem::size_of::<f32>(),
            })
        })
        .collect();
    Some(passes::plan_memory(&intervals, n_slots))
}

/// Execute the plan once on zeros at the declared shapes, recording
/// each slot's element count. Compile-time only.
fn dry_run_sizes(
    steps: &[Step],
    params: &[NdArray],
    inputs: &[TensorDef],
    n_slots: usize,
) -> Result<Vec<usize>, String> {
    // refuse to instantiate absurd declared shapes at load time
    const LIMIT: usize = 1 << 24;
    let mut sizes = vec![0usize; n_slots];
    let mut env: Vec<Option<NdArray>> = vec![None; n_slots];
    for (i, t) in inputs.iter().enumerate() {
        let elems = t
            .dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .filter(|&e| e <= LIMIT)
            .ok_or("declared input too large for compile-time shape inference")?;
        sizes[i] = elems;
        env[i] = Some(NdArray::zeros(&t.dims));
    }
    for st in steps {
        let mut xs: Vec<&NdArray> = Vec::with_capacity(st.args.len());
        for a in &st.args {
            match a {
                Src::Act(s) => xs.push(
                    env[*s].as_ref().ok_or("dry run read an unmaterialized slot")?,
                ),
                Src::Param(i) => xs.push(&params[*i]),
            }
        }
        let y = execute_kernel(&st.kernel, &xs)?;
        drop(xs);
        if y.size() > LIMIT {
            return Err("intermediate too large for compile-time shape inference".into());
        }
        sizes[st.out] = y.size();
        env[st.out] = Some(y);
        for &s in &st.free_after {
            env[s] = None;
        }
    }
    Ok(sizes)
}

/// Execute one step kernel. The dense arms call the very kernels the
/// tape's `F::*` closures call (bit-identical outputs) with
/// input-dependent shape guards kept as clean errors; `Registry` is
/// the shared [`Op::execute`] dispatch.
pub(crate) fn execute_kernel(k: &StepKernel, xs: &[&NdArray]) -> Result<NdArray, String> {
    match k {
        StepKernel::Affine { relu } => {
            if xs[0].rank() < 1 {
                return Err("Affine: input must have a batch axis".into());
            }
            let feat: usize = xs[0].dims()[1..].iter().product();
            if feat != xs[1].dims()[0] {
                return Err(format!(
                    "Affine: input features {feat} do not match weight rows {}",
                    xs[1].dims()[0]
                ));
            }
            let mut y = kernels::affine_forward(xs[0], xs[1], xs.get(2).copied());
            if *relu {
                relu_inplace(&mut y);
            }
            Ok(y)
        }
        StepKernel::Conv2d { geom, relu } => {
            ir::check_conv_geometry(
                xs[0].dims(),
                xs[1].dims(),
                geom.stride,
                geom.pad,
                geom.dilation,
            )?;
            let mut y = kernels::conv2d_forward(xs[0], xs[1], xs.get(2).copied(), geom);
            if *relu {
                relu_inplace(&mut y);
            }
            Ok(y)
        }
        StepKernel::Relu => Ok(ops::map(xs[0], |v| v.max(0.0))),
        StepKernel::Copy => Ok(xs[0].clone()),
        StepKernel::Registry(op) => op.execute(xs),
    }
}

/// Elementwise `max(0)` on a freshly produced (uniquely owned) array,
/// via the SIMD-dispatched kernel. Still bit-identical to the
/// `f32::max` map that `F::relu` and the unfused `Relu` step apply:
/// the vector max matches `f32::max` on NaN, and the only other
/// divergent input (`-0.0`) cannot occur in a fresh GEMM/bias output
/// (see [`kernels::relu_slice_inplace`]) — so O1's fused plans remain
/// bit-identical to the O0 interpreter.
fn relu_inplace(y: &mut NdArray) {
    kernels::relu_slice_inplace(y.data_mut());
}

/// The contract a serving plan exposes, whatever executes underneath —
/// the f32 [`CompiledNet`] or the int8 [`crate::quant::QuantizedNet`].
/// Object-safe: [`crate::serve::Server`] hosts an
/// `Arc<dyn InferencePlan>` so one worker pool serves either backend.
pub trait InferencePlan: Send + Sync {
    /// Network name.
    fn name(&self) -> &str;
    /// Declared inputs, in positional order.
    fn inputs(&self) -> &[TensorDef];
    /// Declared output names, in order.
    fn outputs(&self) -> &[String];
    /// Number of executable steps.
    fn n_steps(&self) -> usize;
    /// Validate positional inputs; returns the batch-row count.
    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String>;
    /// Run on inputs given in declared order (`&self`: thread-shared).
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String>;
    /// Whether rows are provably independent (micro-batching safety).
    fn batch_invariant(&self) -> bool;

    /// Peak working-set bytes per execution from the static memory
    /// plan, when one was computed — the serving layer derives
    /// per-model admission limits (bounded queue capacity) from it.
    fn peak_arena_bytes(&self) -> Option<usize> {
        None
    }

    /// Run on named inputs (declared-order resolution).
    fn execute_named(&self, inputs: &HashMap<String, NdArray>) -> Result<Vec<NdArray>, String> {
        let mut positional = Vec::with_capacity(self.inputs().len());
        for t in self.inputs() {
            positional.push(
                inputs
                    .get(&t.name)
                    .ok_or_else(|| format!("missing input '{}'", t.name))?
                    .clone(),
            );
        }
        self.execute_positional(&positional)
    }
}

impl InferencePlan for CompiledNet {
    fn name(&self) -> &str {
        CompiledNet::name(self)
    }

    fn inputs(&self) -> &[TensorDef] {
        CompiledNet::inputs(self)
    }

    fn outputs(&self) -> &[String] {
        CompiledNet::outputs(self)
    }

    fn n_steps(&self) -> usize {
        CompiledNet::n_steps(self)
    }

    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        CompiledNet::check_inputs(self, inputs)
    }

    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        CompiledNet::execute_positional(self, inputs)
    }

    fn batch_invariant(&self) -> bool {
        CompiledNet::batch_invariant(self)
    }

    fn peak_arena_bytes(&self) -> Option<usize> {
        CompiledNet::peak_arena_bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::interpreter;
    use crate::nnp::ir::Layer;

    fn affine_relu_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "n".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut params = HashMap::new();
        params.insert("W".into(), NdArray::from_slice(&[2, 2], &[1., -1., 1., 1.]));
        params.insert("b".into(), NdArray::from_slice(&[2], &[0., -10.]));
        (net, params)
    }

    #[test]
    fn compile_once_execute_many() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        // fused at O2: affine + relu became one step
        assert_eq!(plan.n_steps(), 1);
        // repeated calls, varying batch size, all matching the interpreter
        for bs in [1usize, 3, 8] {
            let x = NdArray::arange(&[bs, 2]);
            let mut inputs = HashMap::new();
            inputs.insert("x".to_string(), x);
            let got = plan.execute(&inputs).unwrap();
            let want = interpreter::run(&net, &inputs, &params).unwrap();
            assert_eq!(got[0].dims(), want[0].dims());
            assert_eq!(got[0].data(), want[0].data());
        }
    }

    #[test]
    fn opt_levels_report_their_pipeline() {
        let (net, params) = affine_relu_net();
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        assert_eq!(p0.n_steps(), 2);
        assert_eq!(p0.opt_level(), OptLevel::O0);
        assert!(p0.pass_stats().is_empty());
        let p2 = CompiledNet::compile(&net, &params).unwrap();
        assert_eq!(p2.opt_level(), OptLevel::O2);
        let fuse = p2.pass_stats().iter().find(|s| s.pass == "fuse-relu").unwrap();
        assert_eq!(fuse.rewrites, 1);
        assert_eq!(
            p2.op_histogram(),
            vec![("Affine+ReLU".to_string(), 1)]
        );
        assert_eq!(
            p0.op_histogram(),
            vec![("Affine".to_string(), 1), ("ReLU".to_string(), 1)]
        );
    }

    #[test]
    fn static_memory_plan_reports_peak_bytes() {
        let (net, params) = affine_relu_net();
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        let p2 = CompiledNet::compile(&net, &params).unwrap();
        let m0 = p0.memory_plan().expect("O0 memory plan");
        let m2 = p2.memory_plan().expect("O2 memory plan");
        assert!(m0.peak_bytes <= m0.naive_bytes);
        assert!(m2.peak_bytes <= m0.peak_bytes, "{} > {}", m2.peak_bytes, m0.peak_bytes);
        // O0 intermediates: h [1,2] and y [1,2] are live together at
        // the ReLU step, so the peak covers both (inputs are
        // caller-held and never counted)
        assert!(m0.peak_bytes >= 2 * 2 * 4);
        assert_eq!(p2.peak_arena_bytes(), Some(m2.peak_bytes));
    }

    #[test]
    fn positional_matches_named() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let x = NdArray::from_slice(&[1, 2], &[3., 4.]);
        let mut named = HashMap::new();
        named.insert("x".to_string(), x.clone());
        assert_eq!(
            plan.execute(&named).unwrap()[0].data(),
            plan.execute_positional(&[x]).unwrap()[0].data()
        );
    }

    #[test]
    fn missing_param_fails_at_compile() {
        let (net, mut params) = affine_relu_net();
        params.remove("b");
        let err = CompiledNet::compile(&net, &params).unwrap_err();
        assert!(err.contains("missing parameter 'b'"), "{err}");
    }

    #[test]
    fn bad_arity_fails_at_compile() {
        let (mut net, params) = affine_relu_net();
        net.layers[0].params.clear();
        let err = CompiledNet::compile(&net, &params).unwrap_err();
        assert!(err.contains("layer 'fc'"), "{err}");
    }

    #[test]
    fn bad_pool_geometry_fails_at_run_with_clean_error() {
        let net = NetworkDef {
            name: "p".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 1, 2, 2] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "pool".into(),
                op: Op::MaxPool { kernel: (7, 7), stride: (1, 1), pad: (0, 0) },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        // the compile-time dry run fails too — that only disables the
        // memory plan, never the compile
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        assert!(plan.memory_plan().is_none());
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::zeros(&[1, 1, 2, 2]));
        let err = plan.execute(&inputs).unwrap_err();
        assert!(err.contains("layer 'pool'"), "{err}");
        assert!(err.contains("kernel"), "{err}");
    }

    #[test]
    fn intermediates_freed_at_last_use() {
        let (net, params) = affine_relu_net();
        let plan = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        // slot 0 = x (dies after fc), slot 1 = h (dies after relu),
        // slot 2 = y (network output, kept)
        assert_eq!(plan.steps[0].free_after, vec![0]);
        assert_eq!(plan.steps[1].free_after, vec![1]);
        assert_eq!(plan.output_slots, vec![2]);
        // fused: h is never materialized, x still dies at the one step
        let fused = CompiledNet::compile(&net, &params).unwrap();
        assert_eq!(fused.steps.len(), 1);
        assert_eq!(fused.steps[0].free_after, vec![0]);
        assert_eq!(fused.steps[0].out, 2);
        assert_eq!(fused.output_slots, vec![2]);
    }

    #[test]
    fn shadowed_tensor_names_are_rejected_at_compile() {
        // duplicate output names used to silently shadow; they now
        // fail validation with a clear error (see NetworkDef::validate)
        let net = NetworkDef {
            name: "s".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["h".into()],
            layers: vec![
                Layer {
                    name: "a".into(),
                    op: Op::MulScalar { val: 2.0 },
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "b".into(),
                    op: Op::AddScalar { val: 1.0 },
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["h".into()],
                },
            ],
        };
        let err = CompiledNet::compile(&net, &HashMap::new()).unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn output_that_is_also_input_survives() {
        // passthrough output: the input slot must never be freed
        let net = NetworkDef {
            name: "pass".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2] }],
            outputs: vec!["x".into(), "y".into()],
            layers: vec![Layer {
                name: "neg".into(),
                op: Op::Neg,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 2], &[1., -2.]));
        let out = plan.execute(&inputs).unwrap();
        assert_eq!(out[0].data(), &[1., -2.]);
        assert_eq!(out[1].data(), &[-1., 2.]);
    }

    #[test]
    fn batch_invariance_classification() {
        let (net, params) = affine_relu_net();
        assert!(CompiledNet::compile(&net, &params).unwrap().batch_invariant());
        let mut reducing = net.clone();
        reducing.layers.push(Layer {
            name: "s".into(),
            op: Op::SumAll,
            inputs: vec!["y".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        reducing.outputs = vec!["z".into()];
        assert!(!CompiledNet::compile(&reducing, &params).unwrap().batch_invariant());
    }

    #[test]
    fn rank1_last_axis_net_is_not_batch_invariant() {
        // on a rank-1 activation the "last axis" IS the batch axis:
        // micro-batching a softmax over it would mix requests
        let net = NetworkDef {
            name: "sm1".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "sm".into(),
                op: Op::Softmax,
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        assert!(!plan.batch_invariant());
        // rank-reducing reductions are excluded too
        let mut reduced = affine_relu_net().0;
        reduced.layers.push(Layer {
            name: "m".into(),
            op: Op::Mean { axis: 1, keepdims: false },
            inputs: vec!["y".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        reduced.outputs = vec!["z".into()];
        let params = affine_relu_net().1;
        assert!(!CompiledNet::compile(&reduced, &params).unwrap().batch_invariant());
        // but keepdims on a non-batch axis stays batchable
        reduced.layers.last_mut().unwrap().op = Op::Mean { axis: 1, keepdims: true };
        assert!(CompiledNet::compile(&reduced, &params).unwrap().batch_invariant());
    }

    #[test]
    fn compiled_net_is_send_and_sync() {
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<CompiledNet>();
    }

    #[test]
    fn execute_observed_sees_only_materialized_tensors() {
        let (net, params) = affine_relu_net();
        let x = NdArray::from_slice(&[2, 2], &[1., -1., 3., 4.]);
        // O0: input + both layer outputs, in execution order
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0).unwrap();
        let mut seen: Vec<String> = Vec::new();
        let got0 = p0
            .execute_observed(&[x.clone()], &mut |name, _| seen.push(name.to_string()))
            .unwrap();
        assert_eq!(seen, vec!["x", "h", "y"]);
        // O2: the fused intermediate 'h' is never materialized
        let p2 = CompiledNet::compile(&net, &params).unwrap();
        let mut seen2: Vec<String> = Vec::new();
        let got2 = p2
            .execute_observed(&[x.clone()], &mut |name, _| seen2.push(name.to_string()))
            .unwrap();
        assert_eq!(seen2, vec!["x", "y"]);
        let want = p2.execute_positional(&[x]).unwrap();
        assert_eq!(got2[0].data(), want[0].data());
        assert_eq!(got0[0].data(), want[0].data());
    }
}
