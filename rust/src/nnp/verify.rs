//! Static verification — the `nnl check` analysis layer.
//!
//! Two independent verifiers live here:
//!
//! 1. **Graph verification** ([`verify_network`]): full shape inference
//!    over a [`NetworkDef`] with *checked* arithmetic (untrusted
//!    artifacts must never panic the checker), plus lints for
//!    unreachable subgraphs, unused parameters, batch-variant ops that
//!    defeat the serving micro-batcher, and quantization-hostile ops
//!    that silently fall back to f32.
//! 2. **Translation validation** ([`verify_plan`]): an independent
//!    re-derivation of liveness from a compiled plan's scheduled steps
//!    that proves the step order and the static memory plan safe —
//!    every slot written before read, never reused while live, no
//!    overlapping live intervals in the arena, all offsets in bounds.
//!    It deliberately shares *no* code with the scheduler/allocator it
//!    checks; it runs after every compile in debug builds and after
//!    each pass under [`super::passes::PassManager::run_verified`].
//!
//! Every diagnostic carries a **stable error code** (asserted by tests
//! and documented in the README):
//!
//! | code | meaning |
//! |------|---------|
//! | `NNL-E001` | arity / output-count mismatch |
//! | `NNL-E002` | read of an undefined tensor |
//! | `NNL-E003` | cyclic / misordered graph (tensor produced later) |
//! | `NNL-E004` | duplicate tensor definition |
//! | `NNL-E005` | declared network output never produced |
//! | `NNL-E006` | shape inference failure (mismatch or overflow) |
//! | `NNL-E007` | referenced parameter missing from the registry |
//! | `NNL-E008` | invalid attribute (zero stride/kernel/dilation) |
//! | `NNL-E009` | plan compilation failed |
//! | `NNL-W101` | layer unreachable from the network outputs |
//! | `NNL-W102` | parameter never referenced by any layer |
//! | `NNL-W103` | batch-variant op defeats the micro-batcher |
//! | `NNL-W104` | op will silently run in f32 under int8 serving |
//! | `NNL-P001` | step order broken (read-before-write / double write) |
//! | `NNL-P002` | slot read after its planned free |
//! | `NNL-P003` | output slot freed or never produced |
//! | `NNL-P004` | arena allocations overlap while both live |
//! | `NNL-P005` | allocation out of arena bounds / peak above naive |
//! | `NNL-P006` | plan metadata disagrees with derived liveness |
//! | `NNL-P007` | invalid free (unwritten slot / double free) |

use std::collections::{HashMap, HashSet};

use crate::tensor::NdArray;
use crate::utils::json::Json;

use super::ir::{NetworkDef, Op};
use super::passes::{MemoryPlan, OptLevel, SlotAlloc};
use super::plan::{CompiledNet, Src};

/// Stable diagnostic codes. Never renumber — external tooling and the
/// serve DEPLOY rejection path match on these strings.
pub mod codes {
    /// Arity or output-count mismatch.
    pub const ARITY: &str = "NNL-E001";
    /// Read of a tensor that is neither a network input nor produced.
    pub const UNDEFINED_TENSOR: &str = "NNL-E002";
    /// Read of a tensor produced by a *later* layer (cycle/misorder).
    pub const CYCLE: &str = "NNL-E003";
    /// Two definitions of the same tensor name.
    pub const DUPLICATE_TENSOR: &str = "NNL-E004";
    /// Declared network output never produced.
    pub const OUTPUT_MISSING: &str = "NNL-E005";
    /// Shape inference failed (mismatch, bad geometry, or overflow).
    pub const SHAPE_MISMATCH: &str = "NNL-E006";
    /// Referenced parameter missing from the registry.
    pub const MISSING_PARAM: &str = "NNL-E007";
    /// Invalid attribute (zero stride / kernel / dilation).
    pub const BAD_ATTR: &str = "NNL-E008";
    /// Plan compilation failed outright.
    pub const COMPILE_FAILED: &str = "NNL-E009";
    /// Layer unreachable from the network outputs.
    pub const UNREACHABLE_LAYER: &str = "NNL-W101";
    /// Parameter in the registry never referenced by any layer.
    pub const UNUSED_PARAM: &str = "NNL-W102";
    /// Batch-variant op: serving falls back to per-request execution.
    pub const BATCH_VARIANT: &str = "NNL-W103";
    /// Op has no int8 kernel and silently runs in f32 when quantized.
    pub const QUANT_HOSTILE: &str = "NNL-W104";
    /// Step order broken: read-before-write, double write, or a write
    /// to a freed slot.
    pub const PLAN_ORDER: &str = "NNL-P001";
    /// Slot read after its planned free.
    pub const PLAN_USE_AFTER_FREE: &str = "NNL-P002";
    /// Output slot freed, out of range, or never produced.
    pub const PLAN_OUTPUT: &str = "NNL-P003";
    /// Two arena allocations overlap in bytes while both live.
    pub const PLAN_ARENA_OVERLAP: &str = "NNL-P004";
    /// Allocation exceeds `peak_bytes`, or peak exceeds naive.
    pub const PLAN_ARENA_BOUNDS: &str = "NNL-P005";
    /// Plan metadata disagrees with independently derived liveness.
    pub const PLAN_MISMATCH: &str = "NNL-P006";
    /// Invalid free: unwritten slot, double free, or out of range.
    pub const PLAN_BAD_FREE: &str = "NNL-P007";
}

/// Diagnostic severity. Errors block deployment; warnings are lints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One finding: a stable code, a severity, optional op/tensor
/// locations, and a human message.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable code from [`codes`] (e.g. `NNL-E006`).
    pub code: &'static str,
    pub severity: Severity,
    /// The layer (graph verify) or step (plan verify) involved.
    pub layer: Option<String>,
    /// The tensor or slot involved.
    pub tensor: Option<String>,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, message: impl Into<String>) -> Self {
        Diagnostic { code, severity: Severity::Error, layer: None, tensor: None, message: message.into() }
    }

    pub fn warning(code: &'static str, message: impl Into<String>) -> Self {
        Diagnostic { code, severity: Severity::Warning, layer: None, tensor: None, message: message.into() }
    }

    pub fn with_layer(mut self, layer: impl Into<String>) -> Self {
        self.layer = Some(layer.into());
        self
    }

    pub fn with_tensor(mut self, tensor: impl Into<String>) -> Self {
        self.tensor = Some(tensor.into());
        self
    }

    /// One-line rendering: `error[NNL-E006] layer 'fc1' tensor 'x': …`.
    pub fn render(&self) -> String {
        let mut out = format!("{}[{}]", self.severity.label(), self.code);
        if let Some(l) = &self.layer {
            out.push_str(&format!(" layer '{l}'"));
        }
        if let Some(t) = &self.tensor {
            out.push_str(&format!(" tensor '{t}'"));
        }
        out.push_str(": ");
        out.push_str(&self.message);
        out
    }

    fn to_json(&self) -> Json {
        fn opt(s: &Option<String>) -> Json {
            match s {
                Some(v) => Json::str(v.clone()),
                None => Json::Null,
            }
        }
        Json::obj(vec![
            ("code", Json::str(self.code)),
            ("severity", Json::str(self.severity.label())),
            ("layer", opt(&self.layer)),
            ("tensor", opt(&self.tensor)),
            ("message", Json::str(self.message.clone())),
        ])
    }
}

/// An ordered collection of diagnostics from one verification run.
#[derive(Debug, Clone, Default)]
pub struct Report {
    diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    pub fn push(&mut self, d: Diagnostic) {
        self.diags.push(d);
    }

    pub fn merge(&mut self, other: Report) {
        self.diags.extend(other.diags);
    }

    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.diags
    }

    pub fn into_diagnostics(self) -> Vec<Diagnostic> {
        self.diags
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// No findings at all — not even warnings.
    pub fn is_clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// Whether any diagnostic carries `code` — how tests pin the
    /// stable-code contract.
    pub fn has_code(&self, code: &str) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// Multi-line human rendering, errors before warnings (insertion
    /// order preserved within each severity).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for sev in [Severity::Error, Severity::Warning] {
            for d in self.diags.iter().filter(|d| d.severity == sev) {
                out.push_str(&d.render());
                out.push('\n');
            }
        }
        out.push_str(&format!(
            "{} error{}, {} warning{}",
            self.error_count(),
            if self.error_count() == 1 { "" } else { "s" },
            self.warning_count(),
            if self.warning_count() == 1 { "" } else { "s" },
        ));
        out
    }

    /// Machine-readable rendering for `nnl check --json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::num(self.error_count() as f64)),
            ("warnings", Json::num(self.warning_count() as f64)),
            ("diagnostics", Json::Arr(self.diags.iter().map(|d| d.to_json()).collect())),
        ])
    }
}

// ---------------------------------------------------------------------------
// Checked shape inference. All arithmetic over declared dims is checked:
// the inputs are untrusted (byte-flipped artifacts reach this code) and
// the checker must *report* overflow, never panic on it.
// ---------------------------------------------------------------------------

fn prod(dims: &[usize]) -> Result<usize, String> {
    dims.iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or_else(|| format!("element count of {dims:?} overflows usize"))
}

fn ck_add(a: usize, b: usize) -> Result<usize, String> {
    a.checked_add(b).ok_or_else(|| format!("{a} + {b} overflows usize"))
}

fn ck_mul(a: usize, b: usize) -> Result<usize, String> {
    a.checked_mul(b).ok_or_else(|| format!("{a} * {b} overflows usize"))
}

/// Output extent of one conv/pool axis, fully checked.
fn conv_out(h: usize, k: usize, stride: usize, pad: usize, dilation: usize) -> Result<usize, String> {
    if k == 0 || stride == 0 || dilation == 0 {
        return Err("zero kernel, stride or dilation".into());
    }
    let eff = ck_add(ck_mul(dilation, k - 1)?, 1)?;
    let padded = ck_add(h, ck_mul(2, pad)?)?;
    let span = padded
        .checked_sub(eff)
        .ok_or_else(|| format!("kernel extent {eff} larger than padded input {padded}"))?;
    Ok(span / stride + 1)
}

/// NumPy-style right-aligned broadcast of two shapes.
fn broadcast2(a: &[usize], b: &[usize]) -> Result<Vec<usize>, String> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db || db == 1 {
            da
        } else if da == 1 {
            db
        } else {
            return Err(format!("shapes {a:?} and {b:?} are not broadcastable"));
        };
    }
    Ok(out)
}

fn want_rank(name: &str, x: &[usize], rank: usize) -> Result<(), String> {
    if x.len() != rank {
        return Err(format!("{name} expects rank-{rank} input, got {x:?}"));
    }
    Ok(())
}

/// Infer one op's output shape. `xs` holds the activation shapes
/// followed by the parameter shapes, in [`Op::apply`] order — exactly
/// the order [`verify_network`] assembles. Arity is the caller's job;
/// out-of-range accesses here are still guarded defensively.
pub fn infer_op_shape(op: &Op, xs: &[Vec<usize>]) -> Result<Vec<usize>, String> {
    let x = xs.first().ok_or("op has no inputs")?;
    match op {
        Op::Affine => {
            if x.is_empty() {
                return Err("Affine: input must have a batch axis".into());
            }
            let w = xs.get(1).ok_or("Affine: missing weight")?;
            want_rank("Affine weight", w, 2)?;
            let feat = prod(&x[1..])?;
            if feat != w[0] {
                return Err(format!("Affine: input features {feat} do not match weight rows {}", w[0]));
            }
            if let Some(b) = xs.get(2) {
                if prod(b)? != w[1] {
                    return Err(format!("Affine: bias {b:?} does not match {} output features", w[1]));
                }
            }
            Ok(vec![x[0], w[1]])
        }
        Op::Convolution { stride, pad, dilation } => {
            want_rank("Convolution", x, 4)?;
            let w = xs.get(1).ok_or("Convolution: missing weight")?;
            want_rank("Convolution weight", w, 4)?;
            if w[1] != x[1] {
                return Err(format!(
                    "Convolution: weight expects {} input channels, input has {}",
                    w[1], x[1]
                ));
            }
            if let Some(b) = xs.get(2) {
                if prod(b)? != w[0] {
                    return Err(format!("Convolution: bias {b:?} does not match {} output channels", w[0]));
                }
            }
            let oh = conv_out(x[2], w[2], stride.0, pad.0, dilation.0)?;
            let ow = conv_out(x[3], w[3], stride.1, pad.1, dilation.1)?;
            Ok(vec![x[0], w[0], oh, ow])
        }
        Op::Deconvolution { stride, pad } => {
            want_rank("Deconvolution", x, 4)?;
            let w = xs.get(1).ok_or("Deconvolution: missing weight")?;
            want_rank("Deconvolution weight", w, 4)?;
            if w[0] != x[1] {
                return Err(format!(
                    "Deconvolution: weight expects {} input channels, input has {}",
                    w[0], x[1]
                ));
            }
            if stride.0 == 0 || stride.1 == 0 {
                return Err("Deconvolution: zero stride".into());
            }
            if let Some(b) = xs.get(2) {
                if prod(b)? != w[1] {
                    return Err(format!("Deconvolution: bias {b:?} does not match {} output channels", w[1]));
                }
            }
            let deconv_out = |h: usize, k: usize, s: usize, p: usize| -> Result<usize, String> {
                if h == 0 {
                    return Err("Deconvolution: zero-sized spatial input".into());
                }
                let grown = ck_add(ck_mul(h - 1, s)?, k)?;
                grown
                    .checked_sub(ck_mul(2, p)?)
                    .filter(|&o| o > 0)
                    .ok_or_else(|| format!("Deconvolution: padding {p} swallows the {grown}-wide output"))
            };
            let oh = deconv_out(x[2], w[2], stride.0, pad.0)?;
            let ow = deconv_out(x[3], w[3], stride.1, pad.1)?;
            Ok(vec![x[0], w[1], oh, ow])
        }
        Op::MaxPool { kernel, stride, pad } | Op::AvgPool { kernel, stride, pad, .. } => {
            want_rank(op.name(), x, 4)?;
            let oh = conv_out(x[2], kernel.0, stride.0, pad.0, 1)?;
            let ow = conv_out(x[3], kernel.1, stride.1, pad.1, 1)?;
            Ok(vec![x[0], x[1], oh, ow])
        }
        Op::GlobalAvgPool => {
            want_rank("GlobalAveragePooling", x, 4)?;
            Ok(vec![x[0], x[1]])
        }
        Op::BatchNorm { .. } => {
            if x.len() < 2 {
                return Err(format!("BatchNormalization expects rank >= 2, got {x:?}"));
            }
            for (i, name) in ["beta", "gamma", "mean", "var"].iter().enumerate() {
                let p = xs.get(1 + i).ok_or_else(|| format!("BatchNormalization: missing {name}"))?;
                if prod(p)? != x[1] {
                    return Err(format!(
                        "BatchNormalization: {name} {p:?} does not match {} channels",
                        x[1]
                    ));
                }
            }
            Ok(x.clone())
        }
        Op::LayerNorm { .. } => {
            for (i, name) in ["beta", "gamma"].iter().enumerate() {
                let p = xs.get(1 + i).ok_or_else(|| format!("LayerNormalization: missing {name}"))?;
                if broadcast2(x, p)? != *x {
                    return Err(format!(
                        "LayerNormalization: {name} {p:?} does not broadcast into input {x:?}"
                    ));
                }
            }
            Ok(x.clone())
        }
        Op::Add2 | Op::Sub2 | Op::Mul2 | Op::Div2 | Op::SquaredError | Op::SigmoidCrossEntropy => {
            let y = xs.get(1).ok_or_else(|| format!("{}: missing second input", op.name()))?;
            broadcast2(x, y)
        }
        Op::Softmax | Op::LogSoftmax => {
            if x.is_empty() {
                return Err(format!("{} expects rank >= 1, got a scalar", op.name()));
            }
            Ok(x.clone())
        }
        Op::SoftmaxCrossEntropy => {
            if x.len() < 2 {
                return Err(format!("SoftmaxCrossEntropy expects rank >= 2 logits, got {x:?}"));
            }
            let t = xs.get(1).ok_or("SoftmaxCrossEntropy: missing labels")?;
            if prod(t)? != x[0] {
                return Err(format!(
                    "SoftmaxCrossEntropy: {} labels do not match batch {}",
                    prod(t)?,
                    x[0]
                ));
            }
            Ok(vec![x[0], 1])
        }
        Op::SumAll | Op::MeanAll => Ok(vec![]),
        Op::Sum { axis, keepdims } | Op::Mean { axis, keepdims } => {
            if *axis >= x.len() {
                return Err(format!("{}: axis {axis} out of range for {x:?}", op.name()));
            }
            let mut out = x.clone();
            if *keepdims {
                out[*axis] = 1;
            } else {
                out.remove(*axis);
            }
            Ok(out)
        }
        Op::Reshape { dims } => {
            let total = prod(x)?;
            let mut known = 1usize;
            let mut infer_at: Option<usize> = None;
            let mut out = Vec::with_capacity(dims.len());
            for (i, &d) in dims.iter().enumerate() {
                if d > 0 {
                    let d = d as usize;
                    known = ck_mul(known, d)?;
                    out.push(d);
                } else if d == 0 {
                    if i != 0 {
                        return Err(format!("Reshape: 0 only keeps the batch axis (position 0), found at {i}"));
                    }
                    let b = *x.first().ok_or("Reshape: 0 spec needs a batched input")?;
                    known = ck_mul(known, b)?;
                    out.push(b);
                } else if d == -1 {
                    if infer_at.is_some() {
                        return Err("Reshape: more than one -1 in spec".into());
                    }
                    infer_at = Some(i);
                    out.push(0);
                } else {
                    return Err(format!("Reshape: invalid spec entry {d}"));
                }
            }
            match infer_at {
                Some(i) => {
                    if known == 0 || total % known != 0 {
                        return Err(format!(
                            "Reshape: cannot infer -1: {total} elements not divisible by {known}"
                        ));
                    }
                    out[i] = total / known;
                }
                None => {
                    if known != total {
                        return Err(format!(
                            "Reshape: spec {dims:?} has {known} elements, input {x:?} has {total}"
                        ));
                    }
                }
            }
            Ok(out)
        }
        Op::BroadcastTo { dims } => {
            if broadcast2(x, dims)? != *dims {
                return Err(format!("BroadcastTo: input {x:?} does not broadcast to {dims:?}"));
            }
            Ok(dims.clone())
        }
        Op::Slice { axis, start, stop } => {
            if *axis >= x.len() {
                return Err(format!("Slice: axis {axis} out of range for {x:?}"));
            }
            if start > stop || *stop > x[*axis] {
                return Err(format!(
                    "Slice: window [{start}, {stop}) invalid for extent {}",
                    x[*axis]
                ));
            }
            let mut out = x.clone();
            out[*axis] = stop - start;
            Ok(out)
        }
        Op::Transpose { axes } => {
            if axes.len() != x.len() {
                return Err(format!("Transpose: {} axes for rank-{} input", axes.len(), x.len()));
            }
            let mut seen = vec![false; x.len()];
            for &a in axes {
                if a >= x.len() || seen[a] {
                    return Err(format!("Transpose: {axes:?} is not a permutation of 0..{}", x.len()));
                }
                seen[a] = true;
            }
            Ok(axes.iter().map(|&a| x[a]).collect())
        }
        Op::Concat { axis } => {
            let rank = x.len();
            if *axis >= rank {
                return Err(format!("Concatenate: axis {axis} out of range for {x:?}"));
            }
            let mut out = x.clone();
            for y in &xs[1..] {
                if y.len() != rank {
                    return Err(format!("Concatenate: rank mismatch {x:?} vs {y:?}"));
                }
                for i in 0..rank {
                    if i == *axis {
                        out[i] = ck_add(out[i], y[i])?;
                    } else if y[i] != x[i] {
                        return Err(format!("Concatenate: {y:?} differs from {x:?} off the concat axis"));
                    }
                }
            }
            Ok(out)
        }
        Op::Embed => {
            let w = xs.get(1).ok_or("Embed: missing table")?;
            want_rank("Embed table", w, 2)?;
            Ok(vec![prod(x)?, w[1]])
        }
        // All remaining ops are elementwise / identity-shaped.
        Op::ReLU
        | Op::LeakyReLU { .. }
        | Op::Sigmoid
        | Op::Tanh
        | Op::Elu { .. }
        | Op::Swish
        | Op::Gelu
        | Op::Softplus
        | Op::Neg
        | Op::AddScalar { .. }
        | Op::MulScalar { .. }
        | Op::PowScalar { .. }
        | Op::Exp
        | Op::Log
        | Op::StopGradient
        | Op::Dropout { .. }
        | Op::Identity => Ok(x.clone()),
    }
}

// ---------------------------------------------------------------------------
// Graph verification
// ---------------------------------------------------------------------------

/// Zero-stride/kernel/dilation attribute checks, mirroring (and
/// superseding) the hard errors in `NetworkDef::validate`.
fn check_attrs(op: &Op) -> Result<(), String> {
    match op {
        Op::Convolution { stride, dilation, .. } => {
            if stride.0 == 0 || stride.1 == 0 {
                return Err("zero stride".into());
            }
            if dilation.0 == 0 || dilation.1 == 0 {
                return Err("zero dilation".into());
            }
        }
        Op::Deconvolution { stride, .. } => {
            if stride.0 == 0 || stride.1 == 0 {
                return Err("zero stride".into());
            }
        }
        Op::MaxPool { kernel, stride, .. } | Op::AvgPool { kernel, stride, .. } => {
            if kernel.0 == 0 || kernel.1 == 0 {
                return Err("zero kernel".into());
            }
            if stride.0 == 0 || stride.1 == 0 {
                return Err("zero stride".into());
            }
        }
        _ => {}
    }
    Ok(())
}

/// Whether serving can micro-batch through this op (mirrors
/// `CompiledNet::batch_invariant`). `false` ⇒ the op couples rows
/// along axis 0 and W103 fires.
fn op_batch_invariant(op: &Op) -> bool {
    match op {
        Op::SumAll | Op::MeanAll | Op::BroadcastTo { .. } => false,
        Op::Sum { axis, keepdims } | Op::Mean { axis, keepdims } => *axis != 0 && *keepdims,
        Op::Concat { axis } | Op::Slice { axis, .. } => *axis != 0,
        Op::Transpose { axes } => axes.first() == Some(&0),
        Op::Reshape { dims } => dims.len() >= 2 && dims[0] == 0,
        _ => true,
    }
}

/// Full static verification of one network against a parameter
/// registry: structural errors (E001–E008) plus lints (W101–W104).
/// Never panics, whatever the inputs claim about themselves.
pub fn verify_network(net: &NetworkDef, params: &HashMap<String, NdArray>) -> Report {
    let mut r = Report::new();

    // Tensor name -> inferred shape (None once inference broke down —
    // downstream layers are then checked structurally only).
    let mut shapes: HashMap<&str, Option<Vec<usize>>> = HashMap::new();
    for t in &net.inputs {
        if shapes.insert(&t.name, Some(t.dims.clone())).is_some() {
            r.push(
                Diagnostic::error(codes::DUPLICATE_TENSOR, "duplicate network input")
                    .with_tensor(&t.name),
            );
        }
    }

    // Everything *some* layer produces — distinguishes a forward
    // reference (E003, cycle/misorder) from a plain typo (E002).
    let produced: HashSet<&str> =
        net.layers.iter().flat_map(|l| l.outputs.iter().map(String::as_str)).collect();

    let mut used_params: HashSet<&str> = HashSet::new();

    for layer in &net.layers {
        let mut layer_ok = true;

        if let Err(e) = check_attrs(&layer.op) {
            r.push(
                Diagnostic::error(codes::BAD_ATTR, format!("{}: {e}", layer.op.name()))
                    .with_layer(&layer.name),
            );
            layer_ok = false;
        }

        if layer.outputs.len() != 1 {
            r.push(
                Diagnostic::error(
                    codes::ARITY,
                    format!("{} must have exactly 1 output, has {}", layer.op.name(), layer.outputs.len()),
                )
                .with_layer(&layer.name),
            );
            layer_ok = false;
        }

        let total = layer.inputs.len() + layer.params.len();
        let (min, max) = layer.op.arity();
        if total < min || total > max {
            r.push(
                Diagnostic::error(
                    codes::ARITY,
                    format!(
                        "{} takes {} inputs, got {} ({} activations + {} params)",
                        layer.op.name(),
                        if min == max { format!("{min}") } else { format!("{min}..={max}") },
                        total,
                        layer.inputs.len(),
                        layer.params.len(),
                    ),
                )
                .with_layer(&layer.name),
            );
            layer_ok = false;
        }

        let mut arg_shapes: Vec<Option<Vec<usize>>> = Vec::with_capacity(total);
        for input in &layer.inputs {
            match shapes.get(input.as_str()) {
                Some(s) => arg_shapes.push(s.clone()),
                None => {
                    let (code, what) = if produced.contains(input.as_str()) {
                        (codes::CYCLE, "produced by a later layer (cyclic or misordered graph)")
                    } else {
                        (codes::UNDEFINED_TENSOR, "never produced and not a network input")
                    };
                    r.push(
                        Diagnostic::error(code, format!("read of tensor {what}"))
                            .with_layer(&layer.name)
                            .with_tensor(input),
                    );
                    layer_ok = false;
                    arg_shapes.push(None);
                }
            }
        }
        for p in &layer.params {
            used_params.insert(p);
            match params.get(p) {
                Some(a) => arg_shapes.push(Some(a.dims().to_vec())),
                None => {
                    r.push(
                        Diagnostic::error(codes::MISSING_PARAM, "parameter missing from the registry")
                            .with_layer(&layer.name)
                            .with_tensor(p),
                    );
                    layer_ok = false;
                    arg_shapes.push(None);
                }
            }
        }

        let out_shape: Option<Vec<usize>> = if layer_ok && arg_shapes.iter().all(Option::is_some) {
            let xs: Vec<Vec<usize>> = arg_shapes.into_iter().map(Option::unwrap).collect();
            match infer_op_shape(&layer.op, &xs) {
                Ok(s) => Some(s),
                Err(e) => {
                    r.push(Diagnostic::error(codes::SHAPE_MISMATCH, e).with_layer(&layer.name));
                    None
                }
            }
        } else {
            None
        };

        for out in &layer.outputs {
            if shapes.insert(out, out_shape.clone()).is_some() {
                r.push(
                    Diagnostic::error(codes::DUPLICATE_TENSOR, "tensor defined more than once")
                        .with_layer(&layer.name)
                        .with_tensor(out),
                );
            }
        }
    }

    for out in &net.outputs {
        if !shapes.contains_key(out.as_str()) {
            r.push(
                Diagnostic::error(codes::OUTPUT_MISSING, "declared network output is never produced")
                    .with_tensor(out),
            );
        }
    }

    // --- Lints ---

    // W101: backward reachability from the declared outputs.
    let mut needed: HashSet<&str> = net.outputs.iter().map(String::as_str).collect();
    let mut reachable = vec![false; net.layers.len()];
    for (i, layer) in net.layers.iter().enumerate().rev() {
        if layer.outputs.iter().any(|o| needed.contains(o.as_str())) {
            reachable[i] = true;
            needed.extend(layer.inputs.iter().map(String::as_str));
        }
    }
    for (i, layer) in net.layers.iter().enumerate() {
        if !reachable[i] {
            r.push(
                Diagnostic::warning(
                    codes::UNREACHABLE_LAYER,
                    "layer does not contribute to any network output (dead subgraph)",
                )
                .with_layer(&layer.name),
            );
        }
    }

    // W102: registry parameters never referenced (name-sorted for
    // deterministic output).
    let mut unused: Vec<&str> =
        params.keys().map(String::as_str).filter(|p| !used_params.contains(*p)).collect();
    unused.sort_unstable();
    for p in unused {
        r.push(
            Diagnostic::warning(codes::UNUSED_PARAM, "parameter is never referenced by any layer")
                .with_tensor(p),
        );
    }

    // W103: batch variance. A missing or sub-rank-2 input disables
    // micro-batching for the whole network; otherwise flag the ops
    // that couple rows along axis 0.
    if net.inputs.is_empty() || net.inputs.iter().any(|t| t.dims.len() < 2) {
        r.push(Diagnostic::warning(
            codes::BATCH_VARIANT,
            "network signature has no batch axis: serving falls back to per-request execution",
        ));
    } else {
        for (i, layer) in net.layers.iter().enumerate() {
            if reachable[i] && !op_batch_invariant(&layer.op) {
                r.push(
                    Diagnostic::warning(
                        codes::BATCH_VARIANT,
                        format!(
                            "{} couples rows along axis 0: serving cannot micro-batch this network",
                            layer.op.name()
                        ),
                    )
                    .with_layer(&layer.name),
                );
            }
        }
    }

    // W104: quantization-hostile ops (mirrors `dense_weight_axis`: only
    // single-input Affine/Convolution with params get int8 kernels).
    for (i, layer) in net.layers.iter().enumerate() {
        if !reachable[i] {
            continue;
        }
        match layer.op {
            Op::Deconvolution { .. } => {
                r.push(
                    Diagnostic::warning(
                        codes::QUANT_HOSTILE,
                        "Deconvolution has no int8 kernel and will silently run in f32 when quantized",
                    )
                    .with_layer(&layer.name),
                );
            }
            Op::Affine | Op::Convolution { .. }
                if layer.inputs.len() != 1 || layer.params.is_empty() =>
            {
                r.push(
                    Diagnostic::warning(
                        codes::QUANT_HOSTILE,
                        format!(
                            "{} without a unique input and weights will not quantize (f32 fallback)",
                            layer.op.name()
                        ),
                    )
                    .with_layer(&layer.name),
                );
            }
            _ => {}
        }
    }

    r
}

// ---------------------------------------------------------------------------
// Translation validation of a compiled plan
// ---------------------------------------------------------------------------

/// Independent verifier of a compiled plan: re-derives liveness from
/// the scheduled steps and cross-checks the step order (P001/P002/
/// P003/P007) and, when present, the static memory plan (P004/P005/
/// P006). Shares no code with the scheduler or allocator it audits.
pub fn verify_plan(plan: &CompiledNet) -> Report {
    let mut r = Report::new();
    let n = plan.n_slots();
    let steps = plan.steps();
    let outputs: HashSet<usize> = plan.output_slots().iter().copied().collect();

    // Network inputs occupy the first slots and arrive pre-written.
    let mut written = vec![false; n];
    let mut freed = vec![false; n];
    for w in written.iter_mut().take(plan.inputs().len().min(n)) {
        *w = true;
    }

    for (i, st) in steps.iter().enumerate() {
        for a in &st.args {
            if let Src::Act(s) = a {
                if *s >= n {
                    r.push(
                        Diagnostic::error(
                            codes::PLAN_MISMATCH,
                            format!("step {i} reads out-of-range slot {s} (plan has {n})"),
                        )
                        .with_layer(&st.name),
                    );
                } else if freed[*s] {
                    r.push(
                        Diagnostic::error(
                            codes::PLAN_USE_AFTER_FREE,
                            format!("step {i} reads a slot after its planned free"),
                        )
                        .with_layer(&st.name)
                        .with_tensor(plan.slot_name(*s)),
                    );
                } else if !written[*s] {
                    r.push(
                        Diagnostic::error(
                            codes::PLAN_ORDER,
                            format!("step {i} reads a slot no earlier step produced"),
                        )
                        .with_layer(&st.name)
                        .with_tensor(plan.slot_name(*s)),
                    );
                }
            }
        }

        if st.out >= n {
            r.push(
                Diagnostic::error(
                    codes::PLAN_MISMATCH,
                    format!("step {i} writes out-of-range slot {} (plan has {n})", st.out),
                )
                .with_layer(&st.name),
            );
            continue;
        }
        if freed[st.out] {
            r.push(
                Diagnostic::error(codes::PLAN_ORDER, format!("step {i} rewrites a freed slot"))
                    .with_layer(&st.name)
                    .with_tensor(plan.slot_name(st.out)),
            );
        } else if written[st.out] {
            r.push(
                Diagnostic::error(
                    codes::PLAN_ORDER,
                    format!("step {i} writes a slot that already holds a live value"),
                )
                .with_layer(&st.name)
                .with_tensor(plan.slot_name(st.out)),
            );
        }
        written[st.out] = true;

        for &s in &st.free_after {
            if s >= n {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_BAD_FREE,
                        format!("step {i} frees out-of-range slot {s} (plan has {n})"),
                    )
                    .with_layer(&st.name),
                );
            } else if outputs.contains(&s) {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_OUTPUT,
                        format!("step {i} frees a network output slot"),
                    )
                    .with_layer(&st.name)
                    .with_tensor(plan.slot_name(s)),
                );
            } else if !written[s] {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_BAD_FREE,
                        format!("step {i} frees a slot that was never produced"),
                    )
                    .with_layer(&st.name)
                    .with_tensor(plan.slot_name(s)),
                );
            } else if freed[s] {
                r.push(
                    Diagnostic::error(codes::PLAN_BAD_FREE, format!("step {i} frees a slot twice"))
                        .with_layer(&st.name)
                        .with_tensor(plan.slot_name(s)),
                );
            } else {
                freed[s] = true;
            }
        }
    }

    for &o in plan.output_slots() {
        if o >= n {
            r.push(Diagnostic::error(
                codes::PLAN_OUTPUT,
                format!("output slot {o} out of range (plan has {n})"),
            ));
        } else if !written[o] {
            r.push(
                Diagnostic::error(codes::PLAN_OUTPUT, "network output slot is never produced")
                    .with_tensor(plan.slot_name(o)),
            );
        }
    }

    if let Some(m) = plan.memory_plan() {
        verify_memory(plan, m, &mut r);
    }
    r
}

/// Cross-check a memory plan against liveness re-derived from the
/// steps: exact live ranges, in-bounds offsets, and pairwise
/// no-overlap of simultaneously-live allocations.
fn verify_memory(plan: &CompiledNet, m: &MemoryPlan, r: &mut Report) {
    let n = plan.n_slots();
    let steps = plan.steps();
    if m.slots.len() != n {
        r.push(Diagnostic::error(
            codes::PLAN_MISMATCH,
            format!("memory plan covers {} slots, plan has {n}", m.slots.len()),
        ));
        return;
    }

    // Re-derive each slot's live interval the way the allocator defines
    // it: producer step opens the range, reads extend it, network
    // outputs stay live past the last step. Inputs are caller-held and
    // never arena-backed, so they get no interval.
    let mut start: Vec<Option<usize>> = vec![None; n];
    let mut end: Vec<usize> = vec![0; n];
    for (i, st) in steps.iter().enumerate() {
        for a in &st.args {
            if let Src::Act(s) = a {
                if *s < n {
                    end[*s] = end[*s].max(i);
                }
            }
        }
        if st.out < n {
            start[st.out] = Some(i);
            end[st.out] = end[st.out].max(i);
        }
    }
    for &o in plan.output_slots() {
        if o < n && start[o].is_some() {
            end[o] = steps.len();
        }
    }

    let mut allocated: Vec<(usize, SlotAlloc)> = Vec::new();
    for s in 0..n {
        match (start[s], m.slots[s]) {
            (Some(st0), Some(a)) => {
                if a.start != st0 || a.end != end[s] {
                    r.push(
                        Diagnostic::error(
                            codes::PLAN_MISMATCH,
                            format!(
                                "allocation claims live range [{}, {}], steps imply [{st0}, {}]",
                                a.start, a.end, end[s]
                            ),
                        )
                        .with_tensor(plan.slot_name(s)),
                    );
                }
                match a.offset.checked_add(a.bytes) {
                    Some(e) if e <= m.peak_bytes => {}
                    _ => {
                        r.push(
                            Diagnostic::error(
                                codes::PLAN_ARENA_BOUNDS,
                                format!(
                                    "allocation [{}, {} bytes) exceeds the {}-byte arena",
                                    a.offset, a.bytes, m.peak_bytes
                                ),
                            )
                            .with_tensor(plan.slot_name(s)),
                        );
                    }
                }
                allocated.push((s, a));
            }
            (Some(_), None) => {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_MISMATCH,
                        "slot is materialized by a step but has no arena allocation",
                    )
                    .with_tensor(plan.slot_name(s)),
                );
            }
            (None, Some(_)) => {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_MISMATCH,
                        "arena allocation for a slot no step produces",
                    )
                    .with_tensor(plan.slot_name(s)),
                );
            }
            (None, None) => {}
        }
    }

    if m.peak_bytes > m.naive_bytes {
        r.push(Diagnostic::error(
            codes::PLAN_ARENA_BOUNDS,
            format!(
                "peak {} bytes exceeds the naive per-slot total {} bytes",
                m.peak_bytes, m.naive_bytes
            ),
        ));
    }

    // Pairwise: allocations live at the same time must not share bytes.
    // Boundary sharing counts as a time overlap (a producer may read
    // the dying slot while writing the new one); zero-byte ranges can
    // never collide.
    for (i, &(sa, a)) in allocated.iter().enumerate() {
        for &(sb, b) in allocated.iter().skip(i + 1) {
            let time = a.start <= b.end && b.start <= a.end;
            let bytes = a.bytes > 0
                && b.bytes > 0
                && a.offset < b.offset.saturating_add(b.bytes)
                && b.offset < a.offset.saturating_add(a.bytes);
            if time && bytes {
                r.push(
                    Diagnostic::error(
                        codes::PLAN_ARENA_OVERLAP,
                        format!(
                            "arena ranges [{}, {}) and [{}, {}) overlap for simultaneously-live slots '{}' and '{}'",
                            a.offset,
                            a.offset.saturating_add(a.bytes),
                            b.offset,
                            b.offset.saturating_add(b.bytes),
                            plan.slot_name(sa),
                            plan.slot_name(sb),
                        ),
                    )
                    .with_tensor(plan.slot_name(sa)),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Front doors: whole-model and whole-artifact checks
// ---------------------------------------------------------------------------

/// Verify the graph, then — if it is structurally sound — compile at
/// every optimization level and run translation validation on each
/// resulting plan (diagnostics prefixed with the level, so a
/// pass-pipeline bug names the level that exposed it).
pub fn check_model(net: &NetworkDef, params: &HashMap<String, NdArray>) -> Report {
    let mut report = verify_network(net, params);
    if report.has_errors() {
        return report;
    }
    for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2] {
        match CompiledNet::compile_with(net, params, level) {
            Ok(plan) => {
                for mut d in verify_plan(&plan).into_diagnostics() {
                    d.message = format!("[{}] {}", level.name(), d.message);
                    report.push(d);
                }
            }
            Err(e) => {
                report.push(Diagnostic::error(
                    codes::COMPILE_FAILED,
                    format!("[{}] plan compilation failed: {e}", level.name()),
                ));
            }
        }
    }
    report
}

/// Check a serialized NNB/NNB2 artifact end to end: decode, verify the
/// graph, compile, and validate the plan. `Err` means the bytes do not
/// decode at all; `Ok` carries the diagnostics. Never panics, however
/// corrupted the bytes.
pub fn check_artifact(bytes: &[u8]) -> Result<Report, String> {
    use crate::converters::nnb::{load_nnb, NnbImage};
    match load_nnb(bytes)? {
        NnbImage::V1 { net, params } => {
            let pm: HashMap<String, NdArray> = params.into_iter().collect();
            Ok(check_model(&net, &pm))
        }
        NnbImage::V2(model) => {
            let pm: HashMap<String, NdArray> =
                model.params.iter().map(|(n, p)| (n.clone(), p.to_f32())).collect();
            let mut report = verify_network(&model.net, &pm);
            if !report.has_errors() {
                match crate::quant::QuantizedNet::compile(&model) {
                    Ok(q) => report.merge(verify_plan(q.base_plan())),
                    Err(e) => report.push(Diagnostic::error(
                        codes::COMPILE_FAILED,
                        format!("int8 plan compilation failed: {e}"),
                    )),
                }
            }
            Ok(report)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, TensorDef};
    use crate::nnp::passes::SlotAlloc;

    fn layer(name: &str, op: Op, inputs: &[&str], params: &[&str], outputs: &[&str]) -> Layer {
        Layer {
            name: name.into(),
            op,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            params: params.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// x[1,4] -> Affine(w[4,3], b[3]) -> h -> Sigmoid -> y.
    /// (Sigmoid, not ReLU: ReLU would fuse into the Affine step and
    /// the plan-mutation tests need two steps.)
    fn tiny_net() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "tiny".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                layer("fc", Op::Affine, &["x"], &["w", "b"], &["h"]),
                layer("act", Op::Sigmoid, &["h"], &[], &["y"]),
            ],
        };
        let mut params = HashMap::new();
        params.insert("w".to_string(), NdArray::zeros(&[4, 3]));
        params.insert("b".to_string(), NdArray::zeros(&[3]));
        (net, params)
    }

    #[test]
    fn clean_net_is_clean() {
        let (net, params) = tiny_net();
        let r = verify_network(&net, &params);
        assert!(r.is_clean(), "unexpected diagnostics:\n{}", r.render_human());
    }

    #[test]
    fn check_model_accepts_every_level() {
        let (net, params) = tiny_net();
        let r = check_model(&net, &params);
        assert!(r.is_clean(), "{}", r.render_human());
    }

    #[test]
    fn shape_mismatch_is_e006() {
        let (net, mut params) = tiny_net();
        params.insert("w".to_string(), NdArray::zeros(&[3, 2])); // 4 features vs 3 rows
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::SHAPE_MISMATCH), "{}", r.render_human());
        assert!(r.has_errors());
    }

    #[test]
    fn bad_arity_is_e001() {
        let (mut net, params) = tiny_net();
        net.layers[0].params.clear(); // Affine with just x
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::ARITY), "{}", r.render_human());
    }

    #[test]
    fn undefined_tensor_is_e002_and_forward_ref_is_e003() {
        let (mut net, params) = tiny_net();
        net.layers[1].inputs[0] = "ghost".into();
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::UNDEFINED_TENSOR), "{}", r.render_human());

        let (mut net, params) = tiny_net();
        net.layers.swap(0, 1); // Sigmoid now reads 'h' before the Affine defines it
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::CYCLE), "{}", r.render_human());
    }

    #[test]
    fn duplicate_definition_is_e004() {
        let (mut net, params) = tiny_net();
        net.layers[1].outputs[0] = "h".into();
        net.outputs[0] = "h".into();
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::DUPLICATE_TENSOR), "{}", r.render_human());
    }

    #[test]
    fn missing_output_is_e005() {
        let (mut net, params) = tiny_net();
        net.outputs.push("nope".into());
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::OUTPUT_MISSING), "{}", r.render_human());
    }

    #[test]
    fn missing_param_is_e007() {
        let (net, mut params) = tiny_net();
        params.remove("w");
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::MISSING_PARAM), "{}", r.render_human());
    }

    #[test]
    fn zero_stride_is_e008() {
        let (mut net, params) = tiny_net();
        net.layers[1].op =
            Op::MaxPool { kernel: (2, 2), stride: (0, 0), pad: (0, 0) };
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::BAD_ATTR), "{}", r.render_human());
    }

    #[test]
    fn overflowing_declared_shape_reports_instead_of_panicking() {
        let (mut net, params) = tiny_net();
        net.inputs[0].dims = vec![usize::MAX, usize::MAX];
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::SHAPE_MISMATCH), "{}", r.render_human());
    }

    #[test]
    fn dead_layer_and_unused_param_warn() {
        let (mut net, mut params) = tiny_net();
        net.layers.push(layer("dead", Op::Tanh, &["h"], &[], &["z"]));
        params.insert("orphan".to_string(), NdArray::zeros(&[1]));
        let r = verify_network(&net, &params);
        assert!(!r.has_errors(), "{}", r.render_human());
        assert!(r.has_code(codes::UNREACHABLE_LAYER));
        assert!(r.has_code(codes::UNUSED_PARAM));
    }

    #[test]
    fn batch_variant_op_warns_w103() {
        let (mut net, params) = tiny_net();
        net.layers[1].op = Op::Slice { axis: 0, start: 0, stop: 1 };
        let r = verify_network(&net, &params);
        assert!(r.has_code(codes::BATCH_VARIANT), "{}", r.render_human());
        // axis-1 slice is batch-invariant: no warning
        let (mut net, params) = tiny_net();
        net.layers[1].op = Op::Slice { axis: 1, start: 0, stop: 1 };
        let r = verify_network(&net, &params);
        assert!(!r.has_code(codes::BATCH_VARIANT), "{}", r.render_human());
    }

    #[test]
    fn quant_hostile_deconv_warns_w104() {
        let net = NetworkDef {
            name: "up".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2, 4, 4] }],
            outputs: vec!["y".into()],
            layers: vec![layer(
                "up",
                Op::Deconvolution { stride: (2, 2), pad: (0, 0) },
                &["x"],
                &["w"],
                &["y"],
            )],
        };
        let mut params = HashMap::new();
        params.insert("w".to_string(), NdArray::zeros(&[2, 3, 2, 2]));
        let r = verify_network(&net, &params);
        assert!(!r.has_errors(), "{}", r.render_human());
        assert!(r.has_code(codes::QUANT_HOSTILE));
    }

    #[test]
    fn report_renders_and_serializes() {
        let (net, mut params) = tiny_net();
        params.insert("w".to_string(), NdArray::zeros(&[3, 2]));
        let r = verify_network(&net, &params);
        let human = r.render_human();
        assert!(human.contains("error[NNL-E006]"), "{human}");
        assert!(human.contains("1 error"), "{human}");
        let json = r.to_json().to_string();
        assert!(json.contains("\"NNL-E006\""), "{json}");
        assert!(json.contains("\"errors\""), "{json}");
    }

    // --- translation validation: the verifier must reject mutants ---

    fn tiny_plan() -> CompiledNet {
        let (net, params) = tiny_net();
        CompiledNet::compile_with(&net, &params, OptLevel::O0).expect("tiny net compiles")
    }

    #[test]
    fn pristine_plan_verifies() {
        let plan = tiny_plan();
        let r = verify_plan(&plan);
        assert!(r.is_clean(), "{}", r.render_human());
        assert!(plan.memory_plan().is_some(), "tiny plan should have a memory plan");
    }

    #[test]
    fn reordered_steps_are_p001() {
        let mut plan = tiny_plan();
        plan.mutate_steps(|steps| steps.swap(0, 1));
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_ORDER), "{}", r.render_human());
    }

    #[test]
    fn freed_output_slot_is_p003() {
        let mut plan = tiny_plan();
        let out = plan.output_slots()[0];
        plan.mutate_steps(|steps| steps.last_mut().unwrap().free_after.push(out));
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_OUTPUT), "{}", r.render_human());
    }

    #[test]
    fn double_free_is_p007() {
        let mut plan = tiny_plan();
        plan.mutate_steps(|steps| {
            let extra: Vec<usize> =
                steps.iter().flat_map(|s| s.free_after.clone()).collect();
            assert!(!extra.is_empty(), "tiny plan frees its intermediate");
            steps.last_mut().unwrap().free_after.extend(extra);
        });
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_BAD_FREE), "{}", r.render_human());
    }

    #[test]
    fn seeded_arena_overlap_is_p004() {
        let plan = tiny_plan();
        let mut m = plan.memory_plan().expect("memory plan").clone();
        // collapse every allocation onto offset 0: the two live-at-the-
        // boundary slots (h and y) now share bytes
        let n_alloc = m.slots.iter().flatten().count();
        assert!(n_alloc >= 2, "need at least two allocations to collide");
        for a in m.slots.iter_mut().flatten() {
            a.offset = 0;
        }
        let mut plan = plan;
        plan.inject_memory_plan(m);
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_ARENA_OVERLAP), "{}", r.render_human());
    }

    #[test]
    fn shifted_live_range_is_p006() {
        let plan = tiny_plan();
        let mut m = plan.memory_plan().expect("memory plan").clone();
        let a: &mut SlotAlloc =
            m.slots.iter_mut().flatten().next().expect("an allocation");
        a.start += 1;
        let mut plan = plan;
        plan.inject_memory_plan(m);
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_MISMATCH), "{}", r.render_human());
    }

    #[test]
    fn out_of_bounds_offset_is_p005() {
        let plan = tiny_plan();
        let mut m = plan.memory_plan().expect("memory plan").clone();
        let a: &mut SlotAlloc =
            m.slots.iter_mut().flatten().next().expect("an allocation");
        a.offset = m.peak_bytes; // offset + bytes now exceeds the arena
        let mut plan = plan;
        plan.inject_memory_plan(m);
        let r = verify_plan(&plan);
        assert!(r.has_code(codes::PLAN_ARENA_BOUNDS), "{}", r.render_human());
    }

    #[test]
    fn artifact_roundtrip_checks_clean() {
        let (net, params) = tiny_net();
        let plist: Vec<(String, NdArray)> =
            params.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        let bytes = crate::converters::nnb::to_nnb(&net, &plist);
        let r = check_artifact(&bytes).expect("valid artifact decodes");
        assert!(r.is_clean(), "{}", r.render_human());
    }

    #[test]
    fn corrupt_artifact_flags_e006_before_compile() {
        let (net, params) = tiny_net();
        let mut plist: Vec<(String, NdArray)> =
            params.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        for p in plist.iter_mut() {
            if p.0 == "w" {
                p.1 = NdArray::zeros(&[3, 2]); // wrong feature count
            }
        }
        let bytes = crate::converters::nnb::to_nnb(&net, &plist);
        let r = check_artifact(&bytes).expect("artifact still decodes");
        assert!(r.has_code(codes::SHAPE_MISMATCH), "{}", r.render_human());
        assert!(r.has_errors());
    }
}
