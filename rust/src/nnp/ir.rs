//! The network intermediate representation *and* the single operator
//! registry — the role the paper's "protobuf defined in Neural Network
//! Libraries" plays as the converter hub (§3: "this file format
//! converter uses protobuf ... as intermediate format").
//!
//! [`Op`] is the one description of every operator the framework knows:
//! its typed attributes, its canonical (NNabla-style) name, its wire
//! encoding ([`Op::attrs_json`] / [`Op::from_name_attrs`]), and its
//! executable semantics ([`Op::apply`] / [`Op::execute`]). The live
//! tape ([`crate::graph::Variable`]) records an `Op` on every function
//! node, `nnp::trace` reads those descriptors back out into a
//! [`NetworkDef`], and the [`crate::nnp::interpreter`] re-applies them
//! through the same dispatch — so training, export, conversion, and
//! deployment all share one operator definition.
//!
//! A [`NetworkDef`] is a flat, topologically-ordered list of layers
//! over named tensors. It is what NNP stores, what every converter
//! consumes/produces, and what the interpreter executes for
//! deployment-style inference.

use crate::functions as F;
use crate::graph::Variable;
use crate::tensor::NdArray;
use crate::utils::json::Json;

/// Operator type + typed attributes — one variant per framework
/// function. This is the registry every layer of the stack shares.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// `y = x·W + b`; params: `W`, optional `b`.
    Affine,
    /// 2-D convolution; params: `W [oc,c,kh,kw]`, optional `b`.
    Convolution { stride: (usize, usize), pad: (usize, usize), dilation: (usize, usize) },
    /// Transposed convolution; params: `W [c,oc,kh,kw]`, optional `b`.
    Deconvolution { stride: (usize, usize), pad: (usize, usize) },
    MaxPool { kernel: (usize, usize), stride: (usize, usize), pad: (usize, usize) },
    AvgPool { kernel: (usize, usize), stride: (usize, usize), pad: (usize, usize), including_pad: bool },
    GlobalAvgPool,
    ReLU,
    LeakyReLU { alpha: f32 },
    Sigmoid,
    Tanh,
    Elu { alpha: f32 },
    Swish,
    Gelu,
    Softplus,
    Softmax,
    LogSoftmax,
    /// Inference-mode batch norm; params: `beta`, `gamma`, `mean`, `var`.
    BatchNorm { eps: f32 },
    /// Layer norm over the last axis; params: `beta`, `gamma`.
    LayerNorm { eps: f32 },
    /// Elementwise add of two inputs (residual connections).
    Add2,
    /// Elementwise subtract of two inputs.
    Sub2,
    /// Elementwise multiply of two inputs (SE scaling).
    Mul2,
    /// Elementwise divide of two inputs.
    Div2,
    /// Elementwise negation.
    Neg,
    AddScalar { val: f32 },
    MulScalar { val: f32 },
    PowScalar { val: f32 },
    Exp,
    Log,
    /// Identity forward, zero gradient (frozen branches / baselines).
    StopGradient,
    /// Concat of N inputs along an axis.
    Concat { axis: usize },
    /// Reshape spec: `-1` infers, `0` in dim 0 keeps the batch axis.
    Reshape { dims: Vec<i64> },
    /// Broadcast to a fixed target shape.
    BroadcastTo { dims: Vec<usize> },
    /// `[start, stop)` window along one axis.
    Slice { axis: usize, start: usize, stop: usize },
    /// Axis permutation.
    Transpose { axes: Vec<usize> },
    /// Dropout: a no-op at inference; `p` recorded for re-training.
    Dropout { p: f32 },
    /// Embedding lookup; params: `W [V, D]`.
    Embed,
    /// Identity (signature pinning).
    Identity,
    /// Per-example `(x - t)^2`.
    SquaredError,
    /// Stable elementwise binary cross-entropy on logits.
    SigmoidCrossEntropy,
    /// Per-example softmax cross-entropy with integer labels.
    SoftmaxCrossEntropy,
    /// Sum of all elements -> scalar.
    SumAll,
    /// Mean of all elements -> scalar.
    MeanAll,
    /// Sum along one axis.
    Sum { axis: usize, keepdims: bool },
    /// Mean along one axis.
    Mean { axis: usize, keepdims: bool },
}

impl Op {
    /// Canonical function name (matches NNabla function names where
    /// they exist — used by nntxt, the support-query tool and NNB).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Affine => "Affine",
            Op::Convolution { .. } => "Convolution",
            Op::Deconvolution { .. } => "Deconvolution",
            Op::MaxPool { .. } => "MaxPooling",
            Op::AvgPool { .. } => "AveragePooling",
            Op::GlobalAvgPool => "GlobalAveragePooling",
            Op::ReLU => "ReLU",
            Op::LeakyReLU { .. } => "LeakyReLU",
            Op::Sigmoid => "Sigmoid",
            Op::Tanh => "Tanh",
            Op::Elu { .. } => "ELU",
            Op::Swish => "Swish",
            Op::Gelu => "GELU",
            Op::Softplus => "SoftPlus",
            Op::Softmax => "Softmax",
            Op::LogSoftmax => "LogSoftmax",
            Op::BatchNorm { .. } => "BatchNormalization",
            Op::LayerNorm { .. } => "LayerNormalization",
            Op::Add2 => "Add2",
            Op::Sub2 => "Sub2",
            Op::Mul2 => "Mul2",
            Op::Div2 => "Div2",
            Op::Neg => "Neg",
            Op::AddScalar { .. } => "AddScalar",
            Op::MulScalar { .. } => "MulScalar",
            Op::PowScalar { .. } => "PowScalar",
            Op::Exp => "Exp",
            Op::Log => "Log",
            Op::StopGradient => "StopGradient",
            Op::Concat { .. } => "Concatenate",
            Op::Reshape { .. } => "Reshape",
            Op::BroadcastTo { .. } => "BroadcastTo",
            Op::Slice { .. } => "Slice",
            Op::Transpose { .. } => "Transpose",
            Op::Dropout { .. } => "Dropout",
            Op::Embed => "Embed",
            Op::Identity => "Identity",
            Op::SquaredError => "SquaredError",
            Op::SigmoidCrossEntropy => "SigmoidCrossEntropy",
            Op::SoftmaxCrossEntropy => "SoftmaxCrossEntropy",
            Op::SumAll => "SumAll",
            Op::MeanAll => "MeanAll",
            Op::Sum { .. } => "Sum",
            Op::Mean { .. } => "Mean",
        }
    }

    /// Attributes as JSON (for NNP binary / nntxt round-trips).
    pub fn attrs_json(&self) -> Json {
        fn pair(p: (usize, usize)) -> Json {
            Json::arr_of_usize(&[p.0, p.1])
        }
        match self {
            Op::Convolution { stride, pad, dilation } => Json::obj(vec![
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
                ("dilation", pair(*dilation)),
            ]),
            Op::Deconvolution { stride, pad } => {
                Json::obj(vec![("stride", pair(*stride)), ("pad", pair(*pad))])
            }
            Op::MaxPool { kernel, stride, pad } => Json::obj(vec![
                ("kernel", pair(*kernel)),
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
            ]),
            Op::AvgPool { kernel, stride, pad, including_pad } => Json::obj(vec![
                ("kernel", pair(*kernel)),
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
                ("including_pad", Json::Bool(*including_pad)),
            ]),
            Op::LeakyReLU { alpha } => Json::obj(vec![("alpha", Json::num(*alpha as f64))]),
            Op::Elu { alpha } => Json::obj(vec![("alpha", Json::num(*alpha as f64))]),
            Op::BatchNorm { eps } => Json::obj(vec![("eps", Json::num(*eps as f64))]),
            Op::LayerNorm { eps } => Json::obj(vec![("eps", Json::num(*eps as f64))]),
            Op::AddScalar { val } | Op::MulScalar { val } | Op::PowScalar { val } => {
                Json::obj(vec![("val", Json::num(*val as f64))])
            }
            Op::Concat { axis } => Json::obj(vec![("axis", Json::num(*axis as f64))]),
            Op::Reshape { dims } => Json::obj(vec![(
                "dims",
                Json::Arr(dims.iter().map(|&d| Json::num(d as f64)).collect()),
            )]),
            Op::BroadcastTo { dims } => Json::obj(vec![("dims", Json::arr_of_usize(dims))]),
            Op::Slice { axis, start, stop } => Json::obj(vec![
                ("axis", Json::num(*axis as f64)),
                ("start", Json::num(*start as f64)),
                ("stop", Json::num(*stop as f64)),
            ]),
            Op::Transpose { axes } => Json::obj(vec![("axes", Json::arr_of_usize(axes))]),
            Op::Dropout { p } => Json::obj(vec![("p", Json::num(*p as f64))]),
            Op::Sum { axis, keepdims } | Op::Mean { axis, keepdims } => Json::obj(vec![
                ("axis", Json::num(*axis as f64)),
                ("keepdims", Json::Bool(*keepdims)),
            ]),
            _ => Json::obj(vec![]),
        }
    }

    /// Rebuild from name + attrs (NNP binary / nntxt load).
    pub fn from_name_attrs(name: &str, attrs: &Json) -> Option<Op> {
        fn pair(j: &Json) -> Option<(usize, usize)> {
            let v = j.usize_arr()?;
            if v.len() == 2 {
                Some((v[0], v[1]))
            } else {
                None
            }
        }
        Some(match name {
            "Affine" => Op::Affine,
            "Convolution" => Op::Convolution {
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
                dilation: pair(attrs.get("dilation"))?,
            },
            "Deconvolution" => Op::Deconvolution {
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
            },
            "MaxPooling" => Op::MaxPool {
                kernel: pair(attrs.get("kernel"))?,
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
            },
            "AveragePooling" => Op::AvgPool {
                kernel: pair(attrs.get("kernel"))?,
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
                including_pad: attrs.get("including_pad").as_bool().unwrap_or(false),
            },
            "GlobalAveragePooling" => Op::GlobalAvgPool,
            "ReLU" => Op::ReLU,
            "LeakyReLU" => Op::LeakyReLU { alpha: attrs.get("alpha").as_f64()? as f32 },
            "Sigmoid" => Op::Sigmoid,
            "Tanh" => Op::Tanh,
            "ELU" => Op::Elu { alpha: attrs.get("alpha").as_f64()? as f32 },
            "Swish" => Op::Swish,
            "GELU" => Op::Gelu,
            "SoftPlus" => Op::Softplus,
            "Softmax" => Op::Softmax,
            "LogSoftmax" => Op::LogSoftmax,
            "BatchNormalization" => Op::BatchNorm { eps: attrs.get("eps").as_f64()? as f32 },
            "LayerNormalization" => Op::LayerNorm { eps: attrs.get("eps").as_f64()? as f32 },
            "Add2" => Op::Add2,
            "Sub2" => Op::Sub2,
            "Mul2" => Op::Mul2,
            "Div2" => Op::Div2,
            "Neg" => Op::Neg,
            "AddScalar" => Op::AddScalar { val: attrs.get("val").as_f64()? as f32 },
            "MulScalar" => Op::MulScalar { val: attrs.get("val").as_f64()? as f32 },
            "PowScalar" => Op::PowScalar { val: attrs.get("val").as_f64()? as f32 },
            "Exp" => Op::Exp,
            "Log" => Op::Log,
            "StopGradient" => Op::StopGradient,
            "Concatenate" => Op::Concat { axis: attrs.get("axis").as_usize()? },
            "Reshape" => Op::Reshape {
                dims: attrs
                    .get("dims")
                    .as_arr()?
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
            },
            "BroadcastTo" => Op::BroadcastTo { dims: attrs.get("dims").usize_arr()? },
            "Slice" => Op::Slice {
                axis: attrs.get("axis").as_usize()?,
                start: attrs.get("start").as_usize()?,
                stop: attrs.get("stop").as_usize()?,
            },
            "Transpose" => Op::Transpose { axes: attrs.get("axes").usize_arr()? },
            "Dropout" => Op::Dropout { p: attrs.get("p").as_f64()? as f32 },
            "Embed" => Op::Embed,
            "Identity" => Op::Identity,
            "SquaredError" => Op::SquaredError,
            "SigmoidCrossEntropy" => Op::SigmoidCrossEntropy,
            "SoftmaxCrossEntropy" => Op::SoftmaxCrossEntropy,
            "SumAll" => Op::SumAll,
            "MeanAll" => Op::MeanAll,
            "Sum" => Op::Sum {
                axis: attrs.get("axis").as_usize()?,
                keepdims: attrs.get("keepdims").as_bool().unwrap_or(false),
            },
            "Mean" => Op::Mean {
                axis: attrs.get("axis").as_usize()?,
                keepdims: attrs.get("keepdims").as_bool().unwrap_or(false),
            },
            _ => return None,
        })
    }

    /// Combined input arity `(min, max)` — activations plus parameters,
    /// in the concatenated order [`Op::apply`] consumes. This is the
    /// compile-time contract [`NetworkDef::validate`] and
    /// [`crate::nnp::plan::CompiledNet`] enforce so malformed files
    /// fail at load, not mid-request.
    pub fn arity(&self) -> (usize, usize) {
        match self {
            Op::Affine | Op::Convolution { .. } | Op::Deconvolution { .. } => (2, 3),
            Op::BatchNorm { .. } => (5, 5),
            Op::LayerNorm { .. } => (3, 3),
            Op::Concat { .. } => (1, usize::MAX),
            Op::Add2
            | Op::Sub2
            | Op::Mul2
            | Op::Div2
            | Op::Embed
            | Op::SquaredError
            | Op::SigmoidCrossEntropy
            | Op::SoftmaxCrossEntropy => (2, 2),
            _ => (1, 1),
        }
    }

    // --------------------------------------------------------- dispatch

    /// Apply this operator to live variables, recording a fully
    /// differentiable node on the tape (forward runs immediately;
    /// backward is available through `Variable::backward`).
    ///
    /// The input slice carries activations first, then parameters in
    /// the op-defined order (`W[, b]` / `beta, gamma, mean, var` / …) —
    /// exactly the concatenation of a [`Layer`]'s `inputs` and
    /// `params`. This is the *deployment* semantics of each operator:
    /// [`Op::Dropout`] is an inference no-op and [`Op::BatchNorm`] uses
    /// the running statistics. Training-time variants (sampled dropout,
    /// batch-stat BN) are built directly through `F::*` / `PF::*`.
    ///
    /// This single dispatch is what the NNP interpreter, the builder,
    /// and graph reconstruction from converters all run on.
    pub fn apply(&self, xs: &[&Variable]) -> Result<Variable, String> {
        let n = xs.len();
        let ck = |lo: usize, hi: usize| -> Result<(), String> {
            debug_assert_eq!((lo, hi), self.arity(), "arity drift for {}", self.name());
            if n < lo || n > hi {
                if lo == hi {
                    Err(format!("{}: expected {lo} inputs, got {n}", self.name()))
                } else {
                    Err(format!("{}: expected {lo}..={hi} inputs, got {n}", self.name()))
                }
            } else {
                Ok(())
            }
        };
        Ok(match self {
            Op::Affine => {
                ck(2, 3)?;
                F::affine(xs[0], xs[1], xs.get(2).copied())
            }
            Op::Convolution { stride, pad, dilation } => {
                ck(2, 3)?;
                check_conv_geometry(&xs[0].dims(), &xs[1].dims(), *stride, *pad, *dilation)?;
                F::convolution(xs[0], xs[1], xs.get(2).copied(), *stride, *pad, *dilation)
            }
            Op::Deconvolution { stride, pad } => {
                ck(2, 3)?;
                check_deconv_geometry(&xs[0].dims(), &xs[1].dims(), *stride, *pad)?;
                F::deconvolution(xs[0], xs[1], xs.get(2).copied(), *stride, *pad)
            }
            Op::MaxPool { kernel, stride, pad } => {
                ck(1, 1)?;
                check_pool_geometry("MaxPooling", &xs[0].dims(), *kernel, *stride, *pad)?;
                F::max_pooling(xs[0], *kernel, *stride, *pad)
            }
            Op::AvgPool { kernel, stride, pad, including_pad } => {
                ck(1, 1)?;
                check_pool_geometry("AveragePooling", &xs[0].dims(), *kernel, *stride, *pad)?;
                F::average_pooling(xs[0], *kernel, *stride, *pad, *including_pad)
            }
            Op::GlobalAvgPool => {
                ck(1, 1)?;
                if xs[0].dims().len() != 4 {
                    return Err(format!(
                        "GlobalAveragePooling: expected NCHW input, got shape {:?}",
                        xs[0].dims()
                    ));
                }
                F::global_average_pooling(xs[0])
            }
            Op::ReLU => {
                ck(1, 1)?;
                F::relu(xs[0])
            }
            Op::LeakyReLU { alpha } => {
                ck(1, 1)?;
                F::leaky_relu(xs[0], *alpha)
            }
            Op::Sigmoid => {
                ck(1, 1)?;
                F::sigmoid(xs[0])
            }
            Op::Tanh => {
                ck(1, 1)?;
                F::tanh(xs[0])
            }
            Op::Elu { alpha } => {
                ck(1, 1)?;
                F::elu(xs[0], *alpha)
            }
            Op::Swish => {
                ck(1, 1)?;
                F::swish(xs[0])
            }
            Op::Gelu => {
                ck(1, 1)?;
                F::gelu(xs[0])
            }
            Op::Softplus => {
                ck(1, 1)?;
                F::softplus(xs[0])
            }
            Op::Softmax => {
                ck(1, 1)?;
                F::softmax(xs[0])
            }
            Op::LogSoftmax => {
                ck(1, 1)?;
                F::log_softmax(xs[0])
            }
            Op::BatchNorm { eps } => {
                ck(5, 5)?;
                F::batch_normalization(xs[0], xs[1], xs[2], xs[3], xs[4], 0.9, *eps, false)
            }
            Op::LayerNorm { eps } => {
                ck(3, 3)?;
                F::layer_normalization(xs[0], xs[1], xs[2], *eps)
            }
            Op::Add2 => {
                ck(2, 2)?;
                F::add(xs[0], xs[1])
            }
            Op::Sub2 => {
                ck(2, 2)?;
                F::sub(xs[0], xs[1])
            }
            Op::Mul2 => {
                ck(2, 2)?;
                F::mul(xs[0], xs[1])
            }
            Op::Div2 => {
                ck(2, 2)?;
                F::div(xs[0], xs[1])
            }
            Op::Neg => {
                ck(1, 1)?;
                F::neg(xs[0])
            }
            Op::AddScalar { val } => {
                ck(1, 1)?;
                F::add_scalar(xs[0], *val)
            }
            Op::MulScalar { val } => {
                ck(1, 1)?;
                F::mul_scalar(xs[0], *val)
            }
            Op::PowScalar { val } => {
                ck(1, 1)?;
                F::pow_scalar(xs[0], *val)
            }
            Op::Exp => {
                ck(1, 1)?;
                F::exp(xs[0])
            }
            Op::Log => {
                ck(1, 1)?;
                F::log(xs[0])
            }
            Op::StopGradient => {
                ck(1, 1)?;
                F::stop_gradient(xs[0])
            }
            Op::Concat { axis } => {
                ck(1, usize::MAX)?;
                if xs.iter().any(|x| *axis >= x.dims().len()) {
                    return Err(format!("Concatenate: axis {axis} out of range for inputs"));
                }
                F::concat(xs, *axis)
            }
            Op::Reshape { dims } => {
                ck(1, 1)?;
                F::reshape_spec(xs[0], dims)
            }
            Op::BroadcastTo { dims } => {
                ck(1, 1)?;
                F::broadcast_to(xs[0], dims)
            }
            Op::Slice { axis, start, stop } => {
                ck(1, 1)?;
                // loaded attrs are untrusted: bound-check before the
                // kernel's assert can abort the interpreter
                let dims = xs[0].dims();
                if *axis >= dims.len() || start > stop || *stop > dims[*axis] {
                    return Err(format!(
                        "Slice: window [{start}, {stop}) on axis {axis} invalid for shape {dims:?}"
                    ));
                }
                F::slice_axis(xs[0], *axis, *start, *stop)
            }
            Op::Transpose { axes } => {
                ck(1, 1)?;
                let rank = xs[0].dims().len();
                let mut seen = vec![false; rank];
                let valid = axes.len() == rank
                    && axes.iter().all(|&a| a < rank && !std::mem::replace(&mut seen[a], true));
                if !valid {
                    return Err(format!(
                        "Transpose: axes {axes:?} is not a permutation of 0..{rank}"
                    ));
                }
                F::transpose(xs[0], axes)
            }
            Op::Dropout { p } => {
                ck(1, 1)?;
                F::dropout_inference(xs[0], *p)
            }
            Op::Embed => {
                ck(2, 2)?;
                F::embed(xs[0], xs[1])
            }
            Op::Identity => {
                ck(1, 1)?;
                F::identity(xs[0])
            }
            Op::SquaredError => {
                ck(2, 2)?;
                F::squared_error(xs[0], xs[1])
            }
            Op::SigmoidCrossEntropy => {
                ck(2, 2)?;
                F::sigmoid_cross_entropy(xs[0], xs[1])
            }
            Op::SoftmaxCrossEntropy => {
                ck(2, 2)?;
                F::softmax_cross_entropy(xs[0], xs[1])
            }
            Op::SumAll => {
                ck(1, 1)?;
                F::sum_all(xs[0])
            }
            Op::MeanAll => {
                ck(1, 1)?;
                F::mean_all(xs[0])
            }
            Op::Sum { axis, keepdims } => {
                ck(1, 1)?;
                F::sum_axis(xs[0], *axis, *keepdims)
            }
            Op::Mean { axis, keepdims } => {
                ck(1, 1)?;
                F::mean_axis(xs[0], *axis, *keepdims)
            }
        })
    }

    /// Execute this operator on raw arrays (deployment inference).
    /// Shares [`Op::apply`]'s dispatch — and therefore the exact
    /// kernels the training tape runs — so interpreted outputs are
    /// bit-identical to the live graph.
    pub fn execute(&self, xs: &[&NdArray]) -> Result<NdArray, String> {
        let vars: Vec<Variable> =
            xs.iter().map(|a| Variable::from_array((*a).clone(), false)).collect();
        let refs: Vec<&Variable> = vars.iter().collect();
        Ok(self.apply(&refs)?.data())
    }
}

/// Validate pooling geometry before the kernels' index arithmetic can
/// underflow `usize` (`kernel > input + 2·pad` used to panic or attempt
/// an absurd allocation — reachable from untrusted NNP files).
fn check_pool_geometry(
    name: &str,
    dims: &[usize],
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
) -> Result<(), String> {
    if dims.len() != 4 {
        return Err(format!("{name}: expected NCHW input, got shape {dims:?}"));
    }
    if kernel.0 == 0 || kernel.1 == 0 {
        return Err(format!("{name}: kernel {kernel:?} must be non-zero"));
    }
    if stride.0 == 0 || stride.1 == 0 {
        return Err(format!("{name}: stride {stride:?} must be non-zero"));
    }
    let (h, w) = (dims[2], dims[3]);
    // checked: `input + 2·pad` can overflow on untrusted declared dims
    let padded_h = pad.0.checked_mul(2).and_then(|p| h.checked_add(p));
    let padded_w = pad.1.checked_mul(2).and_then(|p| w.checked_add(p));
    match (padded_h, padded_w) {
        (Some(ph), Some(pw)) if kernel.0 <= ph && kernel.1 <= pw => Ok(()),
        _ => Err(format!(
            "{name}: kernel {kernel:?} larger than padded input {h}x{w} (pad {pad:?})"
        )),
    }
}

/// Validate convolution geometry against concrete shapes before the
/// kernels' index arithmetic can underflow `usize` (`effective kernel
/// > input + 2·pad` — the same bug class `pool_out_hw` had, reachable
/// from untrusted NNP files). Shared by [`Op::apply`] and the compiled
/// plan's fused fast path.
pub(crate) fn check_conv_geometry(
    x_dims: &[usize],
    w_dims: &[usize],
    stride: (usize, usize),
    pad: (usize, usize),
    dilation: (usize, usize),
) -> Result<(), String> {
    if x_dims.len() != 4 {
        return Err(format!("Convolution: expected NCHW input, got shape {x_dims:?}"));
    }
    if w_dims.len() != 4 {
        return Err(format!("Convolution: expected OIHW weights, got shape {w_dims:?}"));
    }
    if w_dims[1] != x_dims[1] {
        return Err(format!(
            "Convolution: weight in-channels {} do not match input channels {}",
            w_dims[1], x_dims[1]
        ));
    }
    let g = crate::tensor::ops::Conv2dGeom {
        kernel: (w_dims[2], w_dims[3]),
        stride,
        pad,
        dilation,
    };
    match g.try_out_hw(x_dims[2], x_dims[3]) {
        Some(_) => Ok(()),
        None => Err(format!(
            "Convolution: kernel {:?} stride {stride:?} pad {pad:?} dilation {dilation:?} \
             invalid for {}x{} input",
            g.kernel, x_dims[2], x_dims[3]
        )),
    }
}

/// Deconvolution twin of [`check_conv_geometry`]: `w: [C, OC, KH, KW]`,
/// output `(h-1)·stride + kernel - 2·pad` must stay positive.
pub(crate) fn check_deconv_geometry(
    x_dims: &[usize],
    w_dims: &[usize],
    stride: (usize, usize),
    pad: (usize, usize),
) -> Result<(), String> {
    if x_dims.len() != 4 {
        return Err(format!("Deconvolution: expected NCHW input, got shape {x_dims:?}"));
    }
    if w_dims.len() != 4 {
        return Err(format!("Deconvolution: expected IOHW weights, got shape {w_dims:?}"));
    }
    if w_dims[0] != x_dims[1] {
        return Err(format!(
            "Deconvolution: weight in-channels {} do not match input channels {}",
            w_dims[0], x_dims[1]
        ));
    }
    if stride.0 == 0 || stride.1 == 0 {
        return Err(format!("Deconvolution: stride {stride:?} must be non-zero"));
    }
    if w_dims[2] == 0 || w_dims[3] == 0 {
        return Err(format!(
            "Deconvolution: kernel ({}, {}) must be non-zero",
            w_dims[2], w_dims[3]
        ));
    }
    let (h, w) = (x_dims[2], x_dims[3]);
    if h == 0 || w == 0 {
        return Err(format!("Deconvolution: empty spatial input {h}x{w}"));
    }
    // checked end to end: `(h-1)·stride + kernel - 2·pad` over
    // untrusted declared dims must report, not overflow
    let grown = |extent: usize, s: usize, k: usize, p: usize| {
        (extent - 1)
            .checked_mul(s)
            .and_then(|v| v.checked_add(k))
            .and_then(|v| v.checked_sub(p.checked_mul(2)?))
            .filter(|&v| v > 0)
    };
    let oh = grown(h, stride.0, w_dims[2], pad.0);
    let ow = grown(w, stride.1, w_dims[3], pad.1);
    if oh.is_none() || ow.is_none() {
        return Err(format!(
            "Deconvolution: pad {pad:?} swallows the whole output for {h}x{w} input \
             (kernel ({}, {}), stride {stride:?})",
            w_dims[2], w_dims[3]
        ));
    }
    Ok(())
}

/// One layer: op + tensor names. Parameter tensor names refer to the
/// NNP parameter set; activation names are network-internal.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Unique layer name (e.g. `conv1`).
    pub name: String,
    pub op: Op,
    /// Activation inputs (tensor names).
    pub inputs: Vec<String>,
    /// Parameter inputs (registry names, in op-defined order).
    pub params: Vec<String>,
    /// Activation outputs (tensor names).
    pub outputs: Vec<String>,
}

/// A named tensor signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDef {
    pub name: String,
    pub dims: Vec<usize>,
}

/// The network graph: the `Network` message of the NNP format.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetworkDef {
    pub name: String,
    pub inputs: Vec<TensorDef>,
    pub outputs: Vec<String>,
    pub layers: Vec<Layer>,
}

impl NetworkDef {
    /// All parameter names referenced, in first-use order.
    pub fn param_names(&self) -> Vec<String> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for l in &self.layers {
            for p in &l.params {
                if seen.insert(p.clone()) {
                    out.push(p.clone());
                }
            }
        }
        out
    }

    /// Distinct function (op) names used — the converter support query
    /// runs over this.
    pub fn function_names(&self) -> Vec<&'static str> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for l in &self.layers {
            if seen.insert(l.op.name()) {
                out.push(l.op.name());
            }
        }
        out
    }

    /// Structural validation: every layer input must be produced by an
    /// earlier layer or be a network input (a read of a tensor only
    /// produced *later* is reported as a cyclic/misordered graph, not
    /// an opaque compile failure); tensor names must be unique —
    /// duplicate outputs (shadowing) are rejected, which is also what
    /// makes the optimizer's name-based rewiring sound; outputs must
    /// exist; every layer must carry exactly one output, an
    /// input+param count within its op's declared arity
    /// ([`Op::arity`]), and sane shape-independent attributes
    /// (non-zero strides/kernels/dilations) — so malformed files fail
    /// at load, not mid-request.
    pub fn validate(&self) -> Result<(), String> {
        fn check_attrs(op: &Op) -> Result<(), String> {
            let nz = |what: &str, p: (usize, usize)| {
                if p.0 == 0 || p.1 == 0 {
                    Err(format!("{} {what} {p:?} must be non-zero", op.name()))
                } else {
                    Ok(())
                }
            };
            match op {
                Op::Convolution { stride, dilation, .. } => {
                    nz("stride", *stride)?;
                    nz("dilation", *dilation)
                }
                Op::Deconvolution { stride, .. } => nz("stride", *stride),
                Op::MaxPool { kernel, stride, .. } | Op::AvgPool { kernel, stride, .. } => {
                    nz("kernel", *kernel)?;
                    nz("stride", *stride)
                }
                _ => Ok(()),
            }
        }
        let produced: std::collections::HashSet<&str> =
            self.layers.iter().flat_map(|l| l.outputs.iter().map(String::as_str)).collect();
        let mut known: std::collections::HashSet<&str> =
            self.inputs.iter().map(|t| t.name.as_str()).collect();
        for l in &self.layers {
            check_attrs(&l.op).map_err(|e| format!("layer '{}': {e}", l.name))?;
            for i in &l.inputs {
                if !known.contains(i.as_str()) {
                    return Err(if produced.contains(i.as_str()) {
                        format!(
                            "layer '{}' reads tensor '{}' before it is produced — \
                             the graph is cyclic or not topologically ordered",
                            l.name, i
                        )
                    } else {
                        format!("layer '{}' reads undefined tensor '{}'", l.name, i)
                    });
                }
            }
            if l.outputs.len() != 1 {
                return Err(format!(
                    "layer '{}': expected exactly 1 output tensor, got {}",
                    l.name,
                    l.outputs.len()
                ));
            }
            let (lo, hi) = l.op.arity();
            let n = l.inputs.len() + l.params.len();
            if n < lo || n > hi {
                return Err(if lo == hi {
                    format!("layer '{}': {} expects {lo} inputs, got {n}", l.name, l.op.name())
                } else if hi == usize::MAX {
                    format!(
                        "layer '{}': {} expects at least {lo} inputs, got {n}",
                        l.name,
                        l.op.name()
                    )
                } else {
                    format!(
                        "layer '{}': {} expects {lo}..={hi} inputs, got {n}",
                        l.name,
                        l.op.name()
                    )
                });
            }
            for o in &l.outputs {
                if !known.insert(o) {
                    return Err(format!(
                        "layer '{}': duplicate output tensor '{o}' — tensor names \
                         must be unique (shadowing is not allowed)",
                        l.name
                    ));
                }
            }
        }
        for o in &self.outputs {
            if !known.contains(o.as_str()) {
                return Err(format!("network output '{o}' never produced"));
            }
        }
        Ok(())
    }

    // -------------------------------------------------------------- json

    /// Structural JSON (used by the NNP binary container and the
    /// frozen-graph format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "inputs",
                Json::Arr(
                    self.inputs
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::str(t.name.clone())),
                                ("dims", Json::arr_of_usize(&t.dims)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "outputs",
                Json::Arr(self.outputs.iter().map(|o| Json::str(o.clone())).collect()),
            ),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("name", Json::str(l.name.clone())),
                                ("op", Json::str(l.op.name())),
                                ("attrs", l.op.attrs_json()),
                                (
                                    "inputs",
                                    Json::Arr(
                                        l.inputs.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                                (
                                    "params",
                                    Json::Arr(
                                        l.params.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                                (
                                    "outputs",
                                    Json::Arr(
                                        l.outputs.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<NetworkDef, String> {
        let strs = |j: &Json| -> Vec<String> {
            j.as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        let inputs = j
            .get("inputs")
            .as_arr()
            .ok_or("missing inputs")?
            .iter()
            .map(|t| {
                Ok(TensorDef {
                    name: t.get("name").as_str().ok_or("input name")?.to_string(),
                    dims: t.get("dims").usize_arr().ok_or("input dims")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let layers = j
            .get("layers")
            .as_arr()
            .ok_or("missing layers")?
            .iter()
            .map(|l| {
                let opname = l.get("op").as_str().ok_or("layer op")?;
                let op = Op::from_name_attrs(opname, l.get("attrs"))
                    .ok_or_else(|| format!("unknown op '{opname}'"))?;
                Ok(Layer {
                    name: l.get("name").as_str().ok_or("layer name")?.to_string(),
                    op,
                    inputs: strs(l.get("inputs")),
                    params: strs(l.get("params")),
                    outputs: strs(l.get("outputs")),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(NetworkDef {
            name: j.get("name").as_str().unwrap_or("network").to_string(),
            inputs,
            outputs: strs(j.get("outputs")),
            layers,
        })
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_net() -> NetworkDef {
        NetworkDef {
            name: "tiny".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["fc/W".into(), "fc/b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "act".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        }
    }

    #[test]
    fn validate_accepts_wellformed() {
        assert!(tiny_net().validate().is_ok());
    }

    #[test]
    fn validate_rejects_undefined_tensor() {
        let mut n = tiny_net();
        n.layers[1].inputs[0] = "nope".into();
        assert!(n.validate().is_err());
        let mut m = tiny_net();
        m.outputs[0] = "ghost".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_cyclic_graph_with_clear_error() {
        // a reads b's output, b reads a's output: a hand-built cycle
        let n = NetworkDef {
            name: "cyc".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["u".into()],
            layers: vec![
                Layer {
                    name: "a".into(),
                    op: Op::Neg,
                    inputs: vec!["v".into()],
                    params: vec![],
                    outputs: vec!["u".into()],
                },
                Layer {
                    name: "b".into(),
                    op: Op::Neg,
                    inputs: vec!["u".into()],
                    params: vec![],
                    outputs: vec!["v".into()],
                },
            ],
        };
        let err = n.validate().unwrap_err();
        assert!(err.contains("layer 'a'"), "{err}");
        assert!(err.contains("cyclic"), "{err}");
        // a self-loop is a cycle too
        let mut s = tiny_net();
        s.layers[1].inputs = vec!["y".into()];
        let err = s.validate().unwrap_err();
        assert!(err.contains("cyclic"), "{err}");
    }

    #[test]
    fn validate_rejects_duplicate_output_names() {
        let mut n = tiny_net();
        n.layers[1].outputs = vec!["h".into()]; // shadows layer 0's output
        n.outputs = vec!["h".into()];
        let err = n.validate().unwrap_err();
        assert!(err.contains("duplicate output tensor 'h'"), "{err}");
        // redefining a network input is a duplicate as well
        let mut m = tiny_net();
        m.layers[1].outputs = vec!["x".into()];
        m.outputs = vec!["x".into()];
        let err = m.validate().unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_arity() {
        let mut n = tiny_net();
        n.layers[0].params.clear(); // Affine with no weights
        let err = n.validate().unwrap_err();
        assert!(err.contains("layer 'fc'"), "{err}");
        assert!(err.contains("Affine"), "{err}");
    }

    #[test]
    fn validate_rejects_multi_output_layer() {
        let mut n = tiny_net();
        n.layers[1].outputs.push("y2".into());
        let err = n.validate().unwrap_err();
        assert!(err.contains("exactly 1 output"), "{err}");
    }

    #[test]
    fn arity_lower_bound_enforced_for_all_ops() {
        // every registry op must reject one-fewer-than-minimum inputs
        // with a clean error (never a panic)
        let x = Variable::from_array(NdArray::zeros(&[2, 3, 4, 4]), false);
        for op in all_ops() {
            let (lo, _) = op.arity();
            let vars: Vec<&Variable> = std::iter::repeat(&x).take(lo - 1).collect();
            assert!(op.apply(&vars).is_err(), "{} accepted {} inputs", op.name(), lo - 1);
        }
    }

    #[test]
    fn pool_geometry_is_error_not_panic() {
        // kernel > input + 2*pad used to underflow usize in pool_out_hw
        let x = Variable::from_array(NdArray::zeros(&[1, 1, 2, 2]), false);
        let err = Op::MaxPool { kernel: (5, 5), stride: (1, 1), pad: (0, 0) }
            .apply(&[&x])
            .unwrap_err();
        assert!(err.contains("kernel"), "{err}");
        let err = Op::AvgPool { kernel: (3, 3), stride: (1, 1), pad: (0, 0), including_pad: true }
            .apply(&[&x])
            .unwrap_err();
        assert!(err.contains("kernel"), "{err}");
        // zero stride would divide by zero downstream
        let err = Op::MaxPool { kernel: (2, 2), stride: (0, 1), pad: (0, 0) }
            .apply(&[&x])
            .unwrap_err();
        assert!(err.contains("stride"), "{err}");
        // pooling a non-NCHW tensor is a clean error too
        let flat = Variable::from_array(NdArray::zeros(&[4]), false);
        assert!(Op::GlobalAvgPool.apply(&[&flat]).is_err());
        assert!(Op::MaxPool { kernel: (2, 2), stride: (1, 1), pad: (0, 0) }
            .apply(&[&flat])
            .is_err());
    }

    #[test]
    fn conv_geometry_is_error_not_panic() {
        // effective kernel > input + 2·pad used to underflow usize in
        // Conv2dGeom::out_hw (the pool_out_hw bug class)
        let x = Variable::from_array(NdArray::zeros(&[1, 2, 3, 3]), false);
        let w = Variable::from_array(NdArray::zeros(&[4, 2, 5, 5]), false);
        let err = Op::Convolution { stride: (1, 1), pad: (0, 0), dilation: (1, 1) }
            .apply(&[&x, &w])
            .unwrap_err();
        assert!(err.contains("kernel"), "{err}");
        // dilation pushing the effective kernel out of range too
        let w2 = Variable::from_array(NdArray::zeros(&[4, 2, 3, 3]), false);
        let err = Op::Convolution { stride: (1, 1), pad: (0, 0), dilation: (4, 4) }
            .apply(&[&x, &w2])
            .unwrap_err();
        assert!(err.contains("dilation"), "{err}");
        // zero stride divides by zero downstream
        let err = Op::Convolution { stride: (0, 1), pad: (0, 0), dilation: (1, 1) }
            .apply(&[&x, &w2])
            .unwrap_err();
        assert!(err.contains("stride"), "{err}");
        // channel mismatch is a clean error as well
        let wbad = Variable::from_array(NdArray::zeros(&[4, 3, 2, 2]), false);
        let err = Op::Convolution { stride: (1, 1), pad: (0, 0), dilation: (1, 1) }
            .apply(&[&x, &wbad])
            .unwrap_err();
        assert!(err.contains("channels"), "{err}");
        // deconv: pad swallowing the output
        let dw = Variable::from_array(NdArray::zeros(&[2, 4, 2, 2]), false);
        let err = Op::Deconvolution { stride: (1, 1), pad: (3, 3) }.apply(&[&x, &dw]).unwrap_err();
        assert!(err.contains("pad"), "{err}");
        // and a valid conv still applies
        let y = Op::Convolution { stride: (1, 1), pad: (1, 1), dilation: (1, 1) }
            .apply(&[&x, &w2])
            .unwrap();
        assert_eq!(y.dims(), vec![1, 4, 3, 3]);
    }

    #[test]
    fn validate_rejects_degenerate_attrs_at_load() {
        let mut n = tiny_net();
        n.layers[0].op = Op::Convolution { stride: (0, 1), pad: (0, 0), dilation: (1, 1) };
        let err = n.validate().unwrap_err();
        assert!(err.contains("layer 'fc'"), "{err}");
        assert!(err.contains("stride"), "{err}");
        let mut p = tiny_net();
        p.layers[1].op = Op::MaxPool { kernel: (0, 2), stride: (1, 1), pad: (0, 0) };
        p.layers[1].inputs = vec!["h".into()];
        let err = p.validate().unwrap_err();
        assert!(err.contains("kernel"), "{err}");
    }

    #[test]
    fn param_and_function_names() {
        let n = tiny_net();
        assert_eq!(n.param_names(), vec!["fc/W", "fc/b"]);
        assert_eq!(n.function_names(), vec!["Affine", "ReLU"]);
    }

    /// Every registry variant with representative attrs — shared with
    /// converter tests to pin support matrices against the dispatch.
    pub(crate) fn all_ops() -> Vec<Op> {
        vec![
            Op::Affine,
            Op::Convolution { stride: (2, 1), pad: (1, 1), dilation: (1, 2) },
            Op::Deconvolution { stride: (2, 2), pad: (1, 0) },
            Op::MaxPool { kernel: (2, 2), stride: (2, 2), pad: (0, 0) },
            Op::AvgPool { kernel: (3, 3), stride: (1, 1), pad: (1, 1), including_pad: true },
            Op::GlobalAvgPool,
            Op::ReLU,
            Op::LeakyReLU { alpha: 0.25 },
            Op::Sigmoid,
            Op::Tanh,
            Op::Elu { alpha: 1.5 },
            Op::Swish,
            Op::Gelu,
            Op::Softplus,
            Op::Softmax,
            Op::LogSoftmax,
            Op::BatchNorm { eps: 1e-5 },
            Op::LayerNorm { eps: 1e-6 },
            Op::Add2,
            Op::Sub2,
            Op::Mul2,
            Op::Div2,
            Op::Neg,
            Op::AddScalar { val: 2.5 },
            Op::MulScalar { val: -3.0 },
            Op::PowScalar { val: 2.0 },
            Op::Exp,
            Op::Log,
            Op::StopGradient,
            Op::Concat { axis: 1 },
            Op::Reshape { dims: vec![-1, 8] },
            Op::BroadcastTo { dims: vec![4, 3] },
            Op::Slice { axis: 1, start: 2, stop: 5 },
            Op::Transpose { axes: vec![1, 0] },
            Op::Dropout { p: 0.5 },
            Op::Embed,
            Op::Identity,
            Op::SquaredError,
            Op::SigmoidCrossEntropy,
            Op::SoftmaxCrossEntropy,
            Op::SumAll,
            Op::MeanAll,
            Op::Sum { axis: 0, keepdims: true },
            Op::Mean { axis: 1, keepdims: false },
        ]
    }

    #[test]
    fn json_roundtrip_all_ops() {
        for op in all_ops() {
            let rt = Op::from_name_attrs(op.name(), &op.attrs_json())
                .unwrap_or_else(|| panic!("roundtrip failed for {}", op.name()));
            assert_eq!(rt, op);
        }
    }

    #[test]
    fn network_json_roundtrip() {
        let n = tiny_net();
        let j = n.to_json();
        let n2 = NetworkDef::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(n, n2);
    }

    #[test]
    fn unknown_op_rejected() {
        assert!(Op::from_name_attrs("FancyOp", &Json::Null).is_none());
    }

    // ------------------------------------------------- dispatch tests

    #[test]
    fn apply_records_differentiable_node() {
        let x = Variable::from_array(NdArray::from_slice(&[1, 2], &[1., 2.]), true);
        let w = Variable::from_array(NdArray::from_slice(&[2, 2], &[1., 0., 0., 1.]), true);
        let y = Op::Affine.apply(&[&x, &w]).unwrap();
        assert_eq!(y.data().data(), &[1., 2.]);
        crate::functions::mean_all(&y).backward();
        assert!(w.grad().norm2() > 0.0);
        assert_eq!(y.function_names(), vec!["Affine"]);
    }

    #[test]
    fn apply_rejects_wrong_arity() {
        let x = Variable::from_array(NdArray::zeros(&[1, 2]), false);
        let err = Op::Affine.apply(&[&x]).unwrap_err();
        assert!(err.contains("Affine"), "{err}");
        assert!(Op::ReLU.apply(&[&x, &x]).is_err());
    }

    #[test]
    fn execute_matches_apply() {
        let a = NdArray::from_slice(&[3], &[1., -2., 3.]);
        let out = Op::ReLU.execute(&[&a]).unwrap();
        assert_eq!(out.data(), &[1., 0., 3.]);
    }

    #[test]
    fn execute_dropout_is_inference_noop() {
        let a = NdArray::from_slice(&[4], &[1., 2., 3., 4.]);
        let out = Op::Dropout { p: 0.9 }.execute(&[&a]).unwrap();
        assert_eq!(out.data(), a.data());
    }

    #[test]
    fn execute_reshape_resolves_spec() {
        let a = NdArray::zeros(&[2, 3, 4]);
        let out = Op::Reshape { dims: vec![0, -1] }.execute(&[&a]).unwrap();
        assert_eq!(out.dims(), &[2, 12]);
    }
}
