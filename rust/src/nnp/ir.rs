//! The network intermediate representation — the role the paper's
//! "protobuf defined in Neural Network Libraries" plays as the
//! converter hub (§3: "this file format converter uses protobuf ...
//! as intermediate format").
//!
//! A [`NetworkDef`] is a flat, topologically-ordered list of layers
//! over named tensors. It is what NNP stores, what every converter
//! consumes/produces, and what the [`crate::nnp::interpreter`]
//! executes for deployment-style inference.

use crate::utils::json::Json;

/// Operator type + attributes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// `y = x·W + b`; params: `W`, optional `b`.
    Affine,
    /// 2-D convolution; params: `W [oc,c,kh,kw]`, optional `b`.
    Convolution { stride: (usize, usize), pad: (usize, usize), dilation: (usize, usize) },
    MaxPool { kernel: (usize, usize), stride: (usize, usize), pad: (usize, usize) },
    AvgPool { kernel: (usize, usize), stride: (usize, usize), pad: (usize, usize), including_pad: bool },
    GlobalAvgPool,
    ReLU,
    LeakyReLU { alpha: f32 },
    Sigmoid,
    Tanh,
    Elu { alpha: f32 },
    Swish,
    Gelu,
    Softplus,
    Softmax,
    LogSoftmax,
    /// Inference-mode batch norm; params: `beta`, `gamma`, `mean`, `var`.
    BatchNorm { eps: f32 },
    /// Layer norm over the last axis; params: `beta`, `gamma`.
    LayerNorm { eps: f32 },
    /// Elementwise add of two inputs (residual connections).
    Add2,
    /// Elementwise multiply of two inputs (SE scaling).
    Mul2,
    /// Concat of N inputs along an axis.
    Concat { axis: usize },
    Reshape { dims: Vec<i64> },
    /// Dropout: a no-op at inference; `p` recorded for re-training.
    Dropout { p: f32 },
    /// Embedding lookup; params: `W [V, D]`.
    Embed,
    /// Identity (signature pinning).
    Identity,
}

impl Op {
    /// Canonical function name (matches NNabla function names where
    /// they exist — used by nntxt, the support-query tool and NNB).
    pub fn name(&self) -> &'static str {
        match self {
            Op::Affine => "Affine",
            Op::Convolution { .. } => "Convolution",
            Op::MaxPool { .. } => "MaxPooling",
            Op::AvgPool { .. } => "AveragePooling",
            Op::GlobalAvgPool => "GlobalAveragePooling",
            Op::ReLU => "ReLU",
            Op::LeakyReLU { .. } => "LeakyReLU",
            Op::Sigmoid => "Sigmoid",
            Op::Tanh => "Tanh",
            Op::Elu { .. } => "ELU",
            Op::Swish => "Swish",
            Op::Gelu => "GELU",
            Op::Softplus => "SoftPlus",
            Op::Softmax => "Softmax",
            Op::LogSoftmax => "LogSoftmax",
            Op::BatchNorm { .. } => "BatchNormalization",
            Op::LayerNorm { .. } => "LayerNormalization",
            Op::Add2 => "Add2",
            Op::Mul2 => "Mul2",
            Op::Concat { .. } => "Concatenate",
            Op::Reshape { .. } => "Reshape",
            Op::Dropout { .. } => "Dropout",
            Op::Embed => "Embed",
            Op::Identity => "Identity",
        }
    }

    /// Attributes as JSON (for NNP binary / nntxt round-trips).
    pub fn attrs_json(&self) -> Json {
        fn pair(p: (usize, usize)) -> Json {
            Json::arr_of_usize(&[p.0, p.1])
        }
        match self {
            Op::Convolution { stride, pad, dilation } => Json::obj(vec![
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
                ("dilation", pair(*dilation)),
            ]),
            Op::MaxPool { kernel, stride, pad } => Json::obj(vec![
                ("kernel", pair(*kernel)),
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
            ]),
            Op::AvgPool { kernel, stride, pad, including_pad } => Json::obj(vec![
                ("kernel", pair(*kernel)),
                ("stride", pair(*stride)),
                ("pad", pair(*pad)),
                ("including_pad", Json::Bool(*including_pad)),
            ]),
            Op::LeakyReLU { alpha } => Json::obj(vec![("alpha", Json::num(*alpha as f64))]),
            Op::Elu { alpha } => Json::obj(vec![("alpha", Json::num(*alpha as f64))]),
            Op::BatchNorm { eps } => Json::obj(vec![("eps", Json::num(*eps as f64))]),
            Op::LayerNorm { eps } => Json::obj(vec![("eps", Json::num(*eps as f64))]),
            Op::Concat { axis } => Json::obj(vec![("axis", Json::num(*axis as f64))]),
            Op::Reshape { dims } => Json::obj(vec![(
                "dims",
                Json::Arr(dims.iter().map(|&d| Json::num(d as f64)).collect()),
            )]),
            Op::Dropout { p } => Json::obj(vec![("p", Json::num(*p as f64))]),
            _ => Json::obj(vec![]),
        }
    }

    /// Rebuild from name + attrs (NNP binary / nntxt load).
    pub fn from_name_attrs(name: &str, attrs: &Json) -> Option<Op> {
        fn pair(j: &Json) -> Option<(usize, usize)> {
            let v = j.usize_arr()?;
            if v.len() == 2 {
                Some((v[0], v[1]))
            } else {
                None
            }
        }
        Some(match name {
            "Affine" => Op::Affine,
            "Convolution" => Op::Convolution {
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
                dilation: pair(attrs.get("dilation"))?,
            },
            "MaxPooling" => Op::MaxPool {
                kernel: pair(attrs.get("kernel"))?,
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
            },
            "AveragePooling" => Op::AvgPool {
                kernel: pair(attrs.get("kernel"))?,
                stride: pair(attrs.get("stride"))?,
                pad: pair(attrs.get("pad"))?,
                including_pad: attrs.get("including_pad").as_bool().unwrap_or(false),
            },
            "GlobalAveragePooling" => Op::GlobalAvgPool,
            "ReLU" => Op::ReLU,
            "LeakyReLU" => Op::LeakyReLU { alpha: attrs.get("alpha").as_f64()? as f32 },
            "Sigmoid" => Op::Sigmoid,
            "Tanh" => Op::Tanh,
            "ELU" => Op::Elu { alpha: attrs.get("alpha").as_f64()? as f32 },
            "Swish" => Op::Swish,
            "GELU" => Op::Gelu,
            "SoftPlus" => Op::Softplus,
            "Softmax" => Op::Softmax,
            "LogSoftmax" => Op::LogSoftmax,
            "BatchNormalization" => Op::BatchNorm { eps: attrs.get("eps").as_f64()? as f32 },
            "LayerNormalization" => Op::LayerNorm { eps: attrs.get("eps").as_f64()? as f32 },
            "Add2" => Op::Add2,
            "Mul2" => Op::Mul2,
            "Concatenate" => Op::Concat { axis: attrs.get("axis").as_usize()? },
            "Reshape" => Op::Reshape {
                dims: attrs
                    .get("dims")
                    .as_arr()?
                    .iter()
                    .filter_map(|v| v.as_f64().map(|f| f as i64))
                    .collect(),
            },
            "Dropout" => Op::Dropout { p: attrs.get("p").as_f64()? as f32 },
            "Embed" => Op::Embed,
            "Identity" => Op::Identity,
            _ => return None,
        })
    }
}

/// One layer: op + tensor names. Parameter tensor names refer to the
/// NNP parameter set; activation names are network-internal.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    /// Unique layer name (e.g. `conv1`).
    pub name: String,
    pub op: Op,
    /// Activation inputs (tensor names).
    pub inputs: Vec<String>,
    /// Parameter inputs (registry names, in op-defined order).
    pub params: Vec<String>,
    /// Activation outputs (tensor names).
    pub outputs: Vec<String>,
}

/// A named tensor signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorDef {
    pub name: String,
    pub dims: Vec<usize>,
}

/// The network graph: the `Network` message of the NNP format.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct NetworkDef {
    pub name: String,
    pub inputs: Vec<TensorDef>,
    pub outputs: Vec<String>,
    pub layers: Vec<Layer>,
}

impl NetworkDef {
    /// All parameter names referenced, in first-use order.
    pub fn param_names(&self) -> Vec<String> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for l in &self.layers {
            for p in &l.params {
                if seen.insert(p.clone()) {
                    out.push(p.clone());
                }
            }
        }
        out
    }

    /// Distinct function (op) names used — the converter support query
    /// runs over this.
    pub fn function_names(&self) -> Vec<&'static str> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for l in &self.layers {
            if seen.insert(l.op.name()) {
                out.push(l.op.name());
            }
        }
        out
    }

    /// Structural validation: every layer input must be produced by an
    /// earlier layer or be a network input; outputs must exist.
    pub fn validate(&self) -> Result<(), String> {
        let mut known: std::collections::HashSet<&str> =
            self.inputs.iter().map(|t| t.name.as_str()).collect();
        for l in &self.layers {
            for i in &l.inputs {
                if !known.contains(i.as_str()) {
                    return Err(format!("layer '{}' reads undefined tensor '{}'", l.name, i));
                }
            }
            for o in &l.outputs {
                known.insert(o);
            }
        }
        for o in &self.outputs {
            if !known.contains(o.as_str()) {
                return Err(format!("network output '{o}' never produced"));
            }
        }
        Ok(())
    }

    // -------------------------------------------------------------- json

    /// Structural JSON (used by the NNP binary container and the
    /// frozen-graph format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            (
                "inputs",
                Json::Arr(
                    self.inputs
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::str(t.name.clone())),
                                ("dims", Json::arr_of_usize(&t.dims)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "outputs",
                Json::Arr(self.outputs.iter().map(|o| Json::str(o.clone())).collect()),
            ),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("name", Json::str(l.name.clone())),
                                ("op", Json::str(l.op.name())),
                                ("attrs", l.op.attrs_json()),
                                (
                                    "inputs",
                                    Json::Arr(
                                        l.inputs.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                                (
                                    "params",
                                    Json::Arr(
                                        l.params.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                                (
                                    "outputs",
                                    Json::Arr(
                                        l.outputs.iter().map(|s| Json::str(s.clone())).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<NetworkDef, String> {
        let strs = |j: &Json| -> Vec<String> {
            j.as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        let inputs = j
            .get("inputs")
            .as_arr()
            .ok_or("missing inputs")?
            .iter()
            .map(|t| {
                Ok(TensorDef {
                    name: t.get("name").as_str().ok_or("input name")?.to_string(),
                    dims: t.get("dims").usize_arr().ok_or("input dims")?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        let layers = j
            .get("layers")
            .as_arr()
            .ok_or("missing layers")?
            .iter()
            .map(|l| {
                let opname = l.get("op").as_str().ok_or("layer op")?;
                let op = Op::from_name_attrs(opname, l.get("attrs"))
                    .ok_or_else(|| format!("unknown op '{opname}'"))?;
                Ok(Layer {
                    name: l.get("name").as_str().ok_or("layer name")?.to_string(),
                    op,
                    inputs: strs(l.get("inputs")),
                    params: strs(l.get("params")),
                    outputs: strs(l.get("outputs")),
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(NetworkDef {
            name: j.get("name").as_str().unwrap_or("network").to_string(),
            inputs,
            outputs: strs(j.get("outputs")),
            layers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_net() -> NetworkDef {
        NetworkDef {
            name: "tiny".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["fc/W".into(), "fc/b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "act".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        }
    }

    #[test]
    fn validate_accepts_wellformed() {
        assert!(tiny_net().validate().is_ok());
    }

    #[test]
    fn validate_rejects_undefined_tensor() {
        let mut n = tiny_net();
        n.layers[1].inputs[0] = "nope".into();
        assert!(n.validate().is_err());
        let mut m = tiny_net();
        m.outputs[0] = "ghost".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn param_and_function_names() {
        let n = tiny_net();
        assert_eq!(n.param_names(), vec!["fc/W", "fc/b"]);
        assert_eq!(n.function_names(), vec!["Affine", "ReLU"]);
    }

    #[test]
    fn json_roundtrip_all_ops() {
        let ops = vec![
            Op::Affine,
            Op::Convolution { stride: (2, 1), pad: (1, 1), dilation: (1, 2) },
            Op::MaxPool { kernel: (2, 2), stride: (2, 2), pad: (0, 0) },
            Op::AvgPool { kernel: (3, 3), stride: (1, 1), pad: (1, 1), including_pad: true },
            Op::GlobalAvgPool,
            Op::ReLU,
            Op::LeakyReLU { alpha: 0.25 },
            Op::Sigmoid,
            Op::Tanh,
            Op::Elu { alpha: 1.5 },
            Op::Swish,
            Op::Gelu,
            Op::Softplus,
            Op::Softmax,
            Op::LogSoftmax,
            Op::BatchNorm { eps: 1e-5 },
            Op::LayerNorm { eps: 1e-6 },
            Op::Add2,
            Op::Mul2,
            Op::Concat { axis: 1 },
            Op::Reshape { dims: vec![-1, 8] },
            Op::Dropout { p: 0.5 },
            Op::Embed,
            Op::Identity,
        ];
        for op in ops {
            let rt = Op::from_name_attrs(op.name(), &op.attrs_json())
                .unwrap_or_else(|| panic!("roundtrip failed for {}", op.name()));
            assert_eq!(rt, op);
        }
    }

    #[test]
    fn network_json_roundtrip() {
        let n = tiny_net();
        let j = n.to_json();
        let n2 = NetworkDef::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(n, n2);
    }

    #[test]
    fn unknown_op_rejected() {
        assert!(Op::from_name_attrs("FancyOp", &Json::Null).is_none());
    }
}
