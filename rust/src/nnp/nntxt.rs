//! `.nntxt` — the protobuf-text rendering of the NNP structure (what
//! Neural Network Console imports/exports; paper §5.1 "they can simply
//! import the exported file from NNL (.nntxt format)").

use crate::utils::prototext::{PText, PVal};

use super::{
    DatasetConfig, ExecutorConfig, GlobalConfig, MonitorConfig, NetworkDef, Nnp, OptimizerConfig,
    TrainingConfig,
};
use super::ir::{Layer, Op, TensorDef};

/// Render the structural part of an [`Nnp`] (no parameter data).
pub fn to_nntxt(nnp: &Nnp) -> String {
    let mut root = PText::new();

    let mut gc = PText::new();
    gc.push_str("default_context", nnp.global_config.default_context.clone());
    root.push("global_config", PVal::Msg(gc));

    let mut tc = PText::new();
    tc.push_num("max_epoch", nnp.training_config.max_epoch as f64);
    tc.push_num("iter_per_epoch", nnp.training_config.iter_per_epoch as f64);
    tc.push_num("batch_size", nnp.training_config.batch_size as f64);
    root.push("training_config", PVal::Msg(tc));

    for net in &nnp.networks {
        root.push("network", PVal::Msg(network_to_ptext(net)));
    }
    for d in &nnp.datasets {
        let mut m = PText::new();
        m.push_str("name", d.name.clone());
        m.push_str("uri", d.uri.clone());
        m.push_num("batch_size", d.batch_size as f64);
        m.push("shuffle", PVal::Bool(d.shuffle));
        root.push("dataset", PVal::Msg(m));
    }
    for o in &nnp.optimizers {
        let mut m = PText::new();
        m.push_str("name", o.name.clone());
        m.push_str("network_name", o.network.clone());
        m.push_str("dataset_name", o.dataset.clone());
        let mut solver = PText::new();
        solver.push_str("type", o.solver.clone());
        solver.push_num("learning_rate", o.learning_rate as f64);
        solver.push_num("weight_decay", o.weight_decay as f64);
        m.push("solver", PVal::Msg(solver));
        m.push_str("loss_variable", o.loss_variable.clone());
        root.push("optimizer", PVal::Msg(m));
    }
    for mo in &nnp.monitors {
        let mut m = PText::new();
        m.push_str("name", mo.name.clone());
        m.push_str("network_name", mo.network.clone());
        m.push_str("dataset_name", mo.dataset.clone());
        m.push_str("monitor_variable", mo.monitor_variable.clone());
        root.push("monitor", PVal::Msg(m));
    }
    for e in &nnp.executors {
        let mut m = PText::new();
        m.push_str("name", e.name.clone());
        m.push_str("network_name", e.network.clone());
        for i in &e.inputs {
            m.push_str("data_variable", i.clone());
        }
        for o in &e.outputs {
            m.push_str("output_variable", o.clone());
        }
        root.push("executor", PVal::Msg(m));
    }
    root.to_string()
}

fn network_to_ptext(net: &NetworkDef) -> PText {
    let mut m = PText::new();
    m.push_str("name", net.name.clone());
    for t in &net.inputs {
        let mut v = PText::new();
        v.push_str("name", t.name.clone());
        v.push_str("type", "Buffer");
        for &d in &t.dims {
            v.push_num("dim", d as f64);
        }
        m.push("variable", PVal::Msg(v));
    }
    for o in &net.outputs {
        m.push_str("output_variable", o.clone());
    }
    for l in &net.layers {
        let mut f = PText::new();
        f.push_str("name", l.name.clone());
        f.push_str("type", l.op.name());
        // attributes as a JSON string field (compact, lossless)
        let attrs = l.op.attrs_json().to_string();
        if attrs != "{}" {
            f.push_str("attrs", attrs);
        }
        for i in &l.inputs {
            f.push_str("input", i.clone());
        }
        for p in &l.params {
            f.push_str("param", p.clone());
        }
        for o in &l.outputs {
            f.push_str("output", o.clone());
        }
        m.push("function", PVal::Msg(f));
    }
    m
}

fn network_from_ptext(m: &PText) -> Result<NetworkDef, String> {
    let name = m.get_str("name").unwrap_or("network").to_string();
    let mut inputs = Vec::new();
    for v in m.get_all("variable") {
        if let PVal::Msg(v) = v {
            inputs.push(TensorDef {
                name: v.get_str("name").ok_or("variable missing name")?.to_string(),
                dims: v.get_usizes("dim"),
            });
        }
    }
    let outputs = m
        .get_all("output_variable")
        .into_iter()
        .filter_map(|v| match v {
            PVal::Str(s) => Some(s.clone()),
            _ => None,
        })
        .collect();
    let mut layers = Vec::new();
    for f in m.get_all("function") {
        if let PVal::Msg(f) = f {
            let opname = f.get_str("type").ok_or("function missing type")?;
            let attrs = match f.get_str("attrs") {
                Some(s) => crate::utils::json::Json::parse(s)?,
                None => crate::utils::json::Json::Obj(Default::default()),
            };
            let op = Op::from_name_attrs(opname, &attrs)
                .ok_or(format!("unsupported function '{opname}'"))?;
            let strs = |key: &str| -> Vec<String> {
                f.get_all(key)
                    .into_iter()
                    .filter_map(|v| match v {
                        PVal::Str(s) => Some(s.clone()),
                        _ => None,
                    })
                    .collect()
            };
            layers.push(Layer {
                name: f.get_str("name").unwrap_or("fn").to_string(),
                op,
                inputs: strs("input"),
                params: strs("param"),
                outputs: strs("output"),
            });
        }
    }
    Ok(NetworkDef { name, inputs, outputs, layers })
}

/// Parse an `.nntxt` back into the structural NNP (parameters empty).
pub fn from_nntxt(text: &str) -> Result<Nnp, String> {
    let root = PText::parse(text)?;
    let mut nnp = Nnp::default();
    if let Some(gc) = root.get_msg("global_config") {
        nnp.global_config =
            GlobalConfig { default_context: gc.get_str("default_context").unwrap_or("cpu:float").to_string() };
    }
    if let Some(tc) = root.get_msg("training_config") {
        nnp.training_config = TrainingConfig {
            max_epoch: tc.get_num("max_epoch").unwrap_or(0.0) as usize,
            iter_per_epoch: tc.get_num("iter_per_epoch").unwrap_or(0.0) as usize,
            batch_size: tc.get_num("batch_size").unwrap_or(0.0) as usize,
        };
    }
    for n in root.get_all("network") {
        if let PVal::Msg(m) = n {
            nnp.networks.push(network_from_ptext(m)?);
        }
    }
    for d in root.get_all("dataset") {
        if let PVal::Msg(m) = d {
            nnp.datasets.push(DatasetConfig {
                name: m.get_str("name").unwrap_or("").to_string(),
                uri: m.get_str("uri").unwrap_or("").to_string(),
                batch_size: m.get_num("batch_size").unwrap_or(0.0) as usize,
                shuffle: matches!(m.get("shuffle"), Some(PVal::Bool(true))),
            });
        }
    }
    for o in root.get_all("optimizer") {
        if let PVal::Msg(m) = o {
            let solver = m.get_msg("solver");
            nnp.optimizers.push(OptimizerConfig {
                name: m.get_str("name").unwrap_or("").to_string(),
                network: m.get_str("network_name").unwrap_or("").to_string(),
                dataset: m.get_str("dataset_name").unwrap_or("").to_string(),
                solver: solver.and_then(|s| s.get_str("type")).unwrap_or("Sgd").to_string(),
                learning_rate: solver.and_then(|s| s.get_num("learning_rate")).unwrap_or(0.01)
                    as f32,
                weight_decay: solver.and_then(|s| s.get_num("weight_decay")).unwrap_or(0.0) as f32,
                loss_variable: m.get_str("loss_variable").unwrap_or("").to_string(),
            });
        }
    }
    for mo in root.get_all("monitor") {
        if let PVal::Msg(m) = mo {
            nnp.monitors.push(MonitorConfig {
                name: m.get_str("name").unwrap_or("").to_string(),
                network: m.get_str("network_name").unwrap_or("").to_string(),
                dataset: m.get_str("dataset_name").unwrap_or("").to_string(),
                monitor_variable: m.get_str("monitor_variable").unwrap_or("").to_string(),
            });
        }
    }
    for e in root.get_all("executor") {
        if let PVal::Msg(m) = e {
            let strs = |key: &str| -> Vec<String> {
                m.get_all(key)
                    .into_iter()
                    .filter_map(|v| match v {
                        PVal::Str(s) => Some(s.clone()),
                        _ => None,
                    })
                    .collect()
            };
            nnp.executors.push(ExecutorConfig {
                name: m.get_str("name").unwrap_or("").to_string(),
                network: m.get_str("network_name").unwrap_or("").to_string(),
                inputs: strs("data_variable"),
                outputs: strs("output_variable"),
            });
        }
    }
    Ok(nnp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::tests::sample_nnp;

    #[test]
    fn nntxt_roundtrip_structure() {
        let nnp = sample_nnp();
        let text = to_nntxt(&nnp);
        let back = from_nntxt(&text).unwrap();
        assert_eq!(back.networks, nnp.networks);
        assert_eq!(back.global_config, nnp.global_config);
        assert_eq!(back.training_config, nnp.training_config);
        assert_eq!(back.optimizers, nnp.optimizers);
        assert_eq!(back.datasets, nnp.datasets);
        assert_eq!(back.monitors, nnp.monitors);
        assert_eq!(back.executors, nnp.executors);
    }

    #[test]
    fn nntxt_is_human_readable_prototext() {
        let text = to_nntxt(&sample_nnp());
        assert!(text.contains("network {"));
        assert!(text.contains("type: \"Affine\""));
        assert!(text.contains("default_context: \"xla:half\""));
    }

    #[test]
    fn unsupported_function_is_an_error() {
        // the paper's converter behaviour: unsupported functions error
        let text = r#"
network {
  name: "n"
  function { name: "f" type: "QuantumConv" output: "y" }
}
"#;
        let err = from_nntxt(text).unwrap_err();
        assert!(err.contains("unsupported function 'QuantumConv'"), "{err}");
    }

    #[test]
    fn empty_nntxt_gives_default() {
        let nnp = from_nntxt("").unwrap();
        assert!(nnp.networks.is_empty());
        assert_eq!(nnp.global_config.default_context, "cpu:float");
    }
}
