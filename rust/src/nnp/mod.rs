//! The NNP model-interchange format (paper §3.1).
//!
//! An `.nnp` file is a small archive holding:
//! - `network.nntxt` — protobuf-text structure: GlobalConfig,
//!   TrainingConfig, Network(s), Dataset(s), Optimizer(s), Monitor(s),
//!   Executor(s);
//! - `parameter.h5b` — the parameter blob ("from the performance point
//!   of view, parameters can be saved in HDF5 format"): binary, with
//!   native dtype widths (bf16 params take 2 bytes/elem on disk).
//!
//! [`Nnp`] is the in-memory `NNablaProtoBuf` root message.

pub mod archive;
pub mod interpreter;
pub mod ir;
pub mod nntxt;
pub mod params;
pub mod passes;
pub mod plan;
pub mod trace;
pub mod verify;

pub use ir::{Layer, NetworkDef, Op, TensorDef};
pub use passes::{OptLevel, PassStat};
pub use plan::{CompiledNet, InferencePlan};
pub use trace::trace;
pub use verify::{Diagnostic, Report, Severity};

use crate::tensor::NdArray;
use std::collections::HashMap;
use std::path::Path;

/// GlobalConfig message: environment for training/inference.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalConfig {
    /// Extension-context spec, e.g. `"xla:half"` (Listing 2 analogue).
    pub default_context: String,
}

impl Default for GlobalConfig {
    fn default() -> Self {
        GlobalConfig { default_context: "cpu:float".into() }
    }
}

/// TrainingConfig message.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TrainingConfig {
    pub max_epoch: usize,
    pub iter_per_epoch: usize,
    pub batch_size: usize,
}

/// Dataset message: where training data comes from.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DatasetConfig {
    pub name: String,
    pub uri: String,
    pub batch_size: usize,
    pub shuffle: bool,
}

/// Optimizer message: network + dataset + solver binding.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OptimizerConfig {
    pub name: String,
    pub network: String,
    pub dataset: String,
    pub solver: String,
    pub learning_rate: f32,
    pub weight_decay: f32,
    pub loss_variable: String,
}

/// Monitor message: validation-time evaluation binding.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MonitorConfig {
    pub name: String,
    pub network: String,
    pub dataset: String,
    pub monitor_variable: String,
}

/// Executor message: inference I/O binding.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExecutorConfig {
    pub name: String,
    pub network: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

/// The NNablaProtoBuf root message.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Nnp {
    pub global_config: GlobalConfig,
    pub training_config: TrainingConfig,
    pub networks: Vec<NetworkDef>,
    pub parameters: Vec<(String, NdArray)>,
    pub datasets: Vec<DatasetConfig>,
    pub optimizers: Vec<OptimizerConfig>,
    pub monitors: Vec<MonitorConfig>,
    pub executors: Vec<ExecutorConfig>,
}

impl Nnp {
    /// Minimal NNP: one network + its parameters + a default executor.
    pub fn from_network(net: NetworkDef, params: Vec<(String, NdArray)>) -> Self {
        let executor = ExecutorConfig {
            name: format!("{}_executor", net.name),
            network: net.name.clone(),
            inputs: net.inputs.iter().map(|t| t.name.clone()).collect(),
            outputs: net.outputs.clone(),
        };
        Nnp {
            networks: vec![net],
            parameters: params,
            executors: vec![executor],
            ..Default::default()
        }
    }

    pub fn network(&self, name: &str) -> Option<&NetworkDef> {
        self.networks.iter().find(|n| n.name == name)
    }

    pub fn param_map(&self) -> HashMap<String, NdArray> {
        self.parameters.iter().cloned().collect()
    }

    /// Serialize to an `.nnp` archive on disk.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        let entries = vec![
            ("network.nntxt".to_string(), nntxt::to_nntxt(self).into_bytes()),
            ("parameter.h5b".to_string(), params::save_params(&self.parameters)),
        ];
        archive::write_archive(path, &entries).map_err(|e| e.to_string())
    }

    /// Load from an `.nnp` archive.
    pub fn load(path: &Path) -> Result<Nnp, String> {
        let entries = archive::read_archive(path).map_err(|e| e.to_string())?;
        let text = entries
            .iter()
            .find(|(n, _)| n == "network.nntxt")
            .ok_or("archive missing network.nntxt")?;
        let text = String::from_utf8(text.1.clone()).map_err(|_| "nntxt not utf8")?;
        let mut nnp = nntxt::from_nntxt(&text)?;
        if let Some((_, blob)) = entries.iter().find(|(n, _)| n == "parameter.h5b") {
            nnp.parameters = params::load_params(blob)?;
        }
        Ok(nnp)
    }

    /// Compile a named network (or the first one) against this NNP's
    /// parameters for repeated inference — the load-time half of the
    /// deployment path (see [`plan::CompiledNet`]).
    pub fn compile(&self, network: Option<&str>) -> Result<CompiledNet, String> {
        let net = match network {
            Some(n) => self.network(n).ok_or_else(|| format!("no network '{n}'"))?,
            None => self
                .networks
                .first()
                .ok_or_else(|| "NNP holds no networks".to_string())?,
        };
        CompiledNet::compile(net, &self.param_map())
    }

    /// Run a named executor on inputs (deployment inference).
    pub fn execute(
        &self,
        executor: &str,
        inputs: &HashMap<String, NdArray>,
    ) -> Result<Vec<NdArray>, String> {
        let ex = self
            .executors
            .iter()
            .find(|e| e.name == executor)
            .ok_or_else(|| format!("no executor '{executor}'"))?;
        let net = self
            .network(&ex.network)
            .ok_or_else(|| format!("executor references missing network '{}'", ex.network))?;
        interpreter::run(net, inputs, &self.param_map())
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, TensorDef};

    pub(crate) fn sample_nnp() -> Nnp {
        let net = NetworkDef {
            name: "main".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "fc".into(),
                op: Op::Affine,
                inputs: vec!["x".into()],
                params: vec!["fc/W".into(), "fc/b".into()],
                outputs: vec!["y".into()],
            }],
        };
        let params = vec![
            ("fc/W".to_string(), NdArray::arange(&[3, 2])),
            ("fc/b".to_string(), NdArray::from_slice(&[2], &[0.5, -0.5])),
        ];
        let mut nnp = Nnp::from_network(net, params);
        nnp.global_config.default_context = "xla:half".into();
        nnp.training_config = TrainingConfig { max_epoch: 3, iter_per_epoch: 10, batch_size: 4 };
        nnp.optimizers.push(OptimizerConfig {
            name: "opt".into(),
            network: "main".into(),
            dataset: "train".into(),
            solver: "Adam".into(),
            learning_rate: 1e-3,
            weight_decay: 1e-4,
            loss_variable: "y".into(),
        });
        nnp.datasets.push(DatasetConfig {
            name: "train".into(),
            uri: "synthetic://imagenet-mini".into(),
            batch_size: 4,
            shuffle: true,
        });
        nnp.monitors.push(MonitorConfig {
            name: "valid".into(),
            network: "main".into(),
            dataset: "train".into(),
            monitor_variable: "y".into(),
        });
        nnp
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nnl_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.nnp");
        let nnp = sample_nnp();
        nnp.save(&path).unwrap();
        let back = Nnp::load(&path).unwrap();
        assert_eq!(back.networks, nnp.networks);
        assert_eq!(back.global_config, nnp.global_config);
        assert_eq!(back.training_config, nnp.training_config);
        assert_eq!(back.optimizers, nnp.optimizers);
        assert_eq!(back.datasets, nnp.datasets);
        assert_eq!(back.monitors, nnp.monitors);
        assert_eq!(back.executors, nnp.executors);
        assert_eq!(back.parameters.len(), 2);
        assert_eq!(back.parameters[0].1.data(), nnp.parameters[0].1.data());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn execute_runs_default_executor() {
        let nnp = sample_nnp();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[1., 0., 0.]));
        let out = nnp.execute("main_executor", &inputs).unwrap();
        // row 0 of W = [0,1], + b = [0.5, 0.5]
        assert_eq!(out[0].data(), &[0.5, 0.5]);
    }

    #[test]
    fn execute_unknown_executor_errs() {
        let nnp = sample_nnp();
        assert!(nnp.execute("nope", &HashMap::new()).is_err());
    }
}
