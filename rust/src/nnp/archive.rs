//! The `.nnp` container: a minimal named-entry archive
//! (`magic | count | {name_len, name, data_len, data}*` with a CRC).
//! Stands in for the zip container real NNabla uses; the contract —
//! one file carrying structure text + parameter blob — is identical.

use std::fs;
use std::io::{self, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"NNPA";

fn crc32(data: &[u8]) -> u32 {
    // standard CRC-32 (IEEE), bitwise implementation
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Write entries `(name, bytes)` to `path`.
pub fn write_archive(path: &Path, entries: &[(String, Vec<u8>)]) -> io::Result<()> {
    let mut body = Vec::new();
    body.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    for (name, data) in entries {
        let nb = name.as_bytes();
        body.extend_from_slice(&(nb.len() as u32).to_le_bytes());
        body.extend_from_slice(nb);
        body.extend_from_slice(&(data.len() as u64).to_le_bytes());
        body.extend_from_slice(data);
    }
    let mut f = fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&crc32(&body).to_le_bytes())?;
    f.write_all(&body)?;
    Ok(())
}

/// Read all entries from `path`.
pub fn read_archive(path: &Path) -> io::Result<Vec<(String, Vec<u8>)>> {
    let mut f = fs::File::open(path)?;
    let mut all = Vec::new();
    f.read_to_end(&mut all)?;
    // the full 8-byte header (magic + CRC) must be present before any
    // of it is indexed: a 4-7 byte file is "truncated", not a panic
    if all.len() < 8 {
        return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated NNP archive header"));
    }
    if &all[0..4] != MAGIC {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "not an NNP archive"));
    }
    let stored_crc = u32::from_le_bytes(all[4..8].try_into().unwrap());
    let body = &all[8..];
    if crc32(body) != stored_crc {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "NNP archive CRC mismatch"));
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> io::Result<&[u8]> {
        // untrusted length: compare against the remaining bytes (never
        // `pos + n`, which a crafted u64 length could overflow)
        if n > body.len() - *pos {
            return Err(io::Error::new(io::ErrorKind::UnexpectedEof, "truncated archive"));
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    // every entry costs at least 12 header bytes: reject implausible
    // counts before allocating
    if count > body.len() / 12 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "implausible archive entry count"));
    }
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad entry name"))?;
        let data_len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
        let data = take(&mut pos, data_len)?.to_vec();
        out.push((name, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("nnl_arch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let p = tmp("a.nnp");
        let entries = vec![
            ("net.nntxt".to_string(), b"hello".to_vec()),
            ("params".to_string(), vec![0u8, 1, 2, 255]),
            ("empty".to_string(), vec![]),
        ];
        write_archive(&p, &entries).unwrap();
        assert_eq!(read_archive(&p).unwrap(), entries);
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("b.nnp");
        std::fs::write(&p, b"ZIPPfakedata").unwrap();
        assert!(read_archive(&p).is_err());
    }

    #[test]
    fn detects_corruption() {
        let p = tmp("c.nnp");
        write_archive(&p, &[("x".into(), vec![1, 2, 3, 4])]).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = read_archive(&p).unwrap_err();
        assert!(err.to_string().contains("CRC"));
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn rejects_short_headers() {
        // regression: a 4-7 byte file reaches the CRC read; it must be
        // a clean truncation error, not an index panic
        for len in 0..8usize {
            let p = tmp(&format!("short_{len}.nnp"));
            std::fs::write(&p, &b"NNPAxxxx"[..len]).unwrap();
            let err = read_archive(&p).unwrap_err();
            assert!(
                err.to_string().contains("truncated") || err.to_string().contains("not an"),
                "len {len}: {err}"
            );
        }
    }

    #[test]
    fn every_truncation_errs_cleanly() {
        let p = tmp("trunc.nnp");
        let entries = vec![
            ("net.nntxt".to_string(), b"network { }".to_vec()),
            ("parameter.h5b".to_string(), vec![7u8; 64]),
        ];
        write_archive(&p, &entries).unwrap();
        let full = std::fs::read(&p).unwrap();
        let cut = tmp("trunc_cut.nnp");
        for len in 0..full.len() {
            std::fs::write(&cut, &full[..len]).unwrap();
            assert!(read_archive(&cut).is_err(), "prefix of {len} bytes parsed");
        }
    }

    #[test]
    fn every_single_byte_flip_errs_cleanly() {
        // the CRC covers the whole body, so any flip must surface as a
        // clean error (and flips in magic/CRC fail their own checks)
        let p = tmp("flip.nnp");
        write_archive(&p, &[("x".into(), (0u8..200).collect())]).unwrap();
        let full = std::fs::read(&p).unwrap();
        let flip = tmp("flip_mut.nnp");
        for i in 0..full.len() {
            let mut bytes = full.clone();
            bytes[i] ^= 0x80;
            std::fs::write(&flip, &bytes).unwrap();
            assert!(read_archive(&flip).is_err(), "flip at byte {i} parsed");
        }
    }
}
