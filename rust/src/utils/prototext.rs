//! Protobuf-text-style generic tree (emitter + parser) — the syntax of
//! the paper's `.nntxt` files:
//!
//! ```text
//! network {
//!   name: "net"
//!   layer {
//!     op: "Affine"
//!     input: "x"
//!   }
//! }
//! ```
//!
//! Repeated keys express lists; nested messages use braces.

/// A field value.
#[derive(Debug, Clone, PartialEq)]
pub enum PVal {
    Str(String),
    Num(f64),
    Bool(bool),
    Msg(PText),
}

/// An ordered multimap of fields (repeated keys allowed).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PText {
    pub fields: Vec<(String, PVal)>,
}

impl PText {
    pub fn new() -> Self {
        PText::default()
    }

    pub fn push(&mut self, key: &str, val: PVal) {
        self.fields.push((key.to_string(), val));
    }

    pub fn push_str(&mut self, key: &str, s: impl Into<String>) {
        self.push(key, PVal::Str(s.into()));
    }

    pub fn push_num(&mut self, key: &str, n: f64) {
        self.push(key, PVal::Num(n));
    }

    /// First value for a key.
    pub fn get(&self, key: &str) -> Option<&PVal> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// All values for a (repeated) key.
    pub fn get_all(&self, key: &str) -> Vec<&PVal> {
        self.fields.iter().filter(|(k, _)| k == key).map(|(_, v)| v).collect()
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.get(key)? {
            PVal::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn get_num(&self, key: &str) -> Option<f64> {
        match self.get(key)? {
            PVal::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn get_msg(&self, key: &str) -> Option<&PText> {
        match self.get(key)? {
            PVal::Msg(m) => Some(m),
            _ => None,
        }
    }

    /// Repeated numeric key as usize list (`dim: 1 dim: 4`).
    pub fn get_usizes(&self, key: &str) -> Vec<usize> {
        self.get_all(key)
            .into_iter()
            .filter_map(|v| match v {
                PVal::Num(n) => Some(*n as usize),
                _ => None,
            })
            .collect()
    }

    // -------------------------------------------------------------- emit

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        for (k, v) in &self.fields {
            for _ in 0..indent {
                out.push(' ');
            }
            match v {
                PVal::Str(s) => {
                    out.push_str(k);
                    out.push_str(": \"");
                    for c in s.chars() {
                        match c {
                            '"' => out.push_str("\\\""),
                            '\\' => out.push_str("\\\\"),
                            '\n' => out.push_str("\\n"),
                            c => out.push(c),
                        }
                    }
                    out.push_str("\"\n");
                }
                PVal::Num(n) => {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{k}: {}\n", *n as i64));
                    } else {
                        out.push_str(&format!("{k}: {n}\n"));
                    }
                }
                PVal::Bool(b) => {
                    out.push_str(&format!("{k}: {b}\n"));
                }
                PVal::Msg(m) => {
                    out.push_str(k);
                    out.push_str(" {\n");
                    m.write(out, indent + 2);
                    for _ in 0..indent {
                        out.push(' ');
                    }
                    out.push_str("}\n");
                }
            }
        }
    }

    // -------------------------------------------------------------- parse

    pub fn parse(src: &str) -> Result<PText, String> {
        let mut toks = tokenize(src)?;
        toks.reverse(); // pop from the back
        let msg = parse_fields(&mut toks, true)?;
        Ok(msg)
    }
}

#[derive(Debug, PartialEq)]
enum Tok {
    Ident(String),
    Str(String),
    Num(f64),
    Bool(bool),
    Colon,
    LBrace,
    RBrace,
}

fn tokenize(src: &str) -> Result<Vec<Tok>, String> {
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    let mut out = Vec::new();
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' | '\n' | '\r' | ',' => i += 1,
            '#' => {
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
            }
            ':' => {
                out.push(Tok::Colon);
                i += 1;
            }
            '{' => {
                out.push(Tok::LBrace);
                i += 1;
            }
            '}' => {
                out.push(Tok::RBrace);
                i += 1;
            }
            '"' => {
                i += 1;
                let mut s = String::new();
                while i < b.len() && b[i] != '"' {
                    if b[i] == '\\' && i + 1 < b.len() {
                        i += 1;
                        s.push(match b[i] {
                            'n' => '\n',
                            't' => '\t',
                            c => c,
                        });
                    } else {
                        s.push(b[i]);
                    }
                    i += 1;
                }
                if i >= b.len() {
                    return Err("unterminated string".into());
                }
                i += 1;
                out.push(Tok::Str(s));
            }
            c if c == '-' || c.is_ascii_digit() => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_digit() || matches!(b[i], '.' | 'e' | 'E' | '+' | '-'))
                {
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                out.push(Tok::Num(s.parse().map_err(|_| format!("bad number '{s}'"))?));
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                let s: String = b[start..i].iter().collect();
                match s.as_str() {
                    "true" => out.push(Tok::Bool(true)),
                    "false" => out.push(Tok::Bool(false)),
                    _ => out.push(Tok::Ident(s)),
                }
            }
            c => return Err(format!("unexpected char '{c}'")),
        }
    }
    Ok(out)
}

fn parse_fields(toks: &mut Vec<Tok>, top: bool) -> Result<PText, String> {
    let mut msg = PText::new();
    loop {
        match toks.pop() {
            None => {
                if top {
                    return Ok(msg);
                }
                return Err("unexpected end of input".into());
            }
            Some(Tok::RBrace) => {
                if top {
                    return Err("unbalanced '}'".into());
                }
                return Ok(msg);
            }
            Some(Tok::Ident(key)) => match toks.pop() {
                Some(Tok::Colon) => {
                    let v = match toks.pop() {
                        Some(Tok::Str(s)) => PVal::Str(s),
                        Some(Tok::Num(n)) => PVal::Num(n),
                        Some(Tok::Bool(b)) => PVal::Bool(b),
                        _ => return Err(format!("expected value after '{key}:'")),
                    };
                    msg.push(&key, v);
                }
                Some(Tok::LBrace) => {
                    let inner = parse_fields(toks, false)?;
                    msg.push(&key, PVal::Msg(inner));
                }
                _ => return Err(format!("expected ':' or '{{' after '{key}'")),
            },
            Some(t) => return Err(format!("unexpected token {t:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_and_parse_roundtrip() {
        let mut inner = PText::new();
        inner.push_str("name", "fc1");
        inner.push_num("units", 128.0);
        inner.push("train", PVal::Bool(true));
        let mut root = PText::new();
        root.push_str("version", "1.0");
        root.push("layer", PVal::Msg(inner.clone()));
        root.push("layer", PVal::Msg(inner));
        let text = root.to_string();
        let back = PText::parse(&text).unwrap();
        assert_eq!(back, root);
        assert_eq!(back.get_all("layer").len(), 2);
    }

    #[test]
    fn repeated_scalars_as_list() {
        let p = PText::parse("dim: 1 dim: 4 dim: 28").unwrap();
        assert_eq!(p.get_usizes("dim"), vec![1, 4, 28]);
    }

    #[test]
    fn comments_and_commas_skipped() {
        let p = PText::parse("# a comment\nname: \"x\", value: 3\n").unwrap();
        assert_eq!(p.get_str("name"), Some("x"));
        assert_eq!(p.get_num("value"), Some(3.0));
    }

    #[test]
    fn string_escapes() {
        let mut root = PText::new();
        root.push_str("s", "a\"b\\c\nd");
        let back = PText::parse(&root.to_string()).unwrap();
        assert_eq!(back.get_str("s"), Some("a\"b\\c\nd"));
    }

    #[test]
    fn nested_messages() {
        let p = PText::parse("a { b { c: 1 } }").unwrap();
        assert_eq!(p.get_msg("a").unwrap().get_msg("b").unwrap().get_num("c"), Some(1.0));
    }

    #[test]
    fn errors_on_malformed() {
        assert!(PText::parse("a {").is_err());
        assert!(PText::parse("}").is_err());
        assert!(PText::parse("a: ").is_err());
        assert!(PText::parse("\"floating\"").is_err());
    }
}
