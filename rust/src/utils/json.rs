//! Minimal JSON parser + emitter (offline replacement for serde_json).
//! Used for the artifact manifest, Console trial records, and monitor
//! metadata. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for deterministic
/// output — trial records are diffed in tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -------------------------------------------------------------- access

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with Null fallback.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ------------------------------------------------------------ builders

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_of_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // -------------------------------------------------------------- encode

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    // -------------------------------------------------------------- decode

    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null"); // JSON has no inf/nan
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            o.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"x"],"obj":{"k":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] extra").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn usize_arr_helper() {
        let j = Json::arr_of_usize(&[3, 4, 5]);
        assert_eq!(j.usize_arr().unwrap(), vec![3, 4, 5]);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }
}
