//! bfloat16 / float16 conversion (round-to-nearest-even), used to
//! simulate half-precision *storage* for mixed-precision training
//! (paper §3.3) without a half crate.

/// f32 -> bf16 bits (round-to-nearest-even) -> f32.
#[inline]
pub fn bf16_round(v: f32) -> f32 {
    let bits = v.to_bits();
    if v.is_nan() {
        return f32::from_bits(bits | 0x0040_0000); // quiet NaN, keep payload bit
    }
    // round to nearest even on the truncated 16 bits
    let rounding_bias = 0x7FFF + ((bits >> 16) & 1);
    let r = bits.wrapping_add(rounding_bias) & 0xFFFF_0000;
    f32::from_bits(r)
}

/// f32 -> IEEE-754 binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x007F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        // overflow -> Inf
        return sign | 0x7C00;
    }
    if e >= -14 {
        // normal f16
        let mut m = mant >> 13; // keep 10 bits
        let rem = mant & 0x1FFF;
        // round to nearest even
        if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            // mantissa overflow carries into exponent
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7C00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e >= -25 {
        // subnormal f16 (e == -25 can still round up to the smallest
        // subnormal 2^-24)
        let full = mant | 0x0080_0000; // implicit leading 1
        let shift = (-14 - e) as u32 + 13;
        let m = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m16 = m as u16;
        if rem > half || (rem == half && (m16 & 1) == 1) {
            m16 += 1;
        }
        return sign | m16;
    }
    // underflow to signed zero
    sign
}

/// IEEE-754 binary16 bits -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize. value = mant * 2^-24; with k shifts
            // to set bit 10, f32 exponent field = 113 - k.
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03FF;
            sign | (((113 + e) as u32) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// f32 -> f16 grid -> f32 (round-trip through binary16).
#[inline]
pub fn f16_round(v: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(v))
}

/// Largest finite binary16 value.
pub const F16_MAX: f32 = 65504.0;
/// Largest finite bfloat16 value.
pub const BF16_MAX: f32 = 3.3895314e38;

/// Serialize an f32 slice to little-endian bytes on a dtype grid
/// (bf16/f16 are stored in 2 bytes — real size on disk matters for the
/// NNP parameter blob).
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    (bf16_round(v).to_bits() >> 16) as u16
}

/// bf16 bits -> f32.
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bf16_exact_values_unchanged() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0, -0.125] {
            assert_eq!(bf16_round(v), v);
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // bf16 has 7 mantissa bits: 1 + 2^-7 is exactly representable;
        // 1 + 2^-8 is a tie and rounds to 1.0 (even)
        assert_eq!(bf16_round(1.0 + 2f32.powi(-7)), 1.0 + 2f32.powi(-7));
        assert_eq!(bf16_round(1.0 + 2f32.powi(-8)), 1.0);
        // 1 + 3*2^-8 is a tie between 1+2^-7 (odd lsb) and 1+2^-6
        // (even lsb): ties-to-even picks 1+2^-6
        assert_eq!(bf16_round(1.0 + 3.0 * 2f32.powi(-8)), 1.0 + 2f32.powi(-6));
        // a non-tie just above 1+2^-7 rounds down to it
        assert_eq!(bf16_round(1.0 + 5.0 * 2f32.powi(-9)), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn bf16_keeps_inf_nan() {
        assert!(bf16_round(f32::INFINITY).is_infinite());
        assert!(bf16_round(f32::NAN).is_nan());
    }

    #[test]
    fn f16_exact_small_ints() {
        for v in [0.0f32, 1.0, -1.0, 2.0, 1024.0, 2048.0, -0.5] {
            assert_eq!(f16_round(v), v, "{v}");
        }
    }

    #[test]
    fn f16_max_and_overflow() {
        assert_eq!(f16_round(65504.0), 65504.0);
        assert!(f16_round(65520.0).is_infinite()); // rounds past max
        assert!(f16_round(70000.0).is_infinite());
        assert_eq!(f16_round(-65504.0), -65504.0);
    }

    #[test]
    fn f16_subnormals() {
        let min_sub = 2f32.powi(-24);
        assert_eq!(f16_round(min_sub), min_sub);
        assert_eq!(f16_round(min_sub * 0.49), 0.0); // underflow
        let v = 3.0 * 2f32.powi(-24);
        assert_eq!(f16_round(v), v);
    }

    #[test]
    fn f16_mantissa_precision() {
        // f16 has 10 mantissa bits: 1 + 2^-10 representable, 1 + 2^-11 not
        assert_eq!(f16_round(1.0 + 2f32.powi(-10)), 1.0 + 2f32.powi(-10));
        assert_eq!(f16_round(1.0 + 2f32.powi(-11)), 1.0);
    }

    #[test]
    fn f16_bits_roundtrip_all() {
        // every finite f16 bit pattern round-trips exactly
        for h in 0..=0xFFFFu32 {
            let h = h as u16;
            let exp = (h >> 10) & 0x1F;
            if exp == 31 {
                continue; // inf/nan
            }
            let f = f16_bits_to_f32(h);
            assert_eq!(f32_to_f16_bits(f), h, "bits {h:#06x} -> {f} -> mismatch");
        }
    }

    #[test]
    fn bf16_bits_roundtrip() {
        for v in [1.0f32, -3.5, 0.0, 1e30, -2e-30] {
            let b = f32_to_bf16_bits(v);
            assert_eq!(bf16_bits_to_f32(b), bf16_round(v));
        }
    }
}
