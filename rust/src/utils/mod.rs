//! Self-contained utilities replacing crates unavailable in the
//! offline build: half-precision conversion, a JSON parser/emitter,
//! and a tiny property-testing helper.

pub mod bench;
pub mod half;
pub mod json;
pub mod prop;
pub mod prototext;
