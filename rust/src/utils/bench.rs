//! Minimal benchmark harness (criterion is unavailable offline): warms
//! up, runs timed iterations, reports mean/min secs per iteration.

use std::time::Instant;

/// Result of one measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_secs
    }
}

/// Time `f` over `iters` iterations after `warmup` unmeasured calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    Measurement { name: name.to_string(), iters, mean_secs: mean, min_secs: min }
}

/// Render measurements as an aligned table with a ratio column
/// relative to the first row (the paper tables' "speedup" column).
pub fn table(title: &str, rows: &[Measurement]) -> String {
    let mut s = format!("\n=== {title} ===\n");
    s.push_str(&format!(
        "{:<38} {:>7} {:>12} {:>12} {:>9}\n",
        "case", "iters", "mean_ms", "min_ms", "vs_first"
    ));
    let base = rows.first().map(|r| r.mean_secs).unwrap_or(1.0);
    for r in rows {
        s.push_str(&format!(
            "{:<38} {:>7} {:>12.3} {:>12.3} {:>8.2}x\n",
            r.name,
            r.iters,
            r.mean_secs * 1e3,
            r.min_secs * 1e3,
            base / r.mean_secs
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let m = bench("spin", 1, 3, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert!(m.mean_secs > 0.0);
        assert!(m.min_secs <= m.mean_secs);
        assert_eq!(m.iters, 3);
    }

    #[test]
    fn table_has_ratio_column() {
        let rows = vec![
            Measurement { name: "a".into(), iters: 1, mean_secs: 0.2, min_secs: 0.2 },
            Measurement { name: "b".into(), iters: 1, mean_secs: 0.1, min_secs: 0.1 },
        ];
        let t = table("t", &rows);
        assert!(t.contains("2.00x"));
    }
}
