//! `Variable`: the paper's first building block — "data and their
//! gradients with multi-dimensional arrays" (§2.1) — plus the tape
//! machinery that makes `forward()` / `backward()` work.
//!
//! Every function node on the tape carries a first-class
//! [`Op`] descriptor (the same registry the NNP IR, the converters and
//! the deployment interpreter use), so a define-by-run graph is
//! *self-describing*: `nnp::trace` can walk the tape and emit a
//! [`crate::nnp::NetworkDef`] with zero dual bookkeeping.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

/// Forward closure of a function node: recompute output data from
/// current input data (enables static-graph reuse on new leaf data).
pub type FwdFn = Box<dyn Fn(&[NdArray]) -> NdArray>;

/// Backward closure: given (input data, output data, output grad),
/// return one optional gradient per input (None = not differentiable /
/// not needed).
pub type BwdFn = Box<dyn Fn(&[NdArray], &NdArray, &NdArray) -> Vec<Option<NdArray>>>;

struct FunctionNode {
    /// The operator descriptor: typed attributes + registry identity.
    op: Op,
    inputs: Vec<Variable>,
    fwd: FwdFn,
    bwd: BwdFn,
}

struct VarInner {
    data: NdArray,
    grad: Option<NdArray>,
    need_grad: bool,
    name: String,
    creator: Option<Rc<FunctionNode>>,
}

/// A node in the computation graph. Cheap to clone (shared interior).
///
/// Mirrors `nn.Variable`: `.d` ↔ [`Variable::data`]/[`set_data`],
/// `.g` ↔ [`Variable::grad`], `.forward()` / `.backward()` as in
/// Listing 1.
#[derive(Clone)]
pub struct Variable(Rc<RefCell<VarInner>>);

impl Variable {
    // ------------------------------------------------------------- leaves

    /// New leaf variable holding `data`.
    pub fn from_array(data: NdArray, need_grad: bool) -> Self {
        Variable(Rc::new(RefCell::new(VarInner {
            data,
            grad: None,
            need_grad,
            name: String::new(),
            creator: None,
        })))
    }

    /// `nn.Variable(shape, need_grad=...)` — zero-initialized leaf.
    pub fn new(dims: &[usize], need_grad: bool) -> Self {
        Self::from_array(NdArray::zeros(dims), need_grad)
    }

    /// Result of a function application (framework-internal): records a
    /// tape node carrying the [`Op`] descriptor plus its forward /
    /// backward closures, and runs the forward immediately
    /// (define-by-run).
    pub fn from_function(op: Op, inputs: &[&Variable], fwd: FwdFn, bwd: BwdFn) -> Self {
        let in_data: Vec<NdArray> = inputs.iter().map(|v| v.data()).collect();
        let out = fwd(&in_data);
        let need_grad = inputs.iter().any(|v| v.need_grad());
        let node = FunctionNode {
            op,
            inputs: inputs.iter().map(|&v| v.clone()).collect(),
            fwd,
            bwd,
        };
        Variable(Rc::new(RefCell::new(VarInner {
            data: out,
            grad: None,
            need_grad,
            name: String::new(),
            creator: Some(Rc::new(node)),
        })))
    }

    // ----------------------------------------------------------- accessors

    /// Copy of the data array (`x.d` read). O(1): `NdArray` storage is
    /// copy-on-write, so this only bumps a reference count.
    pub fn data(&self) -> NdArray {
        self.0.borrow().data.clone()
    }

    /// Borrow the data without cloning; `f` must not re-enter the graph.
    pub fn with_data<R>(&self, f: impl FnOnce(&NdArray) -> R) -> R {
        f(&self.0.borrow().data)
    }

    /// Set leaf data (`x.d = ...` write).
    pub fn set_data(&self, data: NdArray) {
        let mut inner = self.0.borrow_mut();
        assert_eq!(
            inner.data.dims(),
            data.dims(),
            "set_data shape mismatch on '{}'",
            inner.name
        );
        inner.data = data;
    }

    /// Copy of the gradient (`x.g`), zeros if never written.
    pub fn grad(&self) -> NdArray {
        let inner = self.0.borrow();
        inner.grad.clone().unwrap_or_else(|| NdArray::zeros(inner.data.dims()))
    }

    /// Overwrite the gradient array.
    pub fn set_grad(&self, g: NdArray) {
        self.0.borrow_mut().grad = Some(g);
    }

    /// Zero / clear the gradient.
    pub fn zero_grad(&self) {
        self.0.borrow_mut().grad = None;
    }

    pub fn need_grad(&self) -> bool {
        self.0.borrow().need_grad
    }

    pub fn set_need_grad(&self, ng: bool) {
        self.0.borrow_mut().need_grad = ng;
    }

    pub fn name(&self) -> String {
        self.0.borrow().name.clone()
    }

    pub fn set_name(&self, name: &str) {
        self.0.borrow_mut().name = name.to_string();
    }

    pub fn dims(&self) -> Vec<usize> {
        self.0.borrow().data.dims().to_vec()
    }

    pub fn size(&self) -> usize {
        self.0.borrow().data.size()
    }

    /// Scalar value of a size-1 variable.
    pub fn item(&self) -> f32 {
        self.0.borrow().data.item()
    }

    /// True if this is a leaf (no creator function).
    pub fn is_leaf(&self) -> bool {
        self.0.borrow().creator.is_none()
    }

    /// Stable identity of this variable's shared interior — two clones
    /// of the same variable have the same `uid`. Used by `nnp::trace`
    /// to match tape inputs against the parameter registry.
    pub fn uid(&self) -> usize {
        Rc::as_ptr(&self.0) as usize
    }

    /// The [`Op`] descriptor of the function that produced this
    /// variable (`None` for leaves).
    pub fn creator_op(&self) -> Option<Op> {
        self.0.borrow().creator.as_ref().map(|n| n.op.clone())
    }

    /// Inputs of the function that produced this variable (empty for
    /// leaves), in op-defined order (activations first, then
    /// parameters).
    pub fn creator_inputs(&self) -> Vec<Variable> {
        self.0
            .borrow()
            .creator
            .as_ref()
            .map(|n| n.inputs.clone())
            .unwrap_or_default()
    }

    // ---------------------------------------------------------- execution

    /// Topological order of function-producing variables ending at self
    /// (leaves excluded), dependencies first.
    fn topo_order(&self) -> Vec<Variable> {
        let mut order = Vec::new();
        let mut seen = HashSet::new();
        // iterative DFS with explicit stack (graphs can be deep)
        enum Step {
            Visit(Variable),
            Emit(Variable),
        }
        let mut stack = vec![Step::Visit(self.clone())];
        while let Some(step) = stack.pop() {
            match step {
                Step::Visit(v) => {
                    if !seen.insert(v.uid()) {
                        continue;
                    }
                    let creator = v.0.borrow().creator.clone();
                    if let Some(node) = creator {
                        stack.push(Step::Emit(v));
                        for inp in node.inputs.iter().rev() {
                            stack.push(Step::Visit(inp.clone()));
                        }
                    }
                }
                Step::Emit(v) => order.push(v),
            }
        }
        order
    }

    /// Re-execute the recorded graph bottom-up using the *current* leaf
    /// data — the static-graph usage of Figure 1: build once, then
    /// `x.d = batch; y.forward()` per batch.
    ///
    /// Hot path: the per-node input gather hands the closures O(1)
    /// copy-on-write handles (`NdArray` storage is `Arc`-backed), not
    /// buffer copies — the clones here cost a refcount bump.
    pub fn forward(&self) {
        for v in self.topo_order() {
            let node = v.0.borrow().creator.clone().expect("topo_order yields non-leaves");
            let in_data: Vec<NdArray> =
                node.inputs.iter().map(|i| i.with_data(|d| d.clone())).collect();
            let out = (node.fwd)(&in_data);
            v.0.borrow_mut().data = out;
        }
    }

    /// Backpropagate from this variable. `grad_seed` scales the seed
    /// gradient — this is exactly `loss.backward(loss_scale)` from the
    /// paper's mixed-precision Listing 6 (seed = loss_scale instead of
    /// 1). Gradients accumulate into `.g`; call [`Variable::zero_grad`]
    /// (or solver `zero_grad`) between iterations.
    pub fn backward_with_scale(&self, grad_seed: f32) {
        self.backward_impl(grad_seed, None);
    }

    /// [`backward_with_scale`] plus a completion hook: `hook` fires
    /// exactly once per `need_grad` **leaf** reachable from this
    /// variable, at the moment that leaf's gradient is final for this
    /// pass (its last pending contribution was processed — including
    /// contributions that turned out to be skipped or `None`). The tape
    /// knows completion order, so distributed training uses this to
    /// launch a gradient bucket's all-reduce while backward is still
    /// running on earlier layers (`comm::bucket`). Firing order depends
    /// only on graph structure, never on gradient values.
    ///
    /// [`backward_with_scale`]: Variable::backward_with_scale
    pub fn backward_with_hook(&self, grad_seed: f32, hook: &mut dyn FnMut(&Variable)) {
        self.backward_impl(grad_seed, Some(hook));
    }

    fn backward_impl(&self, grad_seed: f32, mut hook: Option<&mut dyn FnMut(&Variable)>) {
        let order = self.topo_order();
        // Pending gradient contributions per need_grad leaf: one per
        // occurrence as a function input. The hook fires when a leaf's
        // count hits zero — counts drop even when a node contributes
        // nothing (need_grad off, no gradient flowed, bwd returned
        // None), otherwise a dead branch would starve the hook.
        let mut pending: HashMap<usize, (Variable, usize)> = HashMap::new();
        if hook.is_some() {
            for v in &order {
                let node = v.0.borrow().creator.clone().expect("topo_order yields non-leaves");
                for inp in node.inputs.iter() {
                    if inp.is_leaf() && inp.need_grad() {
                        pending.entry(inp.uid()).or_insert_with(|| (inp.clone(), 0)).1 += 1;
                    }
                }
            }
        }
        // Intermediate (non-leaf) grads are transient: clear them so
        // repeated backward calls accumulate only into leaves (PyTorch
        // / NNabla semantics).
        for v in &order {
            v.0.borrow_mut().grad = None;
        }
        // seed
        {
            let mut inner = self.0.borrow_mut();
            let dims: Vec<usize> = inner.data.dims().to_vec();
            inner.grad = Some(NdArray::full(&dims, grad_seed));
        }
        for v in order.iter().rev() {
            v.propagate_node();
            if let Some(h) = hook.as_mut() {
                let node = v.0.borrow().creator.clone().expect("topo_order yields non-leaves");
                for inp in node.inputs.iter() {
                    if inp.is_leaf() && inp.need_grad() {
                        if let Some(entry) = pending.get_mut(&inp.uid()) {
                            entry.1 -= 1;
                            if entry.1 == 0 {
                                h(&entry.0);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Run one function node's backward and accumulate into its inputs
    /// (no-op when no gradient flowed here).
    fn propagate_node(&self) {
        if !self.need_grad() {
            return;
        }
        let (node, out_data, out_grad) = {
            let inner = self.0.borrow();
            let g = match &inner.grad {
                Some(g) => g.clone(),
                None => return, // no gradient flowed here
            };
            (inner.creator.clone().unwrap(), inner.data.clone(), g)
        };
        // O(1) copy-on-write clones — the backward closures see
        // the same buffers, never copies.
        let in_data: Vec<NdArray> =
            node.inputs.iter().map(|i| i.with_data(|d| d.clone())).collect();
        let grads = (node.bwd)(&in_data, &out_data, &out_grad);
        assert_eq!(
            grads.len(),
            node.inputs.len(),
            "function '{}' returned {} grads for {} inputs",
            node.op.name(),
            grads.len(),
            node.inputs.len()
        );
        for (inp, g) in node.inputs.iter().zip(grads) {
            if !inp.need_grad() {
                continue;
            }
            if let Some(g) = g {
                assert_eq!(
                    g.dims(),
                    inp.dims(),
                    "function '{}' produced grad shape {:?} for input shape {:?}",
                    node.op.name(),
                    g.dims(),
                    inp.dims()
                );
                let mut inner = inp.0.borrow_mut();
                inner.grad = Some(match inner.grad.take() {
                    Some(acc) => ops::add(&acc, &g),
                    None => g,
                });
            }
        }
    }

    /// `y.backward()` — seed gradient of ones.
    pub fn backward(&self) {
        self.backward_with_scale(1.0);
    }

    /// Number of function nodes in the recorded graph (used by the
    /// Console's workload footprinting and by tests).
    pub fn node_count(&self) -> usize {
        self.topo_order().len()
    }

    /// Canonical names of function nodes in topological order (graph
    /// inspection / NNP export) — these are the registry names of each
    /// node's [`Op`] descriptor.
    pub fn function_names(&self) -> Vec<&'static str> {
        self.topo_order()
            .iter()
            .map(|v| v.0.borrow().creator.as_ref().unwrap().op.name())
            .collect()
    }
}

impl Drop for VarInner {
    /// Iterative teardown: naive recursive `Drop` of a deep tape (tens
    /// of thousands of chained nodes) overflows the stack, so detach
    /// creators onto an explicit worklist instead.
    fn drop(&mut self) {
        let mut stack: Vec<Rc<FunctionNode>> = Vec::new();
        if let Some(n) = self.creator.take() {
            stack.push(n);
        }
        while let Some(node) = stack.pop() {
            if let Ok(mut node) = Rc::try_unwrap(node) {
                for inp in node.inputs.drain(..) {
                    if let Ok(cell) = Rc::try_unwrap(inp.0) {
                        let mut inner = cell.into_inner();
                        if let Some(c) = inner.creator.take() {
                            stack.push(c);
                        }
                        // inner now drops with creator == None: no recursion
                    }
                }
            }
        }
    }
}

impl std::fmt::Debug for Variable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.0.borrow();
        write!(
            f,
            "Variable(name={:?}, shape={:?}, need_grad={}, leaf={})",
            inner.name,
            inner.data.dims(),
            inner.need_grad,
            inner.creator.is_none()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops;

    fn add_var(a: &Variable, b: &Variable) -> Variable {
        Variable::from_function(
            Op::Add2,
            &[a, b],
            Box::new(|xs| ops::add(&xs[0], &xs[1])),
            Box::new(|_xs, _y, g| vec![Some(g.clone()), Some(g.clone())]),
        )
    }

    fn mul_var(a: &Variable, b: &Variable) -> Variable {
        Variable::from_function(
            Op::Mul2,
            &[a, b],
            Box::new(|xs| ops::mul(&xs[0], &xs[1])),
            Box::new(|xs, _y, g| {
                vec![Some(ops::mul(g, &xs[1])), Some(ops::mul(g, &xs[0]))]
            }),
        )
    }

    #[test]
    fn forward_happens_at_definition() {
        let x = Variable::from_array(NdArray::full(&[2], 3.0), true);
        let y = Variable::from_array(NdArray::full(&[2], 4.0), true);
        let z = add_var(&x, &y);
        assert_eq!(z.data().data(), &[7.0, 7.0]); // define-by-run
    }

    #[test]
    fn static_reuse_via_forward() {
        // Figure 1 static usage: define once, swap leaf data, forward()
        let x = Variable::new(&[2], true);
        let y = Variable::new(&[2], true);
        let z = add_var(&x, &y);
        x.set_data(NdArray::full(&[2], 1.0));
        y.set_data(NdArray::full(&[2], 2.0));
        z.forward();
        assert_eq!(z.data().data(), &[3.0, 3.0]);
        x.set_data(NdArray::full(&[2], 10.0));
        z.forward();
        assert_eq!(z.data().data(), &[12.0, 12.0]);
    }

    #[test]
    fn backward_product_rule() {
        let x = Variable::from_array(NdArray::full(&[1], 3.0), true);
        let y = Variable::from_array(NdArray::full(&[1], 4.0), true);
        let z = mul_var(&x, &y); // z = x*y
        z.backward();
        assert_eq!(x.grad().item(), 4.0);
        assert_eq!(y.grad().item(), 3.0);
    }

    #[test]
    fn backward_accumulates_through_shared_input() {
        // z = x*x -> dz/dx = 2x (grad accumulates from both uses)
        let x = Variable::from_array(NdArray::full(&[1], 5.0), true);
        let z = mul_var(&x, &x);
        z.backward();
        assert_eq!(x.grad().item(), 10.0);
    }

    #[test]
    fn backward_scale_is_loss_scaling_seed() {
        let x = Variable::from_array(NdArray::full(&[1], 3.0), true);
        let y = Variable::from_array(NdArray::full(&[1], 4.0), true);
        let z = mul_var(&x, &y);
        z.backward_with_scale(8.0); // Listing 6: loss.backward(loss_scale)
        assert_eq!(x.grad().item(), 32.0);
    }

    #[test]
    fn need_grad_false_blocks_gradient() {
        let x = Variable::from_array(NdArray::full(&[1], 3.0), false);
        let y = Variable::from_array(NdArray::full(&[1], 4.0), true);
        let z = mul_var(&x, &y);
        z.backward();
        assert_eq!(x.grad().item(), 0.0); // not computed
        assert_eq!(y.grad().item(), 3.0);
    }

    #[test]
    fn zero_grad_resets_accumulation() {
        let x = Variable::from_array(NdArray::full(&[1], 2.0), true);
        let z = mul_var(&x, &x);
        z.backward();
        z.backward(); // accumulate twice
        assert_eq!(x.grad().item(), 8.0);
        x.zero_grad();
        z.zero_grad();
        z.backward();
        assert_eq!(x.grad().item(), 4.0);
    }

    #[test]
    fn deep_chain_does_not_overflow_stack() {
        let mut v = Variable::from_array(NdArray::full(&[1], 1.0), true);
        let one = Variable::from_array(NdArray::full(&[1], 1.0), false);
        for _ in 0..20_000 {
            v = add_var(&v, &one);
        }
        assert_eq!(v.item(), 20_001.0);
        v.backward(); // iterative DFS: no stack overflow
    }

    #[test]
    fn node_count_and_names() {
        let x = Variable::from_array(NdArray::full(&[1], 1.0), true);
        let y = add_var(&x, &x);
        let z = mul_var(&y, &y);
        assert_eq!(z.node_count(), 2);
        assert_eq!(z.function_names(), vec!["Add2", "Mul2"]);
    }

    #[test]
    fn creator_op_and_inputs_expose_the_tape() {
        let x = Variable::from_array(NdArray::full(&[1], 1.0), true);
        assert!(x.creator_op().is_none());
        assert!(x.creator_inputs().is_empty());
        let y = add_var(&x, &x);
        assert_eq!(y.creator_op(), Some(Op::Add2));
        let ins = y.creator_inputs();
        assert_eq!(ins.len(), 2);
        assert_eq!(ins[0].uid(), x.uid());
        assert_eq!(ins[1].uid(), x.uid());
    }

    #[test]
    fn uid_is_stable_across_clones() {
        let x = Variable::new(&[1], false);
        let y = x.clone();
        assert_eq!(x.uid(), y.uid());
        let z = Variable::new(&[1], false);
        assert_ne!(x.uid(), z.uid());
    }

    #[test]
    fn backward_hook_fires_once_per_leaf_when_grad_final() {
        // two-"layer" chain: y = (x*w1)*w2 — w2's grad is final before
        // w1's (reverse completion order), each fires exactly once
        let x = Variable::from_array(NdArray::full(&[1], 2.0), false);
        let w1 = Variable::from_array(NdArray::full(&[1], 3.0), true);
        let w2 = Variable::from_array(NdArray::full(&[1], 4.0), true);
        w1.set_name("w1");
        w2.set_name("w2");
        let h = mul_var(&x, &w1);
        let y = mul_var(&h, &w2);
        let mut fired: Vec<String> = Vec::new();
        y.backward_with_hook(1.0, &mut |v| fired.push(v.name()));
        assert_eq!(fired, vec!["w2".to_string(), "w1".to_string()]);
        assert_eq!(w2.grad().item(), 6.0); // x*w1
        assert_eq!(w1.grad().item(), 8.0); // x*w2
    }

    #[test]
    fn backward_hook_counts_shared_leaf_uses() {
        // w used twice: hook must wait for both contributions
        let w = Variable::from_array(NdArray::full(&[1], 3.0), true);
        w.set_name("w");
        let a = mul_var(&w, &w); // w^2
        let b = add_var(&a, &w); // hmm: w is input here too
        let mut fired = 0usize;
        b.backward_with_hook(1.0, &mut |v| {
            assert_eq!(v.name(), "w");
            fired += 1;
            // at fire time the grad is final: d(w^2+w)/dw = 2w+1 = 7
            assert_eq!(v.grad().item(), 7.0);
        });
        assert_eq!(fired, 1);
    }

    #[test]
    fn backward_hook_fires_even_on_dead_branches() {
        // z's producer gets no gradient flow (need_grad off upstream
        // kills the path) but the pending count must still drain
        let x = Variable::from_array(NdArray::full(&[1], 2.0), true);
        x.set_name("x");
        let dead = Variable::from_array(NdArray::full(&[1], 5.0), false);
        let d = mul_var(&dead, &dead); // need_grad false: skipped node
        let y = add_var(&mul_var(&x, &x), &d);
        let mut fired: Vec<String> = Vec::new();
        y.backward_with_hook(1.0, &mut |v| fired.push(v.name()));
        assert_eq!(fired, vec!["x".to_string()]);
        assert_eq!(x.grad().item(), 4.0);
    }

    #[test]
    fn backward_with_hook_matches_plain_backward() {
        let x = Variable::from_array(NdArray::full(&[1], 2.0), true);
        let a = add_var(&x, &x);
        let b = mul_var(&x, &x);
        let c = mul_var(&a, &b);
        c.backward();
        let plain = x.grad().item();
        x.zero_grad();
        let mut n = 0usize;
        c.backward_with_hook(1.0, &mut |_| n += 1);
        assert_eq!(x.grad().item(), plain);
        assert_eq!(n, 1);
    }

    #[test]
    fn diamond_graph_grads_correct() {
        // a = x+x; b = x*x; c = a*b = 2x^3, dc/dx = 6x^2 at x=2 -> 24
        let x = Variable::from_array(NdArray::full(&[1], 2.0), true);
        let a = add_var(&x, &x);
        let b = mul_var(&x, &x);
        let c = mul_var(&a, &b);
        assert_eq!(c.item(), 16.0);
        c.backward();
        assert_eq!(x.grad().item(), 24.0);
    }
}
