//! Computation-graph engine: `Variable`s connected by function nodes.
//!
//! This is the paper's §2.2 "flexible computation methods" layer. A
//! graph is built *define-by-run* (dynamic mode): every `F::*` call
//! executes immediately and records a node. The same recorded graph can
//! then be *re-executed* on new leaf data with [`Variable::forward`] —
//! the static-graph usage of Figure 1 ("define the entire graph and
//! then use that graph for computation for each input data"). The
//! speed-optimized static path additionally exists as AOT HLO via
//! [`crate::runtime`]; this module is the flexible native engine.

pub mod variable;

pub use variable::Variable;
