//! Kernel benchmark harness — shared by `nnl bench-kernels` and
//! `benches/kernel_gemm.rs`, emitting `BENCH_kernels.json`.
//!
//! Measures the tentpole numbers of the tiled-kernel work: GEMM
//! GFLOP/s (pre-PR naive loop vs the packed tiled core, single- and
//! multi-thread), the thread-scaling curve, per-ISA microkernel tiers
//! (scalar vs avx2/neon, f32 and int8, at equal threads — with the
//! `simd_no_worse` acceptance bit CI greps for), conv forward/backward
//! step time on the fused im2col-GEMM path, compiled-plan serving
//! throughput, and a tape train-step hot-path proxy. Every report
//! records the detected CPU features and the dispatched ISA so the
//! numbers are attributable to the silicon they ran on.

use crate::functions as F;
use crate::models::zoo;
use crate::nnp::CompiledNet;
use crate::tensor::kernels::dispatch::{self, Isa};
use crate::tensor::kernels::int8::{qgemm, QEpilogue, QMatA, QMatB};
use crate::tensor::{ops, parallel, NdArray, Rng};
use crate::utils::bench::{bench, table, Measurement};
use crate::utils::json::Json;
use crate::Variable;

/// Everything one run produces: the human table and the JSON payload.
pub struct KernelBenchReport {
    pub text: String,
    pub json: Json,
}

fn gflops(flops: f64, m: &Measurement) -> f64 {
    flops / m.mean_secs / 1e9
}

/// Run the suite. `quick` shrinks sizes/iterations for CI smoke use.
pub fn run(quick: bool) -> KernelBenchReport {
    let mut rows: Vec<Measurement> = Vec::new();
    let mut rng = Rng::new(5);

    // --- GEMM: the acceptance measurement (naive vs tiled, 256^3)
    let mm = if quick { 128 } else { 256 };
    let iters = if quick { 3 } else { 10 };
    let a = rng.randn(&[mm, mm], 1.0);
    let b = rng.randn(&[mm, mm], 1.0);
    let flops = 2.0 * (mm as f64).powi(3);
    let naive = bench(&format!("matmul naive (pre-PR) {mm}^3"), 1, iters, || {
        std::hint::black_box(ops::matmul_naive(&a, &b));
    });
    let tiled_1t = bench(&format!("matmul tiled, 1 thread {mm}^3"), 1, iters, || {
        parallel::with_thread_limit(1, || std::hint::black_box(ops::matmul(&a, &b)));
    });
    let nt = parallel::num_threads();
    let tiled_mt = bench(&format!("matmul tiled, {nt} threads {mm}^3"), 1, iters, || {
        std::hint::black_box(ops::matmul(&a, &b));
    });
    let speedup = naive.mean_secs / tiled_mt.mean_secs;
    rows.push(naive.clone());
    rows.push(tiled_1t.clone());
    rows.push(tiled_mt.clone());

    // --- thread-scaling curve (same GEMM, capped pool widths)
    let mut widths: Vec<usize> = Vec::new();
    let mut t = 1;
    while t < nt {
        widths.push(t);
        t *= 2;
    }
    widths.push(nt);
    let mut scaling: Vec<Json> = Vec::new();
    for &w in &widths {
        let m = bench(&format!("matmul tiled, limit {w}"), 1, iters, || {
            parallel::with_thread_limit(w, || std::hint::black_box(ops::matmul(&a, &b)));
        });
        scaling.push(Json::obj(vec![
            ("threads", Json::num(w as f64)),
            ("gflops", Json::num(gflops(flops, &m))),
        ]));
        rows.push(m);
    }

    // --- per-ISA microkernel tiers: f32 + int8 at one thread each, so
    //     the scalar-vs-vector comparison is pure kernel (no pool noise)
    let dispatched = dispatch::isa();
    let qa: Vec<u8> = (0..mm * mm).map(|_| rng.below(256) as u8).collect();
    let qw: Vec<i8> = (0..mm * mm).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let qscales = vec![1.0f32 / 1024.0; mm];
    let qb = QMatB::from_i8_kn(&qw, &qscales, mm, mm);
    let qepi = QEpilogue { scales: &qscales, bias: None, relu: false };
    let mut qout = vec![0.0f32; mm * mm];
    let mut tier_stats: Vec<(Isa, f64, f64)> = Vec::new();
    for isa in dispatch::available_isas() {
        let tag = isa.name();
        let mf = bench(&format!("gemm f32 [{tag}] {mm}^3, 1 thread"), 1, iters, || {
            dispatch::with_isa(isa, || {
                parallel::with_thread_limit(1, || std::hint::black_box(ops::matmul(&a, &b)));
            });
        });
        let mq = bench(&format!("gemm int8 [{tag}] {mm}^3, 1 thread"), 1, iters, || {
            dispatch::with_isa(isa, || {
                parallel::with_thread_limit(1, || {
                    qgemm(&mut qout, &QMatA::Dense { d: &qa, ld: mm }, 3, &qb, mm, &qepi);
                    std::hint::black_box(&qout);
                });
            });
        });
        tier_stats.push((isa, gflops(flops, &mf), gflops(flops, &mq)));
        rows.push(mf);
        rows.push(mq);
    }
    let scalar_tier = tier_stats.iter().find(|t| t.0 == Isa::Scalar).expect("scalar always runs");
    let disp_tier =
        tier_stats.iter().find(|t| t.0 == dispatched).expect("dispatched tier measured");
    // trivially true when dispatch resolves to scalar (pinned or no
    // vector unit): there is no SIMD tier whose regression could hide
    let simd_no_worse =
        dispatched == Isa::Scalar || (disp_tier.1 > scalar_tier.1 && disp_tier.2 > scalar_tier.2);

    // --- conv fwd/bwd on the fused path (reused graph, tape hot loop)
    let (cb, cc, chw, coc, ck) = if quick { (2, 4, 16, 8, 3) } else { (4, 8, 28, 16, 5) };
    let xc = rng.randn(&[cb, cc, chw, chw], 1.0);
    let wc = rng.randn(&[coc, cc, ck, ck], 1.0);
    let xv = Variable::from_array(xc.clone(), true);
    let wv = Variable::from_array(wc, true);
    let pad = (ck / 2, ck / 2);
    let loss = F::mean_all(&F::convolution(&xv, &wv, None, (1, 1), pad, (1, 1)));
    let conv_iters = if quick { 3 } else { 8 };
    let conv_fwd = bench("conv forward (fused im2col-GEMM)", 1, conv_iters, || {
        xv.set_data(xc.clone());
        loss.forward();
    });
    let conv_bwd = bench("conv forward+backward step", 1, conv_iters, || {
        xv.set_data(xc.clone());
        loss.forward();
        xv.zero_grad();
        wv.zero_grad();
        loss.backward();
    });
    rows.push(conv_fwd.clone());
    rows.push(conv_bwd.clone());

    // --- compiled-plan serving throughput (sequential executes)
    let (net, params) = zoo::export_eval("mlp", 11);
    let plan = CompiledNet::compile(&net, &params).expect("mlp compiles");
    let requests = if quick { 32 } else { 128 };
    let reqs: Vec<Vec<NdArray>> = (0..requests)
        .map(|_| {
            net.inputs
                .iter()
                .map(|t| {
                    let mut d = t.dims.clone();
                    if !d.is_empty() {
                        d[0] = 1;
                    }
                    rng.rand(&d, -1.0, 1.0)
                })
                .collect()
        })
        .collect();
    let serve = bench(&format!("compiled mlp x{requests} requests"), 1, 5, || {
        for r in &reqs {
            plan.execute_positional(r).expect("plan execute");
        }
    });
    let serve_rps = requests as f64 / serve.mean_secs;
    rows.push(serve.clone());

    // --- tape hot path proxy: 2-layer MLP train step on reused graph
    let xt = rng.randn(&[32, 256], 1.0);
    let xtv = Variable::from_array(xt.clone(), true);
    let w1 = Variable::from_array(rng.randn(&[256, 128], 0.1), true);
    let b1 = Variable::from_array(NdArray::zeros(&[128]), true);
    let w2 = Variable::from_array(rng.randn(&[128, 10], 0.1), true);
    let b2 = Variable::from_array(NdArray::zeros(&[10]), true);
    let h = F::relu(&F::affine(&xtv, &w1, Some(&b1)));
    let tloss = F::mean_all(&F::affine(&h, &w2, Some(&b2)));
    let tape = bench("MLP train step (affine fwd+bwd)", 2, if quick { 10 } else { 30 }, || {
        xtv.set_data(xt.clone());
        tloss.forward();
        for p in [&w1, &b1, &w2, &b2] {
            p.zero_grad();
        }
        tloss.backward();
    });
    let tape_sps = 1.0 / tape.mean_secs;
    rows.push(tape.clone());

    let json = Json::obj(vec![
        ("nnl_threads", Json::num(nt as f64)),
        ("isa", Json::str(dispatched.name())),
        (
            "cpu_features",
            Json::Arr(dispatch::cpu_features().into_iter().map(Json::str).collect()),
        ),
        (
            "isa_tiers",
            Json::Arr(
                tier_stats
                    .iter()
                    .map(|(isa, f32_gflops, int8_gops)| {
                        Json::obj(vec![
                            ("isa", Json::str(isa.name())),
                            ("dispatched", Json::Bool(*isa == dispatched)),
                            ("threads", Json::num(1.0)),
                            ("f32_gflops", Json::num(*f32_gflops)),
                            ("int8_gops", Json::num(*int8_gops)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("simd_no_worse", Json::Bool(simd_no_worse)),
        (
            "gemm",
            Json::obj(vec![
                ("size", Json::num(mm as f64)),
                ("naive_gflops", Json::num(gflops(flops, &naive))),
                ("tiled_1thread_gflops", Json::num(gflops(flops, &tiled_1t))),
                ("tiled_gflops", Json::num(gflops(flops, &tiled_mt))),
                ("speedup_tiled_vs_naive", Json::num(speedup)),
            ]),
        ),
        ("thread_scaling", Json::Arr(scaling)),
        (
            "conv",
            Json::obj(vec![
                ("x", Json::arr_of_usize(&[cb, cc, chw, chw])),
                ("w", Json::arr_of_usize(&[coc, cc, ck, ck])),
                ("fwd_ms", Json::num(conv_fwd.mean_secs * 1e3)),
                ("fwd_bwd_ms", Json::num(conv_bwd.mean_secs * 1e3)),
            ]),
        ),
        (
            "serve_throughput",
            Json::obj(vec![
                ("model", Json::str("mlp")),
                ("requests_per_sec", Json::num(serve_rps)),
            ]),
        ),
        (
            "tape_hot_path",
            Json::obj(vec![("steps_per_sec", Json::num(tape_sps))]),
        ),
    ]);

    let mut text = table(
        &format!("Tiled kernels vs naive (NNL_THREADS = {nt})"),
        &rows,
    );
    text.push_str(&format!(
        "GEMM {mm}^3: naive {:.2} GF/s | tiled x1 {:.2} GF/s | tiled x{nt} {:.2} GF/s \
         => {speedup:.2}x vs naive\n\
         serve: {serve_rps:.0} requests/s | tape: {tape_sps:.0} steps/s\n",
        gflops(flops, &naive),
        gflops(flops, &tiled_1t),
        gflops(flops, &tiled_mt),
    ));
    text.push_str(&format!(
        "ISA: dispatched {} (features: {}) | f32 {:.2} GF/s vs scalar {:.2} | \
         int8 {:.2} GOP/s vs scalar {:.2} | simd_no_worse: {simd_no_worse}\n",
        dispatched.name(),
        dispatch::cpu_features().join("+"),
        disp_tier.1,
        scalar_tier.1,
        disp_tier.2,
        scalar_tier.2,
    ));
    KernelBenchReport { text, json }
}

/// Write the JSON payload where the acceptance tooling expects it.
pub fn write_json(path: &std::path::Path, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_string_pretty())
}
