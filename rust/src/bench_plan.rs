//! Graph-optimizer benchmark harness — shared by `nnl bench-plan` and
//! `benches/plan_optimizer.rs`, emitting `BENCH_plan.json`.
//!
//! Measures the compile-time pass pipeline's acceptance numbers across
//! zoo models: optimized-vs-unoptimized step counts, static-plan peak
//! arena bytes, per-pass rewrite counts, and sequential serving
//! throughput on both plans. A parity check runs before any timing so
//! the numbers can never describe a wrong plan.

use crate::bench_quant::random_inputs;
use crate::models::zoo;
use crate::nnp::passes::OptLevel;
use crate::nnp::plan::CompiledNet;
use crate::tensor::{parallel, Rng};
use crate::utils::bench::{bench, table, Measurement};
use crate::utils::json::Json;

/// Everything one run produces: the human table and the JSON payload.
pub struct PlanBenchReport {
    pub text: String,
    pub json: Json,
}

/// Run the suite. `quick` shrinks sizes/iterations for CI smoke use
/// (resnet18 stays in — CI asserts the optimizer strictly improves it).
pub fn run(quick: bool) -> PlanBenchReport {
    let mut rows: Vec<Measurement> = Vec::new();
    let mut rng = Rng::new(17);
    let nt = parallel::num_threads();
    let model_names: Vec<&str> = if quick {
        vec!["mlp", "lenet", "resnet18"]
    } else {
        vec!["mlp", "lenet", "resnet18", "resnet50", "mobilenet_v3_small"]
    };
    let n_eval = if quick { 16 } else { 128 };
    let mut model_rows: Vec<Json> = Vec::new();
    let mut no_worse = true;
    let mut resnet_improved = false;
    for name in model_names {
        let (net, params) = zoo::export_eval(name, 11);
        let p0 = CompiledNet::compile_with(&net, &params, OptLevel::O0)
            .unwrap_or_else(|e| panic!("{name} O0 compile: {e}"));
        let p2 = CompiledNet::compile(&net, &params)
            .unwrap_or_else(|e| panic!("{name} O2 compile: {e}"));
        let evals = random_inputs(&net, n_eval, &mut rng);
        // parity sanity before timing anything
        let a = p0.execute_positional(&evals[0]).expect("O0 run");
        let b = p2.execute_positional(&evals[0]).expect("O2 run");
        assert!(
            a[0].allclose(&b[0], 1e-3, 1e-3),
            "{name}: optimized plan drifted by {}",
            a[0].max_abs_diff(&b[0])
        );
        let m0 = bench(&format!("{name} O0 ({} steps) x{n_eval}", p0.n_steps()), 1, 3, || {
            for s in &evals {
                p0.execute_positional(s).expect("O0 serve");
            }
        });
        let m2 = bench(&format!("{name} O2 ({} steps) x{n_eval}", p2.n_steps()), 1, 3, || {
            for s in &evals {
                p2.execute_positional(s).expect("O2 serve");
            }
        });
        let rps0 = n_eval as f64 / m0.mean_secs;
        let rps2 = n_eval as f64 / m2.mean_secs;
        let peak0 = p0.peak_arena_bytes().unwrap_or(0);
        let peak2 = p2.peak_arena_bytes().unwrap_or(0);
        no_worse &=
            p2.n_steps() <= p0.n_steps() && peak2 <= peak0 && peak0 > 0 && peak2 > 0;
        if name == "resnet18" {
            resnet_improved = p2.n_steps() < p0.n_steps() && peak2 < peak0 && peak2 > 0;
        }
        let passes = Json::obj(
            p2.pass_stats()
                .iter()
                .map(|s| (s.pass, Json::num(s.rewrites as f64)))
                .collect(),
        );
        model_rows.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("steps_unoptimized", Json::num(p0.n_steps() as f64)),
            ("steps_optimized", Json::num(p2.n_steps() as f64)),
            ("peak_bytes_unoptimized", Json::num(peak0 as f64)),
            ("peak_bytes_optimized", Json::num(peak2 as f64)),
            ("rps_unoptimized", Json::num(rps0)),
            ("rps_optimized", Json::num(rps2)),
            ("passes", passes),
        ]));
        rows.push(m0);
        rows.push(m2);
    }

    let json = Json::obj(vec![
        ("nnl_threads", Json::num(nt as f64)),
        ("models", Json::Arr(model_rows)),
        ("optimized_no_worse", Json::Bool(no_worse)),
        ("resnet_improved", Json::Bool(resnet_improved)),
    ]);
    let mut text = table(
        &format!("Compile-time graph optimizer: O0 vs O2 plans (NNL_THREADS = {nt})"),
        &rows,
    );
    text.push_str(&format!(
        "optimized plans no worse (steps & peak arena bytes) across models: {no_worse}\n\
         resnet18 strictly improved (fewer steps, lower peak): {resnet_improved}\n",
    ));
    PlanBenchReport { text, json }
}

/// Write the JSON payload where the acceptance tooling expects it.
pub fn write_json(path: &std::path::Path, json: &Json) -> std::io::Result<()> {
    std::fs::write(path, json.to_string_pretty())
}
