//! Mixed-precision training (paper §3.3, Fig. 3-left, Listing 6).
//!
//! The pieces, mapped to the paper:
//! - half *storage* of weights/activations/gradients → `DType::BF16`
//!   arrays (quantized writes) on the dynamic path, bf16 HLO graphs on
//!   the static path;
//! - FP-32 master weights → [`MasterWeights`];
//! - loss scaling, static and dynamic → [`LossScaler`], implementing
//!   Listing 6 verbatim (halve on inf/nan, double after `interval`
//!   clean steps);
//! - FP-32 update → the solver always updates in f32 and re-quantizes.

use crate::graph::Variable;
use crate::solvers::Solver;
use crate::tensor::DType;
#[cfg(test)]
use crate::tensor::NdArray;

/// Dynamic (or static) loss scaler. With `dynamic = false` the scale
/// stays fixed (the first half of Listing 6); with `dynamic = true`
/// it follows the second half: on overflow divide by `factor` and skip
/// the update, after `interval` clean updates multiply by `factor`.
#[derive(Debug, Clone)]
pub struct LossScaler {
    scale: f32,
    factor: f32,
    interval: usize,
    counter: usize,
    dynamic: bool,
    /// Statistics for monitoring (Console / EXPERIMENTS.md).
    pub n_overflows: usize,
    pub n_updates: usize,
}

impl LossScaler {
    /// Fixed scale (`loss_scale = 8` in Listing 6).
    pub fn fixed(scale: f32) -> Self {
        LossScaler {
            scale,
            factor: 1.0,
            interval: usize::MAX,
            counter: 0,
            dynamic: false,
            n_overflows: 0,
            n_updates: 0,
        }
    }

    /// Dynamic scaling (`scaling_factor = 2`, `interval = 2000` in
    /// Listing 6).
    pub fn dynamic(initial: f32, factor: f32, interval: usize) -> Self {
        LossScaler {
            scale: initial,
            factor,
            interval,
            counter: 0,
            dynamic: true,
            n_overflows: 0,
            n_updates: 0,
        }
    }

    /// Current scale — pass to `loss.backward_with_scale(scale)`.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Complete one step given the solver whose gradients were produced
    /// with the current scale. Returns `true` if the update was applied,
    /// `false` if it was skipped due to overflow. This is Listing 6:
    ///
    /// ```text
    /// if solver.check_inf_or_nan_grad():
    ///     loss_scale /= scaling_factor; counter = 0   (skip update)
    /// else:
    ///     solver.scale_grad(1/loss_scale); solver.update()
    ///     if counter > interval: loss_scale *= scaling_factor; counter = 0
    ///     counter += 1
    /// ```
    pub fn step(&mut self, solver: &mut Solver) -> bool {
        if solver.check_inf_or_nan_grad() {
            // skip the update in BOTH modes: applying Inf/NaN gradients
            // would permanently poison the weights. Only the dynamic
            // mode also adapts the scale.
            if self.dynamic {
                self.scale = (self.scale / self.factor).max(1.0);
                self.counter = 0;
            }
            self.n_overflows += 1;
            return false;
        }
        solver.scale_grad(1.0 / self.scale);
        solver.update();
        self.n_updates += 1;
        if self.dynamic {
            if self.counter > self.interval {
                self.scale *= self.factor;
                self.counter = 0;
            }
            self.counter += 1;
        }
        true
    }
}

/// FP-32 master copy of a half-storage parameter set ("a master copy
/// of weights in FP-32", §3.3). The working (half) parameters are what
/// the graph reads; updates land on the master copy and are quantized
/// back into the working copy.
pub struct MasterWeights {
    masters: Vec<(String, Variable)>,
    working: Vec<(String, Variable)>,
}

impl MasterWeights {
    /// Snapshot `params` (assumed half-storage) into f32 masters.
    pub fn new(params: &[(String, Variable)]) -> Self {
        let masters: Vec<(String, Variable)> = params
            .iter()
            .map(|(n, v)| {
                let m = Variable::from_array(v.data().cast(DType::F32), v.need_grad());
                m.set_name(&format!("{n}/master"));
                (n.clone(), m)
            })
            .collect();
        MasterWeights { masters, working: params.to_vec() }
    }

    /// The f32 master variables (bind these to the solver).
    pub fn masters(&self) -> &[(String, Variable)] {
        &self.masters
    }

    /// Copy gradients from the working (half) params onto the masters.
    pub fn pull_grads(&self) {
        for ((_, m), (_, w)) in self.masters.iter().zip(&self.working) {
            m.set_grad(w.grad());
        }
    }

    /// Quantize updated masters back into the working params.
    pub fn push_weights(&self) {
        for ((_, m), (_, w)) in self.masters.iter().zip(&self.working) {
            let dtype = w.data().dtype();
            w.set_data(m.data().cast(dtype));
        }
    }
}

/// Quantize every parameter of a registry snapshot to `dtype` in place
/// (entering half mode on an existing model).
pub fn quantize_params(params: &[(String, Variable)], dtype: DType) {
    for (_, v) in params {
        let mut d = v.data();
        d.set_dtype(dtype);
        v.set_data(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solver_with_param(grad: f32) -> (Solver, Variable) {
        let mut s = Solver::sgd(0.5);
        let w = Variable::from_array(NdArray::full(&[1], 1.0), true);
        s.set_parameters(&[("w".into(), w.clone())]);
        w.set_grad(NdArray::full(&[1], grad));
        (s, w)
    }

    #[test]
    fn fixed_scaler_unscales_before_update() {
        // grad was computed with scale 8: solver sees grad/8
        let (mut s, w) = solver_with_param(8.0);
        let mut sc = LossScaler::fixed(8.0);
        assert!(sc.step(&mut s));
        assert_eq!(w.data().item(), 1.0 - 0.5 * 1.0);
        assert_eq!(sc.scale(), 8.0); // fixed never changes
    }

    #[test]
    fn fixed_scaler_skips_overflow_update() {
        // regression: fixed mode used to apply Inf gradients, leaving
        // the weights NaN forever after a single overflow step
        let (mut s, w) = solver_with_param(f32::INFINITY);
        let mut sc = LossScaler::fixed(8.0);
        assert!(!sc.step(&mut s));
        assert_eq!(w.data().item(), 1.0); // update skipped, weight intact
        assert!(!w.data().has_inf_or_nan());
        assert_eq!(sc.scale(), 8.0); // fixed scale never moves
        assert_eq!(sc.n_overflows, 1);
        // a clean step afterwards still applies normally
        let (mut s2, w2) = solver_with_param(8.0);
        assert!(sc.step(&mut s2));
        assert_eq!(w2.data().item(), 1.0 - 0.5 * 1.0);
    }

    #[test]
    fn dynamic_halves_on_overflow_and_skips() {
        let (mut s, w) = solver_with_param(f32::INFINITY);
        let mut sc = LossScaler::dynamic(1024.0, 2.0, 10);
        assert!(!sc.step(&mut s));
        assert_eq!(sc.scale(), 512.0);
        assert_eq!(w.data().item(), 1.0); // update skipped
        assert_eq!(sc.n_overflows, 1);
    }

    #[test]
    fn dynamic_doubles_after_interval_clean_steps() {
        let mut sc = LossScaler::dynamic(8.0, 2.0, 3);
        for _ in 0..20 {
            let (mut s, _) = solver_with_param(1.0);
            sc.step(&mut s);
        }
        assert!(sc.scale() > 8.0, "scale grew to {}", sc.scale());
    }

    #[test]
    fn dynamic_never_drops_below_one() {
        let mut sc = LossScaler::dynamic(2.0, 2.0, 10);
        for _ in 0..5 {
            let (mut s, _) = solver_with_param(f32::NAN);
            sc.step(&mut s);
        }
        assert!(sc.scale() >= 1.0);
    }

    #[test]
    fn overflow_resets_growth_counter() {
        let mut sc = LossScaler::dynamic(8.0, 2.0, 5);
        for _ in 0..4 {
            let (mut s, _) = solver_with_param(1.0);
            sc.step(&mut s);
        }
        let (mut s, _) = solver_with_param(f32::INFINITY);
        sc.step(&mut s); // overflow at counter=4: scale 4, counter 0
        assert_eq!(sc.scale(), 4.0);
        for _ in 0..4 {
            let (mut s, _) = solver_with_param(1.0);
            sc.step(&mut s);
        }
        assert_eq!(sc.scale(), 4.0); // not yet past interval again
    }

    #[test]
    fn master_weights_roundtrip() {
        let mut half = NdArray::full(&[2], 1.0);
        half.set_dtype(DType::BF16);
        let w = Variable::from_array(half, true);
        let params = vec![("w".to_string(), w.clone())];
        let mw = MasterWeights::new(&params);
        assert_eq!(mw.masters()[0].1.data().dtype(), DType::F32);

        // tiny update below bf16 resolution: master keeps it, working rounds
        let mut s = Solver::sgd(1.0);
        s.set_parameters(mw.masters());
        w.set_grad(NdArray::full(&[2], 2f32.powi(-12)));
        mw.pull_grads();
        s.update();
        mw.push_weights();
        assert_eq!(w.data().data()[0], 1.0); // rounded in working copy
        assert!(mw.masters()[0].1.data().data()[0] < 1.0); // preserved in master

        // after enough accumulation the working copy moves too
        for _ in 0..2000 {
            mw.masters()[0].1.set_grad(NdArray::full(&[2], 2f32.powi(-12)));
            s.update();
        }
        mw.push_weights();
        assert!(w.data().data()[0] < 1.0);
    }

    #[test]
    fn quantize_params_tags_dtype() {
        let w = Variable::from_array(NdArray::full(&[1], 1.0 + 2f32.powi(-10)), true);
        let params = vec![("w".to_string(), w.clone())];
        quantize_params(&params, DType::BF16);
        assert_eq!(w.data().dtype(), DType::BF16);
        assert_eq!(w.data().item(), 1.0); // value snapped to bf16 grid
    }
}
