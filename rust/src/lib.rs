//! # nnl — Neural Network Libraries, reproduced as a Rust + JAX + Pallas stack
//!
//! A full reproduction of *"Neural Network Libraries: A Deep Learning
//! Framework Designed from Engineers' Perspectives"* (Sony, 2021) as a
//! three-layer system:
//!
//! - **L3 (this crate)** — the framework: Variables / Functions /
//!   Parametric Functions, static & dynamic computation graphs, solvers,
//!   mixed-precision training with loss scaling, a data-parallel
//!   communicator, the NNP interchange format + converters, monitors,
//!   and a headless Neural Network Console.
//! - **L2 (`python/compile/model.py`)** — JAX train-step graphs, AOT
//!   lowered to HLO text at build time (`make artifacts`).
//! - **L1 (`python/compile/kernels/`)** — Pallas matmul kernels inside
//!   those graphs, validated against a pure-jnp oracle.
//!
//! Python never runs at inference/training time: the static-graph path
//! loads `artifacts/*.hlo.txt` through PJRT (`runtime`, `pjrt` cargo
//! feature), and the dynamic-graph path runs the native tape engine
//! (`graph` + `functions`).
//!
//! ## The Function-descriptor API: one definition, every backend
//!
//! The paper's compatibility thesis (§2.1, §3.4) is that *one* network
//! definition trains, exports, converts, and deploys everywhere. The
//! architecture that delivers it here:
//!
//! - **[`nnp::Op`] is the single operator registry.** Every operator
//!   the framework knows is one enum variant with typed attributes,
//!   a canonical NNabla-style name, a wire encoding, and executable
//!   semantics ([`nnp::Op::apply`] on variables / `execute` on arrays).
//! - **The tape is self-describing.** Every `F::*` / `PF::*` call
//!   records its `Op` descriptor on the graph node it creates
//!   (`Variable::from_function`), with parameters identified by their
//!   registry names.
//! - **[`nnp::trace`] exports any graph.** Walk the tape from the
//!   outputs and the NNP [`nnp::NetworkDef`] falls out — no builder,
//!   no dual bookkeeping. From there: NNP archives, ONNX, NNB, frozen
//!   graphs, generated Rust source.
//! - **The interpreter is the registry.** Deployment inference
//!   re-applies each layer's descriptor through the same dispatch the
//!   tape recorded it with, so converted models are bit-identical to
//!   the source graph.
//!
//! ## The deployment path: compile once, execute many
//!
//! An `.nnp` file is the deployment contract (§3.4): one trained
//! artifact, many runtimes. Serving it at traffic, though, cannot
//! afford the interpreter's per-call tax (graph re-validation, name
//! hashing, parameter re-binding). The serving stack therefore splits
//! load time from request time:
//!
//! - **[`nnp::CompiledNet`]** compiles a network + parameter map once
//!   into a topologically-ordered, slot-indexed plan: params bound up
//!   front, arity/attribute validation done at load (malformed files
//!   fail before the first request), intermediate buffers freed by
//!   precomputed liveness. `execute` is `&self` and the plan is
//!   `Send + Sync` — one plan, many threads.
//! - **[`serve::Server`]** runs a worker pool over one shared plan and
//!   micro-batches single-example requests along axis 0 (when the plan
//!   is provably row-independent), splitting outputs back per request
//!   and reporting throughput/latency counters.
//! - `interpreter::run` remains the one-shot path — now a thin
//!   compile-then-execute wrapper, so both paths share every kernel
//!   and every validation rule.
//!
//! CLI: `nnl serve --in model.nnp` / `nnl bench-serve`; numbers in
//! `benches/serve_throughput.rs`.
//!
//! ## The graph optimizer: a compile-time pass pipeline
//!
//! Compilation is an explicit **lower → optimize → schedule →
//! allocate** pipeline ([`nnp::passes`]): graph-level passes over the
//! NNP IR (Identity/Dropout elision, dead-op elimination, constant
//! folding of parameter-only subtrees, BatchNorm folding into the
//! preceding Conv/Affine weights) plus a step-level pass fusing
//! Affine/Conv → ReLU chains — all driven by an [`nnp::OptLevel`]:
//! O0 executes the graph exactly as written (the interpreter /
//! training contract), O1 applies only bit-identical rewrites, O2
//! (the serving default) adds the numeric folds. The executor is a
//! dumb step loop: every step knows its kernel at compile time, and a
//! liveness-based static memory plan (greedy interval coloring)
//! assigns slots arena offsets and reports exact peak bytes
//! ([`nnp::CompiledNet::peak_arena_bytes`]). Quantization rides the
//! same pipeline, so BN-folded convolutions reach the int8 path. CLI:
//! `nnl optimize` (pass stats, op histogram, peak bytes) and
//! `nnl bench-plan` (→ `BENCH_plan.json`).
//!
//! ## The embedded path: int8 quantized inference (NNB2)
//!
//! The paper's compatibility story ends at NNP → NNB for the embedded
//! C runtime, where compact artifacts are the whole point. [`quant`]
//! closes that loop: calibrate activation ranges by running a
//! `CompiledNet` over a sample set (min/max, optional percentile
//! clipping), quantize Affine/Convolution weights to per-output-
//! channel symmetric i8, and compile a [`quant::QuantizedNet`] whose
//! dense layers run a register-tiled u8×i8→i32 GEMM
//! ([`tensor::kernels::int8`]) with prepacked weight panels and a
//! fused requantize + bias + ReLU epilogue — row-sharded over the same
//! pool, exact integer accumulation, bit-identical at any thread
//! count. Everything else falls back to the f32 registry dispatch.
//! NNB2 artifacts carry the i8 blobs + scales + calibration table
//! (~4× smaller; v1 stays readable), and both versions execute
//! through [`converters::nnb::NnbEngine`] on the compiled fast path.
//! [`serve::Server`] hosts either backend behind
//! [`nnp::InferencePlan`]. CLI: `nnl quantize` / `nnl bench-quant`
//! (→ `BENCH_quant.json`).
//!
//! ## The serving front end: TCP protocol, registry, hot reload
//!
//! Production traffic reaches all of the above through
//! [`serve::net`]: a TCP server speaking a length-prefixed,
//! version-tagged binary protocol (with a line-oriented JSON fallback
//! — telnet-able, used by tests) over a **multi-model
//! [`serve::net::Registry`]** that hosts many NNP/NNB/NNB2 artifacts
//! concurrently behind [`nnp::InferencePlan`]. Deploying onto a live
//! name is an **atomic hot swap**: in-flight requests finish on the
//! plan that admitted them, new requests land on the new plan, and the
//! old worker pool drains and joins when its last holder lets go —
//! zero dropped requests across a reload. Admission control is per
//! model: bounded queues whose default capacity derives from the
//! static memory plan's peak arena bytes
//! ([`serve::derive_queue_cap`]), shedding with typed
//! [`serve::ServeError::Overloaded`] replies when full. Live counters
//! ([`monitor::metrics::ModelMetrics`]: latency histograms with
//! p50/p99, throughput, queue depth, batch-size distribution, shed
//! counts) survive swaps and export through the `STATS` verb. CLI:
//! `nnl serve --listen ADDR --models name=path,...` and
//! `nnl bench-serve --net` (→ `BENCH_serve.json`).
//!
//! ## Fault tolerance: isolation, supervision, deadlines, chaos
//!
//! The serving stack assumes requests *will* fail and proves it
//! survives: a panicking request is caught at the worker's
//! `catch_unwind` boundary and answered with a typed
//! [`serve::ServeError::Internal`] (that worker's scratch arena is
//! discarded, never reused); serve and pool workers are **supervised**
//! — a panic that escapes per-request isolation resurrects the worker
//! in place and bumps a `worker_restarts` counter, so no worker stays
//! dead. Requests carry optional **deadlines**
//! ([`serve::Client::submit_with_deadline`]): work that expires in the
//! queue is shed *before* compute with
//! [`serve::ServeError::DeadlineExceeded`]. Clients retry transient
//! failures (admission shedding, transport errors — never `Internal`
//! or verifier rejections) with seeded jittered exponential backoff
//! ([`serve::RetryPolicy`]), and load balancers probe the `HEALTH`
//! verb for per-model readiness. All of it is exercised by
//! deterministic fault injection ([`faults`], `--features chaos`):
//! seeded schedules of panics, delays, I/O errors, and corrupt frames
//! at named injection points, compiled to zero-cost no-ops when the
//! feature is off. `tests/chaos_serve.rs` holds the headline
//! invariant: every admitted request gets exactly one typed reply.
//!
//! ## Distributed training: the deterministic ring
//!
//! Data parallelism (§3.2) runs over one [`comm::Collective`] trait
//! with two interchangeable backends: the in-process thread
//! communicator ([`comm::CommHub`]) and a real multi-process TCP ring
//! ([`comm::NetCommunicator`]: rank 0 serves the rendezvous, peers
//! wire a ring of length-prefixed frames). Both compute the *same*
//! fold — every element reduced as `((0 + x_0) + x_1) + …` in rank
//! order, then multiplied by `1/N` — pipelined around the ring in
//! segments, so results are **bit-identical across backends and world
//! sizes** (an fp16 wire mode trades exactness for half the bytes,
//! deterministically). The trainer layers throughput on top without
//! touching the math: gradients coalesce into ~4 MiB buckets
//! ([`comm::plan_buckets`]), and each bucket's all-reduce fires from
//! the autodiff tape's completion hook the moment its last gradient
//! lands ([`graph::Variable::backward_with_hook`]), overlapping
//! communication with the rest of backward on a dedicated
//! [`comm::Reducer`] thread. Dead peers surface as typed
//! [`comm::CommError`]s at every rank within the step deadline —
//! never a hang. CLI: `nnl train-dist` (`--launch N` forks a local
//! world) and `nnl bench-comm` (→ `BENCH_comm.json`);
//! `tests/distributed.rs` proves N-process runs match the sequential
//! oracle bit-for-bit.
//!
//! ## Static verification: the checker beside the compiler
//!
//! [`nnp::verify`] is an independent verifier for everything the
//! compiler and server otherwise trust. It re-infers every tensor
//! shape over a [`nnp::NetworkDef`] (separately from the compiler's
//! own inference, so the two cross-check), emitting structured
//! [`nnp::verify::Diagnostic`]s with stable `NNL-Exxx`/`Wxxx` codes —
//! shape/arity errors, unreachable subgraphs, unused parameters,
//! batch-variant and quantization-hostile ops. A second layer does
//! **translation validation**: [`nnp::verify::verify_plan`] re-derives
//! liveness from a compiled plan's scheduled steps and proves the
//! static memory plan safe (`NNL-P00x` codes), running after every
//! `CompiledNet::compile` in debug builds and after *each* pass under
//! `PassManager::run_verified`, so a broken pass is named directly.
//! The wire `DEPLOY` path runs the artifact checker before any hot
//! swap; `tests/verify_static.rs` fuzzes it with bit-flipped and
//! truncated images; `tests/loom_models.rs` model-checks the serve
//! queue, hot-swap, and worker-pool protocols under loom. CLI:
//! `nnl check` (`--json` for machines) and `nnl optimize --verify`.
//!
//! ## Module map
//!
//! ## The compute floor: tiled, multi-threaded kernels
//!
//! Underneath both modes, every dense FLOP now flows through
//! [`tensor::kernels`]: a packed, register-tiled (8×8 microkernel)
//! GEMM whose operands are *views* — plain, transposed, NCHW-as-rows,
//! or the im2col matrix of an image — so convolution forward/backward
//! never materializes its column matrix; the lowering happens inside
//! panel packing. Work is row-sharded over [`tensor::parallel`], a
//! persistent `std::thread` pool sized by `NNL_THREADS` (default: all
//! cores) with a hard determinism contract: chunk boundaries depend
//! only on shapes and every output element is computed wholly inside
//! one chunk, so results are **bit-identical at any thread count**.
//! The innermost register tile runs on hand-written SIMD microkernels
//! (AVX2+FMA on x86-64, NEON on aarch64, a scalar oracle everywhere)
//! behind one-time runtime dispatch ([`tensor::kernels::dispatch`],
//! overridable via `NNL_ISA`); the int8 tiers reproduce the scalar
//! bits exactly, the f32 tiers stay within 1e-5 relative. A
//! per-thread scratch arena ([`tensor::kernels::Scratch`]) feeds
//! packing buffers and plan intermediates; `CompiledNet::execute`
//! recycles freed activation slots back into it, so steady-state
//! serving performs no per-request heap allocation for conv columns
//! or intermediates. Numbers: `nnl bench-kernels` /
//! `benches/kernel_gemm.rs` → `BENCH_kernels.json`.
//!
//! | module | role |
//! |---|---|
//! | [`tensor`] | `NdArray` storage (COW), dtypes, kernels, RNG |
//! | [`tensor::kernels`] | tiled GEMM, fused conv/affine, scratch arena |
//! | [`tensor::kernels::dispatch`] | runtime ISA dispatch (`NNL_ISA`) |
//! | [`tensor::kernels::int8`] | int8 GEMM, fused requantize epilogue |
//! | [`tensor::parallel`] | `NNL_THREADS` worker pool (bit-identical) |
//! | [`graph`] | define-by-run tape: `Variable`, forward/backward |
//! | [`functions`] | operator kernels recorded on the tape (`F::*`) |
//! | [`parametric`] | parameter registry + parametric layers (`PF::*`) |
//! | [`models`] | zoo architectures + `Gb` builder |
//! | [`solvers`] | SGD/momentum/Adam/… + schedulers |
//! | [`mixed_precision`] | loss scaling, master weights (§3.3) |
//! | [`comm`] | data-parallel collectives: thread + TCP backends (§3.2) |
//! | [`comm::ring`] | deterministic ring all-reduce (transport-agnostic) |
//! | [`comm::net`] | TCP rendezvous + framed ring transport |
//! | [`comm::bucket`] | gradient bucketing, backward/reduce overlap |
//! | [`trainer`] | dynamic / static / distributed training loops |
//! | [`nnp`] | NNP format: IR, trace, archive, interpreter, **plan** |
//! | [`nnp::passes`] | graph optimizer: `Pass` pipeline, memory planner |
//! | [`nnp::verify`] | static verifier: diagnostics, translation validation |
//! | [`quant`] | int8 calibration, `QuantizedNet`, NNB2 model |
//! | [`serve`] | batched multi-threaded inference server |
//! | [`serve::net`] | TCP front end: protocol, registry, hot reload |
//! | [`faults`] | deterministic fault injection (`chaos` feature) |
//! | [`monitor::metrics`] | serving metrics: histograms, shed counts |
//! | [`converters`] | ONNX-lite, NNB/NNB2, frozen graph, Rust source |
//! | [`runtime`] | AOT HLO artifacts through PJRT (`pjrt` feature) |
//! | [`console`] | headless Neural Network Console: trials, search |
//! | [`bench_kernels`] | kernel bench harness (`BENCH_kernels.json`) |
//! | [`bench_quant`] | quantization bench harness (`BENCH_quant.json`) |
//! | [`bench_plan`] | graph-optimizer bench harness (`BENCH_plan.json`) |
//! | [`bench_serve`] | serving front-end bench (`BENCH_serve.json`) |
//! | [`bench_comm`] | distributed-training bench (`BENCH_comm.json`) |
//! | [`data`] | synthetic datasets + loaders |
//! | [`monitor`] | series/time monitors |
//! | [`context`] | backend/precision context (Listing 2) |
//! | [`utils`] | JSON, prototext, bench harness, property testing |
//!
//! Listing 1, end to end:
//!
//! ```
//! use nnl::{functions as F, nnp, parametric as PF, Variable};
//!
//! PF::clear_parameters();
//! let x = Variable::new(&[16, 10], true);
//! x.set_name("x");
//! let y = F::relu(&PF::affine(&x, 5, "fc"));
//! y.forward();
//! y.backward();
//! // the same graph, exported with zero extra bookkeeping:
//! let net = nnp::trace("listing1", &[&y]).unwrap();
//! assert_eq!(net.function_names(), vec!["Affine", "ReLU"]);
//! ```
//!
//! [`models::Gb`] remains as a thin convenience wrapper over tracing
//! (naming, train/eval mode, MAC accounting) — see its module docs for
//! the migration note.

pub mod bench_comm;
pub mod bench_kernels;
pub mod bench_plan;
pub mod bench_quant;
pub mod bench_serve;
pub mod comm;
pub mod console;
pub mod context;
pub mod converters;
pub mod data;
pub mod faults;
pub mod functions;
pub mod graph;
pub mod mixed_precision;
pub mod models;
pub mod monitor;
pub mod nnp;
pub mod parametric;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod tensor;
pub mod trainer;
pub mod utils;

pub use context::{Backend, Context, TypeConfig};
pub use graph::Variable;
pub use tensor::{DType, NdArray, Rng, Shape};
