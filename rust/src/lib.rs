//! # nnl — Neural Network Libraries, reproduced as a Rust + JAX + Pallas stack
//!
//! A full reproduction of *"Neural Network Libraries: A Deep Learning
//! Framework Designed from Engineers' Perspectives"* (Sony, 2021) as a
//! three-layer system:
//!
//! - **L3 (this crate)** — the framework: Variables / Functions /
//!   Parametric Functions, static & dynamic computation graphs, solvers,
//!   mixed-precision training with loss scaling, a data-parallel
//!   communicator, the NNP interchange format + converters, monitors,
//!   and a headless Neural Network Console.
//! - **L2 (`python/compile/model.py`)** — JAX train-step graphs, AOT
//!   lowered to HLO text at build time (`make artifacts`).
//! - **L1 (`python/compile/kernels/`)** — Pallas matmul kernels inside
//!   those graphs, validated against a pure-jnp oracle.
//!
//! Python never runs at inference/training time: the static-graph path
//! loads `artifacts/*.hlo.txt` through PJRT (`runtime`), and the
//! dynamic-graph path runs the native tape engine (`graph` +
//! `functions`).

pub mod comm;
pub mod console;
pub mod context;
pub mod converters;
pub mod data;
pub mod functions;
pub mod graph;
pub mod mixed_precision;
pub mod models;
pub mod monitor;
pub mod nnp;
pub mod parametric;
pub mod runtime;
pub mod solvers;
pub mod tensor;
pub mod trainer;
pub mod utils;

pub use context::{Backend, Context, TypeConfig};
pub use graph::Variable;
pub use tensor::{DType, NdArray, Rng, Shape};
