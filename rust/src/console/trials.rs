//! Trial records — "all trials are recorded automatically, it is easy
//! to analyze the performance and revert to old records ... results of
//! experiments are listed and can be compared to past trials" (§5.1).

use std::path::{Path, PathBuf};

use crate::trainer::TrainReport;
use crate::utils::json::Json;

/// One recorded experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct TrialRecord {
    pub id: usize,
    pub model: String,
    pub backend: String,
    pub steps: usize,
    pub final_loss: f32,
    pub val_error: f32,
    pub wall_secs: f64,
    pub n_params: usize,
    pub macs: u64,
    /// Full loss curve (step, value).
    pub curve: Vec<(usize, f32)>,
}

impl TrialRecord {
    pub fn from_report(id: usize, r: &TrainReport) -> Self {
        TrialRecord {
            id,
            model: r.model.clone(),
            backend: r.backend.to_string(),
            steps: r.steps,
            final_loss: r.final_loss(),
            val_error: r.val_error,
            wall_secs: r.wall_secs,
            n_params: r.n_params,
            macs: r.macs,
            curve: r.losses.points().to_vec(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("model", Json::str(self.model.clone())),
            ("backend", Json::str(self.backend.clone())),
            ("steps", Json::num(self.steps as f64)),
            ("final_loss", Json::num(self.final_loss as f64)),
            ("val_error", Json::num(self.val_error as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("n_params", Json::num(self.n_params as f64)),
            ("macs", Json::num(self.macs as f64)),
            (
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|&(s, v)| {
                            Json::Arr(vec![Json::num(s as f64), Json::num(v as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(j: &Json) -> Option<Self> {
        Some(TrialRecord {
            id: j.get("id").as_usize()?,
            model: j.get("model").as_str()?.to_string(),
            backend: j.get("backend").as_str().unwrap_or("").to_string(),
            steps: j.get("steps").as_usize()?,
            final_loss: j.get("final_loss").as_f64()? as f32,
            val_error: j.get("val_error").as_f64()? as f32,
            wall_secs: j.get("wall_secs").as_f64()?,
            n_params: j.get("n_params").as_usize()?,
            macs: j.get("macs").as_f64()? as u64,
            curve: j
                .get("curve")
                .as_arr()?
                .iter()
                .filter_map(|p| {
                    let a = p.as_arr()?;
                    Some((a[0].as_usize()?, a[1].as_f64()? as f32))
                })
                .collect(),
        })
    }
}

/// Directory-backed trial store (one JSON file per trial).
pub struct TrialStore {
    dir: PathBuf,
}

impl TrialStore {
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        std::fs::create_dir_all(dir)?;
        Ok(TrialStore { dir: dir.to_path_buf() })
    }

    fn next_id(&self) -> usize {
        self.list().map(|t| t.last().map(|r| r.id + 1).unwrap_or(0)).unwrap_or(0)
    }

    /// Record a training report; returns the assigned trial id.
    pub fn record(&self, report: &TrainReport) -> std::io::Result<usize> {
        let id = self.next_id();
        let rec = TrialRecord::from_report(id, report);
        std::fs::write(
            self.dir.join(format!("trial_{id:04}.json")),
            rec.to_json().to_string_pretty(),
        )?;
        Ok(id)
    }

    /// All trials sorted by id.
    pub fn list(&self) -> std::io::Result<Vec<TrialRecord>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            if path.extension().map(|e| e == "json").unwrap_or(false) {
                if let Ok(text) = std::fs::read_to_string(&path) {
                    if let Ok(j) = Json::parse(&text) {
                        if let Some(rec) = TrialRecord::from_json(&j) {
                            out.push(rec);
                        }
                    }
                }
            }
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    pub fn get(&self, id: usize) -> std::io::Result<Option<TrialRecord>> {
        Ok(self.list()?.into_iter().find(|r| r.id == id))
    }

    /// Comparison table across all trials (the Console list view).
    pub fn comparison_table(&self) -> std::io::Result<String> {
        let trials = self.list()?;
        let mut s = format!(
            "{:>4} {:<22} {:<16} {:>7} {:>10} {:>9} {:>9} {:>12} {:>12}\n",
            "id", "model", "backend", "steps", "loss", "val_err", "time_s", "params", "MACs"
        );
        for t in &trials {
            s.push_str(&format!(
                "{:>4} {:<22} {:<16} {:>7} {:>10.4} {:>9.3} {:>9.2} {:>12} {:>12}\n",
                t.id, t.model, t.backend, t.steps, t.final_loss, t.val_error, t.wall_secs,
                t.n_params, t.macs
            ));
        }
        Ok(s)
    }

    /// Best trial by validation error (revert-to-best workflow).
    pub fn best(&self) -> std::io::Result<Option<TrialRecord>> {
        Ok(self
            .list()?
            .into_iter()
            .filter(|t| t.val_error.is_finite())
            .min_by(|a, b| a.val_error.partial_cmp(&b.val_error).unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::MonitorSeries;

    fn fake_report(model: &str, val: f32) -> TrainReport {
        let mut losses = MonitorSeries::new("loss");
        for i in 0..5 {
            losses.add(i, 2.0 - i as f32 * 0.1);
        }
        TrainReport {
            model: model.into(),
            losses,
            val_error: val,
            wall_secs: 1.5,
            steps: 5,
            n_params: 1000,
            macs: 50_000,
            backend: "cpu:float",
            overflow_skips: 0,
        }
    }

    fn store() -> (TrialStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "nnl_trials_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        (TrialStore::open(&dir).unwrap(), dir)
    }

    #[test]
    fn record_list_roundtrip() {
        let (s, dir) = store();
        let id0 = s.record(&fake_report("mlp", 0.3)).unwrap();
        let id1 = s.record(&fake_report("lenet", 0.2)).unwrap();
        assert_eq!((id0, id1), (0, 1));
        let trials = s.list().unwrap();
        assert_eq!(trials.len(), 2);
        assert_eq!(trials[0].model, "mlp");
        assert_eq!(trials[1].curve.len(), 5);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn best_picks_lowest_val_error() {
        let (s, dir) = store();
        s.record(&fake_report("a", 0.5)).unwrap();
        s.record(&fake_report("b", 0.1)).unwrap();
        s.record(&fake_report("c", 0.3)).unwrap();
        assert_eq!(s.best().unwrap().unwrap().model, "b");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn comparison_table_lists_all() {
        let (s, dir) = store();
        s.record(&fake_report("resnet18", 0.25)).unwrap();
        let table = s.comparison_table().unwrap();
        assert!(table.contains("resnet18"));
        assert!(table.contains("val_err"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn get_by_id() {
        let (s, dir) = store();
        s.record(&fake_report("x", 0.5)).unwrap();
        assert!(s.get(0).unwrap().is_some());
        assert!(s.get(99).unwrap().is_none());
        std::fs::remove_dir_all(dir).ok();
    }
}
