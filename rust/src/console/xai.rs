//! Explainable-AI plugins (paper §5.1: "we provide a variety of
//! XAI-related plugins, including Grad-CAM, LIME, and SGD influence").
//!
//! - [`grad_cam`] — Grad-CAM (Selvaraju et al.): class-gradient-
//!   weighted activation maps, computed directly on the tape engine;
//! - [`occlusion_saliency`] — LIME-style local perturbation
//!   attribution: class-score drop per occluded patch.

use crate::functions as F;
use crate::graph::Variable;
use crate::tensor::NdArray;

/// Grad-CAM over a chosen feature map.
///
/// `logits` must be reachable from `feature` (both from the same
/// built graph); the heatmap is `relu(sum_c alpha_c * A_c)` with
/// `alpha_c` the spatially-pooled gradient of the class logit wrt
/// channel `c`. Returns one `[H, W]` map per batch element, each
/// normalized to [0, 1].
pub fn grad_cam(feature: &Variable, logits: &Variable, class: usize) -> Vec<NdArray> {
    let dims = feature.dims();
    assert_eq!(dims.len(), 4, "grad_cam expects a NCHW feature map");
    let (n, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
    // gradient of the summed class logit wrt the feature map
    let class_score = F::mean_all(&F::slice_axis(logits, 1, class, class + 1));
    feature.zero_grad();
    class_score.backward();
    let grads = feature.grad();
    let acts = feature.data();
    let mut out = Vec::with_capacity(n);
    for b in 0..n {
        // alpha_c = spatial mean of dScore/dA_c
        let mut alpha = vec![0.0f32; c];
        for ci in 0..c {
            let base = (b * c + ci) * h * w;
            alpha[ci] =
                grads.data()[base..base + h * w].iter().sum::<f32>() / (h * w) as f32;
        }
        // cam = relu(sum_c alpha_c A_c)
        let mut cam = vec![0.0f32; h * w];
        for ci in 0..c {
            let base = (b * c + ci) * h * w;
            for i in 0..h * w {
                cam[i] += alpha[ci] * acts.data()[base + i];
            }
        }
        let mut max = 0.0f32;
        for v in &mut cam {
            *v = v.max(0.0);
            max = max.max(*v);
        }
        if max > 0.0 {
            for v in &mut cam {
                *v /= max;
            }
        }
        out.push(NdArray::from_vec(&[h, w], cam));
    }
    out
}

/// Occlusion saliency: slide a `patch`-sized zero window over the
/// input and record the class-probability drop — a model-agnostic
/// local explanation in the LIME family. `forward` maps a batch-1
/// NCHW input to logits `[1, classes]`. Returns an `[H, W]` map
/// (larger = more influential).
pub fn occlusion_saliency(
    input: &NdArray,
    class: usize,
    patch: usize,
    stride: usize,
    forward: impl Fn(&NdArray) -> NdArray,
) -> NdArray {
    assert_eq!(input.dims()[0], 1, "occlusion_saliency expects batch 1");
    let (c, h, w) = (input.dims()[1], input.dims()[2], input.dims()[3]);
    let base_probs = softmax_row(&forward(input), class);
    let mut heat = NdArray::zeros(&[h, w]);
    let mut counts = vec![0.0f32; h * w];
    let mut y0 = 0;
    while y0 < h {
        let mut x0 = 0;
        while x0 < w {
            let mut occluded = input.clone();
            for ci in 0..c {
                for y in y0..(y0 + patch).min(h) {
                    for x in x0..(x0 + patch).min(w) {
                        occluded.data_mut()[(ci * h + y) * w + x] = 0.0;
                    }
                }
            }
            let drop = (base_probs - softmax_row(&forward(&occluded), class)).max(0.0);
            for y in y0..(y0 + patch).min(h) {
                for x in x0..(x0 + patch).min(w) {
                    heat.data_mut()[y * w + x] += drop;
                    counts[y * w + x] += 1.0;
                }
            }
            x0 += stride;
        }
        y0 += stride;
    }
    for (v, cnt) in heat.data_mut().iter_mut().zip(&counts) {
        if *cnt > 0.0 {
            *v /= cnt;
        }
    }
    heat
}

fn softmax_row(logits: &NdArray, class: usize) -> f32 {
    let row = logits.data();
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|v| (v - max).exp()).collect();
    exps[class] / exps.iter().sum::<f32>()
}

/// Render a heatmap as ASCII (the Console's visual, headless).
pub fn render_heatmap(map: &NdArray) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let (h, w) = (map.dims()[0], map.dims()[1]);
    let mut s = String::new();
    for y in 0..h {
        for x in 0..w {
            let v = map.at(&[y, x]).clamp(0.0, 1.0);
            let idx = ((v * (RAMP.len() - 1) as f32).round()) as usize;
            s.push(RAMP[idx] as char);
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::Gb;
    use crate::parametric as PF;
    use crate::tensor::Rng;

    /// Tiny conv net whose class-0 logit is literally the sum of the
    /// top-left quadrant: attribution maps must light up there.
    fn quadrant_model() -> (Variable, Variable, Variable) {
        PF::clear_parameters();
        PF::seed_parameter_rng(1);
        let mut g = Gb::new("quad", false);
        let x = g.input("x", &[1, 1, 8, 8]);
        let feat = g.conv(&x, 4, (3, 3), (1, 1), (1, 1), "c1");
        let feat = g.relu(&feat);
        let logits = g.affine(&feat, 2, "head");
        (x.var.clone(), feat.var.clone(), logits.var.clone())
    }

    #[test]
    fn grad_cam_shape_and_range() {
        let (x, feat, logits) = quadrant_model();
        let mut rng = Rng::new(2);
        x.set_data(rng.randn(&[1, 1, 8, 8], 1.0));
        logits.forward();
        let maps = grad_cam(&feat, &logits, 0);
        assert_eq!(maps.len(), 1);
        assert_eq!(maps[0].dims(), &[8, 8]);
        assert!(maps[0].data().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn occlusion_finds_the_signal_region() {
        // model: class prob = f(top-left 4x4 sum); occluding there
        // must dominate the heatmap
        let forward = |x: &NdArray| {
            let mut s = 0.0;
            for y in 0..4 {
                for x2 in 0..4 {
                    s += x.at(&[0, 0, y, x2]);
                }
            }
            NdArray::from_slice(&[1, 2], &[s, 0.0])
        };
        let input = NdArray::ones(&[1, 1, 8, 8]);
        let heat = occlusion_saliency(&input, 0, 2, 2, forward);
        let tl: f32 = (0..4).flat_map(|y| (0..4).map(move |x| (y, x)))
            .map(|(y, x)| heat.at(&[y, x]))
            .sum();
        let br: f32 = (4..8).flat_map(|y| (4..8).map(move |x| (y, x)))
            .map(|(y, x)| heat.at(&[y, x]))
            .sum();
        assert!(tl > br * 5.0, "top-left {tl} vs bottom-right {br}");
    }

    #[test]
    fn heatmap_renders_ascii() {
        let mut m = NdArray::zeros(&[2, 3]);
        m.set(&[0, 0], 1.0);
        let r = render_heatmap(&m);
        assert_eq!(r.lines().count(), 2);
        assert!(r.starts_with('@'));
    }
}
