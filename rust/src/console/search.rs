//! Automatic structure search (§5.1): "searches for optimal neural
//! network structure automatically by repeating experiments with
//! varying network structures. Multiple network structures are
//! evaluated, simultaneously optimizing for accuracy and computational
//! complexity. Users can select from multiple optimization results."
//!
//! Implemented as an evolutionary search over MLP/CNN layer plans with
//! a (val-error, MACs) bi-objective; the result is the Pareto front.

use crate::context::{Backend, Context, TypeConfig};
use crate::data::DataSource;
use crate::functions as F;
use crate::graph::Variable;
use crate::models::Gb;
use crate::parametric as PF;
use crate::solvers::Solver;
use crate::tensor::Rng;

/// Search space: bounds on the layer plan.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    pub max_layers: usize,
    pub widths: Vec<usize>,
    /// Budget per candidate (training steps).
    pub steps: usize,
    pub lr: f32,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace { max_layers: 3, widths: vec![16, 32, 64, 128], steps: 40, lr: 0.1 }
    }
}

/// One evaluated structure.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Hidden-layer widths (the genome).
    pub plan: Vec<usize>,
    pub val_error: f32,
    pub macs: u64,
    pub n_params: usize,
}

impl Candidate {
    /// True if `other` is at least as good on both objectives and
    /// strictly better on one.
    fn dominated_by(&self, other: &Candidate) -> bool {
        (other.val_error <= self.val_error && other.macs <= self.macs)
            && (other.val_error < self.val_error || other.macs < self.macs)
    }
}

fn build_and_train(plan: &[usize], data: &dyn DataSource, space: &SearchSpace, seed: u64) -> Candidate {
    Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
    PF::clear_parameters();
    PF::seed_parameter_rng(seed);
    let batch0 = data.batch(0, 0, 1);
    let bs = batch0.0.dims()[0];
    let feat: usize = data.input_dims().iter().product();

    let mut g = Gb::new("search_mlp", true);
    let x = g.input("x", &[bs, feat]);
    let mut h = x.clone();
    for (i, &w) in plan.iter().enumerate() {
        h = g.affine(&h, w, &format!("fc{i}"));
        h = g.relu(&h);
    }
    let logits = g.affine(&h, data.classes(), "out");
    let macs = g.macs();
    let y = Variable::new(&[bs, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let params = PF::get_parameters();
    let n_params = params.iter().map(|(_, v)| v.size()).sum();
    let mut solver = Solver::momentum(space.lr, 0.9);
    solver.set_parameters(&params);
    for step in 0..space.steps {
        let (bx, by) = data.batch(step, 0, 1);
        x.var.set_data(bx.reshape(&[bs, feat]));
        y.set_data(by.reshape(&[bs, 1]));
        loss.forward();
        solver.zero_grad();
        loss.backward();
        solver.update();
    }
    // validation error
    let classes = data.classes();
    let mut wrong = 0;
    let mut total = 0;
    for i in 0..3 {
        let (bx, by) = data.val_batch(i);
        x.var.set_data(bx.reshape(&[bs, feat]));
        logits.var.forward();
        let out = logits.var.data();
        for b in 0..bs {
            let row = &out.data()[b * classes..(b + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            if pred != by.data()[b] as usize {
                wrong += 1;
            }
            total += 1;
        }
    }
    Candidate { plan: plan.to_vec(), val_error: wrong as f32 / total as f32, macs, n_params }
}

fn random_plan(rng: &mut Rng, space: &SearchSpace) -> Vec<usize> {
    let n = 1 + rng.below(space.max_layers);
    (0..n).map(|_| space.widths[rng.below(space.widths.len())]).collect()
}

fn mutate(rng: &mut Rng, plan: &[usize], space: &SearchSpace) -> Vec<usize> {
    let mut p = plan.to_vec();
    match rng.below(3) {
        0 if p.len() < space.max_layers => {
            p.insert(rng.below(p.len() + 1), space.widths[rng.below(space.widths.len())]);
        }
        1 if p.len() > 1 => {
            p.remove(rng.below(p.len()));
        }
        _ => {
            let i = rng.below(p.len());
            p[i] = space.widths[rng.below(space.widths.len())];
        }
    }
    p
}

/// Evolutionary bi-objective structure search. Returns the Pareto
/// front sorted by val_error (the "multiple optimization results" the
/// user selects from).
pub fn structure_search(
    data: &dyn DataSource,
    space: &SearchSpace,
    generations: usize,
    population: usize,
    seed: u64,
) -> Vec<Candidate> {
    let mut rng = Rng::new(seed);
    let mut evaluated: Vec<Candidate> = (0..population)
        .map(|i| build_and_train(&random_plan(&mut rng, space), data, space, seed + i as u64))
        .collect();
    for gen in 0..generations {
        // parents: current Pareto front (elitist)
        let front = pareto_front(&evaluated);
        let mut children = Vec::new();
        for i in 0..population {
            let parent = &front[rng.below(front.len())];
            let plan = mutate(&mut rng, &parent.plan, space);
            // skip exact duplicates
            if evaluated.iter().any(|c| c.plan == plan) {
                continue;
            }
            children.push(build_and_train(&plan, data, space, seed + (gen * 100 + i) as u64));
        }
        evaluated.extend(children);
    }
    let mut front = pareto_front(&evaluated);
    front.sort_by(|a, b| a.val_error.partial_cmp(&b.val_error).unwrap());
    front
}

fn pareto_front(cands: &[Candidate]) -> Vec<Candidate> {
    let mut front: Vec<Candidate> = Vec::new();
    for c in cands {
        if cands.iter().any(|o| c.dominated_by(o)) {
            continue;
        }
        // dedupe identical plans (the same genome can be sampled twice)
        if !front.iter().any(|f| f.plan == c.plan) {
            front.push(c.clone());
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticImages;

    #[test]
    fn pareto_front_filters_dominated() {
        let mk = |p: usize, e: f32, m: u64| Candidate {
            plan: vec![p],
            val_error: e,
            macs: m,
            n_params: 0,
        };
        let cands =
            vec![mk(1, 0.1, 100), mk(2, 0.2, 50), mk(3, 0.3, 200), mk(4, 0.15, 150)];
        let front = pareto_front(&cands);
        assert_eq!(front.len(), 2); // (0.1,100) and (0.2,50); others dominated
    }

    #[test]
    fn pareto_front_dedupes_identical_plans() {
        let mk = |e: f32, m: u64| Candidate { plan: vec![16], val_error: e, macs: m, n_params: 0 };
        let front = pareto_front(&[mk(0.1, 100), mk(0.1, 100)]);
        assert_eq!(front.len(), 1);
    }

    #[test]
    fn mutation_respects_bounds() {
        let space = SearchSpace::default();
        let mut rng = Rng::new(1);
        let mut plan = vec![32];
        for _ in 0..100 {
            plan = mutate(&mut rng, &plan, &space);
            assert!(!plan.is_empty() && plan.len() <= space.max_layers);
            assert!(plan.iter().all(|w| space.widths.contains(w)));
        }
    }

    #[test]
    fn search_finds_working_structures() {
        let data = SyntheticImages::new(4, 1, 8, 16, 21);
        let space = SearchSpace { steps: 30, widths: vec![16, 32], max_layers: 2, lr: 0.1 };
        let front = structure_search(&data, &space, 1, 3, 9);
        assert!(!front.is_empty());
        // best candidate beats chance (0.75 error) on separable data
        assert!(front[0].val_error < 0.6, "search best err {}", front[0].val_error);
        // front is sorted by error and anti-sorted by macs (Pareto)
        for w in front.windows(2) {
            assert!(w[0].val_error <= w[1].val_error);
            assert!(w[0].macs >= w[1].macs, "not a Pareto front");
        }
    }
}
