//! Confusion matrix — "for a classification task, it displays a
//! confusion matrix" (§5.1).

use crate::tensor::NdArray;

/// Row = true class, column = predicted class.
#[derive(Debug, Clone)]
pub struct ConfusionMatrix {
    pub n: usize,
    counts: Vec<usize>,
}

impl ConfusionMatrix {
    pub fn new(n_classes: usize) -> Self {
        ConfusionMatrix { n: n_classes, counts: vec![0; n_classes * n_classes] }
    }

    pub fn record(&mut self, truth: usize, pred: usize) {
        assert!(truth < self.n && pred < self.n);
        self.counts[truth * self.n + pred] += 1;
    }

    /// Record a batch from logits `[B, C]` and labels `[B]`.
    pub fn record_batch(&mut self, logits: &NdArray, labels: &NdArray) {
        let b = logits.dims()[0];
        let c = logits.dims()[1];
        assert_eq!(c, self.n);
        for i in 0..b {
            let row = &logits.data()[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap();
            self.record(labels.data()[i] as usize, pred);
        }
    }

    pub fn count(&self, truth: usize, pred: usize) -> usize {
        self.counts[truth * self.n + pred]
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    pub fn accuracy(&self) -> f32 {
        let correct: usize = (0..self.n).map(|i| self.count(i, i)).sum();
        correct as f32 / self.total().max(1) as f32
    }

    /// Per-class recall (diagonal / row sum).
    pub fn recall(&self, class: usize) -> f32 {
        let row: usize = (0..self.n).map(|j| self.count(class, j)).sum();
        self.count(class, class) as f32 / row.max(1) as f32
    }

    /// Per-class precision (diagonal / column sum).
    pub fn precision(&self, class: usize) -> f32 {
        let col: usize = (0..self.n).map(|i| self.count(i, class)).sum();
        self.count(class, class) as f32 / col.max(1) as f32
    }

    /// ASCII rendering (the Console's matrix view).
    pub fn render(&self) -> String {
        let mut s = String::from("true\\pred");
        for j in 0..self.n {
            s.push_str(&format!("{j:>6}"));
        }
        s.push_str("  recall\n");
        for i in 0..self.n {
            s.push_str(&format!("{i:>9}"));
            for j in 0..self.n {
                s.push_str(&format!("{:>6}", self.count(i, j)));
            }
            s.push_str(&format!("  {:.2}\n", self.recall(i)));
        }
        s.push_str(&format!("accuracy: {:.3} ({} samples)\n", self.accuracy(), self.total()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_metrics() {
        let mut m = ConfusionMatrix::new(3);
        // class 0: 2 right, 1 confused as 1
        m.record(0, 0);
        m.record(0, 0);
        m.record(0, 1);
        // class 1: 1 right
        m.record(1, 1);
        // class 2: all wrong
        m.record(2, 0);
        assert_eq!(m.total(), 5);
        assert!((m.accuracy() - 0.6).abs() < 1e-6);
        assert!((m.recall(0) - 2.0 / 3.0).abs() < 1e-6);
        assert!((m.precision(1) - 0.5).abs() < 1e-6);
        assert_eq!(m.recall(2), 0.0);
    }

    #[test]
    fn record_batch_from_logits() {
        let mut m = ConfusionMatrix::new(2);
        let logits = NdArray::from_slice(&[3, 2], &[2.0, 1.0, 0.0, 5.0, 3.0, -1.0]);
        let labels = NdArray::from_slice(&[3], &[0.0, 1.0, 1.0]);
        m.record_batch(&logits, &labels);
        assert_eq!(m.count(0, 0), 1); // correct
        assert_eq!(m.count(1, 1), 1); // correct
        assert_eq!(m.count(1, 0), 1); // third sample: pred 0, true 1
    }

    #[test]
    fn render_contains_accuracy() {
        let mut m = ConfusionMatrix::new(2);
        m.record(0, 0);
        m.record(1, 0);
        let r = m.render();
        assert!(r.contains("accuracy: 0.500"));
        assert!(r.contains("recall"));
    }
}
