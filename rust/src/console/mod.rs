//! Headless Neural Network Console (paper §5.1): trial records with
//! automatic bookkeeping and comparison, confusion matrices, parameter
//! / multiply-add footprinting, and automatic structure search — every
//! Console capability that isn't pixels.

pub mod confusion;
pub mod search;
pub mod trials;
pub mod xai;

pub use confusion::ConfusionMatrix;
pub use search::{structure_search, Candidate, SearchSpace};
pub use trials::{TrialRecord, TrialStore};
pub use xai::{grad_cam, occlusion_saliency, render_heatmap};

use crate::models::{build_model, Gb};
use crate::parametric as PF;

/// Parameter + multiply-add footprint of a zoo model — the Console's
/// real-time "number of parameters and multiply-adds" readout.
pub fn footprint(model: &str, input_dims: &[usize], classes: usize) -> (usize, u64) {
    PF::clear_parameters();
    PF::seed_parameter_rng(0);
    let mut g = Gb::new(model, false);
    let dims: Vec<usize> = std::iter::once(1).chain(input_dims.iter().copied()).collect();
    let x = g.input("x", &dims);
    let _ = build_model(&mut g, model, &x, classes);
    let params: usize = PF::get_parameters().iter().map(|(_, v)| v.size()).sum();
    let macs = g.macs();
    PF::clear_parameters();
    (params, macs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn footprint_returns_nonzero() {
        let (params, macs) = footprint("lenet", &[1, 28, 28], 10);
        assert!(params > 10_000);
        assert!(macs > 100_000);
    }

    #[test]
    fn footprint_scales_with_model() {
        let (p18, m18) = footprint("resnet18", &[3, 16, 16], 10);
        let (p50, m50) = footprint("resnet50", &[3, 16, 16], 10);
        assert!(p50 > p18);
        assert!(m50 > m18);
    }
}
