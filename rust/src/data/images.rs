//! Synthetic image classification corpus — the ImageNet stand-in.
//!
//! Each class `c` has a fixed spatial template (a seeded random
//! pattern); a sample is `template(c) * contrast + noise`. This is
//! learnable by convnets (val error well below chance), separable
//! enough that relative model capacity shows in the error columns of
//! Tables 2/3, and fully deterministic.

use crate::tensor::{ops, NdArray, Rng};

use super::{Batch, DataSource};

/// Class-structured synthetic images (NCHW).
#[derive(Debug, Clone)]
pub struct SyntheticImages {
    pub classes: usize,
    pub channels: usize,
    pub img: usize,
    pub batch_size: usize,
    pub noise: f32,
    seed: u64,
    templates: Vec<NdArray>,
}

impl SyntheticImages {
    pub fn new(classes: usize, channels: usize, img: usize, batch_size: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xDA7A);
        let templates = (0..classes)
            .map(|_| rng.randn(&[channels, img, img], 1.0))
            .collect();
        SyntheticImages { classes, channels, img, batch_size, noise: 1.0, seed, templates }
    }

    /// ImageNet-shaped default for the benchmarks (scaled down).
    pub fn imagenet_mini(batch_size: usize) -> Self {
        Self::new(10, 3, 16, batch_size, 1)
    }

    fn make_batch(&self, stream: u64, i: usize) -> Batch {
        let mut rng = Rng::new(self.seed ^ stream.wrapping_mul(0x9E37).wrapping_add(i as u64));
        let n = self.batch_size;
        let feat = self.channels * self.img * self.img;
        let mut x = NdArray::zeros(&[n, self.channels, self.img, self.img]);
        let mut y = NdArray::zeros(&[n]);
        for b in 0..n {
            let c = rng.below(self.classes);
            y.data_mut()[b] = c as f32;
            let noise = rng.randn(&[feat], self.noise);
            let sample = ops::add(&ops::scale(&self.templates[c], 1.5), &noise.reshape(&[
                self.channels,
                self.img,
                self.img,
            ]));
            x.data_mut()[b * feat..(b + 1) * feat].copy_from_slice(sample.data());
        }
        (x, y)
    }
}

impl DataSource for SyntheticImages {
    fn batch(&self, i: usize, rank: usize, world: usize) -> Batch {
        // disjoint streams per rank: stride the global batch index
        self.make_batch(1 + rank as u64, i * world + rank)
    }

    fn val_batch(&self, i: usize) -> Batch {
        self.make_batch(0x7E57, i)
    }

    fn input_dims(&self) -> Vec<usize> {
        vec![self.channels, self.img, self.img]
    }

    fn classes(&self) -> usize {
        self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic() {
        let d = SyntheticImages::new(4, 1, 8, 16, 7);
        let (x1, y1) = d.batch(3, 0, 1);
        let (x2, y2) = d.batch(3, 0, 1);
        assert_eq!(x1.data(), x2.data());
        assert_eq!(y1.data(), y2.data());
        let (x3, _) = d.batch(4, 0, 1);
        assert_ne!(x1.data(), x3.data());
    }

    #[test]
    fn ranks_see_disjoint_streams() {
        let d = SyntheticImages::new(4, 1, 8, 16, 7);
        let (x0, _) = d.batch(0, 0, 2);
        let (x1, _) = d.batch(0, 1, 2);
        assert_ne!(x0.data(), x1.data());
    }

    #[test]
    fn labels_in_range_all_classes_seen() {
        let d = SyntheticImages::new(5, 1, 4, 64, 9);
        let (_, y) = d.batch(0, 0, 1);
        let mut seen = [false; 5];
        for &v in y.data() {
            assert!(v >= 0.0 && v < 5.0);
            seen[v as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 4);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-template classification on clean-ish data beats chance
        let d = SyntheticImages::new(4, 1, 8, 64, 3);
        let (x, y) = d.val_batch(0);
        let feat = 64;
        let mut correct = 0;
        for b in 0..64 {
            let sample = &x.data()[b * feat..(b + 1) * feat];
            let mut best = (f32::INFINITY, 0usize);
            for (c, t) in d.templates.iter().enumerate() {
                let dist: f32 = sample
                    .iter()
                    .zip(t.data())
                    .map(|(s, t)| (s - 1.5 * t) * (s - 1.5 * t))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == y.data()[b] as usize {
                correct += 1;
            }
        }
        assert!(correct > 48, "only {correct}/64 separable"); // >75%
    }

    #[test]
    fn val_differs_from_train() {
        let d = SyntheticImages::new(4, 1, 8, 16, 7);
        let (xt, _) = d.batch(0, 0, 1);
        let (xv, _) = d.val_batch(0);
        assert_ne!(xt.data(), xv.data());
    }
}
