//! Tiny text corpus + byte-level tokenizer for the TransformerLM
//! end-to-end driver (`examples/e2e_train.rs`).

use crate::tensor::{NdArray, Rng};

/// An embedded public-domain-style corpus: enough structure (English
/// character statistics) that a small LM's loss visibly drops from the
/// uniform baseline within a few hundred steps.
pub const DEFAULT_TEXT: &str = "\
deep learning has revolutionized the field of artificial intelligence, \
with state of the art performances in image recognition, speech \
recognition, and machine translation. its application is not restricted \
to research, and has taken up a substantial part of real world \
platforms, such as automated driving and mobile applications. the \
demand for a more flexible and efficient tool grows stronger: users \
need to define complex networks more concisely, and it is necessary to \
easily handle static and dynamic computational graphs. with the advent \
of massively large models, and the costs for accessing remote servers \
skyrocketing, the ability to perform computation in a speedy manner, \
particularly in a distributed setting, has become a pivotal factor. \
another issue that emerges from the massive expansion of deep learning \
tools is compatibility. with countless tools developed and released \
anew on a daily basis, it is possible that we end up with disjoint \
clusters of research and development. a tool to easily make models \
compatible with other frameworks will alleviate such risks. we focus on \
usability and compatibility, from the perspective of engineers: the \
framework enhances usability by flexible network design and speedy \
computation, and provides a wide range of compatibility, being easily \
portable to and from other frameworks. while such aims are equally \
critical for researchers as well, we approach the issues under the \
principle of engineers first, as there already exists a plethora of \
research oriented tools, with strikingly less emphasis on engineering.";

/// Byte-level LM dataset over a fixed corpus.
#[derive(Debug, Clone)]
pub struct TinyCorpus {
    tokens: Vec<u8>,
    pub vocab: usize,
    pub seq: usize,
    pub batch_size: usize,
    seed: u64,
}

impl TinyCorpus {
    /// Tokenize `text` into the printable-byte vocabulary `[0, 96)`
    /// (ASCII 32..127 mapped to 0..95; others to 0).
    pub fn new(text: &str, seq: usize, batch_size: usize, seed: u64) -> Self {
        let tokens: Vec<u8> = text
            .bytes()
            .map(|b| if (32..127).contains(&b) { b - 32 } else { 0 })
            .collect();
        assert!(tokens.len() > seq + 1, "corpus shorter than one window");
        TinyCorpus { tokens, vocab: 96, seq, batch_size, seed }
    }

    pub fn default_corpus(seq: usize, batch_size: usize) -> Self {
        Self::new(DEFAULT_TEXT, seq, batch_size, 11)
    }

    pub fn len_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Batch `i`: windows (x = tokens[j..j+seq], y = next tokens).
    pub fn batch(&self, i: usize, rank: usize, world: usize) -> (NdArray, NdArray) {
        let mut rng =
            Rng::new(self.seed ^ ((i * world + rank) as u64).wrapping_mul(0x9E3779B9));
        let n = self.batch_size;
        let mut x = NdArray::zeros(&[n, self.seq]);
        let mut y = NdArray::zeros(&[n, self.seq]);
        for b in 0..n {
            let start = rng.below(self.tokens.len() - self.seq - 1);
            for t in 0..self.seq {
                x.data_mut()[b * self.seq + t] = self.tokens[start + t] as f32;
                y.data_mut()[b * self.seq + t] = self.tokens[start + t + 1] as f32;
            }
        }
        (x, y)
    }

    /// Decode token ids back to text (sampling demos).
    pub fn decode(&self, ids: &[f32]) -> String {
        ids.iter().map(|&i| (i as u8 + 32) as char).collect()
    }

    /// Uniform-distribution cross-entropy baseline (`ln(vocab)`).
    pub fn uniform_loss(&self) -> f32 {
        (self.vocab as f32).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_are_shifted_pairs() {
        let c = TinyCorpus::default_corpus(16, 4);
        let (x, y) = c.batch(0, 0, 1);
        assert_eq!(x.dims(), &[4, 16]);
        // y[t] == x[t+1] within each window
        for b in 0..4 {
            for t in 0..15 {
                assert_eq!(x.data()[b * 16 + t + 1], y.data()[b * 16 + t]);
            }
        }
    }

    #[test]
    fn tokens_within_vocab() {
        let c = TinyCorpus::default_corpus(8, 8);
        let (x, _) = c.batch(1, 0, 1);
        assert!(x.data().iter().all(|&v| v >= 0.0 && v < 96.0));
    }

    #[test]
    fn decode_inverts_encode() {
        let c = TinyCorpus::new("hello world", 4, 1, 0);
        let ids: Vec<f32> = "hello".bytes().map(|b| (b - 32) as f32).collect();
        assert_eq!(c.decode(&ids), "hello");
    }

    #[test]
    fn deterministic_and_rank_disjoint() {
        let c = TinyCorpus::default_corpus(8, 4);
        let (x1, _) = c.batch(0, 0, 2);
        let (x2, _) = c.batch(0, 0, 2);
        let (x3, _) = c.batch(0, 1, 2);
        assert_eq!(x1.data(), x2.data());
        assert_ne!(x1.data(), x3.data());
    }

    #[test]
    fn uniform_loss_is_ln_vocab() {
        let c = TinyCorpus::default_corpus(8, 1);
        assert!((c.uniform_loss() - 96f32.ln()).abs() < 1e-6);
    }
}
