//! Datasets and iterators.
//!
//! The paper's evaluation uses ImageNet; this testbed has no such
//! corpus (substitution documented in DESIGN.md), so [`SyntheticImages`]
//! generates a deterministic class-structured image distribution whose
//! learnability plays ImageNet's role in every table, plus
//! [`TinyCorpus`], a byte-level text source for the TransformerLM
//! end-to-end driver. Both shard deterministically per worker for
//! data-parallel runs (Listing 3 / Figure 3).

pub mod images;
pub mod text;

pub use images::SyntheticImages;
pub use text::TinyCorpus;

use crate::tensor::NdArray;

/// A batch: inputs + labels (labels stored as f32 indices).
pub type Batch = (NdArray, NdArray);

/// Batched data source.
pub trait DataSource {
    /// Deterministic batch `i` for worker `rank` of `world` (each rank
    /// sees a disjoint stream, as a distributed sampler would give).
    fn batch(&self, i: usize, rank: usize, world: usize) -> Batch;
    /// A held-out validation batch.
    fn val_batch(&self, i: usize) -> Batch;
    /// Input feature dims (without batch axis).
    fn input_dims(&self) -> Vec<usize>;
    /// Number of classes.
    fn classes(&self) -> usize;
}
