//! Post-training int8 quantization — the compact-artifact half of the
//! paper's embedded-deployment story (§3: NNP → NNB for the C
//! runtime), built on the int8 kernels in
//! [`crate::tensor::kernels::int8`].
//!
//! The pipeline, which now rides the compile-time graph optimizer
//! ([`crate::nnp::passes`]) end to end:
//!
//! 0. **Optimize** ([`crate::nnp::passes::optimize`]): the source
//!    graph is rewritten at O2 first — BatchNorm folds into the
//!    preceding Conv/Affine weights, no-ops are elided — so
//!    BN-sandwiched convolutions become plain dense layers the int8
//!    path can actually lower. NNB2 artifacts carry this *optimized*
//!    graph.
//! 1. **Calibrate** ([`calibrate`]): run the optimized [`CompiledNet`]
//!    over a small sample set and record per-tensor activation min/max
//!    (optionally percentile-clipped) through
//!    [`CompiledNet::execute_observed`] — ranges exist for exactly the
//!    tensors the optimized plan materializes (fused and folded
//!    intermediates are excluded by construction).
//! 2. **Quantize** ([`quantize_model`]): every Affine/Convolution
//!    weight whose input range was observed becomes a per-output-
//!    channel symmetric i8 [`QTensor`] (~4× smaller); biases and every
//!    other parameter stay f32. The result is a [`QuantizedModel`] —
//!    the unit NNB2 serializes ([`crate::converters::nnb::to_nnb2`]).
//! 3. **Compile** ([`QuantizedNet::compile`]): the model compiles
//!    through the same pass pipeline as the f32 path; every dense plan
//!    step with an i8 weight and a calibrated input range becomes an
//!    int8 GEMM step with a fused requantize + bias epilogue. A ReLU
//!    the *plan* fused into the dense step (sole-reader chains, see
//!    `nnp::passes::fuse_relu`) folds into the int8 epilogue for free;
//!    every other step runs the same f32 kernels the base plan uses.
//!    Weights a compile-time fold introduced (e.g. BN-folded convs of
//!    an artifact quantized before this optimizer existed) are
//!    re-quantized at load.
//!
//! [`QuantizedNet`] implements [`InferencePlan`], so
//! [`crate::serve::Server`] hosts it exactly like an f32 plan.
//! Quantized execution is bit-identical at any `NNL_THREADS` (exact
//! i32 accumulation + fixed per-element epilogue); `nnl bench-quant`
//! measures the fp32-vs-int8 throughput, artifact size, and top-1
//! agreement numbers (`BENCH_quant.json`).

use std::collections::{HashMap, HashSet};

use crate::nnp::ir::{NetworkDef, Op, TensorDef};
use crate::nnp::passes::{self, OptLevel};
use crate::nnp::plan::{execute_kernel, CompiledNet, InferencePlan, Src, StepKernel};
use crate::tensor::kernels;
use crate::tensor::kernels::int8::{self, ActQuant, QMatB};
use crate::tensor::ops::Conv2dGeom;
use crate::tensor::NdArray;

// ------------------------------------------------------------ calibration

/// Observed activation range of one tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActRange {
    pub lo: f32,
    pub hi: f32,
}

/// Calibration result: tensor name → observed range, name-sorted so
/// serialized artifacts are byte-stable.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibTable {
    pub ranges: Vec<(String, ActRange)>,
}

impl CalibTable {
    pub fn get(&self, name: &str) -> Option<ActRange> {
        self.ranges.iter().find(|(n, _)| n == name).map(|&(_, r)| r)
    }
}

/// Quantization knobs.
#[derive(Debug, Clone, Default)]
pub struct QuantConfig {
    /// `None`: plain min/max ranges. `Some(p)` with `0.5 < p ≤ 1`:
    /// clip each range to the `[1−p, p]` quantiles of the observed
    /// values (outlier-robust at the cost of saturating the tails).
    pub percentile: Option<f32>,
}

/// Per-tensor streaming statistics gathered during calibration.
struct Observed {
    lo: f32,
    hi: f32,
    /// Deterministic value subsample for quantile clipping (strided,
    /// never random — calibration must be reproducible).
    sample: Vec<f32>,
}

/// Run `plan` over `samples` (each a positional input set) and record
/// activation ranges for every network input and materialized step
/// output. Tensors the optimizer fused or folded away are never
/// observed — the table describes what the optimized plan actually
/// computes.
pub fn calibrate(
    plan: &CompiledNet,
    samples: &[Vec<NdArray>],
    cfg: &QuantConfig,
) -> Result<CalibTable, String> {
    if samples.is_empty() {
        return Err("calibration requires at least one sample".into());
    }
    if let Some(p) = cfg.percentile {
        if !(p > 0.5 && p <= 1.0) {
            return Err(format!("percentile must be in (0.5, 1], got {p}"));
        }
    }
    let mut obs: HashMap<String, Observed> = HashMap::new();
    for inputs in samples {
        plan.execute_observed(inputs, &mut |name, a| {
            let e = obs.entry(name.to_string()).or_insert(Observed {
                lo: f32::INFINITY,
                hi: f32::NEG_INFINITY,
                sample: Vec::new(),
            });
            for &v in a.data() {
                if v.is_finite() {
                    e.lo = e.lo.min(v);
                    e.hi = e.hi.max(v);
                }
            }
            if cfg.percentile.is_some() {
                let stride = (a.size() / 512).max(1);
                e.sample.extend(a.data().iter().step_by(stride).filter(|v| v.is_finite()));
            }
        })?;
    }
    let mut ranges: Vec<(String, ActRange)> = obs
        .into_iter()
        .map(|(name, mut o)| {
            let (mut lo, mut hi) = if o.lo <= o.hi { (o.lo, o.hi) } else { (0.0, 0.0) };
            if let Some(p) = cfg.percentile {
                if !o.sample.is_empty() {
                    o.sample.sort_by(f32::total_cmp);
                    let q = |frac: f32| {
                        let i = ((o.sample.len() - 1) as f32 * frac).round() as usize;
                        o.sample[i]
                    };
                    lo = lo.max(q(1.0 - p));
                    hi = hi.min(q(p));
                    if lo > hi {
                        (lo, hi) = (hi, lo);
                    }
                }
            }
            (name, ActRange { lo, hi })
        })
        .collect();
    ranges.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(CalibTable { ranges })
}

// ------------------------------------------------------- quantized params

/// A per-channel symmetric int8 tensor: the on-disk / in-memory form
/// of a quantized weight. `data` keeps the source layout (OIHW for
/// conv, `[in, out]` for Affine); `scales[c]` applies to the slice at
/// index `c` of `channel_axis`.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub dims: Vec<usize>,
    pub channel_axis: usize,
    pub data: Vec<i8>,
    pub scales: Vec<f32>,
}

impl QTensor {
    /// Symmetric per-channel quantization of `w` along `channel_axis`.
    /// Errs (rather than panicking) on an out-of-range axis: the axis
    /// can come from an untrusted artifact's layer table.
    pub fn quantize(w: &NdArray, channel_axis: usize) -> Result<QTensor, String> {
        if channel_axis >= w.rank() {
            return Err(format!(
                "quantize: channel axis {channel_axis} out of range for rank-{} weight",
                w.rank()
            ));
        }
        let dims = w.dims().to_vec();
        let outer: usize = dims[..channel_axis].iter().product();
        let ch = dims[channel_axis];
        let inner: usize = dims[channel_axis + 1..].iter().product();
        let d = w.data();
        let mut scales = vec![0.0f32; ch];
        for o in 0..outer {
            for (c, sc) in scales.iter_mut().enumerate() {
                let base = (o * ch + c) * inner;
                for &v in &d[base..base + inner] {
                    *sc = sc.max(v.abs());
                }
            }
        }
        for sc in &mut scales {
            *sc = if *sc > 0.0 { *sc / 127.0 } else { 1.0 };
        }
        let mut data = Vec::with_capacity(d.len());
        for o in 0..outer {
            for (c, sc) in scales.iter().enumerate() {
                let base = (o * ch + c) * inner;
                data.extend(
                    d[base..base + inner]
                        .iter()
                        .map(|&v| (v / sc).round().clamp(-127.0, 127.0) as i8),
                );
            }
        }
        Ok(QTensor { dims, channel_axis, data, scales })
    }

    /// Back to f32 (the fallback boundary, and the base-plan binding).
    pub fn dequantize(&self) -> NdArray {
        if self.data.is_empty() {
            // zero-element tensor: skip the outer/channel walk (a
            // crafted artifact can pair a zero dim with huge siblings)
            return NdArray::from_vec(&self.dims, Vec::new());
        }
        let outer: usize = self.dims[..self.channel_axis].iter().product();
        let ch = self.dims[self.channel_axis];
        let inner: usize = self.dims[self.channel_axis + 1..].iter().product();
        let mut out = Vec::with_capacity(self.data.len());
        for o in 0..outer {
            for (c, sc) in self.scales.iter().enumerate() {
                let base = (o * ch + c) * inner;
                out.extend(self.data[base..base + inner].iter().map(|&q| q as f32 * sc));
            }
        }
        NdArray::from_vec(&self.dims, out)
    }
}

/// One named parameter of a quantized model.
#[derive(Debug, Clone, PartialEq)]
pub enum QParam {
    Float(NdArray),
    Int8(QTensor),
}

impl QParam {
    /// The f32 view (dequantizing if needed).
    pub fn to_f32(&self) -> NdArray {
        match self {
            QParam::Float(a) => a.clone(),
            QParam::Int8(q) => q.dequantize(),
        }
    }
}

/// A quantized network: structure + mixed f32/i8 parameters +
/// calibration table. The `net` is the *optimized* definition when
/// produced by [`quantize_net`] / `nnl quantize`. Serializable as
/// NNB2, compilable into a [`QuantizedNet`]. Parameters appear in
/// layer binding order; parameters no layer references are dropped
/// (dead for inference).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedModel {
    pub net: NetworkDef,
    pub params: Vec<(String, QParam)>,
    pub calib: CalibTable,
}

/// Whether layer `l` is a dense layer whose weight (first param) can
/// take the int8 path, given the calibrated ranges.
fn dense_weight_axis(l: &crate::nnp::ir::Layer, calib: &CalibTable) -> Option<usize> {
    if l.inputs.len() != 1 || l.params.is_empty() || calib.get(&l.inputs[0]).is_none() {
        return None;
    }
    match l.op {
        Op::Affine => Some(1),
        Op::Convolution { .. } => Some(0),
        _ => None,
    }
}

/// Quantize `net`'s dense weights per output channel. A parameter is
/// stored as i8 only if *every* layer referencing it uses it as the
/// weight of a quantizable dense layer (shared or oddly-wired params
/// conservatively stay f32). Pass the *optimized* definition (see
/// [`crate::nnp::passes::optimize`]) so BN-folded convolutions
/// quantize too — [`quantize_net`] wires this up.
pub fn quantize_model(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    calib: &CalibTable,
) -> Result<QuantizedModel, String> {
    net.validate()?;
    // (quantize?, channel_axis) per param name, ANDed over all uses
    let mut plan_for: HashMap<&str, Option<usize>> = HashMap::new();
    for l in &net.layers {
        let axis = dense_weight_axis(l, calib);
        for (pi, pname) in l.params.iter().enumerate() {
            let this = if pi == 0 { axis } else { None };
            plan_for
                .entry(pname.as_str())
                .and_modify(|e| {
                    if *e != this {
                        *e = None;
                    }
                })
                .or_insert(this);
        }
    }
    let mut out: Vec<(String, QParam)> = Vec::new();
    let mut seen: HashSet<&str> = HashSet::new();
    for l in &net.layers {
        for pname in &l.params {
            if !seen.insert(pname.as_str()) {
                continue;
            }
            let arr = params
                .get(pname.as_str())
                .ok_or_else(|| format!("missing parameter '{pname}'"))?;
            let q = match plan_for.get(pname.as_str()).copied().flatten() {
                // the GEMM reduction depth is elements / output
                // channels; past MAX_EXACT_K the i32 accumulator could
                // overflow, so such weights stay f32
                Some(axis)
                    if axis < arr.rank()
                        && arr.dims()[axis] > 0
                        && arr.size() / arr.dims()[axis] <= int8::MAX_EXACT_K =>
                {
                    QParam::Int8(QTensor::quantize(arr, axis)?)
                }
                _ => QParam::Float(arr.clone()),
            };
            out.push((pname.clone(), q));
        }
    }
    Ok(QuantizedModel { net: net.clone(), params: out, calib: calib.clone() })
}

// ------------------------------------------------------- quantized plans

/// A dense step lowered to the int8 GEMM.
struct QDense {
    weight: QMatB,
    /// Source weight dims (shape validation + error messages).
    wdims: Vec<usize>,
    act: ActQuant,
    /// `act.scale · weight_scale[j]` — the epilogue's per-column scale.
    combined: Vec<f32>,
    bias: Option<NdArray>,
    relu: bool,
    /// `None` = Affine; `Some(geom)` = Convolution.
    conv: Option<Conv2dGeom>,
}

/// What the quantized plan does at one step beyond the base plan.
enum QStep {
    /// Run the base step unchanged (shared kernel dispatch).
    Passthrough,
    /// int8 dense fast path replacing the base step. A ReLU the plan
    /// fused into the base step rides the int8 epilogue.
    Dense(Box<QDense>),
}

/// A compiled plan whose dense layers execute on the int8 GEMM —
/// serve-ready ([`InferencePlan`]), `Send + Sync`, bit-identical at
/// any thread count. Build with [`QuantizedNet::compile`].
pub struct QuantizedNet {
    plan: CompiledNet,
    steps: Vec<QStep>,
    quantized_layers: Vec<String>,
}

/// Reject crafted / inconsistent i8 parameters against the model's
/// own (pre-lowering) layer structure — dims come from untrusted NNB2
/// bytes, and the decoder only checks the *total* element product, so
/// per-axis values must be re-validated before any k·n arithmetic or
/// panel allocation.
fn validate_int8_params(model: &QuantizedModel) -> Result<(), String> {
    let by_name: HashMap<&str, &QParam> =
        model.params.iter().map(|(n, p)| (n.as_str(), p)).collect();
    for l in &model.net.layers {
        let Some(wname) = l.params.first() else { continue };
        let Some(QParam::Int8(qt)) = by_name.get(wname.as_str()) else { continue };
        let elems = qt
            .dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .filter(|&e| e == qt.data.len());
        if qt.dims.is_empty()
            || qt.dims.iter().any(|&d| d == 0)
            || elems.is_none()
            || qt.channel_axis >= qt.dims.len()
            || qt.scales.len() != qt.dims[qt.channel_axis]
        {
            return Err(format!(
                "layer '{}': weight '{wname}' has degenerate quantized shape {:?}",
                l.name, qt.dims
            ));
        }
        let c = match &l.op {
            Op::Affine => {
                if qt.dims.len() != 2 || qt.channel_axis != 1 {
                    return Err(format!(
                        "layer '{}': Affine weight '{wname}' quantized with shape {:?} \
                         axis {} (want rank 2, axis 1)",
                        l.name, qt.dims, qt.channel_axis
                    ));
                }
                qt.dims[1]
            }
            Op::Convolution { .. } => {
                if qt.dims.len() != 4 || qt.channel_axis != 0 {
                    return Err(format!(
                        "layer '{}': Convolution weight '{wname}' quantized with shape \
                         {:?} axis {} (want rank 4, axis 0)",
                        l.name, qt.dims, qt.channel_axis
                    ));
                }
                qt.dims[0]
            }
            other => {
                return Err(format!(
                    "layer '{}': int8 weight '{wname}' on non-dense op {}",
                    l.name,
                    other.name()
                ))
            }
        };
        if l.inputs.len() != 1 || model.calib.get(&l.inputs[0]).is_none() {
            return Err(format!(
                "layer '{}': quantized weight '{wname}' but no calibrated input range",
                l.name
            ));
        }
        if let Some(bname) = l.params.get(1) {
            let b = by_name
                .get(bname.as_str())
                .ok_or_else(|| format!("missing parameter '{bname}'"))?
                .to_f32();
            if b.size() != c {
                return Err(format!(
                    "layer '{}': bias size {} does not match {c} output channels",
                    l.name,
                    b.size()
                ));
            }
        }
    }
    Ok(())
}

impl QuantizedNet {
    /// Compile a [`QuantizedModel`]: the base f32 plan is compiled
    /// against dequantized parameters through the full pass pipeline
    /// (the fallback path for every non-dense step), then each dense
    /// plan step with an i8 weight and a calibrated input range
    /// becomes an int8 GEMM step whose epilogue carries the step's
    /// fused ReLU, bias and requantization.
    pub fn compile(model: &QuantizedModel) -> Result<QuantizedNet, String> {
        validate_int8_params(model)?;
        let mut f32_params: HashMap<String, NdArray> = HashMap::new();
        for (name, p) in &model.params {
            f32_params.insert(name.clone(), p.to_f32());
        }
        let plan = CompiledNet::compile(&model.net, &f32_params)?;
        let by_name: HashMap<&str, &QParam> =
            model.params.iter().map(|(n, p)| (n.as_str(), p)).collect();

        let mut steps: Vec<QStep> = Vec::with_capacity(plan.steps().len());
        let mut quantized_layers = Vec::new();
        for st in plan.steps() {
            let (axis, relu, geom) = match &st.kernel {
                StepKernel::Affine { relu } => (1usize, *relu, None),
                StepKernel::Conv2d { geom, relu } => (0usize, *relu, Some(*geom)),
                _ => {
                    steps.push(QStep::Passthrough);
                    continue;
                }
            };
            let (Some(&Src::Act(xslot)), Some(&Src::Param(wi))) =
                (st.args.first(), st.args.get(1))
            else {
                steps.push(QStep::Passthrough);
                continue;
            };
            let wname = plan.param_name(wi);
            let range = model.calib.get(plan.slot_name(xslot));
            let mut requantized: Option<QTensor> = None;
            let qt: &QTensor = match by_name.get(wname) {
                Some(QParam::Int8(q)) => {
                    // validated above; enforce the exact-i32 bound (a
                    // foreign artifact may carry deeper weights: that
                    // layer stays on the f32 fallback)
                    if q.data.len() / q.dims[axis.min(q.dims.len() - 1)] > int8::MAX_EXACT_K {
                        steps.push(QStep::Passthrough);
                        continue;
                    }
                    q
                }
                // quantize_model deliberately kept this weight f32
                // (shared, or deeper than the exact-i32 bound)
                Some(QParam::Float(_)) => {
                    steps.push(QStep::Passthrough);
                    continue;
                }
                // a weight the compile-time folds introduced (e.g. a
                // BN fold applied to an artifact quantized before the
                // optimizer existed): quantize the bound value at load
                None => {
                    if range.is_none() {
                        steps.push(QStep::Passthrough);
                        continue;
                    }
                    let w = plan.param(wi);
                    let outch = w.dims().get(axis).copied().unwrap_or(0);
                    if outch == 0 || w.size() == 0 || w.size() / outch > int8::MAX_EXACT_K {
                        steps.push(QStep::Passthrough);
                        continue;
                    }
                    &*requantized.insert(QTensor::quantize(w, axis)?)
                }
            };
            let Some(range) = range else {
                return Err(format!(
                    "layer '{}': quantized weight '{wname}' but no calibrated input range",
                    st.name
                ));
            };
            let weight = match geom {
                None => QMatB::from_i8_kn(&qt.data, &qt.scales, qt.dims[0], qt.dims[1]),
                Some(_) => {
                    // no overflow: the dim product was checked against
                    // data.len() during validation
                    let k = qt.dims[1] * qt.dims[2] * qt.dims[3];
                    QMatB::from_i8_nk(&qt.data, &qt.scales, qt.dims[0], k)
                }
            };
            let bias = match st.args.get(2) {
                Some(&Src::Param(bi)) => Some(plan.param(bi).clone()),
                Some(&Src::Act(_)) => {
                    steps.push(QStep::Passthrough);
                    continue;
                }
                None => None,
            };
            if let Some(b) = &bias {
                if b.size() != weight.n() {
                    return Err(format!(
                        "layer '{}': bias size {} does not match {} output channels",
                        st.name,
                        b.size(),
                        weight.n()
                    ));
                }
            }
            let act = ActQuant::from_range(range.lo, range.hi);
            let combined: Vec<f32> = weight.scales().iter().map(|s| s * act.scale).collect();
            steps.push(QStep::Dense(Box::new(QDense {
                weight,
                wdims: qt.dims.clone(),
                act,
                combined,
                bias,
                relu,
                conv: geom,
            })));
            quantized_layers.push(st.name.clone());
        }
        Ok(QuantizedNet { plan, steps, quantized_layers })
    }

    /// The f32 base plan (fallback path; also: shared input signature).
    pub fn base_plan(&self) -> &CompiledNet {
        &self.plan
    }

    /// Names of the layers running on the int8 path.
    pub fn quantized_layers(&self) -> &[String] {
        &self.quantized_layers
    }

    /// How many layers run on the int8 path.
    pub fn n_quantized(&self) -> usize {
        self.quantized_layers.len()
    }

    fn run_dense(&self, q: &QDense, x: &NdArray) -> Result<NdArray, String> {
        match q.conv {
            None => {
                if x.rank() < 1 {
                    return Err("quantized Affine input must have a batch axis".into());
                }
                let feat: usize = x.dims()[1..].iter().product();
                if feat != q.weight.k() {
                    return Err(format!(
                        "quantized Affine: input features {feat} do not match weight rows {}",
                        q.weight.k()
                    ));
                }
                Ok(int8::qaffine_forward(
                    x,
                    &q.act,
                    &q.weight,
                    &q.combined,
                    q.bias.as_ref(),
                    q.relu,
                ))
            }
            Some(g) => {
                if x.rank() != 4 {
                    return Err(format!(
                        "quantized Convolution: expected NCHW input, got shape {:?}",
                        x.dims()
                    ));
                }
                if x.dims()[1] != q.wdims[1] {
                    return Err(format!(
                        "quantized Convolution: weight in-channels {} vs input channels {}",
                        q.wdims[1],
                        x.dims()[1]
                    ));
                }
                if g.try_out_hw(x.dims()[2], x.dims()[3]).is_none() {
                    return Err(format!(
                        "quantized Convolution: geometry invalid on {}x{} input \
                         (kernel {:?} stride {:?} pad {:?} dilation {:?})",
                        x.dims()[2],
                        x.dims()[3],
                        g.kernel,
                        g.stride,
                        g.pad,
                        g.dilation
                    ));
                }
                Ok(int8::qconv2d_forward(
                    x,
                    &q.act,
                    &q.weight,
                    &q.combined,
                    q.bias.as_ref(),
                    q.relu,
                    &g,
                ))
            }
        }
    }
}

impl InferencePlan for QuantizedNet {
    fn name(&self) -> &str {
        self.plan.name()
    }

    fn inputs(&self) -> &[TensorDef] {
        self.plan.inputs()
    }

    fn outputs(&self) -> &[String] {
        self.plan.outputs()
    }

    fn n_steps(&self) -> usize {
        self.plan.n_steps()
    }

    fn check_inputs(&self, inputs: &[NdArray]) -> Result<usize, String> {
        self.plan.check_inputs(inputs)
    }

    fn peak_arena_bytes(&self) -> Option<usize> {
        // the int8 working set is never larger than the f32 plan's
        // (i8/u8 activations, same slot liveness) — the f32 peak is a
        // safe admission-control bound
        self.plan.peak_arena_bytes()
    }

    /// The quantized twin of `CompiledNet::execute_positional`: the
    /// same dumb step loop, slot environment and planned liveness
    /// (freed slots recycle into the scratch arena), but dense steps
    /// run the int8 GEMM with ReLU/bias/requantize fused into the
    /// epilogue.
    fn execute_positional(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>, String> {
        self.plan.check_inputs(inputs)?;
        let mut env: Vec<Option<NdArray>> = vec![None; self.plan.n_slots()];
        for (i, a) in inputs.iter().enumerate() {
            env[i] = Some(a.clone());
        }
        for (st, qs) in self.plan.steps().iter().zip(&self.steps) {
            let act = |s: usize| {
                env[s].as_ref().ok_or_else(|| {
                    format!(
                        "layer '{}': [NNL-P002] slot read after its planned free (plan liveness invariant broken)",
                        st.name
                    )
                })
            };
            let y = match qs {
                QStep::Dense(q) => {
                    let x = match st.args.first() {
                        Some(Src::Act(s)) => act(*s)?,
                        _ => return Err(format!("layer '{}': malformed dense step", st.name)),
                    };
                    self.run_dense(q, x).map_err(|e| format!("layer '{}': {e}", st.name))?
                }
                QStep::Passthrough => {
                    let mut xs: Vec<&NdArray> = Vec::with_capacity(st.args.len());
                    for a in &st.args {
                        match a {
                            Src::Act(s) => xs.push(act(*s)?),
                            Src::Param(i) => xs.push(self.plan.param(*i)),
                        }
                    }
                    execute_kernel(&st.kernel, &xs)
                        .map_err(|e| format!("layer '{}': {e}", st.name))?
                }
            };
            env[st.out] = Some(y);
            for &s in &st.free_after {
                if let Some(dead) = env[s].take() {
                    kernels::recycle(dead);
                }
            }
        }
        self.plan
            .output_slots()
            .iter()
            .map(|&s| {
                env[s].as_ref().cloned().ok_or_else(|| {
                    "[NNL-P003] plan output slot empty (plan liveness invariant broken)"
                        .to_string()
                })
            })
            .collect()
    }

    fn batch_invariant(&self) -> bool {
        // static per-tensor scales: quantized rows stay independent
        self.plan.batch_invariant()
    }
}

// ---------------------------------------------------------- one-stop shop

/// The parameters `net` actually references, in layer binding order —
/// the f32 (NNB1) counterpart of a quantized artifact, used wherever
/// NNB1-vs-NNB2 sizes are compared (`nnl quantize`, `nnl bench-quant`,
/// the parity tests) so the ratio measures quantization, not dropped
/// dead parameters.
pub fn referenced_params(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
) -> Vec<(String, NdArray)> {
    let mut seen: HashSet<&str> = HashSet::new();
    let mut out = Vec::new();
    for l in &net.layers {
        for p in &l.params {
            if seen.insert(p.as_str()) {
                if let Some(a) = params.get(p.as_str()) {
                    out.push((p.clone(), a.clone()));
                }
            }
        }
    }
    out
}

/// Optimize `net` (O2 pass pipeline), calibrate it on `samples`, and
/// quantize the optimized graph: returns the serializable
/// [`QuantizedModel`] (carrying the optimized definition) and its
/// compiled [`QuantizedNet`].
pub fn quantize_net(
    net: &NetworkDef,
    params: &HashMap<String, NdArray>,
    samples: &[Vec<NdArray>],
    cfg: &QuantConfig,
) -> Result<(QuantizedModel, QuantizedNet), String> {
    let (onet, oparams, _) = passes::optimize(net, params, OptLevel::default())?;
    let plan = CompiledNet::compile(&onet, &oparams)?;
    let calib = calibrate(&plan, samples, cfg)?;
    let model = quantize_model(&onet, &oparams, &calib)?;
    let qnet = QuantizedNet::compile(&model)?;
    Ok((model, qnet))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::Layer;
    use crate::tensor::Rng;

    fn affine_net(relu: bool) -> (NetworkDef, HashMap<String, NdArray>) {
        let mut layers = vec![Layer {
            name: "fc".into(),
            op: Op::Affine,
            inputs: vec!["x".into()],
            params: vec!["W".into(), "b".into()],
            outputs: vec!["h".into()],
        }];
        let mut outputs = vec!["h".to_string()];
        if relu {
            layers.push(Layer {
                name: "r".into(),
                op: Op::ReLU,
                inputs: vec!["h".into()],
                params: vec![],
                outputs: vec!["y".into()],
            });
            outputs = vec!["y".to_string()];
        }
        let net = NetworkDef {
            name: "q".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs,
            layers,
        };
        let mut rng = Rng::new(3);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[4, 3], 1.0));
        params.insert("b".to_string(), rng.randn(&[3], 0.5));
        (net, params)
    }

    fn samples(n: usize, dims: &[usize], seed: u64) -> Vec<Vec<NdArray>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| vec![rng.rand(dims, -1.0, 1.0)]).collect()
    }

    #[test]
    fn qtensor_roundtrip_error_bounded_by_half_scale_per_channel() {
        let mut rng = Rng::new(9);
        let w = rng.randn(&[6, 5], 2.0);
        let q = QTensor::quantize(&w, 1).unwrap();
        assert_eq!(q.scales.len(), 5);
        let back = q.dequantize();
        for r in 0..6 {
            for c in 0..5 {
                let err = (w.at(&[r, c]) - back.at(&[r, c])).abs();
                assert!(err <= q.scales[c] * 0.5 + 1e-6, "err {err} at [{r}, {c}]");
            }
        }
        // conv layout: per-dim-0 channel
        let wc = rng.randn(&[3, 2, 2, 2], 1.0);
        let qc = QTensor::quantize(&wc, 0).unwrap();
        assert_eq!(qc.scales.len(), 3);
        assert!(qc.dequantize().allclose(&wc, qc.scales.iter().cloned().fold(0.0, f32::max), 0.0));
    }

    #[test]
    fn quantize_rejects_out_of_range_axis() {
        let w = NdArray::zeros(&[4, 3]);
        assert!(QTensor::quantize(&w, 2).is_err());
    }

    #[test]
    fn calibrate_records_scaled_ranges() {
        // y = 2x: the output range must be twice the input range
        let net = NetworkDef {
            name: "m".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "s".into(),
                op: Op::MulScalar { val: 2.0 },
                inputs: vec!["x".into()],
                params: vec![],
                outputs: vec!["y".into()],
            }],
        };
        let plan = CompiledNet::compile(&net, &HashMap::new()).unwrap();
        let s = vec![
            vec![NdArray::from_slice(&[1, 3], &[-0.5, 0.25, 0.1])],
            vec![NdArray::from_slice(&[1, 3], &[0.75, -0.1, 0.0])],
        ];
        let calib = calibrate(&plan, &s, &QuantConfig::default()).unwrap();
        let rx = calib.get("x").unwrap();
        let ry = calib.get("y").unwrap();
        assert_eq!((rx.lo, rx.hi), (-0.5, 0.75));
        assert_eq!((ry.lo, ry.hi), (-1.0, 1.5));
    }

    #[test]
    fn calibrate_rejects_bad_percentile_and_empty_samples() {
        let (net, params) = affine_net(false);
        let plan = CompiledNet::compile(&net, &params).unwrap();
        assert!(calibrate(&plan, &[], &QuantConfig::default()).is_err());
        let s = samples(1, &[1, 4], 1);
        let bad = QuantConfig { percentile: Some(0.3) };
        assert!(calibrate(&plan, &s, &bad).is_err());
        let ok = QuantConfig { percentile: Some(0.99) };
        assert!(calibrate(&plan, &s, &ok).is_ok());
    }

    #[test]
    fn percentile_clipping_narrows_the_range() {
        let (net, params) = affine_net(false);
        let plan = CompiledNet::compile(&net, &params).unwrap();
        // one wild outlier in otherwise small inputs
        let mut vals = vec![0.1f32; 512];
        vals[100] = 50.0;
        let s = vec![vec![NdArray::from_vec(&[128, 4], vals)]];
        let minmax = calibrate(&plan, &s, &QuantConfig::default()).unwrap();
        let clipped = calibrate(&plan, &s, &QuantConfig { percentile: Some(0.95) }).unwrap();
        assert_eq!(minmax.get("x").unwrap().hi, 50.0);
        assert!(clipped.get("x").unwrap().hi < 1.0);
    }

    #[test]
    fn quantize_model_marks_weights_int8_and_bias_f32() {
        let (net, params) = affine_net(true);
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let calib = calibrate(&plan, &samples(4, &[1, 4], 2), &QuantConfig::default()).unwrap();
        let model = quantize_model(&net, &params, &calib).unwrap();
        assert_eq!(model.params.len(), 2);
        assert!(matches!(
            model.params.iter().find(|(n, _)| n == "W").unwrap().1,
            QParam::Int8(_)
        ));
        assert!(matches!(
            model.params.iter().find(|(n, _)| n == "b").unwrap().1,
            QParam::Float(_)
        ));
    }

    #[test]
    fn quantized_affine_close_to_f32_and_relu_fuses_exactly() {
        let (net, params) = affine_net(true);
        let s = samples(8, &[1, 4], 5);
        let (model, qnet) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        assert_eq!(qnet.n_quantized(), 1);
        // the fused dense step keeps the dense layer's name
        assert_eq!(qnet.quantized_layers(), &["fc".to_string()]);
        // fused output == relu applied to the unfused dense output
        let (net_plain, _) = affine_net(false);
        let model_plain = quantize_model(&net_plain, &params, &model.calib).unwrap();
        let qnet_plain = QuantizedNet::compile(&model_plain).unwrap();
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let x = samples(1, &[2, 4], 7).pop().unwrap();
        let fused = qnet.execute_positional(&x).unwrap();
        let plain = qnet_plain.execute_positional(&x).unwrap();
        for (f, p) in fused[0].data().iter().zip(plain[0].data()) {
            assert_eq!(*f, p.max(0.0), "fused ReLU must match relu(dense)");
        }
        // and the int8 result tracks the f32 plan within a few steps
        let f32_out = plan.execute_positional(&x).unwrap();
        assert!(
            fused[0].allclose(&f32_out[0], 0.15, 0.05),
            "int8 drifted: max diff {}",
            fused[0].max_abs_diff(&f32_out[0])
        );
    }

    #[test]
    fn relu_with_second_reader_is_not_fused() {
        // h feeds both the ReLU and a second layer: the epilogue must
        // not rectify h
        let (mut net, params) = affine_net(true);
        net.layers.push(Layer {
            name: "neg".into(),
            op: Op::Neg,
            inputs: vec!["h".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        net.outputs.push("z".into());
        let s = samples(4, &[1, 4], 11);
        let (_, qnet) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        assert_eq!(qnet.n_quantized(), 1);
        let x = samples(1, &[1, 4], 13).pop().unwrap();
        let out = qnet.execute_positional(&x).unwrap();
        // y = relu(h), z = -h: recover h from z and check consistency
        for (y, z) in out[0].data().iter().zip(out[1].data()) {
            assert_eq!(*y, (-z).max(0.0));
        }
    }

    #[test]
    fn bn_folded_conv_takes_the_int8_path() {
        // conv -> bn -> relu: the optimizer folds the BN, fuses the
        // ReLU, and the quantizer lowers the folded conv onto int8
        let net = NetworkDef {
            name: "cbr".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 2, 6, 6] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "conv".into(),
                    op: Op::Convolution { stride: (1, 1), pad: (1, 1), dilation: (1, 1) },
                    inputs: vec!["x".into()],
                    params: vec!["W".into(), "b".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "bn".into(),
                    op: Op::BatchNorm { eps: 1e-5 },
                    inputs: vec!["h".into()],
                    params: vec!["beta".into(), "gamma".into(), "mean".into(), "var".into()],
                    outputs: vec!["hb".into()],
                },
                Layer {
                    name: "act".into(),
                    op: Op::ReLU,
                    inputs: vec!["hb".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        };
        let mut rng = Rng::new(31);
        let mut params = HashMap::new();
        params.insert("W".to_string(), rng.randn(&[4, 2, 3, 3], 0.5));
        params.insert("b".to_string(), rng.randn(&[4], 0.2));
        params.insert("beta".to_string(), rng.randn(&[4], 0.3));
        params.insert("gamma".to_string(), rng.rand(&[4], 0.5, 1.5));
        params.insert("mean".to_string(), rng.randn(&[4], 0.4));
        params.insert("var".to_string(), rng.rand(&[4], 0.2, 1.2));
        let s = samples(8, &[1, 2, 6, 6], 33);
        let (model, qnet) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        // the BN is gone from the stored artifact and the conv is int8
        assert_eq!(model.net.layers.len(), 2, "{:?}", model.net.layers);
        assert_eq!(qnet.n_quantized(), 1, "{:?}", qnet.quantized_layers());
        // int8 output tracks the unoptimized f32 reference
        let plan = CompiledNet::compile(&net, &params).unwrap();
        let x = samples(1, &[1, 2, 6, 6], 35).pop().unwrap();
        let q = qnet.execute_positional(&x).unwrap();
        let f = plan.execute_positional(&x).unwrap();
        assert!(
            q[0].allclose(&f[0], 0.35, 0.15),
            "int8 folded conv drifted: {}",
            q[0].max_abs_diff(&f[0])
        );
    }

    #[test]
    fn compile_rejects_crafted_degenerate_artifacts() {
        let (net, params) = affine_net(false);
        let s = samples(2, &[1, 4], 23);
        // zero-dim weight with a huge sibling axis: decodes cleanly
        // (total element product is 0), must fail compile, not abort
        let (mut model, _) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        for (name, p) in &mut model.params {
            if name == "W" {
                *p = QParam::Int8(QTensor {
                    dims: vec![usize::MAX / 8, 0],
                    channel_axis: 1,
                    data: Vec::new(),
                    scales: Vec::new(),
                });
            }
        }
        let err = QuantizedNet::compile(&model).unwrap_err();
        assert!(err.contains("degenerate"), "{err}");
        // bias length disagreeing with the output-channel count must
        // fail compile, not panic inside the first request's qgemm
        let (mut model2, _) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        for (name, p) in &mut model2.params {
            if name == "b" {
                *p = QParam::Float(NdArray::zeros(&[7]));
            }
        }
        let err = QuantizedNet::compile(&model2).unwrap_err();
        assert!(err.contains("bias size"), "{err}");
    }

    #[test]
    fn quantized_net_serves_like_a_plan() {
        let (net, params) = affine_net(true);
        let s = samples(4, &[1, 4], 17);
        let (_, qnet) = quantize_net(&net, &params, &s, &QuantConfig::default()).unwrap();
        assert!(qnet.batch_invariant());
        fn assert_ss<T: Send + Sync>() {}
        assert_ss::<QuantizedNet>();
        // named execution through the trait's default method
        let mut named = HashMap::new();
        named.insert("x".to_string(), NdArray::from_slice(&[1, 4], &[0.1, -0.2, 0.3, 0.4]));
        let via_named = qnet.execute_named(&named).unwrap();
        let via_pos = qnet.execute_positional(&[named.get("x").unwrap().clone()]).unwrap();
        assert_eq!(via_named[0].data(), via_pos[0].data());
    }
}
