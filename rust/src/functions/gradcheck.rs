//! Numerical gradient checking via central finite differences — the
//! correctness oracle for every function's backward pass.

use crate::graph::Variable;
use crate::tensor::NdArray;

/// Check analytic gradients of `build` (a scalar-valued graph over the
/// given leaves) against central differences. Panics with the offending
/// element on mismatch.
///
/// `build` is called repeatedly with the same leaf variables, whose data
/// is perturbed between calls; it must rebuild the graph each time
/// (define-by-run, so simply calling the builder again is correct).
pub fn check_grads(
    leaves: &[&Variable],
    build: &dyn Fn() -> Variable,
    eps: f32,
    tol: f32,
) {
    // analytic
    for l in leaves {
        l.zero_grad();
    }
    let out = build();
    assert_eq!(out.size(), 1, "gradcheck requires a scalar output");
    out.backward();
    let analytic: Vec<NdArray> = leaves.iter().map(|l| l.grad()).collect();

    // numeric
    for (li, leaf) in leaves.iter().enumerate() {
        let base = leaf.data();
        for i in 0..base.size() {
            let mut plus = base.clone();
            plus.data_mut()[i] += eps;
            leaf.set_data(plus);
            let f_plus = build().item();

            let mut minus = base.clone();
            minus.data_mut()[i] -= eps;
            leaf.set_data(minus);
            let f_minus = build().item();

            leaf.set_data(base.clone());
            let numeric = (f_plus - f_minus) / (2.0 * eps);
            let a = analytic[li].data()[i];
            let denom = a.abs().max(numeric.abs()).max(1.0);
            assert!(
                (a - numeric).abs() / denom <= tol,
                "grad mismatch leaf {li} elem {i}: analytic {a} vs numeric {numeric}"
            );
        }
    }
}

/// Convenience: random leaf of the given shape for gradcheck tests.
pub fn rand_leaf(rng: &mut crate::tensor::Rng, dims: &[usize]) -> Variable {
    Variable::from_array(rng.randn(dims, 1.0), true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{ops, Rng};

    #[test]
    fn catches_correct_gradient() {
        let mut rng = Rng::new(0);
        let x = rand_leaf(&mut rng, &[3]);
        // f = sum(x*x); df/dx = 2x
        let build = || {
            Variable::from_function(
                crate::nnp::ir::Op::Identity,
                &[&x],
                Box::new(|xs| NdArray::scalar(xs[0].data().iter().map(|v| v * v).sum())),
                Box::new(|xs, _y, g| vec![Some(ops::scale(&xs[0], 2.0 * g.item()))]),
            )
        };
        check_grads(&[&x], &build, 1e-3, 1e-3);
    }

    #[test]
    #[should_panic(expected = "grad mismatch")]
    fn catches_wrong_gradient() {
        let mut rng = Rng::new(1);
        let x = rand_leaf(&mut rng, &[3]);
        let build = || {
            Variable::from_function(
                crate::nnp::ir::Op::Identity,
                &[&x],
                Box::new(|xs| NdArray::scalar(xs[0].data().iter().map(|v| v * v).sum())),
                Box::new(|xs, _y, g| vec![Some(ops::scale(&xs[0], 3.0 * g.item()))]), // wrong: 3x
            )
        };
        check_grads(&[&x], &build, 1e-3, 1e-3);
    }
}
