//! Broadcasted elementwise arithmetic on Variables.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

/// `a + b` with NumPy broadcasting.
pub fn add(a: &Variable, b: &Variable) -> Variable {
    Variable::from_function(
        Op::Add2,
        &[a, b],
        Box::new(|xs| ops::add(&xs[0], &xs[1])),
        Box::new(|xs, _y, g| {
            vec![
                Some(ops::reduce_to_shape(g, xs[0].shape())),
                Some(ops::reduce_to_shape(g, xs[1].shape())),
            ]
        }),
    )
}

/// `a - b`.
pub fn sub(a: &Variable, b: &Variable) -> Variable {
    Variable::from_function(
        Op::Sub2,
        &[a, b],
        Box::new(|xs| ops::sub(&xs[0], &xs[1])),
        Box::new(|xs, _y, g| {
            vec![
                Some(ops::reduce_to_shape(g, xs[0].shape())),
                Some(ops::reduce_to_shape(&ops::scale(g, -1.0), xs[1].shape())),
            ]
        }),
    )
}

/// `a * b`.
pub fn mul(a: &Variable, b: &Variable) -> Variable {
    Variable::from_function(
        Op::Mul2,
        &[a, b],
        Box::new(|xs| ops::mul(&xs[0], &xs[1])),
        Box::new(|xs, _y, g| {
            vec![
                Some(ops::reduce_to_shape(&ops::mul(g, &xs[1]), xs[0].shape())),
                Some(ops::reduce_to_shape(&ops::mul(g, &xs[0]), xs[1].shape())),
            ]
        }),
    )
}

/// `a / b`.
pub fn div(a: &Variable, b: &Variable) -> Variable {
    Variable::from_function(
        Op::Div2,
        &[a, b],
        Box::new(|xs| ops::div(&xs[0], &xs[1])),
        Box::new(|xs, _y, g| {
            let ga = ops::div(g, &xs[1]);
            // d/db (a/b) = -a/b^2
            let gb = ops::mul(g, &ops::div(&ops::scale(&xs[0], -1.0), &ops::mul(&xs[1], &xs[1])));
            vec![
                Some(ops::reduce_to_shape(&ga, xs[0].shape())),
                Some(ops::reduce_to_shape(&gb, xs[1].shape())),
            ]
        }),
    )
}

/// `-a`.
pub fn neg(a: &Variable) -> Variable {
    Variable::from_function(
        Op::Neg,
        &[a],
        Box::new(|xs| ops::scale(&xs[0], -1.0)),
        Box::new(|_xs, _y, g| vec![Some(ops::scale(g, -1.0))]),
    )
}

/// `a + s` (scalar).
pub fn add_scalar(a: &Variable, s: f32) -> Variable {
    Variable::from_function(
        Op::AddScalar { val: s },
        &[a],
        Box::new(move |xs| ops::map(&xs[0], |v| v + s)),
        Box::new(|_xs, _y, g| vec![Some(g.clone())]),
    )
}

/// `a * s` (scalar).
pub fn mul_scalar(a: &Variable, s: f32) -> Variable {
    Variable::from_function(
        Op::MulScalar { val: s },
        &[a],
        Box::new(move |xs| ops::scale(&xs[0], s)),
        Box::new(move |_xs, _y, g| vec![Some(ops::scale(g, s))]),
    )
}

/// `a ^ p` (elementwise, scalar exponent).
pub fn pow_scalar(a: &Variable, p: f32) -> Variable {
    Variable::from_function(
        Op::PowScalar { val: p },
        &[a],
        Box::new(move |xs| ops::map(&xs[0], |v| v.powf(p))),
        Box::new(move |xs, _y, g| {
            vec![Some(ops::mul(g, &ops::map(&xs[0], |v| p * v.powf(p - 1.0))))]
        }),
    )
}

/// `exp(a)`.
pub fn exp(a: &Variable) -> Variable {
    Variable::from_function(
        Op::Exp,
        &[a],
        Box::new(|xs| ops::map(&xs[0], f32::exp)),
        Box::new(|_xs, y, g| vec![Some(ops::mul(g, y))]),
    )
}

/// `ln(a)`.
pub fn log(a: &Variable) -> Variable {
    Variable::from_function(
        Op::Log,
        &[a],
        Box::new(|xs| ops::map(&xs[0], f32::ln)),
        Box::new(|xs, _y, g| vec![Some(ops::div(g, &xs[0]))]),
    )
}

/// Stop-gradient identity (useful for baselines / frozen branches).
pub fn stop_gradient(a: &Variable) -> Variable {
    Variable::from_function(
        Op::StopGradient,
        &[a],
        Box::new(|xs| xs[0].clone()),
        Box::new(|xs, _y, _g| vec![None::<NdArray>; xs.len()]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::{mean_all};
    use crate::tensor::Rng;

    #[test]
    fn arithmetic_values() {
        let a = Variable::from_array(NdArray::from_slice(&[2], &[1., 2.]), true);
        let b = Variable::from_array(NdArray::from_slice(&[2], &[3., 4.]), true);
        assert_eq!(add(&a, &b).data().data(), &[4., 6.]);
        assert_eq!(sub(&a, &b).data().data(), &[-2., -2.]);
        assert_eq!(mul(&a, &b).data().data(), &[3., 8.]);
        assert_eq!(div(&a, &b).data().data(), &[1. / 3., 0.5]);
        assert_eq!(neg(&a).data().data(), &[-1., -2.]);
        assert_eq!(add_scalar(&a, 10.).data().data(), &[11., 12.]);
        assert_eq!(mul_scalar(&a, 3.).data().data(), &[3., 6.]);
        assert_eq!(pow_scalar(&a, 2.).data().data(), &[1., 4.]);
    }

    #[test]
    fn grads_binary_ops() {
        let mut rng = Rng::new(10);
        let a = rand_leaf(&mut rng, &[2, 3]);
        let b = rand_leaf(&mut rng, &[2, 3]);
        // keep b away from 0 for div
        b.set_data(crate::tensor::ops::map(&b.data(), |v| v + 3.0 * v.signum() + 0.5));
        for (name, f) in [
            ("add", add as fn(&Variable, &Variable) -> Variable),
            ("sub", sub),
            ("mul", mul),
            ("div", div),
        ] {
            let build = || mean_all(&f(&a, &b));
            check_grads(&[&a, &b], &build, 1e-3, 2e-2);
            let _ = name;
        }
    }

    #[test]
    fn grads_broadcast_bias() {
        let mut rng = Rng::new(11);
        let x = rand_leaf(&mut rng, &[4, 3]);
        let bias = rand_leaf(&mut rng, &[3]);
        let build = || mean_all(&add(&x, &bias));
        check_grads(&[&x, &bias], &build, 1e-3, 1e-2);
    }

    #[test]
    fn grads_unary_ops() {
        let mut rng = Rng::new(12);
        let x = rand_leaf(&mut rng, &[5]);
        x.set_data(crate::tensor::ops::map(&x.data(), |v| v.abs() + 0.5)); // positive for log
        for f in [exp as fn(&Variable) -> Variable, log, neg] {
            let build = || mean_all(&f(&x));
            check_grads(&[&x], &build, 1e-3, 2e-2);
        }
        let build = || mean_all(&pow_scalar(&x, 3.0));
        check_grads(&[&x], &build, 1e-3, 2e-2);
    }

    #[test]
    fn stop_gradient_blocks_backward() {
        let x = Variable::from_array(NdArray::full(&[2], 2.0), true);
        let y = mean_all(&stop_gradient(&mul(&x, &x)));
        y.backward();
        assert_eq!(x.grad().data(), &[0., 0.]);
    }
}
