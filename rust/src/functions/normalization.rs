//! Batch / layer normalization.
//!
//! Batch normalization follows the paper's mixed-precision rule: it is
//! always computed in f32 even under `type_config='half'` ("batch
//! normalization is in FP-32", §3.3) — in this engine all compute is
//! f32, so the rule holds by construction; the *storage* of its
//! parameters is what the half config quantizes.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

/// View `[N, C, ...]` as (n, c, s) with s = product of trailing dims.
fn ncs(x: &NdArray) -> (usize, usize, usize) {
    let d = x.dims();
    assert!(d.len() >= 2, "batch_normalization needs rank >= 2, got {:?}", d);
    (d[0], d[1], d[2..].iter().product::<usize>().max(1))
}

/// Per-channel batch statistics over (N, spatial).
fn channel_stats(x: &NdArray) -> (Vec<f32>, Vec<f32>) {
    let (n, c, s) = ncs(x);
    let cnt = (n * s) as f32;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for ni in 0..n {
        for ci in 0..c {
            let base = (ni * c + ci) * s;
            for si in 0..s {
                mean[ci] += x.data()[base + si];
            }
        }
    }
    for m in &mut mean {
        *m /= cnt;
    }
    for ni in 0..n {
        for ci in 0..c {
            let base = (ni * c + ci) * s;
            for si in 0..s {
                let d = x.data()[base + si] - mean[ci];
                var[ci] += d * d;
            }
        }
    }
    for v in &mut var {
        *v /= cnt;
    }
    (mean, var)
}

fn bn_apply(x: &NdArray, mean: &[f32], var: &[f32], gamma: &NdArray, beta: &NdArray, eps: f32) -> NdArray {
    let (n, c, s) = ncs(x);
    let mut out = vec![0.0f32; x.size()];
    for ni in 0..n {
        for ci in 0..c {
            let inv = 1.0 / (var[ci] + eps).sqrt();
            let (g, b) = (gamma.data()[ci], beta.data()[ci]);
            let base = (ni * c + ci) * s;
            for si in 0..s {
                out[base + si] = g * (x.data()[base + si] - mean[ci]) * inv + b;
            }
        }
    }
    NdArray::from_vec(x.dims(), out)
}

/// Batch normalization over the channel axis (axis 1).
///
/// Inputs: `x [N,C,...]`, `beta [C]`, `gamma [C]`, and the running
/// `mean`/`var` leaves (updated in place when `batch_stat` is true,
/// with `rm = decay·rm + (1-decay)·batch_mean`).
#[allow(clippy::too_many_arguments)]
pub fn batch_normalization(
    x: &Variable,
    beta: &Variable,
    gamma: &Variable,
    mean: &Variable,
    var: &Variable,
    decay: f32,
    eps: f32,
    batch_stat: bool,
) -> Variable {
    if batch_stat {
        // capture running-stat variables for the in-place update
        let rm = mean.clone();
        let rv = var.clone();
        Variable::from_function(
            Op::BatchNorm { eps },
            &[x, beta, gamma, mean, var],
            Box::new(move |xs| {
                let (bm, bv) = channel_stats(&xs[0]);
                // update running stats (training-time side effect)
                let old_m = rm.data();
                let old_v = rv.data();
                let new_m: Vec<f32> = old_m
                    .data()
                    .iter()
                    .zip(&bm)
                    .map(|(&o, &b)| decay * o + (1.0 - decay) * b)
                    .collect();
                let new_v: Vec<f32> = old_v
                    .data()
                    .iter()
                    .zip(&bv)
                    .map(|(&o, &b)| decay * o + (1.0 - decay) * b)
                    .collect();
                rm.set_data(NdArray::from_vec(old_m.dims(), new_m));
                rv.set_data(NdArray::from_vec(old_v.dims(), new_v));
                bn_apply(&xs[0], &bm, &bv, &xs[2], &xs[1], eps)
            }),
            Box::new(move |xs, _y, gy| {
                let x = &xs[0];
                let gamma = &xs[2];
                let (n, c, s) = ncs(x);
                let cnt = (n * s) as f32;
                let (bm, bv) = channel_stats(x);
                // per-channel sums: sum(gy), sum(gy * xhat)
                let mut sum_g = vec![0.0f32; c];
                let mut sum_gx = vec![0.0f32; c];
                for ni in 0..n {
                    for ci in 0..c {
                        let inv = 1.0 / (bv[ci] + eps).sqrt();
                        let base = (ni * c + ci) * s;
                        for si in 0..s {
                            let xhat = (x.data()[base + si] - bm[ci]) * inv;
                            sum_g[ci] += gy.data()[base + si];
                            sum_gx[ci] += gy.data()[base + si] * xhat;
                        }
                    }
                }
                let mut gx = vec![0.0f32; x.size()];
                for ni in 0..n {
                    for ci in 0..c {
                        let inv = 1.0 / (bv[ci] + eps).sqrt();
                        let base = (ni * c + ci) * s;
                        for si in 0..s {
                            let xhat = (x.data()[base + si] - bm[ci]) * inv;
                            gx[base + si] = gamma.data()[ci] * inv / cnt
                                * (cnt * gy.data()[base + si] - sum_g[ci] - xhat * sum_gx[ci]);
                        }
                    }
                }
                vec![
                    Some(NdArray::from_vec(x.dims(), gx)),
                    Some(NdArray::from_vec(&[c], sum_g)),  // dbeta
                    Some(NdArray::from_vec(&[c], sum_gx)), // dgamma
                    None,
                    None,
                ]
            }),
        )
    } else {
        // inference: use running stats, no side effects
        Variable::from_function(
            Op::BatchNorm { eps },
            &[x, beta, gamma, mean, var],
            Box::new(move |xs| {
                bn_apply(&xs[0], xs[3].data(), xs[4].data(), &xs[2], &xs[1], eps)
            }),
            Box::new(move |xs, _y, gy| {
                let x = &xs[0];
                let gamma = &xs[2];
                let (n, c, s) = ncs(x);
                let rm = xs[3].data();
                let rv = xs[4].data();
                let mut gx = vec![0.0f32; x.size()];
                let mut sum_g = vec![0.0f32; c];
                let mut sum_gx = vec![0.0f32; c];
                for ni in 0..n {
                    for ci in 0..c {
                        let inv = 1.0 / (rv[ci] + eps).sqrt();
                        let base = (ni * c + ci) * s;
                        for si in 0..s {
                            let xhat = (x.data()[base + si] - rm[ci]) * inv;
                            gx[base + si] = gamma.data()[ci] * inv * gy.data()[base + si];
                            sum_g[ci] += gy.data()[base + si];
                            sum_gx[ci] += gy.data()[base + si] * xhat;
                        }
                    }
                }
                vec![
                    Some(NdArray::from_vec(x.dims(), gx)),
                    Some(NdArray::from_vec(&[c], sum_g)),
                    Some(NdArray::from_vec(&[c], sum_gx)),
                    None,
                    None,
                ]
            }),
        )
    }
}

/// Layer normalization over the last axis with learnable `gamma`/`beta`
/// of shape `[D]` (used by the TransformerLM).
pub fn layer_normalization(x: &Variable, beta: &Variable, gamma: &Variable, eps: f32) -> Variable {
    Variable::from_function(
        Op::LayerNorm { eps },
        &[x, beta, gamma],
        Box::new(move |xs| {
            let x = &xs[0];
            let last = x.rank() - 1;
            let mu = ops::mean_axis(x, last, true);
            let xc = ops::sub(x, &mu);
            let var = ops::mean_axis(&ops::mul(&xc, &xc), last, true);
            let inv = ops::map(&var, |v| 1.0 / (v + eps).sqrt());
            ops::add(&ops::mul(&ops::mul(&xc, &inv), &xs[2]), &xs[1])
        }),
        Box::new(move |xs, _y, gy| {
            let x = &xs[0];
            let gamma = &xs[2];
            let last = x.rank() - 1;
            let d = x.dims()[last] as f32;
            let mu = ops::mean_axis(x, last, true);
            let xc = ops::sub(x, &mu);
            let var = ops::mean_axis(&ops::mul(&xc, &xc), last, true);
            let inv = ops::map(&var, |v| 1.0 / (v + eps).sqrt());
            let xhat = ops::mul(&xc, &inv);
            let gg = ops::mul(gy, gamma); // dL/dxhat
            let m1 = ops::mean_axis(&gg, last, true);
            let m2 = ops::mean_axis(&ops::mul(&gg, &xhat), last, true);
            let gx = ops::mul(&inv, &ops::sub(&ops::sub(&gg, &m1), &ops::mul(&xhat, &m2)));
            // dbeta/dgamma: reduce over all axes but the last
            let gbeta = ops::reduce_to_shape(gy, xs[1].shape());
            let ggamma = ops::reduce_to_shape(&ops::mul(gy, &xhat), xs[2].shape());
            let _ = d;
            vec![Some(gx), Some(gbeta), Some(ggamma)]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    fn bn_params(c: usize) -> (Variable, Variable, Variable, Variable) {
        let beta = Variable::from_array(NdArray::zeros(&[c]), true);
        let gamma = Variable::from_array(NdArray::ones(&[c]), true);
        let mean = Variable::from_array(NdArray::zeros(&[c]), false);
        let var = Variable::from_array(NdArray::ones(&[c]), false);
        (beta, gamma, mean, var)
    }

    #[test]
    fn bn_normalizes_batch() {
        let mut rng = Rng::new(100);
        let x = Variable::from_array(rng.randn(&[8, 3, 4, 4], 5.0), true);
        let (beta, gamma, mean, var) = bn_params(3);
        let y = batch_normalization(&x, &beta, &gamma, &mean, &var, 0.9, 1e-5, true);
        let (m, v) = channel_stats(&y.data());
        for c in 0..3 {
            assert!(m[c].abs() < 1e-4, "mean {}", m[c]);
            assert!((v[c] - 1.0).abs() < 1e-2, "var {}", v[c]);
        }
    }

    #[test]
    fn bn_updates_running_stats() {
        let mut rng = Rng::new(101);
        let x = Variable::from_array(
            ops::add(&rng.randn(&[16, 2, 2, 2], 1.0), &NdArray::full(&[16, 2, 2, 2], 10.0)),
            false,
        );
        let (beta, gamma, mean, var) = bn_params(2);
        let _ = batch_normalization(&x, &beta, &gamma, &mean, &var, 0.5, 1e-5, true);
        // rm = 0.5*0 + 0.5*~10
        for c in 0..2 {
            assert!((mean.data().data()[c] - 5.0).abs() < 0.5);
        }
    }

    #[test]
    fn bn_inference_uses_running_stats() {
        let x = Variable::from_array(NdArray::full(&[2, 1, 1, 1], 4.0), false);
        let (beta, gamma, mean, var) = bn_params(1);
        mean.set_data(NdArray::from_slice(&[1], &[2.0]));
        var.set_data(NdArray::from_slice(&[1], &[4.0]));
        let y = batch_normalization(&x, &beta, &gamma, &mean, &var, 0.9, 0.0, false);
        // (4-2)/2 = 1
        assert!((y.data().data()[0] - 1.0).abs() < 1e-5);
        // running stats untouched in inference
        assert_eq!(mean.data().data(), &[2.0]);
    }

    #[test]
    fn bn_gradcheck_training() {
        let mut rng = Rng::new(102);
        let x = rand_leaf(&mut rng, &[4, 2, 2, 2]);
        let (beta, gamma, mean, var) = bn_params(2);
        let beta2 = rand_leaf(&mut rng, &[2]);
        let gamma2 = rand_leaf(&mut rng, &[2]);
        let _ = (beta, gamma);
        let build =
            || mean_all(&crate::functions::pow_scalar(
                &batch_normalization(&x, &beta2, &gamma2, &mean, &var, 1.0, 1e-5, true),
                2.0,
            ));
        check_grads(&[&x, &beta2, &gamma2], &build, 1e-2, 3e-2);
    }

    #[test]
    fn bn_gradcheck_inference() {
        let mut rng = Rng::new(103);
        let x = rand_leaf(&mut rng, &[3, 2]);
        let beta = rand_leaf(&mut rng, &[2]);
        let gamma = rand_leaf(&mut rng, &[2]);
        let mean = Variable::from_array(rng.randn(&[2], 1.0), false);
        let var = Variable::from_array(NdArray::from_slice(&[2], &[1.5, 0.7]), false);
        let build = || {
            mean_all(&crate::functions::pow_scalar(
                &batch_normalization(&x, &beta, &gamma, &mean, &var, 0.9, 1e-5, false),
                2.0,
            ))
        };
        check_grads(&[&x, &beta, &gamma], &build, 1e-3, 2e-2);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let mut rng = Rng::new(104);
        let x = Variable::from_array(rng.randn(&[4, 8], 3.0), true);
        let beta = Variable::from_array(NdArray::zeros(&[8]), true);
        let gamma = Variable::from_array(NdArray::ones(&[8]), true);
        let y = layer_normalization(&x, &beta, &gamma, 1e-5).data();
        for i in 0..4 {
            let row = &y.data()[i * 8..(i + 1) * 8];
            let m: f32 = row.iter().sum::<f32>() / 8.0;
            let v: f32 = row.iter().map(|r| (r - m) * (r - m)).sum::<f32>() / 8.0;
            assert!(m.abs() < 1e-4);
            assert!((v - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn layer_norm_gradcheck() {
        let mut rng = Rng::new(105);
        let x = rand_leaf(&mut rng, &[3, 5]);
        let beta = rand_leaf(&mut rng, &[5]);
        let gamma = rand_leaf(&mut rng, &[5]);
        let build = || {
            mean_all(&crate::functions::pow_scalar(
                &layer_normalization(&x, &beta, &gamma, 1e-5),
                2.0,
            ))
        };
        check_grads(&[&x, &beta, &gamma], &build, 1e-2, 3e-2);
    }
}
