//! 2-D convolution / deconvolution via im2col + matmul — the same
//! lowering the static path's Pallas kernel consumes, so the two
//! backends agree structurally (and numerically, see integration
//! tests).

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::ops::{self, Conv2dGeom};
use crate::tensor::NdArray;

/// Shared im2col cache between a conv node's forward and backward
/// closures (dropout-mask pattern): backward reuses the columns the
/// last forward produced instead of recomputing them — a measured
/// ~15-25% dynamic-path train-step win (EXPERIMENTS.md §Perf).
type ColsCache = std::rc::Rc<std::cell::RefCell<Option<NdArray>>>;

fn conv_forward(
    x: &NdArray,
    w: &NdArray,
    b: Option<&NdArray>,
    g: &Conv2dGeom,
    cache: &ColsCache,
) -> NdArray {
    let (n, _c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let oc = w.dims()[0];
    let (oh, ow) = g.out_hw(h, wd);
    let cols = ops::im2col(x, g); // [n*oh*ow, c*kh*kw]
    let wr = w.reshape(&[oc, w.size() / oc]).t(); // [c*kh*kw, oc]
    let mut y = ops::matmul(&cols, &wr); // [n*oh*ow, oc]
    *cache.borrow_mut() = Some(cols);
    if let Some(b) = b {
        y = ops::add(&y, b);
    }
    // [n, oh, ow, oc] -> [n, oc, oh, ow]
    y.reshape(&[n, oh, ow, oc]).transpose(&[0, 3, 1, 2])
}

fn conv_backward(
    x: &NdArray,
    w: &NdArray,
    has_bias: bool,
    g: &Conv2dGeom,
    gy: &NdArray,
    cache: &ColsCache,
) -> (NdArray, NdArray, Option<NdArray>) {
    let (n, _c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let oc = w.dims()[0];
    let (oh, ow) = g.out_hw(h, wd);
    // gy: [n, oc, oh, ow] -> rows [n*oh*ow, oc]
    let gyr = gy.transpose(&[0, 2, 3, 1]).reshape(&[n * oh * ow, oc]);
    let wr = w.reshape(&[oc, w.size() / oc]); // [oc, ckk]
    // dX = col2im(gyr · wr)
    let gcols = ops::matmul(&gyr, &wr); // [n*oh*ow, ckk]
    let gx = ops::col2im(&gcols, x.dims(), g);
    // dW = (im2col(x)^T · gyr)^T reshaped — reuse forward's columns
    let ckk = w.size() / oc;
    let cached = cache.borrow();
    let cols = match cached.as_ref() {
        Some(c) if c.dims() == [n * oh * ow, ckk] => c.clone(),
        _ => ops::im2col(x, g),
    };
    drop(cached);
    let gw = ops::matmul(&gyr.t(), &cols).reshape(w.dims()); // [oc, ckk]
    let gb = if has_bias { Some(ops::sum_axis(&gyr, 0, false)) } else { None };
    (gx, gw, gb)
}

/// Convolution. `x: [N, C, H, W]`, `w: [OC, C, KH, KW]`, `b: [OC]`.
pub fn convolution(
    x: &Variable,
    w: &Variable,
    b: Option<&Variable>,
    stride: (usize, usize),
    pad: (usize, usize),
    dilation: (usize, usize),
) -> Variable {
    let mk_geom = move |w: &NdArray| Conv2dGeom {
        kernel: (w.dims()[2], w.dims()[3]),
        stride,
        pad,
        dilation,
    };
    let cache: ColsCache = Default::default();
    let cache_b = cache.clone();
    match b {
        Some(b) => Variable::from_function(
            Op::Convolution { stride, pad, dilation },
            &[x, w, b],
            Box::new(move |xs| {
                conv_forward(&xs[0], &xs[1], Some(&xs[2]), &mk_geom(&xs[1]), &cache)
            }),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, gb) =
                    conv_backward(&xs[0], &xs[1], true, &mk_geom(&xs[1]), gy, &cache_b);
                vec![Some(gx), Some(gw), gb]
            }),
        ),
        None => Variable::from_function(
            Op::Convolution { stride, pad, dilation },
            &[x, w],
            Box::new(move |xs| conv_forward(&xs[0], &xs[1], None, &mk_geom(&xs[1]), &cache)),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, _) =
                    conv_backward(&xs[0], &xs[1], false, &mk_geom(&xs[1]), gy, &cache_b);
                vec![Some(gx), Some(gw)]
            }),
        ),
    }
}

/// Transposed convolution (deconvolution): the adjoint of
/// [`convolution`] in its spatial mapping. `x: [N, C, H, W]`,
/// `w: [C, OC, KH, KW]` (input-channel-major, NNabla convention).
pub fn deconvolution(
    x: &Variable,
    w: &Variable,
    b: Option<&Variable>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Variable {
    // output spatial size: (h-1)*s - 2p + k
    let fwd = move |x: &NdArray, w: &NdArray, b: Option<&NdArray>| -> NdArray {
        let (n, c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        let (oc, kh, kw) = (w.dims()[1], w.dims()[2], w.dims()[3]);
        let oh = (h - 1) * stride.0 + kh - 2 * pad.0;
        let ow = (wd - 1) * stride.1 + kw - 2 * pad.1;
        let geom = Conv2dGeom { kernel: (kh, kw), stride, pad, dilation: (1, 1) };
        // deconv fwd == conv bwd wrt input: x plays gy, w transposed
        // x rows: [n*h*w, c]
        let xr = x.transpose(&[0, 2, 3, 1]).reshape(&[n * h * wd, c]);
        let wr = w.reshape(&[c, oc * kh * kw]); // [c, oc*kh*kw]
        let cols = ops::matmul(&xr, &wr); // [n*h*w, oc*kh*kw]
        let mut y = ops::col2im(&cols, &[n, oc, oh, ow], &geom);
        if let Some(b) = b {
            y = ops::add(&y, &b.reshape(&[1, oc, 1, 1]));
        }
        y
    };
    let bwd = move |x: &NdArray, w: &NdArray, has_bias: bool, gy: &NdArray| {
        let (n, c, h, wd) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        let (oc, kh, kw) = (w.dims()[1], w.dims()[2], w.dims()[3]);
        let geom = Conv2dGeom { kernel: (kh, kw), stride, pad, dilation: (1, 1) };
        // dX = conv(gy, w): gy cols against w
        let gycols = ops::im2col(gy, &geom); // [n*h*w, oc*kh*kw]
        let wr = w.reshape(&[c, oc * kh * kw]);
        let gx = ops::matmul(&gycols, &wr.t()) // [n*h*w, c]
            .reshape(&[n, h, wd, c])
            .transpose(&[0, 3, 1, 2]);
        // dW = x^T · gycols
        let xr = x.transpose(&[0, 2, 3, 1]).reshape(&[n * h * wd, c]);
        let gw = ops::matmul(&xr.t(), &gycols).reshape(w.dims());
        let gb = if has_bias {
            // sum gy over n, h, w
            let s = ops::sum_axis(&ops::sum_axis(&ops::sum_axis(gy, 3, false), 2, false), 0, false);
            Some(s)
        } else {
            None
        };
        (gx, gw, gb)
    };
    match b {
        Some(b) => Variable::from_function(
            Op::Deconvolution { stride, pad },
            &[x, w, b],
            Box::new(move |xs| fwd(&xs[0], &xs[1], Some(&xs[2]))),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, gb) = bwd(&xs[0], &xs[1], true, gy);
                vec![Some(gx), Some(gw), gb]
            }),
        ),
        None => Variable::from_function(
            Op::Deconvolution { stride, pad },
            &[x, w],
            Box::new(move |xs| fwd(&xs[0], &xs[1], None)),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, _) = bwd(&xs[0], &xs[1], false, gy);
                vec![Some(gx), Some(gw)]
            }),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 = identity
        let x = Variable::from_array(NdArray::arange(&[1, 1, 3, 3]), true);
        let w = Variable::from_array(NdArray::ones(&[1, 1, 1, 1]), true);
        let y = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        assert_eq!(y.data().data(), x.data().data());
    }

    #[test]
    fn conv_known_sum_kernel() {
        // 2x2 all-ones kernel on arange(3x3): each output = sum of patch
        let x = Variable::from_array(NdArray::arange(&[1, 1, 3, 3]), true);
        let w = Variable::from_array(NdArray::ones(&[1, 1, 2, 2]), true);
        let y = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        assert_eq!(y.dims(), vec![1, 1, 2, 2]);
        assert_eq!(y.data().data(), &[8., 12., 20., 24.]);
    }

    #[test]
    fn conv_stride_padding_shapes() {
        let mut rng = Rng::new(40);
        let x = rand_leaf(&mut rng, &[2, 3, 8, 8]);
        let w = rand_leaf(&mut rng, &[4, 3, 3, 3]);
        let y = convolution(&x, &w, None, (2, 2), (1, 1), (1, 1));
        assert_eq!(y.dims(), vec![2, 4, 4, 4]);
    }

    #[test]
    fn conv_bias_broadcasts_per_channel() {
        let x = Variable::from_array(NdArray::zeros(&[1, 1, 2, 2]), false);
        let w = Variable::from_array(NdArray::ones(&[2, 1, 1, 1]), false);
        let b = Variable::from_array(NdArray::from_slice(&[2], &[5., 7.]), false);
        let y = convolution(&x, &w, Some(&b), (1, 1), (0, 0), (1, 1));
        assert_eq!(y.data().data(), &[5., 5., 5., 5., 7., 7., 7., 7.]);
    }

    #[test]
    fn conv_gradcheck() {
        let mut rng = Rng::new(41);
        let x = rand_leaf(&mut rng, &[2, 2, 4, 4]);
        let w = rand_leaf(&mut rng, &[3, 2, 3, 3]);
        let b = rand_leaf(&mut rng, &[3]);
        let build = || mean_all(&convolution(&x, &w, Some(&b), (1, 1), (1, 1), (1, 1)));
        check_grads(&[&x, &w, &b], &build, 1e-2, 2e-2);
    }

    #[test]
    fn conv_gradcheck_strided_dilated() {
        let mut rng = Rng::new(42);
        let x = rand_leaf(&mut rng, &[1, 2, 6, 6]);
        let w = rand_leaf(&mut rng, &[2, 2, 2, 2]);
        let build = || mean_all(&convolution(&x, &w, None, (2, 2), (0, 0), (2, 2)));
        check_grads(&[&x, &w], &build, 1e-2, 2e-2);
    }

    #[test]
    fn deconv_upsamples() {
        let mut rng = Rng::new(43);
        let x = rand_leaf(&mut rng, &[1, 2, 3, 3]);
        let w = rand_leaf(&mut rng, &[2, 4, 2, 2]);
        let y = deconvolution(&x, &w, None, (2, 2), (0, 0));
        assert_eq!(y.dims(), vec![1, 4, 6, 6]);
    }

    #[test]
    fn deconv_gradcheck() {
        let mut rng = Rng::new(44);
        let x = rand_leaf(&mut rng, &[1, 2, 3, 3]);
        let w = rand_leaf(&mut rng, &[2, 2, 2, 2]);
        let b = rand_leaf(&mut rng, &[2]);
        let build = || mean_all(&deconvolution(&x, &w, Some(&b), (1, 1), (0, 0)));
        check_grads(&[&x, &w, &b], &build, 1e-2, 2e-2);
    }

    #[test]
    fn deconv_is_conv_adjoint() {
        // <conv(x), y> == <x, deconv(y)> with shared kernel (no bias)
        let mut rng = Rng::new(45);
        let xa = rng.randn(&[1, 2, 5, 5], 1.0);
        let wa = rng.randn(&[3, 2, 3, 3], 1.0);
        let x = Variable::from_array(xa.clone(), false);
        let w = Variable::from_array(wa.clone(), false);
        let cy = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        let ya = rng.randn(&cy.dims(), 1.0);
        let lhs: f32 = cy.data().data().iter().zip(ya.data()).map(|(a, b)| a * b).sum();
        // deconv weight layout [C_in, C_out, KH, KW]: the conv weight
        // [OC, C, KH, KW] reinterpreted as-is (OC is deconv's input side)
        let wt = Variable::from_array(wa.clone(), false);
        let yv = Variable::from_array(ya, false);
        let dx = deconvolution(&yv, &wt, None, (1, 1), (0, 0));
        let rhs: f32 = xa.data().iter().zip(dx.data().data()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-3, "{lhs} vs {rhs}");
    }
}
