//! 2-D convolution / deconvolution via the fused im2col-GEMM kernels
//! in [`crate::tensor::kernels`] — the same lowering the static path's
//! Pallas kernel consumes, so the two backends agree structurally (and
//! numerically, see integration tests).
//!
//! Forward and backward never materialize the `[n·oh·ow, c·kh·kw]`
//! column matrix: the tiled GEMM packs im2col panels straight from the
//! input image. This replaced the old materialize-then-cache scheme
//! (forward built the columns and backward reused them) — fusing the
//! columns into packing does the same index math the cache avoided,
//! but at pack bandwidth the GEMM was paying anyway, without holding
//! an O(n·oh·ow·c·kh·kw) buffer alive between forward and backward.
//! These closures are exactly what the compiled plan's fast path runs,
//! so tape, interpreter and plan outputs are bit-identical.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::kernels;
use crate::tensor::ops::Conv2dGeom;
use crate::tensor::NdArray;

/// Convolution. `x: [N, C, H, W]`, `w: [OC, C, KH, KW]`, `b: [OC]`.
pub fn convolution(
    x: &Variable,
    w: &Variable,
    b: Option<&Variable>,
    stride: (usize, usize),
    pad: (usize, usize),
    dilation: (usize, usize),
) -> Variable {
    let mk_geom = move |w: &NdArray| Conv2dGeom {
        kernel: (w.dims()[2], w.dims()[3]),
        stride,
        pad,
        dilation,
    };
    match b {
        Some(b) => Variable::from_function(
            Op::Convolution { stride, pad, dilation },
            &[x, w, b],
            Box::new(move |xs| {
                kernels::conv2d_forward(&xs[0], &xs[1], Some(&xs[2]), &mk_geom(&xs[1]))
            }),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, gb) =
                    kernels::conv2d_backward(&xs[0], &xs[1], gy, true, &mk_geom(&xs[1]));
                vec![Some(gx), Some(gw), gb]
            }),
        ),
        None => Variable::from_function(
            Op::Convolution { stride, pad, dilation },
            &[x, w],
            Box::new(move |xs| kernels::conv2d_forward(&xs[0], &xs[1], None, &mk_geom(&xs[1]))),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, _) =
                    kernels::conv2d_backward(&xs[0], &xs[1], gy, false, &mk_geom(&xs[1]));
                vec![Some(gx), Some(gw)]
            }),
        ),
    }
}

/// Transposed convolution (deconvolution): the adjoint of
/// [`convolution`] in its spatial mapping. `x: [N, C, H, W]`,
/// `w: [C, OC, KH, KW]` (input-channel-major, NNabla convention).
pub fn deconvolution(
    x: &Variable,
    w: &Variable,
    b: Option<&Variable>,
    stride: (usize, usize),
    pad: (usize, usize),
) -> Variable {
    match b {
        Some(b) => Variable::from_function(
            Op::Deconvolution { stride, pad },
            &[x, w, b],
            Box::new(move |xs| {
                kernels::deconv2d_forward(&xs[0], &xs[1], Some(&xs[2]), stride, pad)
            }),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, gb) =
                    kernels::deconv2d_backward(&xs[0], &xs[1], gy, true, stride, pad);
                vec![Some(gx), Some(gw), gb]
            }),
        ),
        None => Variable::from_function(
            Op::Deconvolution { stride, pad },
            &[x, w],
            Box::new(move |xs| kernels::deconv2d_forward(&xs[0], &xs[1], None, stride, pad)),
            Box::new(move |xs, _y, gy| {
                let (gx, gw, _) =
                    kernels::deconv2d_backward(&xs[0], &xs[1], gy, false, stride, pad);
                vec![Some(gx), Some(gw)]
            }),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 = identity
        let x = Variable::from_array(NdArray::arange(&[1, 1, 3, 3]), true);
        let w = Variable::from_array(NdArray::ones(&[1, 1, 1, 1]), true);
        let y = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        assert_eq!(y.data().data(), x.data().data());
    }

    #[test]
    fn conv_known_sum_kernel() {
        // 2x2 all-ones kernel on arange(3x3): each output = sum of patch
        let x = Variable::from_array(NdArray::arange(&[1, 1, 3, 3]), true);
        let w = Variable::from_array(NdArray::ones(&[1, 1, 2, 2]), true);
        let y = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        assert_eq!(y.dims(), vec![1, 1, 2, 2]);
        assert_eq!(y.data().data(), &[8., 12., 20., 24.]);
    }

    #[test]
    fn conv_stride_padding_shapes() {
        let mut rng = Rng::new(40);
        let x = rand_leaf(&mut rng, &[2, 3, 8, 8]);
        let w = rand_leaf(&mut rng, &[4, 3, 3, 3]);
        let y = convolution(&x, &w, None, (2, 2), (1, 1), (1, 1));
        assert_eq!(y.dims(), vec![2, 4, 4, 4]);
    }

    #[test]
    fn conv_bias_broadcasts_per_channel() {
        let x = Variable::from_array(NdArray::zeros(&[1, 1, 2, 2]), false);
        let w = Variable::from_array(NdArray::ones(&[2, 1, 1, 1]), false);
        let b = Variable::from_array(NdArray::from_slice(&[2], &[5., 7.]), false);
        let y = convolution(&x, &w, Some(&b), (1, 1), (0, 0), (1, 1));
        assert_eq!(y.data().data(), &[5., 5., 5., 5., 7., 7., 7., 7.]);
    }

    #[test]
    fn conv_gradcheck() {
        let mut rng = Rng::new(41);
        let x = rand_leaf(&mut rng, &[2, 2, 4, 4]);
        let w = rand_leaf(&mut rng, &[3, 2, 3, 3]);
        let b = rand_leaf(&mut rng, &[3]);
        let build = || mean_all(&convolution(&x, &w, Some(&b), (1, 1), (1, 1), (1, 1)));
        check_grads(&[&x, &w, &b], &build, 1e-2, 2e-2);
    }

    #[test]
    fn conv_gradcheck_strided_dilated() {
        let mut rng = Rng::new(42);
        let x = rand_leaf(&mut rng, &[1, 2, 6, 6]);
        let w = rand_leaf(&mut rng, &[2, 2, 2, 2]);
        let build = || mean_all(&convolution(&x, &w, None, (2, 2), (0, 0), (2, 2)));
        check_grads(&[&x, &w], &build, 1e-2, 2e-2);
    }

    #[test]
    fn deconv_upsamples() {
        let mut rng = Rng::new(43);
        let x = rand_leaf(&mut rng, &[1, 2, 3, 3]);
        let w = rand_leaf(&mut rng, &[2, 4, 2, 2]);
        let y = deconvolution(&x, &w, None, (2, 2), (0, 0));
        assert_eq!(y.dims(), vec![1, 4, 6, 6]);
    }

    #[test]
    fn deconv_gradcheck() {
        let mut rng = Rng::new(44);
        let x = rand_leaf(&mut rng, &[1, 2, 3, 3]);
        let w = rand_leaf(&mut rng, &[2, 2, 2, 2]);
        let b = rand_leaf(&mut rng, &[2]);
        let build = || mean_all(&deconvolution(&x, &w, Some(&b), (1, 1), (0, 0)));
        check_grads(&[&x, &w, &b], &build, 1e-2, 2e-2);
    }

    #[test]
    fn deconv_is_conv_adjoint() {
        // <conv(x), y> == <x, deconv(y)> with shared kernel (no bias)
        let mut rng = Rng::new(45);
        let xa = rng.randn(&[1, 2, 5, 5], 1.0);
        let wa = rng.randn(&[3, 2, 3, 3], 1.0);
        let x = Variable::from_array(xa.clone(), false);
        let w = Variable::from_array(wa.clone(), false);
        let cy = convolution(&x, &w, None, (1, 1), (0, 0), (1, 1));
        let ya = rng.randn(&cy.dims(), 1.0);
        let lhs: f32 = cy.data().data().iter().zip(ya.data()).map(|(a, b)| a * b).sum();
        // deconv weight layout [C_in, C_out, KH, KW]: the conv weight
        // [OC, C, KH, KW] reinterpreted as-is (OC is deconv's input side)
        let wt = Variable::from_array(wa.clone(), false);
        let yv = Variable::from_array(ya, false);
        let dx = deconvolution(&yv, &wt, None, (1, 1), (0, 0));
        let rhs: f32 = xa.data().iter().zip(dx.data().data()).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() / lhs.abs().max(1.0) < 1e-3, "{lhs} vs {rhs}");
    }
}
