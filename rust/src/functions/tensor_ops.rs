//! Shape-manipulation functions + embedding lookup.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray, Shape};

/// Reshape to fixed dims (`usize::MAX` dim = infer). Recorded on the
/// tape as a [`Op::Reshape`] spec (`usize::MAX` → `-1`) so traced
/// graphs keep the inference dimension symbolic.
pub fn reshape(x: &Variable, dims: &[usize]) -> Variable {
    let spec: Vec<i64> =
        dims.iter().map(|&d| if d == usize::MAX { -1 } else { d as i64 }).collect();
    reshape_spec(x, &spec)
}

/// Reshape by symbolic spec: `-1` infers one dimension, `0` in the
/// leading position keeps the input's batch axis. The spec is resolved
/// against the input shape on *every* forward execution, so a traced
/// graph stays batch-size flexible.
pub fn reshape_spec(x: &Variable, spec: &[i64]) -> Variable {
    let op = Op::Reshape { dims: spec.to_vec() };
    let spec = spec.to_vec();
    Variable::from_function(
        op,
        &[x],
        Box::new(move |xs| {
            let dims: Vec<usize> = spec
                .iter()
                .enumerate()
                .map(|(i, &d)| {
                    if d == -1 {
                        usize::MAX // NdArray::reshape infers this dim
                    } else if d == 0 && i == 0 {
                        xs[0].dims()[0] // keep batch
                    } else {
                        d as usize
                    }
                })
                .collect();
            xs[0].reshape(&dims)
        }),
        Box::new(|xs, _y, g| vec![Some(g.reshape(xs[0].dims()))]),
    )
}

/// Transpose by axis permutation.
pub fn transpose(x: &Variable, axes: &[usize]) -> Variable {
    let op = Op::Transpose { axes: axes.to_vec() };
    let axes = axes.to_vec();
    // inverse permutation for backward
    let mut inv = vec![0usize; axes.len()];
    for (i, &a) in axes.iter().enumerate() {
        inv[a] = i;
    }
    Variable::from_function(
        op,
        &[x],
        Box::new(move |xs| xs[0].transpose(&axes)),
        Box::new(move |_xs, _y, g| vec![Some(g.transpose(&inv))]),
    )
}

/// Broadcast to a target shape.
pub fn broadcast_to(x: &Variable, dims: &[usize]) -> Variable {
    let op = Op::BroadcastTo { dims: dims.to_vec() };
    let dims = dims.to_vec();
    Variable::from_function(
        op,
        &[x],
        Box::new(move |xs| xs[0].broadcast_to(&dims)),
        Box::new(|xs, _y, g| vec![Some(ops::reduce_to_shape(g, xs[0].shape()))]),
    )
}

/// Concatenate along `axis`.
pub fn concat(parts: &[&Variable], axis: usize) -> Variable {
    assert!(!parts.is_empty());
    let sizes: Vec<usize> = parts.iter().map(|p| p.dims()[axis]).collect();
    Variable::from_function(
        Op::Concat { axis },
        parts,
        Box::new(move |xs| {
            let refs: Vec<&NdArray> = xs.iter().collect();
            NdArray::concat(&refs, axis)
        }),
        Box::new(move |_xs, _y, g| {
            let mut out = Vec::with_capacity(sizes.len());
            let mut start = 0;
            for &s in &sizes {
                out.push(Some(g.slice_axis(axis, start, start + s)));
                start += s;
            }
            out
        }),
    )
}

/// Slice `[start, stop)` along `axis`.
pub fn slice_axis(x: &Variable, axis: usize, start: usize, stop: usize) -> Variable {
    Variable::from_function(
        Op::Slice { axis, start, stop },
        &[x],
        Box::new(move |xs| xs[0].slice_axis(axis, start, stop)),
        Box::new(move |xs, _y, g| {
            let mut gx = NdArray::zeros(xs[0].dims());
            // scatter g back into the slice window
            let dims = xs[0].dims().to_vec();
            let outer: usize = dims[..axis].iter().product();
            let inner: usize = dims[axis + 1..].iter().product();
            let a = dims[axis];
            let width = stop - start;
            let gd = g.data();
            let gxd = gx.data_mut();
            for o in 0..outer {
                for k in 0..width {
                    let dst = (o * a + start + k) * inner;
                    let src = (o * width + k) * inner;
                    gxd[dst..dst + inner].copy_from_slice(&gd[src..src + inner]);
                }
            }
            vec![Some(gx)]
        }),
    )
}

/// Embedding lookup: `ids: [B]` (f32-stored indices) into
/// `table: [V, D]` -> `[B, D]`.
pub fn embed(ids: &Variable, table: &Variable) -> Variable {
    Variable::from_function(
        Op::Embed,
        &[ids, table],
        Box::new(|xs| {
            let (ids, table) = (&xs[0], &xs[1]);
            let b = ids.size();
            let d = table.dims()[1];
            let v = table.dims()[0];
            let mut out = Vec::with_capacity(b * d);
            for i in 0..b {
                let id = ids.data()[i] as usize;
                assert!(id < v, "embed id {id} out of range {v}");
                out.extend_from_slice(&table.data()[id * d..(id + 1) * d]);
            }
            NdArray::from_vec(&[b, d], out)
        }),
        Box::new(|xs, _y, g| {
            let (ids, table) = (&xs[0], &xs[1]);
            let b = ids.size();
            let d = table.dims()[1];
            let mut gt = NdArray::zeros(table.dims());
            let gd = g.data();
            let gtd = gt.data_mut();
            for i in 0..b {
                let id = ids.data()[i] as usize;
                for j in 0..d {
                    gtd[id * d + j] += gd[i * d + j];
                }
            }
            vec![None, Some(gt)]
        }),
    )
}

/// Identity with a shape assertion — used by converters to pin I/O
/// signatures.
pub fn identity(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Identity,
        &[x],
        Box::new(|xs| xs[0].clone()),
        Box::new(|_xs, _y, g| vec![Some(g.clone())]),
    )
}

/// Adjoint-checked helper reused by tests.
pub(crate) fn _shape_of(v: &Variable) -> Shape {
    Shape::new(&v.dims())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    #[test]
    fn reshape_transpose_roundtrip() {
        let mut rng = Rng::new(90);
        let x = rand_leaf(&mut rng, &[2, 3, 4]);
        let y = transpose(&reshape(&x, &[6, 4]), &[1, 0]);
        assert_eq!(y.dims(), vec![4, 6]);
        // build must reconstruct the whole chain (define-by-run)
        let build = || {
            mean_all(&crate::functions::mul_scalar(
                &transpose(&reshape(&x, &[6, 4]), &[1, 0]),
                1.7,
            ))
        };
        check_grads(&[&x], &build, 1e-3, 1e-2);
    }

    #[test]
    fn reshape_records_symbolic_spec() {
        let x = rand_leaf(&mut Rng::new(94), &[2, 3, 4]);
        let y = reshape(&x, &[6, usize::MAX]);
        assert_eq!(y.dims(), vec![6, 4]);
        assert_eq!(y.creator_op(), Some(Op::Reshape { dims: vec![6, -1] }));
        // batch-keeping spec re-resolves on forward
        let z = reshape_spec(&x, &[0, -1]);
        assert_eq!(z.dims(), vec![2, 12]);
    }

    #[test]
    fn concat_slice_grads() {
        let mut rng = Rng::new(91);
        let a = rand_leaf(&mut rng, &[2, 2]);
        let b = rand_leaf(&mut rng, &[2, 3]);
        let build = || mean_all(&slice_axis(&concat(&[&a, &b], 1), 1, 1, 4));
        check_grads(&[&a, &b], &build, 1e-3, 1e-2);
    }

    #[test]
    fn broadcast_grad_sums() {
        let mut rng = Rng::new(92);
        let x = rand_leaf(&mut rng, &[1, 3]);
        let build = || mean_all(&broadcast_to(&x, &[4, 3]));
        check_grads(&[&x], &build, 1e-3, 1e-2);
    }

    #[test]
    fn embed_lookup_and_grad() {
        let ids = Variable::from_array(NdArray::from_slice(&[3], &[2., 0., 2.]), false);
        let table = Variable::from_array(NdArray::arange(&[4, 2]), true);
        let y = embed(&ids, &table);
        assert_eq!(y.data().data(), &[4., 5., 0., 1., 4., 5.]);
        mean_all(&y).backward();
        let g = table.grad();
        // row 2 used twice, row 0 once, rows 1/3 never
        assert!((g.at(&[2, 0]) - 2.0 / 6.0).abs() < 1e-6);
        assert!((g.at(&[0, 0]) - 1.0 / 6.0).abs() < 1e-6);
        assert_eq!(g.at(&[1, 0]), 0.0);
        assert_eq!(g.at(&[3, 1]), 0.0);
    }

    #[test]
    fn embed_gradcheck_on_table() {
        let mut rng = Rng::new(93);
        let ids = Variable::from_array(NdArray::from_slice(&[4], &[1., 3., 0., 1.]), false);
        let table = rand_leaf(&mut rng, &[5, 3]);
        let build = || mean_all(&embed(&ids, &table));
        check_grads(&[&table], &build, 1e-3, 1e-2);
    }

    #[test]
    fn slice_scatter_grad_zero_outside() {
        let x = Variable::from_array(NdArray::arange(&[2, 4]), true);
        mean_all(&slice_axis(&x, 1, 1, 3)).backward();
        let g = x.grad();
        assert_eq!(g.at(&[0, 0]), 0.0);
        assert_eq!(g.at(&[1, 3]), 0.0);
        assert!(g.at(&[0, 1]) > 0.0);
    }
}
