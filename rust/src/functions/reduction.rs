//! Reductions over Variables.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

/// Sum of all elements -> scalar.
pub fn sum_all(x: &Variable) -> Variable {
    Variable::from_function(
        Op::SumAll,
        &[x],
        Box::new(|xs| NdArray::scalar(xs[0].sum_all())),
        Box::new(|xs, _y, g| vec![Some(NdArray::full(xs[0].dims(), g.item()))]),
    )
}

/// Mean of all elements -> scalar.
pub fn mean_all(x: &Variable) -> Variable {
    Variable::from_function(
        Op::MeanAll,
        &[x],
        Box::new(|xs| NdArray::scalar(xs[0].mean_all())),
        Box::new(|xs, _y, g| {
            let n = xs[0].size() as f32;
            vec![Some(NdArray::full(xs[0].dims(), g.item() / n))]
        }),
    )
}

/// Sum along one axis.
pub fn sum_axis(x: &Variable, axis: usize, keepdims: bool) -> Variable {
    Variable::from_function(
        Op::Sum { axis, keepdims },
        &[x],
        Box::new(move |xs| ops::sum_axis(&xs[0], axis, keepdims)),
        Box::new(move |xs, _y, g| {
            // broadcast grad back across the reduced axis
            let mut gdims = xs[0].dims().to_vec();
            gdims[axis] = 1;
            let g2 = g.reshape(&gdims);
            vec![Some(g2.broadcast_to(xs[0].dims()))]
        }),
    )
}

/// Mean along one axis.
pub fn mean_axis(x: &Variable, axis: usize, keepdims: bool) -> Variable {
    Variable::from_function(
        Op::Mean { axis, keepdims },
        &[x],
        Box::new(move |xs| ops::mean_axis(&xs[0], axis, keepdims)),
        Box::new(move |xs, _y, g| {
            let n = xs[0].dims()[axis] as f32;
            let mut gdims = xs[0].dims().to_vec();
            gdims[axis] = 1;
            let g2 = ops::scale(&g.reshape(&gdims), 1.0 / n);
            vec![Some(g2.broadcast_to(xs[0].dims()))]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::tensor::Rng;

    #[test]
    fn values() {
        let x = Variable::from_array(NdArray::from_slice(&[2, 2], &[1., 2., 3., 4.]), true);
        assert_eq!(sum_all(&x).item(), 10.0);
        assert_eq!(mean_all(&x).item(), 2.5);
        assert_eq!(sum_axis(&x, 0, false).data().data(), &[4., 6.]);
        assert_eq!(mean_axis(&x, 1, false).data().data(), &[1.5, 3.5]);
        assert_eq!(sum_axis(&x, 1, true).dims(), vec![2, 1]);
    }

    #[test]
    fn gradchecks() {
        let mut rng = Rng::new(80);
        let x = rand_leaf(&mut rng, &[3, 4]);
        check_grads(&[&x], &|| sum_all(&x), 1e-3, 1e-2);
        check_grads(&[&x], &|| mean_all(&x), 1e-3, 1e-2);
        check_grads(&[&x], &|| mean_all(&sum_axis(&x, 0, false)), 1e-3, 1e-2);
        check_grads(&[&x], &|| mean_all(&mean_axis(&x, 1, true)), 1e-3, 1e-2);
    }
}
