//! Softmax / log-softmax over the last axis (numerically stabilized).

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

pub(crate) fn softmax_fwd(x: &NdArray) -> NdArray {
    let last = x.rank() - 1;
    let (mx, _) = ops::max_axis(x, last, true);
    let shifted = ops::sub(x, &mx);
    let e = ops::map(&shifted, f32::exp);
    let s = ops::sum_axis(&e, last, true);
    ops::div(&e, &s)
}

/// Softmax over the last axis.
pub fn softmax(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Softmax,
        &[x],
        Box::new(|xs| softmax_fwd(&xs[0])),
        Box::new(|_xs, y, g| {
            // dx = y * (g - sum(g*y, last, keep))
            let last = y.rank() - 1;
            let gy = ops::mul(g, y);
            let s = ops::sum_axis(&gy, last, true);
            vec![Some(ops::mul(y, &ops::sub(g, &s)))]
        }),
    )
}

/// Log-softmax over the last axis.
pub fn log_softmax(x: &Variable) -> Variable {
    Variable::from_function(
        Op::LogSoftmax,
        &[x],
        Box::new(|xs| {
            let last = xs[0].rank() - 1;
            let (mx, _) = ops::max_axis(&xs[0], last, true);
            let shifted = ops::sub(&xs[0], &mx);
            let lse = ops::map(
                &ops::sum_axis(&ops::map(&shifted, f32::exp), last, true),
                f32::ln,
            );
            ops::sub(&shifted, &lse)
        }),
        Box::new(|_xs, y, g| {
            // dx = g - softmax(x) * sum(g, last, keep); softmax = exp(y)
            let last = y.rank() - 1;
            let sm = ops::map(y, f32::exp);
            let s = ops::sum_axis(g, last, true);
            vec![Some(ops::sub(g, &ops::mul(&sm, &s)))]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::{mean_all, mul};
    use crate::tensor::Rng;

    #[test]
    fn softmax_sums_to_one() {
        let mut rng = Rng::new(60);
        let x = rand_leaf(&mut rng, &[3, 5]);
        let y = softmax(&x).data();
        for i in 0..3 {
            let s: f32 = y.data()[i * 5..(i + 1) * 5].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(y.data().iter().all(|&v| v > 0.0));
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let x = Variable::from_array(NdArray::from_slice(&[1, 3], &[1000., 1001., 1002.]), true);
        let y = softmax(&x).data();
        assert!(!y.has_inf_or_nan());
        let x2 = Variable::from_array(NdArray::from_slice(&[1, 3], &[0., 1., 2.]), true);
        assert!(y.allclose(&softmax(&x2).data(), 1e-6, 1e-5));
    }

    #[test]
    fn log_softmax_is_log_of_softmax() {
        let mut rng = Rng::new(61);
        let x = rand_leaf(&mut rng, &[2, 4]);
        let a = log_softmax(&x).data();
        let b = ops::map(&softmax(&x).data(), f32::ln);
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }

    #[test]
    fn softmax_gradcheck() {
        let mut rng = Rng::new(62);
        let x = rand_leaf(&mut rng, &[2, 4]);
        let w = Variable::from_array(rng.randn(&[2, 4], 1.0), false); // project to non-symmetric scalar
        let build = || mean_all(&mul(&softmax(&x), &w));
        check_grads(&[&x], &build, 1e-3, 2e-2);
    }

    #[test]
    fn log_softmax_gradcheck() {
        let mut rng = Rng::new(63);
        let x = rand_leaf(&mut rng, &[2, 4]);
        let w = Variable::from_array(rng.randn(&[2, 4], 1.0), false);
        let build = || mean_all(&mul(&log_softmax(&x), &w));
        check_grads(&[&x], &build, 1e-3, 2e-2);
    }
}
