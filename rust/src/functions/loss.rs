//! Loss functions. Per NNabla convention these return *per-example*
//! losses of shape `[B, 1]`; reduce with `F::mean_all` to get the
//! scalar training loss.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray};

use super::softmax::softmax_fwd;

/// Softmax cross-entropy with integer labels. `x: [B, C]`,
/// `t: [B, 1]` (label indices stored as f32). Output `[B, 1]`.
pub fn softmax_cross_entropy(x: &Variable, t: &Variable) -> Variable {
    Variable::from_function(
        Op::SoftmaxCrossEntropy,
        &[x, t],
        Box::new(|xs| {
            let (x, t) = (&xs[0], &xs[1]);
            let b = x.dims()[0];
            let c = x.dims()[1];
            let p = softmax_fwd(x);
            let mut out = vec![0.0f32; b];
            for i in 0..b {
                let label = t.data()[i] as usize;
                assert!(label < c, "label {label} out of range {c}");
                out[i] = -p.data()[i * c + label].max(1e-30).ln();
            }
            NdArray::from_vec(&[b, 1], out)
        }),
        Box::new(|xs, _y, gy| {
            let (x, t) = (&xs[0], &xs[1]);
            let b = x.dims()[0];
            let c = x.dims()[1];
            let p = softmax_fwd(x);
            let mut gx = p.into_vec();
            for i in 0..b {
                let label = t.data()[i] as usize;
                gx[i * c + label] -= 1.0;
                let gv = gy.data()[i];
                for j in 0..c {
                    gx[i * c + j] *= gv;
                }
            }
            vec![Some(NdArray::from_vec(x.dims(), gx)), None]
        }),
    )
}

/// Elementwise squared error `(x - t)^2` (no reduction).
pub fn squared_error(x: &Variable, t: &Variable) -> Variable {
    Variable::from_function(
        Op::SquaredError,
        &[x, t],
        Box::new(|xs| ops::zip_broadcast(&xs[0], &xs[1], |a, b| (a - b) * (a - b))),
        Box::new(|xs, _y, g| {
            let d = ops::sub(&xs[0], &xs[1]);
            let gx = ops::mul(g, &ops::scale(&d, 2.0));
            vec![
                Some(ops::reduce_to_shape(&gx, xs[0].shape())),
                Some(ops::reduce_to_shape(&ops::scale(&gx, -1.0), xs[1].shape())),
            ]
        }),
    )
}

/// Sigmoid cross-entropy with binary targets (elementwise, stable form
/// `max(x,0) - x*t + log(1+exp(-|x|))`).
pub fn sigmoid_cross_entropy(x: &Variable, t: &Variable) -> Variable {
    Variable::from_function(
        Op::SigmoidCrossEntropy,
        &[x, t],
        Box::new(|xs| {
            ops::zip_broadcast(&xs[0], &xs[1], |x, t| {
                x.max(0.0) - x * t + (1.0 + (-x.abs()).exp()).ln()
            })
        }),
        Box::new(|xs, _y, g| {
            let gx = ops::zip_broadcast(&xs[0], &xs[1], |x, t| {
                let s = 1.0 / (1.0 + (-x).exp());
                s - t
            });
            vec![Some(ops::mul(g, &gx)), None]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    #[test]
    fn sce_uniform_logits_is_log_c() {
        let x = Variable::from_array(NdArray::zeros(&[2, 4]), true);
        let t = Variable::from_array(NdArray::from_slice(&[2, 1], &[0., 3.]), false);
        let l = softmax_cross_entropy(&x, &t);
        assert_eq!(l.dims(), vec![2, 1]);
        for &v in l.data().data() {
            assert!((v - 4f32.ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn sce_perfect_prediction_near_zero() {
        let mut x = NdArray::zeros(&[1, 3]);
        x.set(&[0, 1], 100.0);
        let xv = Variable::from_array(x, true);
        let t = Variable::from_array(NdArray::from_slice(&[1, 1], &[1.]), false);
        assert!(softmax_cross_entropy(&xv, &t).item() < 1e-5);
    }

    #[test]
    fn sce_gradcheck() {
        let mut rng = Rng::new(70);
        let x = rand_leaf(&mut rng, &[3, 5]);
        let t = Variable::from_array(NdArray::from_slice(&[3, 1], &[0., 2., 4.]), false);
        let build = || mean_all(&softmax_cross_entropy(&x, &t));
        check_grads(&[&x], &build, 1e-3, 2e-2);
    }

    #[test]
    fn sce_grad_is_p_minus_onehot() {
        let mut rng = Rng::new(71);
        let x = rand_leaf(&mut rng, &[2, 3]);
        let t = Variable::from_array(NdArray::from_slice(&[2, 1], &[1., 0.]), false);
        let l = mean_all(&softmax_cross_entropy(&x, &t));
        l.backward();
        let p = softmax_fwd(&x.data());
        let g = x.grad();
        // g = (p - onehot)/2 (mean over 2 examples)
        assert!((g.at(&[0, 1]) - (p.at(&[0, 1]) - 1.0) / 2.0).abs() < 1e-5);
        assert!((g.at(&[0, 0]) - p.at(&[0, 0]) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn squared_error_values_and_grads() {
        let x = Variable::from_array(NdArray::from_slice(&[2], &[3., 5.]), true);
        let t = Variable::from_array(NdArray::from_slice(&[2], &[1., 1.]), false);
        let l = squared_error(&x, &t);
        assert_eq!(l.data().data(), &[4., 16.]);
        let m = mean_all(&l);
        m.backward();
        assert_eq!(x.grad().data(), &[2., 4.]); // 2(x-t)/2
    }

    #[test]
    fn squared_error_gradcheck_both_sides() {
        let mut rng = Rng::new(72);
        let x = rand_leaf(&mut rng, &[4]);
        let t = rand_leaf(&mut rng, &[4]);
        let build = || mean_all(&squared_error(&x, &t));
        check_grads(&[&x, &t], &build, 1e-3, 1e-2);
    }

    #[test]
    fn bce_matches_naive_formula() {
        let mut rng = Rng::new(73);
        let x = rand_leaf(&mut rng, &[6]);
        let t = Variable::from_array(
            NdArray::from_slice(&[6], &[1., 0., 1., 1., 0., 0.]),
            false,
        );
        let stable = sigmoid_cross_entropy(&x, &t).data();
        let naive = ops::zip_broadcast(&x.data(), &t.data(), |x, t| {
            let s = 1.0 / (1.0 + (-x).exp());
            -(t * s.ln() + (1.0 - t) * (1.0 - s).ln())
        });
        assert!(stable.allclose(&naive, 1e-5, 1e-4));
    }

    #[test]
    fn bce_gradcheck() {
        let mut rng = Rng::new(74);
        let x = rand_leaf(&mut rng, &[5]);
        let t = Variable::from_array(NdArray::from_slice(&[5], &[1., 0., 1., 0., 1.]), false);
        let build = || mean_all(&sigmoid_cross_entropy(&x, &t));
        check_grads(&[&x], &build, 1e-3, 2e-2);
    }
}
