//! Dropout — the paper's flagship *dynamic graph* example ("networks
//! containing randomly dropping layers for each minibatch", §2.2).
//!
//! The mask is resampled on every forward execution (including graph
//! re-execution via `Variable::forward`), and shared with the backward
//! closure through interior mutability.

use std::cell::RefCell;
use std::rc::Rc;

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::{ops, NdArray, Rng};

thread_local! {
    static DROPOUT_RNG: RefCell<Rng> = RefCell::new(Rng::new(0x5EED));
}

/// Reseed this thread's dropout RNG (reproducible runs / tests).
pub fn seed_dropout(seed: u64) {
    DROPOUT_RNG.with(|r| *r.borrow_mut() = Rng::new(seed));
}

/// Inverted dropout with drop probability `p`. Scaling by `1/(1-p)` at
/// train time keeps inference a no-op (just don't apply the function).
pub fn dropout(x: &Variable, p: f32) -> Variable {
    assert!((0.0..1.0).contains(&p), "dropout p must be in [0,1), got {p}");
    let mask: Rc<RefCell<Option<NdArray>>> = Rc::new(RefCell::new(None));
    let mask_fwd = mask.clone();
    let keep = 1.0 - p;
    Variable::from_function(
        Op::Dropout { p },
        &[x],
        Box::new(move |xs| {
            let m = DROPOUT_RNG.with(|r| {
                let mut rng = r.borrow_mut();
                let n = xs[0].size();
                let data: Vec<f32> = (0..n)
                    .map(|_| if rng.uniform() < p { 0.0 } else { 1.0 / keep })
                    .collect();
                NdArray::from_vec(xs[0].dims(), data)
            });
            let y = ops::mul(&xs[0], &m);
            *mask_fwd.borrow_mut() = Some(m);
            y
        }),
        Box::new(move |xs, _y, g| {
            let m = mask.borrow();
            let m = m.as_ref().unwrap_or_else(|| panic!("dropout backward before forward"));
            assert_eq!(m.dims(), xs[0].dims());
            vec![Some(ops::mul(g, m))]
        }),
    )
}

/// Inference-mode dropout: identity on the data path, but still
/// recorded on the tape as [`Op::Dropout`] — so a traced graph keeps
/// the layer (NNP re-training, frozen-graph folding) while eval-mode
/// execution is exactly a no-op. This is what [`Op::apply`] dispatches
/// to: deployment semantics, bit-identical between the live graph and
/// the interpreter.
///
/// Unlike [`dropout`], `p` is *not* validated here: this constructor
/// sits on the interpreter's deserialization path (`Op::apply` on a
/// loaded NNP/ONNX/NNB layer), which must report malformed attributes
/// as `Err`, never panic — and since the op is an identity at
/// inference, any recorded `p` executes safely.
pub fn dropout_inference(x: &Variable, p: f32) -> Variable {
    Variable::from_function(
        Op::Dropout { p },
        &[x],
        Box::new(|xs| xs[0].clone()),
        Box::new(|_xs, _y, g| vec![Some(g.clone())]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropout_inference_is_identity_but_recorded() {
        let x = Variable::from_array(NdArray::arange(&[6]), true);
        let y = dropout_inference(&x, 0.7);
        assert_eq!(y.data().data(), x.data().data());
        assert_eq!(y.creator_op(), Some(Op::Dropout { p: 0.7 }));
        crate::functions::sum_all(&y).backward();
        assert_eq!(x.grad().data(), &[1.0f32; 6]);
    }

    #[test]
    fn dropout_zero_p_is_identity() {
        seed_dropout(1);
        let x = Variable::from_array(NdArray::arange(&[10]), true);
        let y = dropout(&x, 0.0);
        assert_eq!(y.data().data(), x.data().data());
    }

    #[test]
    fn dropout_preserves_expectation() {
        seed_dropout(2);
        let x = Variable::from_array(NdArray::ones(&[10_000]), true);
        let y = dropout(&x, 0.5);
        let mean = y.data().mean_all();
        assert!((mean - 1.0).abs() < 0.05, "inverted dropout mean {mean}");
        // zeros make up ~p of the entries
        let zeros = y.data().data().iter().filter(|&&v| v == 0.0).count();
        assert!((zeros as f32 / 10_000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn dropout_resamples_each_forward() {
        // the dynamic-graph behaviour of §2.2
        seed_dropout(3);
        let x = Variable::from_array(NdArray::ones(&[1000]), true);
        let y = dropout(&x, 0.5);
        let first = y.data();
        y.forward();
        let second = y.data();
        assert_ne!(first.data(), second.data());
    }

    #[test]
    fn dropout_backward_uses_same_mask() {
        seed_dropout(4);
        let x = Variable::from_array(NdArray::ones(&[1000]), true);
        let y = dropout(&x, 0.5);
        let out = y.data();
        crate::functions::sum_all(&y).backward();
        let g = x.grad();
        // gradient equals the mask: nonzero exactly where output nonzero
        for i in 0..1000 {
            assert_eq!(g.data()[i] == 0.0, out.data()[i] == 0.0, "elem {i}");
        }
    }

    #[test]
    fn dropout_deterministic_under_seed() {
        seed_dropout(42);
        let x = Variable::from_array(NdArray::ones(&[100]), false);
        let a = dropout(&x, 0.3).data();
        seed_dropout(42);
        let b = dropout(&x, 0.3).data();
        assert_eq!(a.data(), b.data());
    }
}
