//! Activation functions (each `F.<name>` in the paper's listings).

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::ops;

/// Rectified linear unit.
pub fn relu(x: &Variable) -> Variable {
    Variable::from_function(
        Op::ReLU,
        &[x],
        Box::new(|xs| ops::map(&xs[0], |v| v.max(0.0))),
        Box::new(|xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], |gv, xv| if xv > 0.0 { gv } else { 0.0 }))]
        }),
    )
}

/// Leaky ReLU with slope `alpha` for x < 0.
pub fn leaky_relu(x: &Variable, alpha: f32) -> Variable {
    Variable::from_function(
        Op::LeakyReLU { alpha },
        &[x],
        Box::new(move |xs| ops::map(&xs[0], |v| if v > 0.0 { v } else { alpha * v })),
        Box::new(move |xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], move |gv, xv| {
                if xv > 0.0 {
                    gv
                } else {
                    alpha * gv
                }
            }))]
        }),
    )
}

/// Logistic sigmoid.
pub fn sigmoid(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Sigmoid,
        &[x],
        Box::new(|xs| ops::map(&xs[0], |v| 1.0 / (1.0 + (-v).exp()))),
        Box::new(|_xs, y, g| {
            vec![Some(ops::zip_broadcast(g, y, |gv, yv| gv * yv * (1.0 - yv)))]
        }),
    )
}

/// Hyperbolic tangent.
pub fn tanh(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Tanh,
        &[x],
        Box::new(|xs| ops::map(&xs[0], f32::tanh)),
        Box::new(|_xs, y, g| vec![Some(ops::zip_broadcast(g, y, |gv, yv| gv * (1.0 - yv * yv)))]),
    )
}

/// Exponential linear unit.
pub fn elu(x: &Variable, alpha: f32) -> Variable {
    Variable::from_function(
        Op::Elu { alpha },
        &[x],
        Box::new(move |xs| ops::map(&xs[0], |v| if v > 0.0 { v } else { alpha * (v.exp() - 1.0) })),
        Box::new(move |xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], move |gv, xv| {
                if xv > 0.0 {
                    gv
                } else {
                    gv * alpha * xv.exp()
                }
            }))]
        }),
    )
}

/// Swish / SiLU: `x * sigmoid(x)` (used by MobileNetV3 / EfficientNet).
pub fn swish(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Swish,
        &[x],
        Box::new(|xs| ops::map(&xs[0], |v| v / (1.0 + (-v).exp()))),
        Box::new(|xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], |gv, xv| {
                let s = 1.0 / (1.0 + (-xv).exp());
                gv * (s + xv * s * (1.0 - s))
            }))]
        }),
    )
}

/// Gaussian error linear unit (tanh approximation).
pub fn gelu(x: &Variable) -> Variable {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    Variable::from_function(
        Op::Gelu,
        &[x],
        Box::new(|xs| {
            ops::map(&xs[0], |v| 0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh()))
        }),
        Box::new(|xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], |gv, v| {
                let u = C * (v + 0.044715 * v * v * v);
                let t = u.tanh();
                let du = C * (1.0 + 3.0 * 0.044715 * v * v);
                gv * (0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du)
            }))]
        }),
    )
}

/// Softplus: `ln(1 + e^x)`.
pub fn softplus(x: &Variable) -> Variable {
    Variable::from_function(
        Op::Softplus,
        &[x],
        Box::new(|xs| ops::map(&xs[0], |v| if v > 20.0 { v } else { (1.0 + v.exp()).ln() })),
        Box::new(|xs, _y, g| {
            vec![Some(ops::zip_broadcast(g, &xs[0], |gv, xv| gv / (1.0 + (-xv).exp())))]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::{NdArray, Rng};

    #[test]
    fn relu_values() {
        let x = Variable::from_array(NdArray::from_slice(&[4], &[-2., -0.5, 0.5, 2.]), true);
        assert_eq!(relu(&x).data().data(), &[0., 0., 0.5, 2.]);
        assert_eq!(leaky_relu(&x, 0.1).data().data(), &[-0.2, -0.05, 0.5, 2.]);
    }

    #[test]
    fn sigmoid_tanh_known_points() {
        let x = Variable::from_array(NdArray::from_slice(&[1], &[0.0]), true);
        assert!((sigmoid(&x).item() - 0.5).abs() < 1e-6);
        assert!(tanh(&x).item().abs() < 1e-6);
    }

    #[test]
    fn all_activations_gradcheck() {
        let mut rng = Rng::new(20);
        let x = rand_leaf(&mut rng, &[7]);
        // keep away from relu kink
        x.set_data(crate::tensor::ops::map(&x.data(), |v| {
            if v.abs() < 0.1 {
                v + 0.2
            } else {
                v
            }
        }));
        let fns: Vec<(&str, Box<dyn Fn(&Variable) -> Variable>)> = vec![
            ("relu", Box::new(|v: &Variable| relu(v))),
            ("leaky", Box::new(|v: &Variable| leaky_relu(v, 0.2))),
            ("sigmoid", Box::new(|v: &Variable| sigmoid(v))),
            ("tanh", Box::new(|v: &Variable| tanh(v))),
            ("elu", Box::new(|v: &Variable| elu(v, 1.0))),
            ("swish", Box::new(|v: &Variable| swish(v))),
            ("gelu", Box::new(|v: &Variable| gelu(v))),
            ("softplus", Box::new(|v: &Variable| softplus(v))),
        ];
        for (name, f) in &fns {
            let build = || mean_all(&f(&x));
            check_grads(&[&x], &build, 1e-3, 2e-2);
            let _ = name;
        }
    }

    #[test]
    fn swish_matches_x_times_sigmoid() {
        let mut rng = Rng::new(21);
        let x = rand_leaf(&mut rng, &[10]);
        let a = swish(&x).data();
        let b = crate::tensor::ops::mul(&x.data(), &sigmoid(&x).data());
        assert!(a.allclose(&b, 1e-6, 1e-6));
    }
}
