//! Spatial pooling: max / average / global-average (NCHW).

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::NdArray;

/// Output H/W of a pooling window. Geometry must satisfy
/// `kernel <= input + 2·pad` and a non-zero stride; `Op::apply`
/// validates untrusted (NNP-loaded) attributes before reaching this,
/// so the `checked_sub` here only guards direct misuse of the Rust API
/// (a clear panic instead of a usize underflow / absurd allocation).
fn pool_out_hw(h: usize, w: usize, k: (usize, usize), s: (usize, usize), p: (usize, usize)) -> (usize, usize) {
    let eh = (h + 2 * p.0)
        .checked_sub(k.0)
        .unwrap_or_else(|| panic!("pooling kernel {k:?} larger than padded input {h}x{w} (pad {p:?})"));
    let ew = (w + 2 * p.1)
        .checked_sub(k.1)
        .unwrap_or_else(|| panic!("pooling kernel {k:?} larger than padded input {h}x{w} (pad {p:?})"));
    (eh / s.0 + 1, ew / s.1 + 1)
}

fn max_pool_fwd(
    x: &NdArray,
    k: (usize, usize),
    s: (usize, usize),
    p: (usize, usize),
) -> (NdArray, Vec<usize>) {
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (oh, ow) = pool_out_hw(h, w, k, s, p);
    let mut out = vec![f32::NEG_INFINITY; n * c * oh * ow];
    let mut arg = vec![usize::MAX; n * c * oh * ow];
    let xd = x.data();
    for ni in 0..n {
        for ci in 0..c {
            let plane = (ni * c + ci) * h * w;
            for oy in 0..oh {
                for ox in 0..ow {
                    let oi = ((ni * c + ci) * oh + oy) * ow + ox;
                    for ky in 0..k.0 {
                        let iy = (oy * s.0 + ky) as isize - p.0 as isize;
                        if iy < 0 || iy as usize >= h {
                            continue;
                        }
                        for kx in 0..k.1 {
                            let ix = (ox * s.1 + kx) as isize - p.1 as isize;
                            if ix < 0 || ix as usize >= w {
                                continue;
                            }
                            let src = plane + iy as usize * w + ix as usize;
                            if xd[src] > out[oi] {
                                out[oi] = xd[src];
                                arg[oi] = src;
                            }
                        }
                    }
                }
            }
        }
    }
    (NdArray::from_vec(&[n, c, oh, ow], out), arg)
}

/// Max pooling (`F.max_pooling` in Listings 4/5).
pub fn max_pooling(
    x: &Variable,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
) -> Variable {
    Variable::from_function(
        Op::MaxPool { kernel, stride, pad },
        &[x],
        Box::new(move |xs| max_pool_fwd(&xs[0], kernel, stride, pad).0),
        Box::new(move |xs, _y, gy| {
            // recompute argmax (cheap relative to storing state)
            let (_, arg) = max_pool_fwd(&xs[0], kernel, stride, pad);
            let mut gx = vec![0.0f32; xs[0].size()];
            for (oi, &src) in arg.iter().enumerate() {
                if src != usize::MAX {
                    gx[src] += gy.data()[oi];
                }
            }
            vec![Some(NdArray::from_vec(xs[0].dims(), gx))]
        }),
    )
}

/// Average pooling. `including_pad=false` divides by the count of valid
/// (non-padding) cells, matching NNabla's default.
pub fn average_pooling(
    x: &Variable,
    kernel: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    including_pad: bool,
) -> Variable {
    let fwd = move |x: &NdArray| -> NdArray {
        let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        let (oh, ow) = pool_out_hw(h, w, kernel, stride, pad);
        let mut out = vec![0.0f32; n * c * oh * ow];
        let xd = x.data();
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f32;
                        let mut cnt = 0usize;
                        for ky in 0..kernel.0 {
                            let iy = (oy * stride.0 + ky) as isize - pad.0 as isize;
                            for kx in 0..kernel.1 {
                                let ix = (ox * stride.1 + kx) as isize - pad.1 as isize;
                                if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                    acc += xd[plane + iy as usize * w + ix as usize];
                                    cnt += 1;
                                }
                            }
                        }
                        let denom = if including_pad { kernel.0 * kernel.1 } else { cnt.max(1) };
                        out[((ni * c + ci) * oh + oy) * ow + ox] = acc / denom as f32;
                    }
                }
            }
        }
        NdArray::from_vec(&[n, c, oh, ow], out)
    };
    Variable::from_function(
        Op::AvgPool { kernel, stride, pad, including_pad },
        &[x],
        Box::new(move |xs| fwd(&xs[0])),
        Box::new(move |xs, _y, gy| {
            let (n, c, h, w) =
                (xs[0].dims()[0], xs[0].dims()[1], xs[0].dims()[2], xs[0].dims()[3]);
            let (oh, ow) = pool_out_hw(h, w, kernel, stride, pad);
            let mut gx = vec![0.0f32; xs[0].size()];
            for ni in 0..n {
                for ci in 0..c {
                    let plane = (ni * c + ci) * h * w;
                    for oy in 0..oh {
                        for ox in 0..ow {
                            // count valid cells for the divisor
                            let mut cnt = 0usize;
                            for ky in 0..kernel.0 {
                                let iy = (oy * stride.0 + ky) as isize - pad.0 as isize;
                                for kx in 0..kernel.1 {
                                    let ix = (ox * stride.1 + kx) as isize - pad.1 as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                        cnt += 1;
                                    }
                                }
                            }
                            let denom =
                                if including_pad { kernel.0 * kernel.1 } else { cnt.max(1) };
                            let gv = gy.data()[((ni * c + ci) * oh + oy) * ow + ox]
                                / denom as f32;
                            for ky in 0..kernel.0 {
                                let iy = (oy * stride.0 + ky) as isize - pad.0 as isize;
                                for kx in 0..kernel.1 {
                                    let ix = (ox * stride.1 + kx) as isize - pad.1 as isize;
                                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                                        gx[plane + iy as usize * w + ix as usize] += gv;
                                    }
                                }
                            }
                        }
                    }
                }
            }
            vec![Some(NdArray::from_vec(xs[0].dims(), gx))]
        }),
    )
}

/// Global average pooling: `[N, C, H, W] -> [N, C]`.
pub fn global_average_pooling(x: &Variable) -> Variable {
    Variable::from_function(
        Op::GlobalAvgPool,
        &[x],
        Box::new(|xs| {
            let (n, c, h, w) =
                (xs[0].dims()[0], xs[0].dims()[1], xs[0].dims()[2], xs[0].dims()[3]);
            let mut out = vec![0.0f32; n * c];
            for i in 0..n * c {
                let s: f32 = xs[0].data()[i * h * w..(i + 1) * h * w].iter().sum();
                out[i] = s / (h * w) as f32;
            }
            NdArray::from_vec(&[n, c], out)
        }),
        Box::new(|xs, _y, gy| {
            let (n, c, h, w) =
                (xs[0].dims()[0], xs[0].dims()[1], xs[0].dims()[2], xs[0].dims()[3]);
            let mut gx = vec![0.0f32; xs[0].size()];
            for i in 0..n * c {
                let gv = gy.data()[i] / (h * w) as f32;
                for j in 0..h * w {
                    gx[i * h * w + j] = gv;
                }
            }
            vec![Some(NdArray::from_vec(xs[0].dims(), gx))]
        }),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::Rng;

    #[test]
    fn max_pool_known_values() {
        let x = Variable::from_array(NdArray::arange(&[1, 1, 4, 4]), true);
        let y = max_pooling(&x, (2, 2), (2, 2), (0, 0));
        assert_eq!(y.dims(), vec![1, 1, 2, 2]);
        assert_eq!(y.data().data(), &[5., 7., 13., 15.]);
    }

    #[test]
    fn avg_pool_known_values() {
        let x = Variable::from_array(NdArray::arange(&[1, 1, 4, 4]), true);
        let y = average_pooling(&x, (2, 2), (2, 2), (0, 0), false);
        assert_eq!(y.data().data(), &[2.5, 4.5, 10.5, 12.5]);
    }

    #[test]
    fn avg_pool_pad_divisor_modes() {
        let x = Variable::from_array(NdArray::ones(&[1, 1, 2, 2]), true);
        // 3x3 kernel pad 1: corner windows see 4 valid ones
        let excl = average_pooling(&x, (3, 3), (2, 2), (1, 1), false);
        assert_eq!(excl.data().data()[0], 1.0); // 4/4
        let incl = average_pooling(&x, (3, 3), (2, 2), (1, 1), true);
        assert_eq!(incl.data().data()[0], 4.0 / 9.0);
    }

    #[test]
    fn global_avg_pool_values() {
        let x = Variable::from_array(NdArray::arange(&[1, 2, 2, 2]), true);
        let y = global_average_pooling(&x);
        assert_eq!(y.dims(), vec![1, 2]);
        assert_eq!(y.data().data(), &[1.5, 5.5]);
    }

    #[test]
    fn max_pool_gradcheck() {
        let mut rng = Rng::new(50);
        let x = rand_leaf(&mut rng, &[1, 2, 4, 4]);
        // spread values to avoid argmax ties under perturbation
        x.set_data(crate::tensor::ops::map(&NdArray::arange(&[1, 2, 4, 4]), |v| v * 0.37));
        let build = || mean_all(&max_pooling(&x, (2, 2), (2, 2), (0, 0)));
        check_grads(&[&x], &build, 1e-3, 1e-2);
    }

    #[test]
    fn avg_pool_gradcheck_with_pad() {
        let mut rng = Rng::new(51);
        let x = rand_leaf(&mut rng, &[1, 2, 4, 4]);
        let build = || mean_all(&average_pooling(&x, (3, 3), (2, 2), (1, 1), false));
        check_grads(&[&x], &build, 1e-3, 1e-2);
    }

    #[test]
    fn global_avg_pool_gradcheck() {
        let mut rng = Rng::new(52);
        let x = rand_leaf(&mut rng, &[2, 3, 3, 3]);
        let build = || mean_all(&global_average_pooling(&x));
        check_grads(&[&x], &build, 1e-3, 1e-2);
    }
}
