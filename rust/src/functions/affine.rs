//! Affine (fully-connected) layer: `y = flatten(x) · W + b`.
//!
//! This is the op of the paper's Listing 1 — and its inner matmul is
//! exactly what the L1 Pallas kernel implements on the static path.
//! Forward and backward run on [`crate::tensor::kernels`]'s tiled GEMM
//! with the weight/input transposes taken as packing views (the old
//! closures materialized `W.t()` and `x.t()` on every backward step);
//! the bias fuses into the forward output buffer. The compiled plan's
//! fast path calls the same [`kernels::affine_forward`], so tape and
//! deployment outputs are bit-identical.

use crate::graph::Variable;
use crate::nnp::ir::Op;
use crate::tensor::kernels;

/// `x: [B, ...] -> [B, out]` with `w: [in, out]`, optional `b: [out]`.
/// Leading axis is the batch axis (NNabla `base_axis=1`); trailing axes
/// are flattened into the input feature dimension.
pub fn affine(x: &Variable, w: &Variable, b: Option<&Variable>) -> Variable {
    match b {
        Some(b) => Variable::from_function(
            Op::Affine,
            &[x, w, b],
            Box::new(move |xs| kernels::affine_forward(&xs[0], &xs[1], Some(&xs[2]))),
            Box::new(move |xs, _y, g| {
                let (gx, gw, gb) = kernels::affine_backward(&xs[0], &xs[1], g, true);
                vec![Some(gx), Some(gw), gb]
            }),
        ),
        None => Variable::from_function(
            Op::Affine,
            &[x, w],
            Box::new(move |xs| kernels::affine_forward(&xs[0], &xs[1], None)),
            Box::new(move |xs, _y, g| {
                let (gx, gw, _) = kernels::affine_backward(&xs[0], &xs[1], g, false);
                vec![Some(gx), Some(gw)]
            }),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functions::gradcheck::{check_grads, rand_leaf};
    use crate::functions::mean_all;
    use crate::tensor::{NdArray, Rng};

    #[test]
    fn affine_known_values() {
        let x = Variable::from_array(NdArray::from_slice(&[1, 2], &[1., 2.]), true);
        let w = Variable::from_array(NdArray::from_slice(&[2, 3], &[1., 0., 2., 0., 1., 3.]), true);
        let b = Variable::from_array(NdArray::from_slice(&[3], &[10., 20., 30.]), true);
        let y = affine(&x, &w, Some(&b));
        assert_eq!(y.dims(), vec![1, 3]);
        assert_eq!(y.data().data(), &[11., 22., 38.]);
    }

    #[test]
    fn affine_flattens_trailing_axes() {
        let mut rng = Rng::new(30);
        let x = rand_leaf(&mut rng, &[2, 3, 4]); // flattened to [2, 12]
        let w = rand_leaf(&mut rng, &[12, 5]);
        let y = affine(&x, &w, None);
        assert_eq!(y.dims(), vec![2, 5]);
    }

    #[test]
    fn affine_gradcheck_with_bias() {
        let mut rng = Rng::new(31);
        let x = rand_leaf(&mut rng, &[3, 4]);
        let w = rand_leaf(&mut rng, &[4, 2]);
        let b = rand_leaf(&mut rng, &[2]);
        let build = || mean_all(&affine(&x, &w, Some(&b)));
        check_grads(&[&x, &w, &b], &build, 1e-3, 1e-2);
    }

    #[test]
    fn affine_gradcheck_no_bias_4d_input() {
        let mut rng = Rng::new(32);
        let x = rand_leaf(&mut rng, &[2, 2, 2, 2]);
        let w = rand_leaf(&mut rng, &[8, 3]);
        let build = || mean_all(&affine(&x, &w, None));
        check_grads(&[&x, &w], &build, 1e-3, 1e-2);
    }

    #[test]
    fn listing1_forward_backward() {
        // Listing 1: x = nn.Variable((16, 10)); y = PF.affine(x, 5)
        let mut rng = Rng::new(33);
        let x = Variable::from_array(rng.rand(&[16, 10], 0.0, 1.0), true);
        let w = rand_leaf(&mut rng, &[10, 5]);
        let b = Variable::from_array(NdArray::zeros(&[5]), true);
        let y = affine(&x, &w, Some(&b));
        y.forward();
        y.backward();
        assert_eq!(y.dims(), vec![16, 5]);
        assert!(x.grad().norm2() > 0.0);
        assert!(w.grad().norm2() > 0.0);
        assert_eq!(b.grad().data(), &[16.0f32; 5]); // seed ones summed over batch
    }
}
