//! `F::*` — the paper's second building block: "mathematical operations
//! that can be applied to variables" (§2.1). Every function records a
//! node on the tape (forward + backward closures) *tagged with its
//! [`crate::nnp::Op`] descriptor*, so graphs built from these run in
//! both dynamic (define-by-run) and static-reuse modes — and can be
//! exported directly with `nnp::trace` (no builder required).
//!
//! Conventions (matching NNabla):
//! - image tensors are NCHW;
//! - `affine`/losses treat axis 0 as the batch axis (`base_axis=1`);
//! - losses return per-example values; use [`mean_all`] to reduce.

pub mod activation;
pub mod affine;
pub mod convolution;
pub mod dropout;
pub mod elementwise;
pub mod gradcheck;
pub mod loss;
pub mod normalization;
pub mod pooling;
pub mod reduction;
pub mod softmax;
pub mod tensor_ops;

pub use activation::{elu, gelu, leaky_relu, relu, sigmoid, softplus, swish, tanh};
pub use affine::affine;
pub use convolution::{convolution, deconvolution};
pub use dropout::{dropout, dropout_inference};
pub use elementwise::{
    add, add_scalar, div, exp, log, mul, mul_scalar, neg, pow_scalar, stop_gradient, sub,
};
pub use loss::{sigmoid_cross_entropy, softmax_cross_entropy, squared_error};
pub use normalization::{batch_normalization, layer_normalization};
pub use pooling::{average_pooling, global_average_pooling, max_pooling};
pub use reduction::{mean_all, mean_axis, sum_all, sum_axis};
pub use softmax::{log_softmax, softmax};
pub use tensor_ops::{
    broadcast_to, concat, embed, identity, reshape, reshape_spec, slice_axis, transpose,
};
