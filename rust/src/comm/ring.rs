//! Ring all-reduce with a **deterministic segment reduction order**.
//!
//! Classic ring all-reduce starts segment `s` at rank `s % N`, which
//! makes the float association depend on the segment index — results
//! differ from the sequential sum and between world sizes. Here every
//! segment's reduce flows in increasing rank order around the ring:
//!
//! ```text
//! rank 0: (0 + x_0) ──▶ rank 1: (+ x_1) ──▶ ... ──▶ rank N-1: (+ x_{N-1}, ÷N)
//!                                                        │ finals
//!            rank 0 ◀── rank N-1          rank 0 ──▶ 1 ──▶ ... ──▶ N-2
//! ```
//!
//! so each element is reduced as `((0 + x_0) + x_1) + ... + x_{N-1}`
//! — exactly the fold of the thread backend
//! ([`super::collective::Communicator`]) and of a sequential
//! simulation of the same data-parallel step. Rank `N-1` finalizes
//! (including the `1/N` division) and the finals circulate the same
//! ring edges back to every rank, so all ranks store *identical
//! bytes*. Pipelining comes from cutting the buffer into segments:
//! while segment `s` is being finalized downstream, segment `s+1` is
//! still being reduced upstream, so the wire and the adders stay busy
//! — and each rank moves ~2× the buffer regardless of N.
//!
//! Optional fp16 wire compression halves the bytes per hop: each hop
//! decodes the incoming f16 partial to f32, adds its own f32
//! contribution, and re-encodes — accumulation stays in f32 and the
//! reduction order is unchanged, so the result is still deterministic
//! and identical on every rank (rank `N-1` stores its own final
//! *through* the f16 grid for exact agreement with the decoders).
//!
//! Everything here is transport-agnostic over the [`Link`] trait:
//! `comm::net` drives it over TCP sockets, and the unit tests drive
//! it over in-process channels.

use super::CommError;
use crate::utils::half::{f16_bits_to_f32, f32_to_f16_bits};

/// Default ring segment length (f32 elements): 256 KiB frames, small
/// enough to pipeline, large enough to amortize framing.
pub const DEFAULT_SEGMENT_ELEMS: usize = 64 * 1024;

/// Hard cap on elements per segment — bounds every frame allocation.
pub const MAX_SEGMENT_ELEMS: usize = 1 << 21;

/// Wire payload of one segment: f32 (exact) or f16 (compressed hops).
#[derive(Debug, Clone, PartialEq)]
pub enum Wire {
    F32(Vec<f32>),
    F16(Vec<u16>),
}

impl Wire {
    pub fn len(&self) -> usize {
        match self {
            Wire::F32(v) => v.len(),
            Wire::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Decode to f32 working values.
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Wire::F32(v) => v.clone(),
            Wire::F16(v) => v.iter().map(|&h| f16_bits_to_f32(h)).collect(),
        }
    }
}

fn encode(vals: &[f32], fp16: bool) -> Wire {
    if fp16 {
        Wire::F16(vals.iter().map(|&v| f32_to_f16_bits(v)).collect())
    } else {
        Wire::F32(vals.to_vec())
    }
}

/// Message kinds moving around the ring.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Reduce-phase running sum, flowing rank 0 → N-1.
    Partial,
    /// Finalized segment, flowing N-1 → 0 → 1 → ... → N-2.
    Final,
    /// Broadcast chunk, flowing 0 → 1 → ... → N-1.
    Bcast,
}

/// One framed segment. `op` is the per-communicator collective
/// counter and `seg` the segment index — both validated on receive so
/// a desynchronized peer surfaces a typed error, not silent
/// corruption.
#[derive(Debug, Clone, PartialEq)]
pub struct Msg {
    pub kind: MsgKind,
    pub op: u64,
    pub seg: u32,
    pub data: Wire,
}

/// A rank's pair of ring edges: `send` goes to rank `(r+1) % N`,
/// `recv` comes from `(r-1+N) % N`. `send` must be non-blocking with
/// respect to the protocol loop (the TCP impl queues to a writer
/// thread) — the ring drivers below rely on that for deadlock
/// freedom. `recv` must honor the transport's deadline and return
/// [`CommError::Timeout`] rather than hang.
pub trait Link {
    fn send(&mut self, msg: Msg) -> Result<(), CommError>;
    fn recv(&mut self) -> Result<Msg, CommError>;
}

/// Byte ranges of the `ceil(len / seg_elems)` segments.
pub fn segments(len: usize, seg_elems: usize) -> Vec<std::ops::Range<usize>> {
    let seg = seg_elems.clamp(1, MAX_SEGMENT_ELEMS);
    (0..len.div_ceil(seg)).map(|i| i * seg..((i + 1) * seg).min(len)).collect()
}

fn check(m: &Msg, kind: MsgKind, op: u64, seg: usize, len: usize) -> Result<(), CommError> {
    if m.kind != kind || m.op != op || m.seg as usize != seg {
        return Err(CommError::Protocol(format!(
            "out-of-order ring message: got {:?} op {} seg {}, expected {:?} op {op} seg {seg}",
            m.kind, m.op, m.seg, kind
        )));
    }
    if m.data.len() != len {
        return Err(CommError::SizeMismatch { expected: len, got: m.data.len() });
    }
    Ok(())
}

/// Drive one ring all-reduce of `buf` for this rank. Every rank must
/// call with the same `op`, buffer length, `division`, `fp16` and
/// `seg_elems`. On success all ranks hold identical bytes equal to
/// the rank-order sequential fold (exactly, when `fp16` is off).
pub fn all_reduce(
    rank: usize,
    size: usize,
    op: u64,
    buf: &mut [f32],
    division: bool,
    fp16: bool,
    seg_elems: usize,
    link: &mut dyn Link,
) -> Result<(), CommError> {
    if size == 1 || buf.is_empty() {
        return Ok(());
    }
    let segs = segments(buf.len(), seg_elems);
    let scale = 1.0 / size as f32;
    let succ_is_last = (rank + 1) % size == size - 1;

    // Rank 0 originates every partial up-front: sends are queued, not
    // blocking, so injecting all segments before draining finals
    // cannot deadlock and keeps the pipeline full.
    if rank == 0 {
        for (s, r) in segs.iter().enumerate() {
            // `0.0 + x` seeds the fold exactly like the thread
            // backend's zero-initialized accumulator (it also
            // normalizes -0.0 the same way).
            let vals: Vec<f32> = buf[r.clone()].iter().map(|&v| 0.0 + v).collect();
            link.send(Msg { kind: MsgKind::Partial, op, seg: s as u32, data: encode(vals.as_slice(), fp16) })?;
        }
    }

    let need_partials = if rank == 0 { 0 } else { segs.len() };
    let need_finals = if rank == size - 1 { 0 } else { segs.len() };
    let (mut pdone, mut fdone) = (0usize, 0usize);
    while pdone < need_partials || fdone < need_finals {
        let m = link.recv()?;
        match m.kind {
            MsgKind::Partial if rank != 0 => {
                let range = segs[pdone].clone();
                check(&m, MsgKind::Partial, op, pdone, range.len())?;
                let mut vals = m.data.to_f32();
                for (v, mine) in vals.iter_mut().zip(&buf[range.clone()]) {
                    *v += *mine;
                }
                if rank == size - 1 {
                    if division {
                        for v in vals.iter_mut() {
                            *v *= scale;
                        }
                    }
                    let data = encode(&vals, fp16);
                    // store exactly what every decoder will see: on
                    // the fp16 wire that means our own final goes
                    // through the f16 grid too
                    buf[range].copy_from_slice(&data.to_f32());
                    link.send(Msg { kind: MsgKind::Final, op, seg: pdone as u32, data })?;
                } else {
                    link.send(Msg {
                        kind: MsgKind::Partial,
                        op,
                        seg: pdone as u32,
                        data: encode(&vals, fp16),
                    })?;
                }
                pdone += 1;
            }
            MsgKind::Final if rank != size - 1 => {
                let range = segs[fdone].clone();
                check(&m, MsgKind::Final, op, fdone, range.len())?;
                buf[range].copy_from_slice(&m.data.to_f32());
                if !succ_is_last {
                    link.send(m)?;
                }
                fdone += 1;
            }
            _ => {
                return Err(CommError::Protocol(format!(
                    "unexpected {:?} message at rank {rank}",
                    m.kind
                )))
            }
        }
    }
    Ok(())
}

/// Broadcast rank 0's `buf` along the chain 0 → 1 → ... → N-1. Always
/// f32 on the wire: weight broadcast must be exact even when gradient
/// hops are compressed (an f16-rounded initial sync would silently
/// diverge the replicas).
pub fn bcast(
    rank: usize,
    size: usize,
    op: u64,
    buf: &mut [f32],
    seg_elems: usize,
    link: &mut dyn Link,
) -> Result<(), CommError> {
    if size == 1 || buf.is_empty() {
        return Ok(());
    }
    let segs = segments(buf.len(), seg_elems);
    if rank == 0 {
        for (s, r) in segs.iter().enumerate() {
            link.send(Msg {
                kind: MsgKind::Bcast,
                op,
                seg: s as u32,
                data: Wire::F32(buf[r.clone()].to_vec()),
            })?;
        }
    } else {
        for (s, r) in segs.iter().enumerate() {
            let m = link.recv()?;
            check(&m, MsgKind::Bcast, op, s, r.len())?;
            buf[r.clone()].copy_from_slice(&m.data.to_f32());
            if rank != size - 1 {
                link.send(m)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{channel, Receiver, Sender};
    use std::time::Duration;

    /// In-process ring edges over channels (unit-test transport).
    struct ChanLink {
        tx: Sender<Msg>,
        rx: Receiver<Msg>,
    }

    impl Link for ChanLink {
        fn send(&mut self, msg: Msg) -> Result<(), CommError> {
            self.tx.send(msg).map_err(|_| CommError::Io("peer gone".into()))
        }
        fn recv(&mut self) -> Result<Msg, CommError> {
            self.rx
                .recv_timeout(Duration::from_secs(10))
                .map_err(|_| CommError::Timeout { what: "test recv", ms: 10_000 })
        }
    }

    fn ring_links(n: usize) -> Vec<ChanLink> {
        let chans: Vec<(Sender<Msg>, Receiver<Msg>)> = (0..n).map(|_| channel()).collect();
        let mut txs: Vec<Option<Sender<Msg>>> = chans.iter().map(|(t, _)| Some(t.clone())).collect();
        chans
            .into_iter()
            .enumerate()
            .map(|(r, (_, rx))| ChanLink { tx: txs[(r + 1) % n].take().expect("succ tx"), rx })
            .collect()
    }

    fn run_ring(
        n: usize,
        data: Vec<Vec<f32>>,
        division: bool,
        fp16: bool,
        seg_elems: usize,
    ) -> Vec<Result<Vec<f32>, CommError>> {
        let links = ring_links(n);
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(r, mut link)| {
                let mut buf = data[r].clone();
                std::thread::spawn(move || {
                    all_reduce(r, n, 7, &mut buf, division, fp16, seg_elems, &mut link)
                        .map(|_| buf)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("ring worker")).collect()
    }

    fn sequential_fold(data: &[Vec<f32>], division: bool) -> Vec<f32> {
        let n = data.len();
        let mut acc = vec![0.0f32; data[0].len()];
        for d in data {
            for (a, v) in acc.iter_mut().zip(d) {
                *a += *v;
            }
        }
        if division {
            for a in acc.iter_mut() {
                *a *= 1.0 / n as f32;
            }
        }
        acc
    }

    #[test]
    fn ring_matches_sequential_fold_bitwise() {
        for n in [2usize, 3, 4, 5] {
            let data: Vec<Vec<f32>> = (0..n)
                .map(|r| (0..37).map(|i| ((i * (r + 1)) as f32).sin() * 3.7).collect())
                .collect();
            let expect = sequential_fold(&data, true);
            for seg in [4usize, 16, 64] {
                let results = run_ring(n, data.clone(), true, false, seg);
                for res in &results {
                    let got = res.as_ref().expect("ring ok");
                    let a: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = expect.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "n={n} seg={seg}");
                }
            }
        }
    }

    #[test]
    fn fp16_wire_close_and_all_ranks_identical() {
        let n = 4;
        let data: Vec<Vec<f32>> =
            (0..n).map(|r| (0..50).map(|i| (i as f32 * 0.01) + r as f32 * 0.1).collect()).collect();
        let expect = sequential_fold(&data, true);
        let results = run_ring(n, data, true, true, 16);
        let first = results[0].as_ref().expect("ring ok").clone();
        for res in &results {
            let got = res.as_ref().expect("ring ok");
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                first.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "ranks must agree bitwise even on the fp16 wire"
            );
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() <= 1e-3, "fp16 wire drifted: {g} vs {e}");
            }
        }
    }

    #[test]
    fn bcast_chains_rank0_values() {
        let n = 4;
        let links = ring_links(n);
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(r, mut link)| {
                std::thread::spawn(move || {
                    let mut buf =
                        if r == 0 { vec![1.0f32, 2.0, 3.0, 4.0, 5.0] } else { vec![0.0f32; 5] };
                    bcast(r, n, 3, &mut buf, 2, &mut link).map(|_| buf)
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().expect("worker").expect("bcast"), vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        }
    }

    #[test]
    fn mismatched_lengths_surface_typed_error() {
        let n = 2;
        let links = ring_links(n);
        let handles: Vec<_> = links
            .into_iter()
            .enumerate()
            .map(|(r, mut link)| {
                std::thread::spawn(move || {
                    let mut buf = vec![1.0f32; if r == 0 { 8 } else { 5 }];
                    all_reduce(r, n, 0, &mut buf, false, false, 64, &mut link)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().expect("worker")).collect();
        assert!(
            results.iter().any(|r| matches!(
                r,
                Err(CommError::SizeMismatch { .. }) | Err(CommError::Timeout { .. })
            )),
            "length disagreement must surface typed errors: {results:?}"
        );
    }

    #[test]
    fn segments_cover_exactly() {
        assert_eq!(segments(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(segments(4, 4), vec![0..4]);
        assert_eq!(segments(0, 4), Vec::<std::ops::Range<usize>>::new());
        // zero-size request clamps instead of dividing by zero
        assert_eq!(segments(3, 0), vec![0..1, 1..2, 2..3]);
    }
}
