//! Gradient bucketing and backward/reduce overlap — the throughput
//! half of distributed data parallelism (paper §2.3: "speedy
//! computation on distributed setting").
//!
//! Small parameters make terrible collectives: per-message latency
//! dominates and the ring never fills. [`plan_buckets`] coalesces
//! parameters into ~4 MiB groups, ordered so each bucket's members
//! finish their gradients at about the same time during backward
//! (parameters complete in roughly reverse registration order — the
//! output layer's gradient lands first). [`Reducer`] then runs the
//! collectives on a dedicated communication thread: the trainer
//! enqueues a bucket the moment its last gradient lands (via the
//! autodiff tape hook, see `trainer`) and keeps running backward
//! while the ring moves bytes. Time the comm thread spends busy
//! *while a backward pass is in flight* is the overlap win, and is
//! accounted to `monitor::metrics::comm().overlap_ns_hidden`.
//!
//! Determinism is untouched by any of this: buckets partition the
//! parameter list in a fixed order, each bucket's all-reduce uses the
//! deterministic rank-order sum, and the trainer scatters results
//! back by bucket id — so overlap-on and overlap-off produce
//! bit-identical updates.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use super::{Collective, CommError};
use crate::monitor::metrics;

/// Default bucket capacity: ~4 MiB of f32 gradients, the sweet spot
/// between per-collective latency and overlap granularity.
pub const DEFAULT_BUCKET_BYTES: usize = 4 << 20;

/// Partition parameter indices `0..sizes.len()` into buckets of at
/// most `cap_bytes` (4 bytes per element), walking indices in
/// **reverse** order so bucket 0 holds the parameters whose gradients
/// land first during backward. A parameter larger than the cap gets a
/// bucket of its own. Every index appears in exactly one bucket; the
/// plan depends only on `(sizes, cap_bytes)`, so all ranks agree.
pub fn plan_buckets(sizes: &[usize], cap_bytes: usize) -> Vec<Vec<usize>> {
    let cap_elems = (cap_bytes / 4).max(1);
    let mut buckets: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_elems = 0usize;
    for idx in (0..sizes.len()).rev() {
        let n = sizes[idx];
        if !cur.is_empty() && cur_elems + n > cap_elems {
            buckets.push(std::mem::take(&mut cur));
            cur_elems = 0;
        }
        cur.push(idx);
        cur_elems += n;
    }
    if !cur.is_empty() {
        buckets.push(cur);
    }
    buckets
}

enum Cmd {
    Reduce { id: usize, data: Vec<f32>, division: bool },
    Bcast { data: Vec<f32> },
    Gather { v: f32 },
}

enum Reply {
    Reduced { id: usize, data: Vec<f32> },
    Bcasted { data: Vec<f32> },
    Gathered { vals: Vec<f32> },
}

/// A [`Collective`] driven from a dedicated communication thread.
///
/// Commands are processed strictly FIFO — both backends require every
/// rank to issue the same collective sequence, and the trainer
/// guarantees a deterministic enqueue order (bucket fire order is
/// data-independent; see `trainer`). Replies arrive in the same
/// order, tagged with the caller's bucket id.
pub struct Reducer {
    rank: usize,
    size: usize,
    tx: Option<Sender<Cmd>>,
    rx: Receiver<Result<Reply, CommError>>,
    in_backward: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Reducer {
    /// Move `comm` onto a background thread and return the handle the
    /// trainer talks to.
    pub fn spawn<C: Collective + 'static>(comm: C) -> Reducer {
        let (rank, size) = (comm.rank(), comm.size());
        let (cmd_tx, cmd_rx): (Sender<Cmd>, Receiver<Cmd>) = channel();
        let (rep_tx, rep_rx) = channel();
        let in_backward = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&in_backward);
        let handle = std::thread::Builder::new()
            .name(format!("nnl-reducer-r{rank}"))
            .spawn(move || {
                let mut comm = comm;
                for cmd in cmd_rx {
                    let t0 = Instant::now();
                    let overlappable = matches!(cmd, Cmd::Reduce { .. });
                    let reply = match cmd {
                        Cmd::Reduce { id, mut data, division } => comm
                            .all_reduce_flat(&mut data, division)
                            .map(|()| Reply::Reduced { id, data }),
                        Cmd::Bcast { mut data } => {
                            comm.bcast_flat(&mut data).map(|()| Reply::Bcasted { data })
                        }
                        Cmd::Gather { v } => {
                            comm.all_gather_scalar(v).map(|vals| Reply::Gathered { vals })
                        }
                    };
                    // busy time that coincided with backward is the
                    // communication the bucketing actually hid
                    if overlappable && flag.load(Ordering::Relaxed) {
                        metrics::comm()
                            .overlap_ns_hidden
                            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    if rep_tx.send(reply).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn reducer thread");
        Reducer {
            rank,
            size,
            tx: Some(cmd_tx),
            rx: rep_rx,
            in_backward,
            handle: Some(handle),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Mark the start of a backward pass: comm-thread busy time now
    /// counts as hidden.
    pub fn begin_backward(&self) {
        self.in_backward.store(true, Ordering::Relaxed);
    }

    /// Backward finished; subsequent comm time is exposed, not hidden.
    pub fn end_backward(&self) {
        self.in_backward.store(false, Ordering::Relaxed);
    }

    fn tx(&self) -> &Sender<Cmd> {
        self.tx.as_ref().expect("reducer not shut down")
    }

    fn gone() -> CommError {
        CommError::Io("reducer comm thread gone".into())
    }

    /// Enqueue one bucket's flattened gradients for all-reduce.
    /// Returns immediately; collect the result with [`next_reduced`].
    ///
    /// [`next_reduced`]: Reducer::next_reduced
    pub fn reduce(&self, id: usize, data: Vec<f32>, division: bool) -> Result<(), CommError> {
        self.tx().send(Cmd::Reduce { id, data, division }).map_err(|_| Self::gone())
    }

    /// Block for the next finished reduce, in enqueue order.
    pub fn next_reduced(&self) -> Result<(usize, Vec<f32>), CommError> {
        match self.rx.recv().map_err(|_| Self::gone())?? {
            Reply::Reduced { id, data } => Ok((id, data)),
            _ => Err(CommError::Protocol("reducer reply out of order".into())),
        }
    }

    /// Synchronous broadcast of rank 0's values (initial weight sync).
    pub fn bcast_flat(&self, data: Vec<f32>) -> Result<Vec<f32>, CommError> {
        self.tx().send(Cmd::Bcast { data }).map_err(|_| Self::gone())?;
        match self.rx.recv().map_err(|_| Self::gone())?? {
            Reply::Bcasted { data } => Ok(data),
            _ => Err(CommError::Protocol("reducer reply out of order".into())),
        }
    }

    /// Synchronous all-gather of one scalar per rank (loss reporting).
    pub fn gather(&self, v: f32) -> Result<Vec<f32>, CommError> {
        self.tx().send(Cmd::Gather { v }).map_err(|_| Self::gone())?;
        match self.rx.recv().map_err(|_| Self::gone())?? {
            Reply::Gathered { vals } => Ok(vals),
            _ => Err(CommError::Protocol("reducer reply out of order".into())),
        }
    }

    /// Stop the comm thread and release the communicator.
    pub fn shutdown(mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Reducer {
    fn drop(&mut self) {
        self.tx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommHub;
    use crate::utils::prop;

    #[test]
    fn buckets_partition_reverse_order_under_cap() {
        let sizes = [10, 3000, 5, 5, 2000, 1];
        let cap = 4096 * 4; // 4096 elems
        let plan = plan_buckets(&sizes, cap);
        // every index exactly once
        let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        // reverse walk: first bucket starts at the last index
        assert_eq!(plan[0][0], 5);
        // cap respected unless a bucket is a single oversize param
        for b in &plan {
            let elems: usize = b.iter().map(|&i| sizes[i]).sum();
            assert!(elems * 4 <= cap || b.len() == 1, "bucket {b:?} breaks cap");
        }
    }

    #[test]
    fn oversize_param_gets_own_bucket() {
        let sizes = [10, 9999, 10];
        let plan = plan_buckets(&sizes, 100 * 4);
        assert!(plan.contains(&vec![1]));
    }

    #[test]
    fn bucket_plan_properties() {
        prop::check(
            0xB0C4E7,
            200,
            |rng: &mut crate::tensor::Rng| {
                let n = rng.below(20);
                let sizes: Vec<usize> = (0..n).map(|_| rng.below(5000)).collect();
                let cap = (1 + rng.below(4000)) * 4;
                (sizes, cap)
            },
            |(sizes, cap)| {
                let plan = plan_buckets(sizes, *cap);
                let mut seen: Vec<usize> = plan.iter().flatten().copied().collect();
                seen.sort_unstable();
                if seen != (0..sizes.len()).collect::<Vec<_>>() {
                    return Err(format!("not a partition: {seen:?}"));
                }
                for b in &plan {
                    if b.is_empty() {
                        return Err("empty bucket".into());
                    }
                    let elems: usize = b.iter().map(|&i| sizes[i]).sum();
                    if elems * 4 > *cap && b.len() > 1 {
                        return Err(format!("multi-param bucket over cap: {b:?}"));
                    }
                }
                // determinism: same inputs, same plan
                if plan != plan_buckets(sizes, *cap) {
                    return Err("plan not deterministic".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn reducer_pipelines_buckets_in_order() {
        let world = 3;
        let mut hub = CommHub::new(world);
        let comms: Vec<_> =
            (0..world).map(|r| hub.communicator(r).expect("fresh rank")).collect();
        let handles: Vec<_> = comms
            .into_iter()
            .map(|comm| {
                std::thread::spawn(move || {
                    let rank = comm.rank();
                    let red = Reducer::spawn(comm);
                    red.begin_backward();
                    // enqueue two buckets before collecting anything
                    red.reduce(0, vec![rank as f32 + 1.0; 4], true).expect("enqueue");
                    red.reduce(1, vec![10.0 * (rank as f32 + 1.0); 2], false).expect("enqueue");
                    let a = red.next_reduced().expect("bucket 0");
                    let b = red.next_reduced().expect("bucket 1");
                    red.end_backward();
                    let g = red.gather(rank as f32).expect("gather");
                    red.shutdown();
                    (a, b, g)
                })
            })
            .collect();
        for h in handles {
            let ((id0, d0), (id1, d1), g) = h.join().expect("worker");
            assert_eq!(id0, 0);
            assert_eq!(id1, 1);
            // mean of 1,2,3 = 2; sum of 10,20,30 = 60
            assert_eq!(d0, vec![2.0; 4]);
            assert_eq!(d1, vec![60.0; 2]);
            assert_eq!(g, vec![0.0, 1.0, 2.0]);
        }
    }
}
