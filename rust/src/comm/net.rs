//! Multi-process communicator over TCP — the real-world backend of
//! the [`super::Collective`] surface (paper §2.3: multi-node
//! data-parallel training; our Fig. 3 reproduction runs it over
//! loopback).
//!
//! ## Rendezvous
//!
//! Rank 0 listens on the rendezvous address. Every other rank
//! connects and sends `HELLO{rank, size, ring_addr}`; rank 0 collects
//! all `size - 1` hellos (validating version, size agreement, rank
//! range and duplicates), then replies to each with the full
//! `PEERS{addrs}` table. Each rank then dials its ring **successor**
//! `(rank + 1) % size` and accepts one connection from its
//! **predecessor** — two sockets per rank, the only edges the
//! [`super::ring`] collectives ever use.
//!
//! ## Wire format
//!
//! House style (`serve::net`): length-prefixed frames, a version
//! byte, then a tag — with a bounds-checked reader on the way in, so
//! hostile or damaged frames surface typed [`CommError`]s and no
//! allocation ever trusts a claimed length (claims are capped by
//! [`MAX_FRAME`] / [`ring::MAX_SEGMENT_ELEMS`] before any buffer is
//! sized).
//!
//! ## Liveness
//!
//! Every blocking step — rendezvous accept, peer dial, frame read —
//! runs under a deadline ([`NetOptions::connect_timeout`] during
//! setup, [`NetOptions::step_deadline`] per collective). A dropped
//! peer therefore surfaces as [`CommError::Timeout`] or
//! [`CommError::Io`] at every surviving rank within the deadline,
//! never as a hang. Outbound frames go through a per-rank writer
//! thread, so the protocol loop never blocks on a full socket buffer
//! (the deadlock-freedom assumption of [`ring::Link::send`]). The
//! chaos points `comm.connect` / `comm.send` / `comm.recv`
//! ([`crate::faults`]) inject failures on exactly these paths.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::ring::{self, Msg, MsgKind, Wire};
use super::{Collective, CommError};
use crate::faults::{self, Point};
use crate::monitor::metrics;

/// Comm wire protocol version (frame byte 0).
pub const COMM_VERSION: u8 = 1;

/// Hard cap on a comm frame: the largest legal segment
/// (`ring::MAX_SEGMENT_ELEMS` f32s) plus headroom for headers. Length
/// claims beyond this are rejected before any allocation.
pub const MAX_FRAME: usize = ring::MAX_SEGMENT_ELEMS * 4 + 256;

/// Cap on embedded strings (peer addresses, reject reasons).
const MAX_STR: usize = 1024;

const TAG_HELLO: u8 = 1;
const TAG_PEERS: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_RING: u8 = 4;
const TAG_SEG: u8 = 5;

/// Configuration of the TCP communicator.
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Deadline for one whole collective (the "never hang" bound).
    pub step_deadline: Duration,
    /// Deadline for rendezvous + ring wiring at startup.
    pub connect_timeout: Duration,
    /// Ring segment length in f32 elements (pipelining granularity).
    pub segment_elems: usize,
    /// Compress gradient hops to f16 on the wire (all-reduce only;
    /// broadcasts stay exact f32). Accumulation stays f32 and
    /// deterministic; see `comm::ring`.
    pub fp16_wire: bool,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            step_deadline: Duration::from_secs(30),
            connect_timeout: Duration::from_secs(10),
            segment_elems: ring::DEFAULT_SEGMENT_ELEMS,
            fp16_wire: false,
        }
    }
}

// ------------------------------------------------------------- codec

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Bounds-checked frame reader: every accessor validates remaining
/// length before touching bytes, and the only allocations are sized
/// by *validated* counts.
struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Self {
        Rd { b, pos: 0 }
    }

    fn truncated(&self, what: &str) -> CommError {
        CommError::Protocol(format!("truncated frame while reading {what}"))
    }

    fn u8(&mut self, what: &str) -> Result<u8, CommError> {
        if self.pos >= self.b.len() {
            return Err(self.truncated(what));
        }
        self.pos += 1;
        Ok(self.b[self.pos - 1])
    }

    fn u32(&mut self, what: &str) -> Result<u32, CommError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.truncated(what));
        }
        let v = u32::from_le_bytes(self.b[self.pos..self.pos + 4].try_into().expect("4 bytes"));
        self.pos += 4;
        Ok(v)
    }

    fn u64(&mut self, what: &str) -> Result<u64, CommError> {
        if self.pos + 8 > self.b.len() {
            return Err(self.truncated(what));
        }
        let v = u64::from_le_bytes(self.b[self.pos..self.pos + 8].try_into().expect("8 bytes"));
        self.pos += 8;
        Ok(v)
    }

    fn bytes(&mut self, n: usize, what: &str) -> Result<&'a [u8], CommError> {
        if self.pos + n > self.b.len() {
            return Err(self.truncated(what));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn str_(&mut self, what: &str) -> Result<String, CommError> {
        let n = self.u32(what)? as usize;
        if n > MAX_STR {
            return Err(CommError::Protocol(format!(
                "string length claim {n} exceeds cap {MAX_STR} in {what}"
            )));
        }
        let raw = self.bytes(n, what)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| CommError::Protocol(format!("non-UTF8 string in {what}")))
    }

    fn done(&self) -> Result<(), CommError> {
        if self.pos != self.b.len() {
            return Err(CommError::Protocol(format!(
                "{} trailing bytes after frame payload",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn check_version(rd: &mut Rd, what: &str) -> Result<(), CommError> {
    let v = rd.u8(what)?;
    if v != COMM_VERSION {
        return Err(CommError::Protocol(format!(
            "unsupported comm protocol version {v} (expected {COMM_VERSION}) in {what}"
        )));
    }
    Ok(())
}

/// Encode one ring segment message as a frame payload (no length
/// prefix).
pub fn encode_seg(m: &Msg) -> Vec<u8> {
    let (dtype, n, data_bytes): (u8, usize, Vec<u8>) = match &m.data {
        Wire::F32(v) => {
            let mut b = Vec::with_capacity(v.len() * 4);
            for x in v {
                b.extend_from_slice(&x.to_le_bytes());
            }
            (0, v.len(), b)
        }
        Wire::F16(v) => {
            let mut b = Vec::with_capacity(v.len() * 2);
            for x in v {
                b.extend_from_slice(&x.to_le_bytes());
            }
            (1, v.len(), b)
        }
    };
    let kind = match m.kind {
        MsgKind::Partial => 0u8,
        MsgKind::Final => 1,
        MsgKind::Bcast => 2,
    };
    let mut out = Vec::with_capacity(data_bytes.len() + 24);
    out.push(COMM_VERSION);
    out.push(TAG_SEG);
    out.push(kind);
    out.push(dtype);
    put_u64(&mut out, m.op);
    put_u32(&mut out, m.seg);
    put_u32(&mut out, n as u32);
    out.extend_from_slice(&data_bytes);
    out
}

/// Decode one ring segment message from a frame payload. Hostile
/// element-count claims are rejected against
/// [`ring::MAX_SEGMENT_ELEMS`] *and* the actual payload length before
/// any buffer is allocated.
pub fn decode_seg(payload: &[u8]) -> Result<Msg, CommError> {
    let mut rd = Rd::new(payload);
    check_version(&mut rd, "segment")?;
    let tag = rd.u8("segment tag")?;
    if tag != TAG_SEG {
        return Err(CommError::Protocol(format!("expected segment frame, got tag {tag}")));
    }
    let kind = match rd.u8("segment kind")? {
        0 => MsgKind::Partial,
        1 => MsgKind::Final,
        2 => MsgKind::Bcast,
        k => return Err(CommError::Protocol(format!("unknown segment kind {k}"))),
    };
    let dtype = rd.u8("segment dtype")?;
    let op = rd.u64("segment op")?;
    let seg = rd.u32("segment index")?;
    let n = rd.u32("segment element count")? as usize;
    if n > ring::MAX_SEGMENT_ELEMS {
        return Err(CommError::Protocol(format!(
            "segment element claim {n} exceeds cap {}",
            ring::MAX_SEGMENT_ELEMS
        )));
    }
    let data = match dtype {
        0 => {
            let raw = rd.bytes(n * 4, "f32 segment data")?;
            Wire::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
                    .collect(),
            )
        }
        1 => {
            let raw = rd.bytes(n * 2, "f16 segment data")?;
            Wire::F16(
                raw.chunks_exact(2)
                    .map(|c| u16::from_le_bytes(c.try_into().expect("2 bytes")))
                    .collect(),
            )
        }
        d => return Err(CommError::Protocol(format!("unknown segment dtype {d}"))),
    };
    rd.done()?;
    Ok(Msg { kind, op, seg, data })
}

fn encode_hello(rank: usize, size: usize, ring_addr: &str) -> Vec<u8> {
    let mut out = vec![COMM_VERSION, TAG_HELLO];
    put_u32(&mut out, rank as u32);
    put_u32(&mut out, size as u32);
    put_str(&mut out, ring_addr);
    out
}

fn decode_hello(payload: &[u8]) -> Result<(usize, usize, String), CommError> {
    let mut rd = Rd::new(payload);
    check_version(&mut rd, "hello")?;
    let tag = rd.u8("hello tag")?;
    if tag != TAG_HELLO {
        return Err(CommError::Protocol(format!("expected hello frame, got tag {tag}")));
    }
    let rank = rd.u32("hello rank")? as usize;
    let size = rd.u32("hello size")? as usize;
    let addr = rd.str_("hello ring address")?;
    rd.done()?;
    Ok((rank, size, addr))
}

fn encode_peers(addrs: &[String]) -> Vec<u8> {
    let mut out = vec![COMM_VERSION, TAG_PEERS];
    put_u32(&mut out, addrs.len() as u32);
    for a in addrs {
        put_str(&mut out, a);
    }
    out
}

fn encode_reject(reason: &str) -> Vec<u8> {
    let mut out = vec![COMM_VERSION, TAG_REJECT];
    put_str(&mut out, reason);
    out
}

/// PEERS (the table) or REJECT (a reason) — the two legal rendezvous
/// replies.
fn decode_reply(payload: &[u8]) -> Result<Vec<String>, CommError> {
    let mut rd = Rd::new(payload);
    check_version(&mut rd, "rendezvous reply")?;
    match rd.u8("reply tag")? {
        TAG_PEERS => {
            let n = rd.u32("peer count")? as usize;
            if n > 4096 {
                return Err(CommError::Protocol(format!("peer count claim {n} exceeds cap 4096")));
            }
            let mut addrs = Vec::with_capacity(n.min(64));
            for i in 0..n {
                addrs.push(rd.str_(&format!("peer address {i}"))?);
            }
            rd.done()?;
            Ok(addrs)
        }
        TAG_REJECT => {
            let reason = rd.str_("reject reason")?;
            Err(CommError::Rendezvous(reason))
        }
        t => Err(CommError::Protocol(format!("unexpected rendezvous reply tag {t}"))),
    }
}

fn encode_ring_hello(from: usize) -> Vec<u8> {
    let mut out = vec![COMM_VERSION, TAG_RING];
    put_u32(&mut out, from as u32);
    out
}

fn decode_ring_hello(payload: &[u8]) -> Result<usize, CommError> {
    let mut rd = Rd::new(payload);
    check_version(&mut rd, "ring handshake")?;
    let tag = rd.u8("ring tag")?;
    if tag != TAG_RING {
        return Err(CommError::Protocol(format!("expected ring handshake, got tag {tag}")));
    }
    let from = rd.u32("ring peer rank")? as usize;
    rd.done()?;
    Ok(from)
}

// ----------------------------------------------------------- framing

fn frame(payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut out = Vec::with_capacity(payload.len() + 4);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    out
}

fn write_frame(stream: &mut TcpStream, payload: Vec<u8>) -> Result<(), CommError> {
    let buf = frame(payload);
    metrics::comm().bytes_sent.fetch_add(buf.len() as u64, std::sync::atomic::Ordering::Relaxed);
    stream.write_all(&buf)?;
    Ok(())
}

/// Read one frame under `deadline`. Length claims beyond
/// [`MAX_FRAME`] are rejected before allocation; timeouts and resets
/// surface as typed errors. Counts received bytes and ring stalls
/// (reads that blocked > 1 ms) into the comm metrics.
fn read_frame(
    stream: &mut TcpStream,
    deadline: Instant,
    what: &'static str,
) -> Result<Vec<u8>, CommError> {
    faults::io_gate(Point::CommRecv)?;
    let t0 = Instant::now();
    let mut len_buf = [0u8; 4];
    read_deadline(stream, &mut len_buf, deadline, what)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(CommError::Protocol(format!(
            "frame length claim {len} outside (0, {MAX_FRAME}]"
        )));
    }
    let mut payload = vec![0u8; len];
    read_deadline(stream, &mut payload, deadline, what)?;
    let c = metrics::comm();
    c.bytes_recv.fetch_add(4 + len as u64, std::sync::atomic::Ordering::Relaxed);
    if t0.elapsed() > Duration::from_millis(1) {
        c.ring_stalls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    Ok(payload)
}

/// `read_exact` bounded by `deadline` via the socket read timeout.
fn read_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    deadline: Instant,
    what: &'static str,
) -> Result<(), CommError> {
    let now = Instant::now();
    let Some(remaining) = deadline.checked_duration_since(now).filter(|d| !d.is_zero()) else {
        return Err(CommError::Timeout { what, ms: 0 });
    };
    stream.set_read_timeout(Some(remaining)).map_err(|e| CommError::Io(e.to_string()))?;
    stream.read_exact(buf).map_err(|e| match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            CommError::Timeout { what, ms: remaining.as_millis() as u64 }
        }
        _ => CommError::Io(format!("{what}: {e}")),
    })
}

/// Dial `addr` with retries (the peer's listener may not be up yet)
/// until `deadline`. The `comm.connect` chaos point gates every
/// attempt.
fn connect_retry(addr: &str, deadline: Instant) -> Result<TcpStream, CommError> {
    loop {
        let attempt = (|| -> std::io::Result<TcpStream> {
            faults::io_gate(Point::CommConnect)?;
            TcpStream::connect(addr)
        })();
        match attempt {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(CommError::Timeout { what: "connecting to peer", ms: 0 });
                }
                // refused/reset while the peer boots: retry shortly
                let _ = e;
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Accept one connection under `deadline` (std listeners have no
/// accept timeout, so poll in non-blocking mode).
fn accept_deadline(
    listener: &TcpListener,
    deadline: Instant,
    what: &'static str,
) -> Result<TcpStream, CommError> {
    listener.set_nonblocking(true).map_err(|e| CommError::Io(e.to_string()))?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                s.set_nonblocking(false).map_err(|e| CommError::Io(e.to_string()))?;
                return Ok(s);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(CommError::Timeout { what, ms: 0 });
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(CommError::Io(e.to_string())),
        }
    }
}

// ----------------------------------------------------- communicator

/// Socket-backed [`Collective`]: one predecessor stream (reads), one
/// successor stream owned by a writer thread (non-blocking sends),
/// and the deterministic ring collectives of [`super::ring`] on top.
pub struct NetCommunicator {
    rank: usize,
    size: usize,
    opts: NetOptions,
    /// Per-communicator collective counter, embedded in every frame
    /// and validated on receive (catches desynchronized peers).
    op: u64,
    pred: Option<TcpStream>,
    out_tx: Option<Sender<Vec<u8>>>,
    out_err: Arc<Mutex<Option<String>>>,
    writer: Option<std::thread::JoinHandle<()>>,
}

impl NetCommunicator {
    /// Bind the rendezvous listener up-front (launchers bind `:0`
    /// first, learn the real port, then pass it to children).
    pub fn rendezvous_bind(addr: &str) -> std::io::Result<TcpListener> {
        TcpListener::bind(addr)
    }

    /// Join the world: rank 0 binds and serves the rendezvous at
    /// `rendezvous`, every other rank dials it.
    pub fn connect(
        rank: usize,
        size: usize,
        rendezvous: &str,
        opts: NetOptions,
    ) -> Result<Self, CommError> {
        if rank >= size {
            return Err(CommError::InvalidRank { rank, size });
        }
        if rank == 0 {
            let listener = Self::rendezvous_bind(rendezvous)
                .map_err(|e| CommError::Rendezvous(format!("binding {rendezvous}: {e}")))?;
            Self::connect_with_listener(listener, size, opts)
        } else {
            Self::connect_worker(rank, size, rendezvous, opts)
        }
    }

    /// Rank 0's join path with a pre-bound rendezvous listener.
    pub fn connect_with_listener(
        listener: TcpListener,
        size: usize,
        opts: NetOptions,
    ) -> Result<Self, CommError> {
        if size == 1 {
            return Ok(Self::trivial(0, opts));
        }
        let deadline = Instant::now() + opts.connect_timeout;
        let ring_listener = TcpListener::bind((
            listener.local_addr().map_err(|e| CommError::Io(e.to_string()))?.ip(),
            0,
        ))
        .map_err(|e| CommError::Io(format!("binding ring listener: {e}")))?;
        let my_ring_addr =
            ring_listener.local_addr().map_err(|e| CommError::Io(e.to_string()))?.to_string();

        // collect size-1 hellos, one per worker rank
        let mut addrs: Vec<Option<String>> = vec![None; size];
        addrs[0] = Some(my_ring_addr);
        let mut conns: Vec<(usize, TcpStream)> = Vec::with_capacity(size - 1);
        while conns.len() < size - 1 {
            let mut s = accept_deadline(&listener, deadline, "rendezvous accept")?;
            let payload = read_frame(&mut s, deadline, "rendezvous hello")?;
            let (peer_rank, peer_size, ring_addr) = decode_hello(&payload)?;
            if peer_size != size {
                let _ = write_frame(
                    &mut s,
                    encode_reject(&format!("world size mismatch: rank 0 has {size}, you claim {peer_size}")),
                );
                return Err(CommError::Rendezvous(format!(
                    "rank {peer_rank} joined with world size {peer_size}, expected {size}"
                )));
            }
            if peer_rank == 0 || peer_rank >= size {
                let _ = write_frame(&mut s, encode_reject("rank out of range"));
                return Err(CommError::InvalidRank { rank: peer_rank, size });
            }
            if addrs[peer_rank].is_some() {
                let _ = write_frame(&mut s, encode_reject("duplicate rank"));
                return Err(CommError::DuplicateRank { rank: peer_rank });
            }
            addrs[peer_rank] = Some(ring_addr);
            conns.push((peer_rank, s));
        }
        let table: Vec<String> = addrs.into_iter().map(|a| a.expect("all ranks joined")).collect();
        for (_, mut s) in conns {
            write_frame(&mut s, encode_peers(&table))?;
        }
        Self::wire_ring(0, size, &table, ring_listener, deadline, opts)
    }

    fn connect_worker(
        rank: usize,
        size: usize,
        rendezvous: &str,
        opts: NetOptions,
    ) -> Result<Self, CommError> {
        let deadline = Instant::now() + opts.connect_timeout;
        let mut s = connect_retry(rendezvous, deadline)?;
        let local_ip = s.local_addr().map_err(|e| CommError::Io(e.to_string()))?.ip();
        let ring_listener = TcpListener::bind((local_ip, 0))
            .map_err(|e| CommError::Io(format!("binding ring listener: {e}")))?;
        let my_ring_addr =
            ring_listener.local_addr().map_err(|e| CommError::Io(e.to_string()))?.to_string();
        write_frame(&mut s, encode_hello(rank, size, &my_ring_addr))?;
        let reply = read_frame(&mut s, deadline, "rendezvous reply")?;
        let table = decode_reply(&reply)?;
        if table.len() != size {
            return Err(CommError::Rendezvous(format!(
                "peer table has {} entries for world size {size}",
                table.len()
            )));
        }
        Self::wire_ring(rank, size, &table, ring_listener, deadline, opts)
    }

    /// Dial the successor, accept the predecessor, start the writer.
    fn wire_ring(
        rank: usize,
        size: usize,
        table: &[String],
        ring_listener: TcpListener,
        deadline: Instant,
        opts: NetOptions,
    ) -> Result<Self, CommError> {
        let succ_addr = &table[(rank + 1) % size];
        let mut succ = connect_retry(succ_addr, deadline)?;
        succ.set_nodelay(true).ok();
        write_frame(&mut succ, encode_ring_hello(rank))?;

        let mut pred = accept_deadline(&ring_listener, deadline, "ring accept")?;
        pred.set_nodelay(true).ok();
        let payload = read_frame(&mut pred, deadline, "ring handshake")?;
        let from = decode_ring_hello(&payload)?;
        let expect = (rank + size - 1) % size;
        if from != expect {
            return Err(CommError::Rendezvous(format!(
                "ring predecessor identified as rank {from}, expected {expect}"
            )));
        }

        // writer thread: owns the successor stream; the protocol loop
        // enqueues frames and never blocks on socket backpressure
        let (tx, rx): (Sender<Vec<u8>>, Receiver<Vec<u8>>) = channel();
        let out_err = Arc::new(Mutex::new(None::<String>));
        let err_slot = Arc::clone(&out_err);
        let writer = std::thread::Builder::new()
            .name(format!("nnl-comm-w{rank}"))
            .spawn(move || {
                for buf in rx {
                    if let Err(e) = succ.write_all(&buf) {
                        *err_slot.lock().unwrap_or_else(|p| p.into_inner()) = Some(e.to_string());
                        return;
                    }
                }
            })
            .map_err(|e| CommError::Io(format!("spawning comm writer: {e}")))?;

        Ok(NetCommunicator {
            rank,
            size,
            opts,
            op: 0,
            pred: Some(pred),
            out_tx: Some(tx),
            out_err,
            writer: Some(writer),
        })
    }

    fn trivial(rank: usize, opts: NetOptions) -> Self {
        NetCommunicator {
            rank,
            size: 1,
            opts,
            op: 0,
            pred: None,
            out_tx: None,
            out_err: Arc::new(Mutex::new(None)),
            writer: None,
        }
    }

    pub fn options(&self) -> &NetOptions {
        &self.opts
    }

    fn link(&mut self, deadline: Instant) -> NetLink<'_> {
        NetLink {
            pred: self.pred.as_mut().expect("size > 1"),
            out_tx: self.out_tx.as_ref().expect("size > 1"),
            out_err: &self.out_err,
            deadline,
        }
    }
}

impl Drop for NetCommunicator {
    fn drop(&mut self) {
        // closing the channel stops the writer; join so queued frames
        // flush before the successor sees EOF
        self.out_tx = None;
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
    }
}

/// The [`ring::Link`] over this rank's two TCP edges.
struct NetLink<'a> {
    pred: &'a mut TcpStream,
    out_tx: &'a Sender<Vec<u8>>,
    out_err: &'a Arc<Mutex<Option<String>>>,
    deadline: Instant,
}

impl ring::Link for NetLink<'_> {
    fn send(&mut self, msg: Msg) -> Result<(), CommError> {
        if let Some(e) = self.out_err.lock().unwrap_or_else(|p| p.into_inner()).clone() {
            return Err(CommError::Io(format!("successor link failed: {e}")));
        }
        let mut payload = encode_seg(&msg);
        // `comm.send` chaos: may delay, error, or truncate the frame
        // payload (the receiver's bounds-checked decoder reports it)
        faults::mangle(Point::CommSend, &mut payload)?;
        let buf = frame(payload);
        metrics::comm()
            .bytes_sent
            .fetch_add(buf.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.out_tx.send(buf).map_err(|_| CommError::Io("comm writer thread gone".into()))
    }

    fn recv(&mut self) -> Result<Msg, CommError> {
        let payload = read_frame(self.pred, self.deadline, "ring segment")?;
        decode_seg(&payload)
    }
}

impl Collective for NetCommunicator {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn all_reduce_flat(&mut self, buf: &mut [f32], division: bool) -> Result<(), CommError> {
        metrics::comm().allreduce_calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.op += 1;
        if self.size == 1 {
            return Ok(());
        }
        let (rank, size, op) = (self.rank, self.size, self.op);
        let (fp16, seg) = (self.opts.fp16_wire, self.opts.segment_elems);
        let deadline = Instant::now() + self.opts.step_deadline;
        let mut link = self.link(deadline);
        ring::all_reduce(rank, size, op, buf, division, fp16, seg, &mut link)
    }

    fn bcast_flat(&mut self, buf: &mut [f32]) -> Result<(), CommError> {
        self.op += 1;
        if self.size == 1 {
            return Ok(());
        }
        let (rank, size, op) = (self.rank, self.size, self.op);
        let seg = self.opts.segment_elems;
        let deadline = Instant::now() + self.opts.step_deadline;
        let mut link = self.link(deadline);
        ring::bcast(rank, size, op, buf, seg, &mut link)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::utils::prop;

    fn loopback_world(
        n: usize,
        opts: NetOptions,
    ) -> Vec<std::thread::JoinHandle<Result<NetCommunicator, CommError>>> {
        let listener = NetCommunicator::rendezvous_bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let mut handles = Vec::new();
        {
            let opts = opts.clone();
            handles.push(std::thread::spawn(move || {
                NetCommunicator::connect_with_listener(listener, n, opts)
            }));
        }
        for rank in 1..n {
            let addr = addr.clone();
            let opts = opts.clone();
            handles.push(std::thread::spawn(move || {
                NetCommunicator::connect(rank, n, &addr, opts)
            }));
        }
        handles
    }

    fn run_world<T: Send + 'static>(
        n: usize,
        opts: NetOptions,
        f: impl Fn(NetCommunicator) -> Result<T, CommError> + Send + Sync + Clone + 'static,
    ) -> Vec<Result<T, CommError>> {
        let joins = loopback_world(n, opts);
        let handles: Vec<_> = joins
            .into_iter()
            .map(|j| {
                let f = f.clone();
                std::thread::spawn(move || {
                    let comm = j.join().expect("join thread")?;
                    f(comm)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker")).collect()
    }

    #[test]
    fn tcp_all_reduce_matches_sequential_fold() {
        for n in [1usize, 2, 3, 4] {
            let results = run_world(n, NetOptions::default(), move |mut comm| {
                let r = comm.rank();
                let mut buf: Vec<f32> = (0..130).map(|i| (i as f32 + r as f32 * 0.5).cos()).collect();
                comm.all_reduce_flat(&mut buf, true)?;
                Ok(buf)
            });
            let mut expect = vec![0.0f32; 130];
            for r in 0..n {
                for (i, e) in expect.iter_mut().enumerate() {
                    *e += (i as f32 + r as f32 * 0.5).cos();
                }
            }
            if n > 1 {
                for e in expect.iter_mut() {
                    *e *= 1.0 / n as f32;
                }
            }
            for res in results {
                let got = res.expect("all_reduce");
                if n == 1 {
                    // world 1 is a no-op, matching the thread backend
                    assert_eq!(got.len(), 130);
                    continue;
                }
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    expect.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "n={n}"
                );
            }
        }
    }

    #[test]
    fn tcp_bcast_and_gather() {
        let results = run_world(3, NetOptions::default(), |mut comm| {
            let mut w = if comm.rank() == 0 { vec![5.0f32, 6.0, 7.0] } else { vec![0.0; 3] };
            comm.bcast_flat(&mut w)?;
            let g = comm.all_gather_scalar(comm.rank() as f32 * 10.0)?;
            comm.barrier()?;
            Ok((w, g))
        });
        for res in results {
            let (w, g) = res.expect("collectives");
            assert_eq!(w, vec![5.0, 6.0, 7.0]);
            assert_eq!(g, vec![0.0, 10.0, 20.0]);
        }
    }

    #[test]
    fn dropped_peer_times_out_with_typed_error_not_hang() {
        let opts = NetOptions {
            step_deadline: Duration::from_millis(300),
            connect_timeout: Duration::from_secs(5),
            ..NetOptions::default()
        };
        let results = run_world(3, opts, |mut comm| {
            if comm.rank() == 2 {
                // this rank dies before the collective
                return Ok(vec![]);
            }
            let mut buf = vec![1.0f32; 64];
            comm.all_reduce_flat(&mut buf, false).map(|_| buf)
        });
        let mut errs = 0;
        for (rank, res) in results.into_iter().enumerate() {
            if rank == 2 {
                continue;
            }
            match res {
                Err(CommError::Timeout { .. }) | Err(CommError::Io(_)) => errs += 1,
                other => panic!("rank {rank}: expected timeout/io error, got {other:?}"),
            }
        }
        assert_eq!(errs, 2, "every surviving rank must surface the failure");
    }

    #[test]
    fn duplicate_rank_is_rejected() {
        let listener = NetCommunicator::rendezvous_bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let opts = NetOptions {
            connect_timeout: Duration::from_secs(5),
            ..NetOptions::default()
        };
        let r0 = {
            let opts = opts.clone();
            std::thread::spawn(move || NetCommunicator::connect_with_listener(listener, 3, opts))
        };
        let w = |rank: usize| {
            let addr = addr.clone();
            let opts = opts.clone();
            std::thread::spawn(move || NetCommunicator::connect(rank, 3, &addr, opts))
        };
        let a = w(1);
        // give rank 1 a head start so the duplicate arrives second
        std::thread::sleep(Duration::from_millis(100));
        let b = w(1);
        let r0 = r0.join().expect("thread");
        assert!(
            matches!(r0, Err(CommError::DuplicateRank { rank: 1 })),
            "rendezvous must reject the duplicate: {r0:?}"
        );
        // at least one of the two rank-1 joins must fail with a typed error
        let (ra, rb) = (a.join().expect("thread"), b.join().expect("thread"));
        assert!(ra.is_err() || rb.is_err());
    }

    #[test]
    fn seg_codec_roundtrips() {
        for fp16 in [false, true] {
            let data = if fp16 {
                Wire::F16(vec![0x3C00, 0x4000, 0xBC00])
            } else {
                Wire::F32(vec![1.0, -2.5, 3.25])
            };
            let m = Msg { kind: MsgKind::Final, op: 42, seg: 7, data };
            let enc = encode_seg(&m);
            assert_eq!(decode_seg(&enc).expect("roundtrip"), m);
        }
    }

    #[test]
    fn seg_decoder_survives_hostile_inputs() {
        // truncations, bit flips and hostile length claims must all
        // surface typed errors — never panic, never allocate from an
        // untrusted claim (same bar as the NNB/archive decoders)
        prop::check(
            0xC0FFEE,
            300,
            |rng: &mut crate::tensor::Rng| {
                let n = rng.below(40);
                let vals: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let m = Msg {
                    kind: if rng.below(2) == 0 { MsgKind::Partial } else { MsgKind::Final },
                    op: rng.below(1000) as u64,
                    seg: rng.below(100) as u32,
                    data: if rng.below(2) == 0 {
                        Wire::F32(vals)
                    } else {
                        Wire::F16(vals.iter().map(|&v| crate::utils::half::f32_to_f16_bits(v)).collect())
                    },
                };
                let mut enc = encode_seg(&m);
                match rng.below(3) {
                    0 => {
                        // truncate
                        let keep = rng.below(enc.len() + 1);
                        enc.truncate(keep);
                    }
                    1 => {
                        // flip bits
                        crate::faults::flip_bytes(rng.below(1 << 30) as u64, &mut enc);
                    }
                    _ => {
                        // hostile element-count claim over real payload
                        if enc.len() >= 20 {
                            let claim = (u32::MAX - rng.below(1000) as u32).to_le_bytes();
                            enc[16..20].copy_from_slice(&claim);
                        }
                    }
                }
                enc
            },
            |enc| {
                // must return, not panic; any Ok must be internally sane
                match decode_seg(enc) {
                    Ok(m) => {
                        if m.data.len() > ring::MAX_SEGMENT_ELEMS {
                            return Err("decoder accepted an oversized segment".into());
                        }
                        Ok(())
                    }
                    Err(_) => Ok(()),
                }
            },
        );
    }

    #[test]
    fn hostile_length_claim_rejected_before_allocation() {
        let m = Msg { kind: MsgKind::Partial, op: 1, seg: 0, data: Wire::F32(vec![1.0; 4]) };
        let mut enc = encode_seg(&m);
        // element count field sits after ver/tag/kind/dtype (4) + op
        // (8) + seg (4) = offset 16
        enc[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        match decode_seg(&enc) {
            Err(CommError::Protocol(msg)) => {
                assert!(msg.contains("exceeds cap"), "{msg}");
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
    }
}
