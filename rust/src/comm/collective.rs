//! Collectives over a shared rendezvous: each worker deposits its
//! contribution in a rank-indexed slot, then every worker reduces the
//! slots **in rank order** — giving bit-deterministic results (unlike
//! real NCCL, where ring order depends on topology; determinism here
//! is a feature for reproducible trials, and the semantics match).
//! This is the in-process backend of [`super::Collective`]; the fold
//! it computes — `((0 + x_0) + x_1) + ...`, then `× 1/N` — is exactly
//! the one `comm::ring` reproduces over TCP, so the two backends are
//! bit-interchangeable.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier, Mutex};

use super::{Collective, CommError};
use crate::monitor::metrics;

struct Slots {
    bufs: Mutex<Vec<Option<Vec<f32>>>>,
}

/// Shared hub: create once, then [`CommHub::communicator`] per worker.
pub struct CommHub {
    n: usize,
    barrier: Arc<Barrier>,
    slots: Arc<Slots>,
    taken: Vec<bool>,
}

impl CommHub {
    /// Hub for `n` simulated devices.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        CommHub {
            n,
            barrier: Arc::new(Barrier::new(n)),
            slots: Arc::new(Slots { bufs: Mutex::new(vec![None; n]) }),
            taken: vec![false; n],
        }
    }

    /// Take the communicator endpoint for `rank` — once per rank; a
    /// repeat or out-of-range rank is a typed error, not a panic.
    pub fn communicator(&mut self, rank: usize) -> Result<Communicator, CommError> {
        if rank >= self.n {
            return Err(CommError::InvalidRank { rank, size: self.n });
        }
        if self.taken[rank] {
            return Err(CommError::DuplicateRank { rank });
        }
        self.taken[rank] = true;
        Ok(Communicator {
            rank,
            n: self.n,
            barrier: self.barrier.clone(),
            slots: self.slots.clone(),
        })
    }
}

/// Per-worker endpoint — `C.MultiProcessDataParalellCommunicator`.
pub struct Communicator {
    rank: usize,
    n: usize,
    barrier: Arc<Barrier>,
    slots: Arc<Slots>,
}

impl Communicator {
    /// Deposit `mine`, wait, then fold all contributions in rank order.
    fn exchange<R>(&self, mine: Vec<f32>, fold: impl FnOnce(&[Option<Vec<f32>>]) -> R) -> R {
        {
            let mut bufs = self.slots.bufs.lock().unwrap();
            bufs[self.rank] = Some(mine);
        }
        self.barrier.wait(); // all deposited
        let out = {
            let bufs = self.slots.bufs.lock().unwrap();
            fold(&bufs)
        };
        self.barrier.wait(); // all have read
        if self.rank == 0 {
            let mut bufs = self.slots.bufs.lock().unwrap();
            for b in bufs.iter_mut() {
                *b = None;
            }
        }
        self.barrier.wait(); // slots cleared for the next collective
        out
    }
}

impl Collective for Communicator {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.n
    }

    fn all_reduce_flat(&mut self, buf: &mut [f32], division: bool) -> Result<(), CommError> {
        metrics::comm().allreduce_calls.fetch_add(1, Ordering::Relaxed);
        if self.n == 1 {
            return Ok(());
        }
        let len = buf.len();
        let reduced = self.exchange(buf.to_vec(), |bufs| {
            let mut acc = vec![0.0f32; len];
            for b in bufs.iter() {
                let b = b.as_ref().expect("missing contribution");
                if b.len() != len {
                    return Err(CommError::SizeMismatch { expected: len, got: b.len() });
                }
                for (a, v) in acc.iter_mut().zip(b) {
                    *a += v;
                }
            }
            Ok(acc)
        })?;
        if division {
            let scale = 1.0 / self.n as f32;
            for (dst, src) in buf.iter_mut().zip(&reduced) {
                *dst = *src * scale;
            }
        } else {
            buf.copy_from_slice(&reduced);
        }
        Ok(())
    }

    fn bcast_flat(&mut self, buf: &mut [f32]) -> Result<(), CommError> {
        if self.n == 1 {
            return Ok(());
        }
        let len = buf.len();
        let mine = if self.rank == 0 { buf.to_vec() } else { Vec::new() };
        let root = self.exchange(mine, |bufs| {
            let b = bufs[0].as_ref().expect("root contribution");
            if b.len() != len {
                return Err(CommError::SizeMismatch { expected: len, got: b.len() });
            }
            Ok(b.clone())
        })?;
        buf.copy_from_slice(&root);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{NdArray, Rng};
    use crate::utils::prop;

    fn run_workers<T: Send + 'static>(
        n: usize,
        f: impl Fn(Communicator) -> T + Send + Sync + Clone + 'static,
    ) -> Vec<T> {
        let mut hub = CommHub::new(n);
        let mut handles = Vec::new();
        for r in 0..n {
            let comm = hub.communicator(r).expect("fresh rank");
            let f = f.clone();
            handles.push(std::thread::spawn(move || f(comm)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_equals_sequential_sum() {
        for n in [1, 2, 3, 4, 7] {
            let results = run_workers(n, move |mut comm| {
                let r = comm.rank();
                let mut a = NdArray::from_vec(&[3], vec![r as f32, 1.0, (r * r) as f32]);
                comm.all_reduce(std::slice::from_mut(&mut a), false).expect("all_reduce");
                a
            });
            let expect_0: f32 = (0..n).map(|r| r as f32).sum();
            let expect_2: f32 = (0..n).map(|r| (r * r) as f32).sum();
            for a in &results {
                assert_eq!(a.data(), &[expect_0, n as f32, expect_2], "n={n}");
            }
        }
    }

    #[test]
    fn all_reduce_division_averages() {
        let results = run_workers(4, |mut comm| {
            let mut a = NdArray::full(&[2], comm.rank() as f32);
            comm.all_reduce(std::slice::from_mut(&mut a), true).expect("all_reduce");
            a
        });
        for a in &results {
            assert_eq!(a.data(), &[1.5, 1.5]); // (0+1+2+3)/4
        }
    }

    #[test]
    fn all_reduce_multiple_arrays_packed() {
        let results = run_workers(3, |mut comm| {
            let mut arrays =
                vec![NdArray::full(&[2], 1.0), NdArray::full(&[3], comm.rank() as f32)];
            comm.all_reduce(&mut arrays, false).expect("all_reduce");
            arrays
        });
        for arrays in &results {
            assert_eq!(arrays[0].data(), &[3.0, 3.0]);
            assert_eq!(arrays[1].data(), &[3.0, 3.0, 3.0]);
        }
    }

    #[test]
    fn repeated_collectives_do_not_cross_talk() {
        let results = run_workers(3, |mut comm| {
            let mut out = Vec::new();
            for round in 0..5 {
                let mut a = NdArray::full(&[1], (comm.rank() + round) as f32);
                comm.all_reduce(std::slice::from_mut(&mut a), false).expect("all_reduce");
                out.push(a.item());
            }
            out
        });
        for r in &results {
            assert_eq!(r, &[3., 6., 9., 12., 15.]);
        }
    }

    #[test]
    fn bcast_syncs_initial_weights() {
        let results = run_workers(4, |mut comm| {
            let mut a = if comm.rank() == 0 {
                NdArray::from_slice(&[3], &[7., 8., 9.])
            } else {
                NdArray::zeros(&[3])
            };
            comm.bcast(std::slice::from_mut(&mut a)).expect("bcast");
            a
        });
        for a in &results {
            assert_eq!(a.data(), &[7., 8., 9.]);
        }
    }

    #[test]
    fn all_gather_scalar_collects_by_rank() {
        let results = run_workers(5, |mut comm| {
            comm.all_gather_scalar((comm.rank() * 10) as f32).expect("gather")
        });
        for g in &results {
            assert_eq!(g, &[0., 10., 20., 30., 40.]);
        }
    }

    #[test]
    fn all_reduce_is_deterministic_property() {
        prop::check(
            77,
            8,
            |rng: &mut Rng| {
                let n = 2 + rng.below(4);
                let len = 1 + rng.below(16);
                let data: Vec<Vec<f32>> =
                    (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
                (n, len, data)
            },
            |(n, len, data)| {
                let (n, len) = (*n, *len);
                let run = {
                    let data = data.clone();
                    move || {
                        let data = data.clone();
                        run_workers(n, move |mut comm| {
                            let mut a = NdArray::from_vec(&[len], data[comm.rank()].clone());
                            comm.all_reduce(std::slice::from_mut(&mut a), true)
                                .expect("all_reduce");
                            a
                        })
                    }
                };
                let r1 = run();
                let r2 = run();
                for (a, b) in r1.iter().zip(&r2) {
                    if a.data() != b.data() {
                        return Err("nondeterministic all_reduce".into());
                    }
                }
                for a in &r1[1..] {
                    if a.data() != r1[0].data() {
                        return Err("ranks disagree".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn communicator_misuse_is_a_typed_error_not_a_panic() {
        let mut hub = CommHub::new(2);
        let _a = hub.communicator(0).expect("first take");
        match hub.communicator(0) {
            Err(CommError::DuplicateRank { rank: 0 }) => {}
            other => panic!("expected DuplicateRank, got {other:?}"),
        }
        match hub.communicator(5) {
            Err(CommError::InvalidRank { rank: 5, size: 2 }) => {}
            other => panic!("expected InvalidRank, got {other:?}"),
        }
        // rank 1 is still claimable after the failures above
        assert!(hub.communicator(1).is_ok());
    }
}
