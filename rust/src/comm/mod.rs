//! Data-parallel distributed training (paper §2.3, Listing 3).
//!
//! The paper uses NCCL/MPI across GPUs; here the same collective
//! surface is served by two interchangeable backends behind the
//! [`Collective`] trait:
//!
//! - [`collective`] — N *simulated devices* as OS threads sharing a
//!   rendezvous (`CommHub`/`Communicator`), reducing in rank order;
//! - [`net`] — N real OS **processes** over TCP: a rank-0 rendezvous
//!   hands out a peer table, then a [`ring`] all-reduce moves
//!   gradients over length-prefixed frames with a deterministic
//!   segment reduction order.
//!
//! ```text
//! comm = C.MultiProcessDataParalellCommunicator(ctx); comm.init()
//! ...
//! loss.backward(clear_buffer=True)
//! comm.all_reduce(params)
//! ```
//!
//! Both backends are exactly reproducible and provably equal to the
//! sequential sum: every element is reduced as
//! `((0 + x_0) + x_1) + ... + x_{n-1}` regardless of transport, so an
//! N-process run is bit-identical to the thread backend and to a
//! sequential simulation of the same data-parallel step (see the
//! property tests and `tests/distributed.rs`). [`bucket`] adds the
//! training-side machinery: gradient bucketing and reduce/backward
//! overlap on a background communication thread.

pub mod bucket;
pub mod collective;
pub mod net;
pub mod ring;

pub use bucket::{plan_buckets, Reducer};
pub use collective::{CommHub, Communicator};
pub use net::{NetCommunicator, NetOptions};

use crate::tensor::NdArray;

/// Typed communicator failure — every collective surfaces one of
/// these instead of hanging or panicking, including under chaos
/// injection (`comm.connect` / `comm.send` / `comm.recv` points).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    /// Rank outside `0..size`.
    InvalidRank { rank: usize, size: usize },
    /// The same rank joined (or was taken) twice.
    DuplicateRank { rank: usize },
    /// Rendezvous/setup failure (size disagreement, bad peer table,
    /// refused handshake).
    Rendezvous(String),
    /// Transport-level I/O failure (peer died, connection reset).
    Io(String),
    /// A blocking step exceeded the step deadline — the "never hang"
    /// guarantee: a dropped peer surfaces here at every live rank.
    Timeout { what: &'static str, ms: u64 },
    /// Frame/codec violation (bad version, hostile length claim,
    /// truncated or out-of-order message).
    Protocol(String),
    /// Collective arguments disagree across call sites.
    SizeMismatch { expected: usize, got: usize },
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::InvalidRank { rank, size } => {
                write!(f, "rank {rank} out of range for world size {size}")
            }
            CommError::DuplicateRank { rank } => {
                write!(f, "communicator already taken for rank {rank}")
            }
            CommError::Rendezvous(m) => write!(f, "rendezvous failed: {m}"),
            CommError::Io(m) => write!(f, "comm I/O error: {m}"),
            CommError::Timeout { what, ms } => {
                write!(f, "comm deadline exceeded after {ms} ms while {what}")
            }
            CommError::Protocol(m) => write!(f, "comm protocol violation: {m}"),
            CommError::SizeMismatch { expected, got } => {
                write!(f, "collective size mismatch: expected {expected} elements, got {got}")
            }
        }
    }
}

impl std::error::Error for CommError {}

impl From<std::io::Error> for CommError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
                CommError::Timeout { what: "socket I/O", ms: 0 }
            }
            _ => CommError::Io(e.to_string()),
        }
    }
}

/// The collective surface both backends implement — what the trainer
/// programs against. All methods take `&mut self` so a socket-backed
/// implementation can own its streams without interior locking.
///
/// Determinism contract: `all_reduce*` reduces every element in rank
/// order starting from `+0.0` (`((0 + x_0) + x_1) + ...`), and every
/// rank receives identical bytes. `division` additionally multiplies
/// by `1.0 / size as f32` after the sum.
pub trait Collective: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;

    /// Element-wise sum (optionally averaged) of `buf` across ranks;
    /// all ranks must pass equal lengths.
    fn all_reduce_flat(&mut self, buf: &mut [f32], division: bool) -> Result<(), CommError>;

    /// Broadcast rank 0's `buf` to everyone.
    fn bcast_flat(&mut self, buf: &mut [f32]) -> Result<(), CommError>;

    /// `comm.all_reduce(grads)` over whole arrays: packs into one flat
    /// buffer (one collective per call), then writes back through
    /// `requantize` so half-precision contexts stay on their grid.
    fn all_reduce(&mut self, arrays: &mut [NdArray], division: bool) -> Result<(), CommError> {
        let total: usize = arrays.iter().map(|a| a.size()).sum();
        let mut flat = Vec::with_capacity(total);
        for a in arrays.iter() {
            flat.extend_from_slice(a.data());
        }
        self.all_reduce_flat(&mut flat, division)?;
        let mut off = 0;
        for a in arrays.iter_mut() {
            let n = a.size();
            a.data_mut().copy_from_slice(&flat[off..off + n]);
            a.requantize();
            off += n;
        }
        Ok(())
    }

    /// Broadcast rank 0's arrays to everyone (initial weight sync).
    fn bcast(&mut self, arrays: &mut [NdArray]) -> Result<(), CommError> {
        let total: usize = arrays.iter().map(|a| a.size()).sum();
        let mut flat = Vec::with_capacity(total);
        for a in arrays.iter() {
            flat.extend_from_slice(a.data());
        }
        self.bcast_flat(&mut flat)?;
        let mut off = 0;
        for a in arrays.iter_mut() {
            let n = a.size();
            a.data_mut().copy_from_slice(&flat[off..off + n]);
            a.requantize();
            off += n;
        }
        Ok(())
    }

    /// All-gather scalars (e.g. per-worker losses) indexed by rank —
    /// expressed as a one-hot all-reduce, which is exact in f32 (each
    /// slot sums one value and zeros).
    fn all_gather_scalar(&mut self, v: f32) -> Result<Vec<f32>, CommError> {
        let mut buf = vec![0.0f32; self.size()];
        buf[self.rank()] = v;
        self.all_reduce_flat(&mut buf, false)?;
        Ok(buf)
    }

    /// Synchronization barrier across all ranks.
    fn barrier(&mut self) -> Result<(), CommError> {
        let mut one = [0.0f32];
        self.all_reduce_flat(&mut one, false)
    }
}
