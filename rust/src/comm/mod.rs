//! Data-parallel distributed training (paper §2.3, Listing 3).
//!
//! The paper uses NCCL/MPI across GPUs; here each *simulated device*
//! is an OS thread with its own graph/parameters/executable, and the
//! communicator provides the same collective surface:
//!
//! ```text
//! comm = C.MultiProcessDataParalellCommunicator(ctx); comm.init()
//! ...
//! loss.backward(clear_buffer=True)
//! comm.all_reduce(params)
//! ```
//!
//! Collectives are implemented ring-style over channels with a
//! deterministic reduction order, so `all_reduce` is exactly
//! reproducible and provably equal to the sequential sum (see the
//! property tests).

pub mod collective;

pub use collective::{CommHub, Communicator};
