//! Model zoo — the paper's §4 evaluation architectures, scaled to this
//! testbed (DESIGN.md §Hardware-Adaptation): ResNet-18/50, ResNeXt-50,
//! SE-ResNet-50, SE-ResNeXt-50 (Table 2), MobileNetV3-S/L and
//! EfficientNet-B0..B3 (Table 3), plus LeNet (Listings 4/5) and an MLP.
//!
//! Models are built through [`builder::Gb`] on the self-describing
//! tape: the live training graph *is* the network definition
//! ([`Gb::finish`](builder::Gb::finish) traces it into a
//! [`crate::nnp::NetworkDef`]) — so every zoo model trains on the
//! dynamic engine, exports to NNP/ONNX, runs in the deployment
//! interpreter, and reports parameter/MAC footprints (the Console
//! feature of §5.1) from one definition.

pub mod builder;
pub mod zoo;

pub use builder::{Gb, T};
pub use zoo::{build_model, model_names};
