//! The architectures of Tables 2 and 3 (mini widths; DESIGN.md
//! documents the scaling substitution). All operate on
//! `[B, 3, 16, 16]` synthetic-ImageNet input and 10 classes, except
//! LeNet (`[B, 1, 28, 28]`, Listings 4/5) and the MLP.

use super::builder::{Gb, T};

/// Basic 3x3-3x3 residual block (ResNet-18 style).
fn basic_block(g: &mut Gb, x: &T, w: usize, stride: usize, name: &str) -> T {
    let r = g.conv(x, w, (3, 3), (stride, stride), (1, 1), &format!("{name}/c1"));
    let r = g.bn(&r, &format!("{name}/b1"));
    let r = g.relu(&r);
    let r = g.conv(&r, w, (3, 3), (1, 1), (1, 1), &format!("{name}/c2"));
    let r = g.bn(&r, &format!("{name}/b2"));
    let sc = if x.var.dims()[1] != w || stride != 1 {
        let s = g.conv(x, w, (1, 1), (stride, stride), (0, 0), &format!("{name}/proj"));
        g.bn(&s, &format!("{name}/projbn"))
    } else {
        x.clone()
    };
    let y = g.add(&r, &sc, &format!("{name}/add"));
    g.relu(&y)
}

/// Squeeze-and-excitation gate (Hu et al., Table 2's SE- variants).
fn se_gate(g: &mut Gb, x: &T, reduction: usize, name: &str) -> T {
    let c = x.var.dims()[1];
    let s = g.global_avg_pool(x); // [B, C]
    let s = g.affine(&s, (c / reduction).max(1), &format!("{name}/fc1"));
    let s = g.relu(&s);
    let s = g.affine(&s, c, &format!("{name}/fc2"));
    let s = g.sigmoid(&s);
    let s = g.reshape(&s, &[0, c as i64, 1, 1], &format!("{name}/rs"));
    g.mul(x, &s, &format!("{name}/scale"))
}

/// Bottleneck 1x1-3x3-1x1 block (ResNet-50 style), optional grouped
/// 3x3 (ResNeXt) and optional SE.
#[allow(clippy::too_many_arguments)]
fn bottleneck(
    g: &mut Gb,
    x: &T,
    w: usize,
    stride: usize,
    groups: usize,
    se: bool,
    name: &str,
) -> T {
    // ResNeXt convention: the grouped 3x3 is *wider* than the plain
    // bottleneck's (32x4d in the paper) — cardinality buys width
    let mid = if groups > 1 { w } else { w / 2 };
    let r = g.conv(x, mid, (1, 1), (1, 1), (0, 0), &format!("{name}/c1"));
    let r = g.bn(&r, &format!("{name}/b1"));
    let r = g.relu(&r);
    let r = g.group_conv(&r, mid, (3, 3), (stride, stride), (1, 1), groups, &format!("{name}/c2"));
    let r = g.bn(&r, &format!("{name}/b2"));
    let r = g.relu(&r);
    let r = g.conv(&r, w, (1, 1), (1, 1), (0, 0), &format!("{name}/c3"));
    let mut r = g.bn(&r, &format!("{name}/b3"));
    if se {
        r = se_gate(g, &r, 4, &format!("{name}/se"));
    }
    let sc = if x.var.dims()[1] != w || stride != 1 {
        let s = g.conv(x, w, (1, 1), (stride, stride), (0, 0), &format!("{name}/proj"));
        g.bn(&s, &format!("{name}/projbn"))
    } else {
        x.clone()
    };
    let y = g.add(&r, &sc, &format!("{name}/add"));
    g.relu(&y)
}

fn resnet_backbone(
    g: &mut Gb,
    x: &T,
    widths: &[usize],
    blocks: &[usize],
    bottleneck_blocks: bool,
    groups: usize,
    se: bool,
) -> T {
    let mut h = g.conv(x, widths[0], (3, 3), (1, 1), (1, 1), "stem");
    h = g.bn(&h, "stembn");
    h = g.relu(&h);
    for (s, (&w, &n)) in widths.iter().zip(blocks).enumerate() {
        for b in 0..n {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let name = format!("s{s}b{b}");
            h = if bottleneck_blocks {
                bottleneck(g, &h, w, stride, groups, se, &name)
            } else {
                basic_block(g, &h, w, stride, &name)
            };
        }
    }
    h
}

fn classifier_head(g: &mut Gb, h: &T, classes: usize) -> T {
    let p = g.global_avg_pool(h);
    g.affine(&p, classes, "head")
}

/// Inverted-residual MBConv block (MobileNetV3 / EfficientNet).
fn mbconv(g: &mut Gb, x: &T, out: usize, expand: usize, stride: usize, se: bool, name: &str) -> T {
    let c = x.var.dims()[1];
    let mid = c * expand;
    let mut r = x.clone();
    if expand != 1 {
        r = g.conv(&r, mid, (1, 1), (1, 1), (0, 0), &format!("{name}/exp"));
        r = g.bn(&r, &format!("{name}/expbn"));
        r = g.swish(&r);
    }
    // depthwise = group conv with groups == channels
    r = g.group_conv(&r, mid, (3, 3), (stride, stride), (1, 1), mid, &format!("{name}/dw"));
    r = g.bn(&r, &format!("{name}/dwbn"));
    r = g.swish(&r);
    if se {
        r = se_gate(g, &r, 4, &format!("{name}/se"));
    }
    r = g.conv(&r, out, (1, 1), (1, 1), (0, 0), &format!("{name}/prj"));
    r = g.bn(&r, &format!("{name}/prjbn"));
    if c == out && stride == 1 {
        r = g.add(&r, x, &format!("{name}/add"));
    }
    r
}

fn mobilenet_v3(g: &mut Gb, x: &T, large: bool, classes: usize) -> T {
    let mut h = g.conv(x, 8, (3, 3), (1, 1), (1, 1), "stem");
    h = g.bn(&h, "stembn");
    h = g.swish(&h);
    let plan: &[(usize, usize, usize, bool)] = if large {
        // (out, expand, stride, se)
        &[(8, 1, 1, false), (12, 4, 2, false), (12, 3, 1, false), (16, 3, 2, true), (16, 3, 1, true), (24, 6, 1, true)]
    } else {
        &[(8, 1, 2, true), (12, 4, 2, false), (16, 4, 1, true)]
    };
    for (i, &(out, exp, st, se)) in plan.iter().enumerate() {
        h = mbconv(g, &h, out, exp, st, se, &format!("mb{i}"));
    }
    classifier_head(g, &h, classes)
}

fn efficientnet(g: &mut Gb, x: &T, width_mult: f32, depth_mult: f32, classes: usize) -> T {
    let w = |base: usize| -> usize { ((base as f32 * width_mult).round() as usize).max(4) & !1 };
    let d = |base: usize| -> usize { (base as f32 * depth_mult).ceil() as usize };
    let mut h = g.conv(x, w(8), (3, 3), (1, 1), (1, 1), "stem");
    h = g.bn(&h, "stembn");
    h = g.swish(&h);
    // (base_out, expand, stride, repeats)
    let plan: &[(usize, usize, usize, usize)] =
        &[(8, 1, 1, 1), (12, 4, 2, 2), (16, 4, 2, 2), (24, 4, 1, 1)];
    let mut bi = 0;
    for &(out, exp, st, reps) in plan {
        for r in 0..d(reps) {
            let stride = if r == 0 { st } else { 1 };
            h = mbconv(g, &h, w(out), exp, stride, true, &format!("mb{bi}"));
            bi += 1;
        }
    }
    classifier_head(g, &h, classes)
}

/// LeNet exactly as Listing 4 (28x28 grayscale).
fn lenet(g: &mut Gb, x: &T, classes: usize) -> T {
    let h = g.conv(x, 16, (5, 5), (1, 1), (0, 0), "conv1");
    let h = g.max_pool(&h, (2, 2), (2, 2));
    let h = g.relu(&h);
    let h = g.conv(&h, 16, (5, 5), (1, 1), (0, 0), "conv2");
    let h = g.max_pool(&h, (2, 2), (2, 2));
    let h = g.relu(&h);
    let h = g.affine(&h, 50, "affine3");
    let h = g.relu(&h);
    g.affine(&h, classes, "affine4")
}

fn mlp(g: &mut Gb, x: &T, classes: usize) -> T {
    let h = g.affine(x, 128, "fc1");
    let h = g.relu(&h);
    let h = g.dropout(&h, 0.1, "drop1");
    let h = g.affine(&h, 64, "fc2");
    let h = g.relu(&h);
    g.affine(&h, classes, "out")
}

/// All zoo model names, grouped by the table they reproduce.
pub fn model_names() -> Vec<&'static str> {
    vec![
        // Listings / quickstart
        "mlp",
        "lenet",
        // Table 2
        "resnet18",
        "resnet50",
        "resnext50",
        "se_resnet50",
        "se_resnext50",
        // Table 3
        "mobilenet_v3_small",
        "mobilenet_v3_large",
        "efficientnet_b0",
        "efficientnet_b1",
        "efficientnet_b2",
        "efficientnet_b3",
    ]
}

/// Whether `name` is a known zoo model (cheap pre-validation for
/// untrusted config — CLI flags, nntxt — before any graph building).
pub fn has_model(name: &str) -> bool {
    model_names().contains(&name)
}

/// Build `name` on `g` from input `x`; returns logits, or a clean
/// error listing the zoo for an unknown name (untrusted-config entry;
/// [`build_model`] is the panicking wrapper for callers that already
/// validated).
pub fn try_build_model(g: &mut Gb, name: &str, x: &T, classes: usize) -> Result<T, String> {
    Ok(match name {
        "mlp" => mlp(g, x, classes),
        "lenet" => lenet(g, x, classes),
        "resnet18" => {
            let h = resnet_backbone(g, x, &[8, 16, 32], &[2, 2, 2], false, 1, false);
            classifier_head(g, &h, classes)
        }
        "resnet50" => {
            let h = resnet_backbone(g, x, &[24, 48, 96], &[2, 3, 2], true, 1, false);
            classifier_head(g, &h, classes)
        }
        "resnext50" => {
            let h = resnet_backbone(g, x, &[24, 48, 96], &[2, 3, 2], true, 4, false);
            classifier_head(g, &h, classes)
        }
        "se_resnet50" => {
            let h = resnet_backbone(g, x, &[24, 48, 96], &[2, 3, 2], true, 1, true);
            classifier_head(g, &h, classes)
        }
        "se_resnext50" => {
            let h = resnet_backbone(g, x, &[24, 48, 96], &[2, 3, 2], true, 4, true);
            classifier_head(g, &h, classes)
        }
        "mobilenet_v3_small" => mobilenet_v3(g, x, false, classes),
        "mobilenet_v3_large" => mobilenet_v3(g, x, true, classes),
        "efficientnet_b0" => efficientnet(g, x, 1.0, 1.0, classes),
        "efficientnet_b1" => efficientnet(g, x, 1.0, 1.3, classes),
        "efficientnet_b2" => efficientnet(g, x, 1.15, 1.6, classes),
        "efficientnet_b3" => efficientnet(g, x, 1.3, 2.0, classes),
        other => {
            return Err(format!(
                "unknown model '{other}' (available: {:?})",
                model_names()
            ))
        }
    })
}

/// Build `name` on `g` from input `x`; returns logits. Panics on an
/// unknown name — internal callers pass validated names; untrusted
/// paths go through [`try_build_model`] / [`has_model`].
pub fn build_model(g: &mut Gb, name: &str, x: &T, classes: usize) -> T {
    try_build_model(g, name, x, classes).unwrap_or_else(|e| panic!("{e}"))
}

/// Input dims (without batch) for a zoo model.
pub fn input_dims(name: &str) -> Vec<usize> {
    match name {
        "mlp" => vec![64],
        "lenet" => vec![1, 28, 28],
        _ => vec![3, 16, 16],
    }
}

/// Build `name` in eval mode with freshly-seeded parameters and export
/// it as (definition, parameter snapshot) — the one entry point the
/// serving CLI, benches, and tests share for "give me a runnable model
/// without an `.nnp` on disk". Resets the parameter registry.
pub fn export_eval(
    name: &str,
    seed: u64,
) -> (crate::nnp::NetworkDef, std::collections::HashMap<String, crate::tensor::NdArray>) {
    crate::parametric::clear_parameters();
    crate::parametric::seed_parameter_rng(seed);
    let dims: Vec<usize> = std::iter::once(1).chain(input_dims(name)).collect();
    let mut g = Gb::new(name, false);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, name, &x, 10);
    let def = g.finish(&[&logits]);
    let params = crate::parametric::get_parameters()
        .into_iter()
        .map(|(n, v)| (n, v.data()))
        .collect();
    (def, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parametric::{clear_parameters, get_parameters, seed_parameter_rng};
    use crate::tensor::Rng;

    fn reset() {
        clear_parameters();
        seed_parameter_rng(5);
    }

    #[test]
    fn every_model_builds_and_forwards() {
        for name in model_names() {
            reset();
            let mut g = Gb::new(name, true);
            let dims: Vec<usize> = std::iter::once(2).chain(input_dims(name)).collect();
            let x = g.input("x", &dims);
            let y = build_model(&mut g, name, &x, 10);
            assert_eq!(y.var.dims(), vec![2, 10], "{name} logits shape");
            let def = g.finish(&[&y]);
            assert!(def.validate().is_ok(), "{name} IR invalid");
            // forward with real data works
            let mut rng = Rng::new(1);
            x.var.set_data(rng.randn(&dims, 1.0));
            y.var.forward();
            assert!(!y.var.data().has_inf_or_nan(), "{name} produced inf/nan");
        }
    }

    #[test]
    fn table2_models_ordered_by_cost() {
        // the Table 2 "shape": rn18 < rn50 < rnext50 <= se variants
        let macs: Vec<u64> = ["resnet18", "resnet50", "se_resnet50", "se_resnext50"]
            .iter()
            .map(|name| {
                reset();
                let mut g = Gb::new(name, true);
                let x = g.input("x", &[1, 3, 16, 16]);
                let _ = build_model(&mut g, name, &x, 10);
                g.macs()
            })
            .collect();
        assert!(macs[0] < macs[1], "rn18 {} !< rn50 {}", macs[0], macs[1]);
        assert!(macs[1] <= macs[2], "rn50 {} !<= se_rn50 {}", macs[1], macs[2]);
    }

    #[test]
    fn efficientnet_compound_scaling_grows() {
        let params: Vec<usize> = ["efficientnet_b0", "efficientnet_b1", "efficientnet_b2", "efficientnet_b3"]
            .iter()
            .map(|name| {
                reset();
                let mut g = Gb::new(name, true);
                let x = g.input("x", &[1, 3, 16, 16]);
                let _ = build_model(&mut g, name, &x, 10);
                get_parameters().iter().map(|(_, v)| v.size()).sum()
            })
            .collect();
        assert!(params.windows(2).all(|w| w[0] < w[1]), "{params:?}");
    }

    #[test]
    fn gradients_flow_through_se_resnext() {
        reset();
        let mut g = Gb::new("se_resnext50", true);
        let x = g.input("x", &[2, 3, 16, 16]);
        let y = build_model(&mut g, "se_resnext50", &x, 10);
        let mut rng = Rng::new(2);
        x.var.set_data(rng.randn(&[2, 3, 16, 16], 1.0));
        y.var.forward();
        crate::functions::mean_all(&y.var).backward();
        let trainable_with_grad = get_parameters()
            .iter()
            .filter(|(_, v)| v.need_grad() && v.grad().norm2() > 0.0)
            .count();
        let trainable: usize =
            get_parameters().iter().filter(|(_, v)| v.need_grad()).count();
        assert!(
            trainable_with_grad * 10 >= trainable * 9,
            "{trainable_with_grad}/{trainable} params got grads"
        );
    }

    #[test]
    #[should_panic(expected = "unknown model")]
    fn unknown_model_panics_with_listing() {
        let mut g = Gb::new("x", true);
        let x = g.input("x", &[1, 3, 16, 16]);
        let _ = build_model(&mut g, "vgg999", &x, 10);
    }

    #[test]
    fn unknown_model_errs_cleanly_on_the_try_path() {
        let mut g = Gb::new("x", true);
        let x = g.input("x", &[1, 3, 16, 16]);
        let err = try_build_model(&mut g, "vgg999", &x, 10).unwrap_err();
        assert!(err.contains("unknown model 'vgg999'"), "{err}");
        assert!(err.contains("resnet18"), "error must list the zoo: {err}");
        assert!(!has_model("vgg999"));
        assert!(has_model("lenet"));
    }
}
