//! `Gb` — the graph builder. Each method applies the corresponding
//! `PF::*`/`F::*` to the live tape (so the result trains immediately)
//! *and* appends the layer to a [`NetworkDef`] (so the same definition
//! exports, converts, deploys, and is footprint-countable). One model
//! definition, every backend — the usability thesis of §2.1.

use crate::functions as F;
use crate::graph::Variable;
use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use crate::parametric as PF;
use crate::tensor::NdArray;

/// A tracked tensor: live variable + IR name.
#[derive(Clone)]
pub struct T {
    pub var: Variable,
    pub name: String,
}

/// Graph + IR builder.
pub struct Gb {
    /// Training mode: batch-stat BN, active dropout.
    pub train: bool,
    def: NetworkDef,
    next: usize,
    macs: u64,
}

impl Gb {
    pub fn new(model_name: &str, train: bool) -> Self {
        Gb {
            train,
            def: NetworkDef { name: model_name.to_string(), ..Default::default() },
            next: 0,
            macs: 0,
        }
    }

    fn fresh(&mut self) -> String {
        self.next += 1;
        format!("t{}", self.next)
    }

    fn push(&mut self, lname: &str, op: Op, inputs: &[&T], params: Vec<String>, var: Variable) -> T {
        let out = self.fresh();
        self.def.layers.push(Layer {
            name: lname.to_string(),
            op,
            inputs: inputs.iter().map(|t| t.name.clone()).collect(),
            params,
            outputs: vec![out.clone()],
        });
        T { var, name: out }
    }

    /// Declare a network input.
    pub fn input(&mut self, name: &str, dims: &[usize]) -> T {
        self.def.inputs.push(TensorDef { name: name.to_string(), dims: dims.to_vec() });
        T { var: Variable::new(dims, false), name: name.to_string() }
    }

    /// Finish: mark outputs, return (validated) definition.
    pub fn finish(mut self, outputs: &[&T]) -> NetworkDef {
        self.def.outputs = outputs.iter().map(|t| t.name.clone()).collect();
        self.def.validate().expect("builder produced invalid network");
        self.def
    }

    /// Multiply-accumulate footprint so far (Console §5.1 readout).
    pub fn macs(&self) -> u64 {
        self.macs
    }

    // ------------------------------------------------------- parametric

    pub fn affine(&mut self, x: &T, n_out: usize, name: &str) -> T {
        let fan_in: usize = x.var.dims()[1..].iter().product();
        let batch = x.var.dims()[0];
        let y = PF::affine(&x.var, n_out, name);
        self.macs += (batch * fan_in * n_out) as u64;
        self.push(
            name,
            Op::Affine,
            &[x],
            vec![format!("{name}/affine/W"), format!("{name}/affine/b")],
            y,
        )
    }

    pub fn conv(
        &mut self,
        x: &T,
        outmaps: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        name: &str,
    ) -> T {
        let inmaps = x.var.dims()[1];
        let y = PF::convolution(&x.var, outmaps, kernel, stride, pad, name);
        let out_elems: usize = y.dims().iter().product();
        self.macs += (out_elems * inmaps * kernel.0 * kernel.1) as u64;
        self.push(
            name,
            Op::Convolution { stride, pad, dilation: (1, 1) },
            &[x],
            vec![format!("{name}/conv/W"), format!("{name}/conv/b")],
            y,
        )
    }

    /// Grouped convolution (ResNeXt cardinality / depthwise when
    /// `groups == channels`), lowered to split + conv-per-group +
    /// concat — expressible in every converter target.
    pub fn group_conv(
        &mut self,
        x: &T,
        outmaps: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        groups: usize,
        name: &str,
    ) -> T {
        let c = x.var.dims()[1];
        assert!(c % groups == 0 && outmaps % groups == 0, "groups must divide channels");
        if groups == 1 {
            return self.conv(x, outmaps, kernel, stride, pad, name);
        }
        let cg = c / groups;
        let og = outmaps / groups;
        let mut parts = Vec::with_capacity(groups);
        for g in 0..groups {
            let slice = self.slice_channels(x, g * cg, (g + 1) * cg, &format!("{name}/slice{g}"));
            parts.push(self.conv(&slice, og, kernel, stride, pad, &format!("{name}/g{g}")));
        }
        let refs: Vec<&T> = parts.iter().collect();
        self.concat(&refs, 1, &format!("{name}/cat"))
    }

    pub fn bn(&mut self, x: &T, name: &str) -> T {
        let y = PF::batch_normalization(&x.var, self.train, name);
        self.push(
            name,
            Op::BatchNorm { eps: 1e-5 },
            &[x],
            vec![
                format!("{name}/bn/beta"),
                format!("{name}/bn/gamma"),
                format!("{name}/bn/mean"),
                format!("{name}/bn/var"),
            ],
            y,
        )
    }

    pub fn layer_norm(&mut self, x: &T, name: &str) -> T {
        let y = PF::layer_normalization(&x.var, name);
        self.push(
            name,
            Op::LayerNorm { eps: 1e-5 },
            &[x],
            vec![format!("{name}/ln/beta"), format!("{name}/ln/gamma")],
            y,
        )
    }

    pub fn embed(&mut self, ids: &T, vocab: usize, dim: usize, name: &str) -> T {
        let y = PF::embed(&ids.var, vocab, dim, name);
        self.push(name, Op::Embed, &[ids], vec![format!("{name}/embed/W")], y)
    }

    // ------------------------------------------------------ activations

    fn unary(&mut self, x: &T, op: Op, var: Variable, name: &str) -> T {
        self.push(name, op, &[x], vec![], var)
    }

    pub fn relu(&mut self, x: &T) -> T {
        let y = F::relu(&x.var);
        self.unary(x, Op::ReLU, y, "relu")
    }

    pub fn swish(&mut self, x: &T) -> T {
        let y = F::swish(&x.var);
        self.unary(x, Op::Swish, y, "swish")
    }

    pub fn sigmoid(&mut self, x: &T) -> T {
        let y = F::sigmoid(&x.var);
        self.unary(x, Op::Sigmoid, y, "sigmoid")
    }

    pub fn gelu(&mut self, x: &T) -> T {
        let y = F::gelu(&x.var);
        self.unary(x, Op::Gelu, y, "gelu")
    }

    pub fn softmax(&mut self, x: &T) -> T {
        let y = F::softmax(&x.var);
        self.unary(x, Op::Softmax, y, "softmax")
    }

    pub fn dropout(&mut self, x: &T, p: f32, name: &str) -> T {
        // active only in training; always recorded (inference no-op)
        let y = if self.train { F::dropout(&x.var, p) } else { x.var.clone() };
        self.push(name, Op::Dropout { p }, &[x], vec![], y)
    }

    // ----------------------------------------------------------- shapes

    pub fn max_pool(&mut self, x: &T, kernel: (usize, usize), stride: (usize, usize)) -> T {
        let y = F::max_pooling(&x.var, kernel, stride, (0, 0));
        self.push("max_pool", Op::MaxPool { kernel, stride, pad: (0, 0) }, &[x], vec![], y)
    }

    pub fn global_avg_pool(&mut self, x: &T) -> T {
        let y = F::global_average_pooling(&x.var);
        self.push("gap", Op::GlobalAvgPool, &[x], vec![], y)
    }

    pub fn add(&mut self, a: &T, b: &T, name: &str) -> T {
        let y = F::add(&a.var, &b.var);
        self.push(name, Op::Add2, &[a, b], vec![], y)
    }

    pub fn mul(&mut self, a: &T, b: &T, name: &str) -> T {
        let y = F::mul(&a.var, &b.var);
        self.push(name, Op::Mul2, &[a, b], vec![], y)
    }

    pub fn concat(&mut self, parts: &[&T], axis: usize, name: &str) -> T {
        let vars: Vec<&Variable> = parts.iter().map(|t| &t.var).collect();
        let y = F::concat(&vars, axis);
        self.push(name, Op::Concat { axis }, parts, vec![], y)
    }

    pub fn reshape(&mut self, x: &T, dims: &[i64], name: &str) -> T {
        let batch = x.var.dims()[0];
        let resolved: Vec<usize> = dims
            .iter()
            .enumerate()
            .map(|(i, &d)| {
                if d == -1 {
                    usize::MAX
                } else if d == 0 && i == 0 {
                    batch
                } else {
                    d as usize
                }
            })
            .collect();
        let y = F::reshape(&x.var, &resolved);
        self.push(name, Op::Reshape { dims: dims.to_vec() }, &[x], vec![], y)
    }

    pub fn slice_channels(&mut self, x: &T, start: usize, stop: usize, name: &str) -> T {
        // IR has no Slice op: express as a fixed 1x1 "selector" conv?
        // No — keep the IR honest: record as Identity on a sliced
        // tensor is not convertible. Instead we model group-conv slices
        // with a Concat-compatible trick: slice on the live graph and
        // register a Reshape-free pseudo-layer. For convertibility,
        // the slice is recorded as a 1x1 Convolution with a constant
        // selector kernel parameter.
        let c = x.var.dims()[1];
        let width = stop - start;
        let y = F::slice_axis(&x.var, 1, start, stop);
        // constant selector kernel [width, c, 1, 1]: one-hot rows
        let pname = format!("{name}/selector/W");
        let existing = PF::get_parameter(&pname);
        if existing.is_none() {
            let mut w = NdArray::zeros(&[width, c, 1, 1]);
            for i in 0..width {
                w.set(&[i, start + i, 0, 0], 1.0);
            }
            PF::set_parameter(&pname, Variable::from_array(w, false));
        }
        self.push(
            name,
            Op::Convolution { stride: (1, 1), pad: (0, 0), dilation: (1, 1) },
            &[x],
            vec![pname],
            y,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::interpreter;
    use crate::parametric::{clear_parameters, get_parameters, seed_parameter_rng};
    use crate::tensor::Rng;
    use std::collections::HashMap;

    fn reset() {
        clear_parameters();
        seed_parameter_rng(1);
    }

    fn mini_cnn(train: bool) -> (NetworkDef, T, T) {
        let mut g = Gb::new("mini", train);
        let x = g.input("x", &[2, 3, 8, 8]);
        let h = g.conv(&x, 4, (3, 3), (1, 1), (1, 1), "c1");
        let h = g.bn(&h, "bn1");
        let h = g.relu(&h);
        let h = g.global_avg_pool(&h);
        let y = g.affine(&h, 10, "head");
        let def = g.finish(&[&y]);
        (def, x, y)
    }

    #[test]
    fn builds_live_graph_and_ir_together() {
        reset();
        let (def, x, y) = mini_cnn(true);
        assert_eq!(y.var.dims(), vec![2, 10]);
        assert_eq!(def.layers.len(), 5);
        assert!(def.validate().is_ok());
        // live graph trains
        let mut rng = Rng::new(2);
        x.var.set_data(rng.randn(&[2, 3, 8, 8], 1.0));
        y.var.forward();
        crate::functions::mean_all(&y.var).backward();
        let (_, w) = get_parameters().into_iter().find(|(n, _)| n == "c1/conv/W").unwrap();
        assert!(w.grad().norm2() > 0.0);
    }

    #[test]
    fn ir_interpreter_matches_live_graph() {
        reset();
        let (def, x, y) = mini_cnn(false); // eval mode: BN uses running stats
        let mut rng = Rng::new(3);
        let input = rng.randn(&[2, 3, 8, 8], 1.0);
        x.var.set_data(input.clone());
        y.var.forward();
        let live = y.var.data();

        let params: HashMap<String, NdArray> =
            get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), input);
        let interp = interpreter::run(&def, &inputs, &params).unwrap();
        assert!(
            live.allclose(&interp[0], 1e-4, 1e-4),
            "max diff {}",
            live.max_abs_diff(&interp[0])
        );
    }

    #[test]
    fn group_conv_slices_convert_faithfully() {
        reset();
        let mut g = Gb::new("grp", false);
        let x = g.input("x", &[1, 4, 4, 4]);
        let y = g.group_conv(&x, 8, (3, 3), (1, 1), (1, 1), 2, "gc");
        let def = g.finish(&[&y]);
        let mut rng = Rng::new(4);
        let input = rng.randn(&[1, 4, 4, 4], 1.0);
        x.var.set_data(input.clone());
        y.var.forward();
        let live = y.var.data();
        let params: HashMap<String, NdArray> =
            get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), input);
        let interp = interpreter::run(&def, &inputs, &params).unwrap();
        assert!(live.allclose(&interp[0], 1e-4, 1e-4));
    }

    #[test]
    fn macs_counted() {
        reset();
        let (_, _, _) = mini_cnn(true);
        // rebuild with a fresh Gb to read macs
        reset();
        let mut g = Gb::new("m", true);
        let x = g.input("x", &[1, 1, 4, 4]);
        let _ = g.conv(&x, 2, (3, 3), (1, 1), (1, 1), "c");
        // out 1x2x4x4 = 32 elems x (1*3*3) = 288
        assert_eq!(g.macs(), 288);
    }

    #[test]
    fn dropout_recorded_but_inert_in_eval() {
        reset();
        let mut g = Gb::new("d", false);
        let x = g.input("x", &[1, 4]);
        let y = g.dropout(&x, 0.5, "drop");
        let def = g.finish(&[&y]);
        assert!(matches!(def.layers[0].op, Op::Dropout { .. }));
        x.var.set_data(NdArray::ones(&[1, 4]));
        y.var.forward();
        assert_eq!(y.var.data().data(), &[1., 1., 1., 1.]);
    }
}
