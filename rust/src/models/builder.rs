//! `Gb` — the graph builder, now a *thin convenience wrapper* over the
//! self-describing tape.
//!
//! ## Migration note (dual-recording → trace)
//!
//! `Gb` used to dual-record: every method applied `PF::*`/`F::*` to the
//! live tape *and* appended a shadow layer to a [`NetworkDef`] by hand.
//! Since the tape now carries a first-class [`crate::nnp::Op`]
//! descriptor on every node, the shadow bookkeeping is gone:
//! [`Gb::finish`] simply calls [`crate::nnp::trace`] on the outputs and
//! the IR falls out of the graph itself. Two practical consequences:
//!
//! - **`Gb` is optional.** A graph built from raw `F::*`/`PF::*` calls
//!   (Listing 1 style) exports identically — name your input variables
//!   with `set_name` and call `nnp::trace(name, &[&y])`.
//! - **The IR is the tape.** What executes live is exactly what
//!   exports; there is no way for the two to drift (the old
//!   `slice_channels` selector-convolution hack is gone — grouped
//!   convolutions trace to first-class `Slice` layers).
//!
//! What `Gb` still adds on top of tracing: naming ergonomics (inputs
//! and intermediate tensors get stable `t<N>` names), the train/eval
//! switch (batch-stat BN, sampled vs inert dropout), and the Console's
//! multiply-accumulate footprint accounting ([`Gb::macs`], §5.1).

use crate::functions as F;
use crate::graph::Variable;
use crate::nnp::{trace, NetworkDef};
use crate::parametric as PF;

/// A tracked tensor: live variable + its tape name (used as the IR
/// tensor name when the graph is traced).
#[derive(Clone)]
pub struct T {
    pub var: Variable,
    pub name: String,
}

/// Graph builder: applies parametric/functional ops to the live tape,
/// names the tensors, and counts MACs. The IR comes from tracing.
pub struct Gb {
    /// Training mode: batch-stat BN, active dropout.
    pub train: bool,
    model_name: String,
    next: usize,
    macs: u64,
}

impl Gb {
    pub fn new(model_name: &str, train: bool) -> Self {
        Gb { train, model_name: model_name.to_string(), next: 0, macs: 0 }
    }

    /// Track a produced variable: name it `name` (user-chosen, kept in
    /// the traced IR) or auto-assign `t<N>`.
    fn track(&mut self, var: Variable, name: Option<&str>) -> T {
        self.next += 1;
        let name = match name {
            Some(n) => n.to_string(),
            None => format!("t{}", self.next),
        };
        var.set_name(&name);
        T { var, name }
    }

    /// Declare a (named) network input.
    pub fn input(&mut self, name: &str, dims: &[usize]) -> T {
        let var = Variable::new(dims, false);
        var.set_name(name);
        T { var, name: name.to_string() }
    }

    /// Finish: trace the tape from `outputs` into a validated
    /// [`NetworkDef`] — no dual bookkeeping, the graph describes itself.
    pub fn finish(self, outputs: &[&T]) -> NetworkDef {
        let vars: Vec<&Variable> = outputs.iter().map(|t| &t.var).collect();
        trace(&self.model_name, &vars).expect("builder produced untraceable network")
    }

    /// Multiply-accumulate footprint so far (Console §5.1 readout).
    pub fn macs(&self) -> u64 {
        self.macs
    }

    // ------------------------------------------------------- parametric

    pub fn affine(&mut self, x: &T, n_out: usize, name: &str) -> T {
        let fan_in: usize = x.var.dims()[1..].iter().product();
        let batch = x.var.dims()[0];
        let y = PF::affine(&x.var, n_out, name);
        self.macs += (batch * fan_in * n_out) as u64;
        self.track(y, None)
    }

    pub fn conv(
        &mut self,
        x: &T,
        outmaps: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        name: &str,
    ) -> T {
        let inmaps = x.var.dims()[1];
        let y = PF::convolution(&x.var, outmaps, kernel, stride, pad, name);
        let out_elems: usize = y.dims().iter().product();
        self.macs += (out_elems * inmaps * kernel.0 * kernel.1) as u64;
        self.track(y, None)
    }

    /// Grouped convolution (ResNeXt cardinality / depthwise when
    /// `groups == channels`), lowered to slice + conv-per-group +
    /// concat. `Slice` is a first-class registry op, so the lowering
    /// traces and converts faithfully — no selector-kernel tricks.
    pub fn group_conv(
        &mut self,
        x: &T,
        outmaps: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        groups: usize,
        name: &str,
    ) -> T {
        let c = x.var.dims()[1];
        assert!(c % groups == 0 && outmaps % groups == 0, "groups must divide channels");
        if groups == 1 {
            return self.conv(x, outmaps, kernel, stride, pad, name);
        }
        let cg = c / groups;
        let og = outmaps / groups;
        let mut parts = Vec::with_capacity(groups);
        for g in 0..groups {
            let slice = self.slice_channels(x, g * cg, (g + 1) * cg, &format!("{name}/slice{g}"));
            parts.push(self.conv(&slice, og, kernel, stride, pad, &format!("{name}/g{g}")));
        }
        let refs: Vec<&T> = parts.iter().collect();
        self.concat(&refs, 1, &format!("{name}/cat"))
    }

    pub fn bn(&mut self, x: &T, name: &str) -> T {
        let y = PF::batch_normalization(&x.var, self.train, name);
        self.track(y, None)
    }

    pub fn layer_norm(&mut self, x: &T, name: &str) -> T {
        let y = PF::layer_normalization(&x.var, name);
        self.track(y, None)
    }

    pub fn embed(&mut self, ids: &T, vocab: usize, dim: usize, name: &str) -> T {
        let y = PF::embed(&ids.var, vocab, dim, name);
        self.track(y, None)
    }

    // ------------------------------------------------------ activations

    pub fn relu(&mut self, x: &T) -> T {
        let y = F::relu(&x.var);
        self.track(y, None)
    }

    pub fn swish(&mut self, x: &T) -> T {
        let y = F::swish(&x.var);
        self.track(y, None)
    }

    pub fn sigmoid(&mut self, x: &T) -> T {
        let y = F::sigmoid(&x.var);
        self.track(y, None)
    }

    pub fn gelu(&mut self, x: &T) -> T {
        let y = F::gelu(&x.var);
        self.track(y, None)
    }

    pub fn softmax(&mut self, x: &T) -> T {
        let y = F::softmax(&x.var);
        self.track(y, None)
    }

    pub fn dropout(&mut self, x: &T, p: f32, name: &str) -> T {
        // active only in training; recorded either way (the inference
        // variant is an identity node that still carries Op::Dropout,
        // so the traced IR keeps the layer for re-training)
        let y = if self.train { F::dropout(&x.var, p) } else { F::dropout_inference(&x.var, p) };
        self.track(y, Some(name))
    }

    // ----------------------------------------------------------- shapes

    pub fn max_pool(&mut self, x: &T, kernel: (usize, usize), stride: (usize, usize)) -> T {
        let y = F::max_pooling(&x.var, kernel, stride, (0, 0));
        self.track(y, None)
    }

    pub fn global_avg_pool(&mut self, x: &T) -> T {
        let y = F::global_average_pooling(&x.var);
        self.track(y, None)
    }

    pub fn add(&mut self, a: &T, b: &T, name: &str) -> T {
        let y = F::add(&a.var, &b.var);
        self.track(y, Some(name))
    }

    pub fn mul(&mut self, a: &T, b: &T, name: &str) -> T {
        let y = F::mul(&a.var, &b.var);
        self.track(y, Some(name))
    }

    pub fn concat(&mut self, parts: &[&T], axis: usize, name: &str) -> T {
        let vars: Vec<&Variable> = parts.iter().map(|t| &t.var).collect();
        let y = F::concat(&vars, axis);
        self.track(y, Some(name))
    }

    pub fn reshape(&mut self, x: &T, dims: &[i64], name: &str) -> T {
        let y = F::reshape_spec(&x.var, dims);
        self.track(y, Some(name))
    }

    /// Channel-window slice, recorded as a first-class `Slice` layer.
    pub fn slice_channels(&mut self, x: &T, start: usize, stop: usize, name: &str) -> T {
        let y = F::slice_axis(&x.var, 1, start, stop);
        self.track(y, Some(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::interpreter;
    use crate::nnp::Op;
    use crate::parametric::{clear_parameters, get_parameters, seed_parameter_rng};
    use crate::tensor::{NdArray, Rng};
    use std::collections::HashMap;

    fn reset() {
        clear_parameters();
        seed_parameter_rng(1);
    }

    fn mini_cnn(train: bool) -> (NetworkDef, T, T) {
        let mut g = Gb::new("mini", train);
        let x = g.input("x", &[2, 3, 8, 8]);
        let h = g.conv(&x, 4, (3, 3), (1, 1), (1, 1), "c1");
        let h = g.bn(&h, "bn1");
        let h = g.relu(&h);
        let h = g.global_avg_pool(&h);
        let y = g.affine(&h, 10, "head");
        let def = g.finish(&[&y]);
        (def, x, y)
    }

    #[test]
    fn builds_live_graph_and_traced_ir_together() {
        reset();
        let (def, x, y) = mini_cnn(true);
        assert_eq!(y.var.dims(), vec![2, 10]);
        assert_eq!(def.layers.len(), 5);
        assert!(def.validate().is_ok());
        // layer names derive from parameter scopes
        assert_eq!(def.layers[0].name, "c1");
        assert_eq!(def.layers[0].params, vec!["c1/conv/W", "c1/conv/b"]);
        assert_eq!(def.layers[1].name, "bn1");
        assert_eq!(def.layers[4].name, "head");
        // live graph trains
        let mut rng = Rng::new(2);
        x.var.set_data(rng.randn(&[2, 3, 8, 8], 1.0));
        y.var.forward();
        crate::functions::mean_all(&y.var).backward();
        let (_, w) = get_parameters().into_iter().find(|(n, _)| n == "c1/conv/W").unwrap();
        assert!(w.grad().norm2() > 0.0);
    }

    #[test]
    fn ir_interpreter_matches_live_graph() {
        reset();
        let (def, x, y) = mini_cnn(false); // eval mode: BN uses running stats
        let mut rng = Rng::new(3);
        let input = rng.randn(&[2, 3, 8, 8], 1.0);
        x.var.set_data(input.clone());
        y.var.forward();
        let live = y.var.data();

        let params: HashMap<String, NdArray> =
            get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), input);
        let interp = interpreter::run(&def, &inputs, &params).unwrap();
        // same kernels through the same Op dispatch: exactly equal
        assert_eq!(live.data(), interp[0].data(), "trace→interpreter must be bit-identical");
    }

    #[test]
    fn group_conv_traces_to_slice_layers_and_matches() {
        reset();
        let mut g = Gb::new("grp", false);
        let x = g.input("x", &[1, 4, 4, 4]);
        let y = g.group_conv(&x, 8, (3, 3), (1, 1), (1, 1), 2, "gc");
        let def = g.finish(&[&y]);
        assert!(def.layers.iter().any(|l| matches!(l.op, Op::Slice { .. })));
        let mut rng = Rng::new(4);
        let input = rng.randn(&[1, 4, 4, 4], 1.0);
        x.var.set_data(input.clone());
        y.var.forward();
        let live = y.var.data();
        let params: HashMap<String, NdArray> =
            get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), input);
        let interp = interpreter::run(&def, &inputs, &params).unwrap();
        assert_eq!(live.data(), interp[0].data());
    }

    #[test]
    fn macs_counted() {
        reset();
        let mut g = Gb::new("m", true);
        let x = g.input("x", &[1, 1, 4, 4]);
        let _ = g.conv(&x, 2, (3, 3), (1, 1), (1, 1), "c");
        // out 1x2x4x4 = 32 elems x (1*3*3) = 288
        assert_eq!(g.macs(), 288);
    }

    #[test]
    fn dropout_recorded_but_inert_in_eval() {
        reset();
        let mut g = Gb::new("d", false);
        let x = g.input("x", &[1, 4]);
        let y = g.dropout(&x, 0.5, "drop");
        let def = g.finish(&[&y]);
        assert!(matches!(def.layers[0].op, Op::Dropout { .. }));
        x.var.set_data(NdArray::ones(&[1, 4]));
        y.var.forward();
        assert_eq!(y.var.data().data(), &[1., 1., 1., 1.]);
    }

    #[test]
    fn unused_input_simply_absent_from_trace() {
        reset();
        let mut g = Gb::new("u", false);
        let _unused = g.input("ghost", &[1, 2]);
        let x = g.input("x", &[1, 2]);
        let y = g.relu(&x);
        let def = g.finish(&[&y]);
        assert_eq!(def.inputs.len(), 1);
        assert_eq!(def.inputs[0].name, "x");
    }
}
