//! Deterministic fault injection for the serving stack.
//!
//! Robustness claims are only as good as the failures they were tested
//! against, so this module makes failure a first-class, *reproducible*
//! input: a seeded [`Schedule`] maps named injection [`Point`]s (queue
//! admission, worker execution, pool chunk dispatch, net read/write,
//! artifact decode) to faults — panics, delays, I/O errors, corrupt
//! frames — fired deterministically from `hash(seed, point, hit#)`.
//! The same seed and spec always produce the same fault sequence, so a
//! chaos-test failure replays exactly.
//!
//! The whole layer is gated behind the `chaos` cargo feature. With the
//! feature **off** (every production build), [`fired`] is an
//! `#[inline(always)]` `None`: the helpers below constant-fold away
//! and the injection points in `serve`, `serve::net`, and
//! `tensor::parallel` cost literally nothing — the CI `chaos` job
//! asserts the serve bench is unchanged. With the feature **on**, a
//! schedule is armed either from the environment
//! (`NNL_CHAOS_SPEC` + `NNL_CHAOS_SEED`) on first use or
//! programmatically via [`install`]/[`clear`] in tests.
//!
//! Spec grammar (comma-separated rules):
//!
//! ```text
//! point:kind[:rate[:param]]
//!   point ∈ admit | exec | worker | pool | net.read | net.write | decode
//!         | comm.connect | comm.send | comm.recv
//!   kind  ∈ panic | delay | ioerr | corrupt
//!   rate  ∈ [0.0, 1.0]   probability per hit (default 1.0)
//!   param = delay millis (delay) or corruption salt (corrupt); default 5
//! ```
//!
//! Example: `NNL_CHAOS_SPEC="exec:panic:0.1,net.write:corrupt:0.2" \
//! NNL_CHAOS_SEED=42 cargo test --features chaos --test chaos_serve`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Named injection points, one per fault-tolerance boundary the
/// serving stack defends. The short names are the spec syntax.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Point {
    /// `admit` — request admission in `serve::submit_on`, before the
    /// bounded queue is touched.
    QueueAdmit,
    /// `exec` — inside a serve worker's `catch_unwind` boundary,
    /// alongside `InferencePlan::execute_positional`. A panic here
    /// must become a typed `ServeError::Internal` for that request.
    WorkerExec,
    /// `worker` — a serve worker's batch loop, *outside* the
    /// per-request boundary. A panic here kills the worker iteration:
    /// the reply guard must still answer every held request and
    /// supervision must resurrect the worker.
    WorkerLoop,
    /// `pool` — a `tensor::parallel` pool worker between taking a job
    /// and draining chunks. The submitter always drains remaining
    /// chunks itself, so a dying pool worker may slow a job but never
    /// hang it.
    PoolDispatch,
    /// `net.read` — the connection handler's socket read path.
    NetRead,
    /// `net.write` — the connection handler's binary reply path;
    /// `corrupt` truncates the reply payload (detectably) so clients
    /// exercise resync + retry.
    NetWrite,
    /// `decode` — artifact bytes entering `Registry::deploy_artifact`;
    /// `corrupt` flips bits so the decoder/verifier rejection path is
    /// exercised with real damage.
    ArtifactDecode,
    /// `comm.connect` — every TCP dial/accept attempt in
    /// `comm::net` rendezvous and ring wiring. An `ioerr` here
    /// simulates a peer that never comes up; the joiner must surface
    /// a typed `CommError` within its connect deadline.
    CommConnect,
    /// `comm.send` — a ring hop leaving a rank; `corrupt` truncates
    /// the segment payload so the receiving rank's bounds-checked
    /// decoder reports `CommError::Protocol`.
    CommSend,
    /// `comm.recv` — a ring hop arriving at a rank; `ioerr`/`delay`
    /// model a dropped or stalled peer, which must surface as a typed
    /// error at *every* surviving rank within the step deadline.
    CommRecv,
}

/// Number of distinct injection points (sizes per-point hit counters).
const N_POINTS: usize = 10;

impl Point {
    /// Every injection point, in spec-name order.
    pub const ALL: [Point; N_POINTS] = [
        Point::QueueAdmit,
        Point::WorkerExec,
        Point::WorkerLoop,
        Point::PoolDispatch,
        Point::NetRead,
        Point::NetWrite,
        Point::ArtifactDecode,
        Point::CommConnect,
        Point::CommSend,
        Point::CommRecv,
    ];

    /// The spec-syntax name (`admit`, `exec`, `worker`, `pool`,
    /// `net.read`, `net.write`, `decode`, `comm.connect`, `comm.send`,
    /// `comm.recv`).
    pub fn name(self) -> &'static str {
        match self {
            Point::QueueAdmit => "admit",
            Point::WorkerExec => "exec",
            Point::WorkerLoop => "worker",
            Point::PoolDispatch => "pool",
            Point::NetRead => "net.read",
            Point::NetWrite => "net.write",
            Point::ArtifactDecode => "decode",
            Point::CommConnect => "comm.connect",
            Point::CommSend => "comm.send",
            Point::CommRecv => "comm.recv",
        }
    }

    fn from_name(s: &str) -> Option<Point> {
        Point::ALL.iter().copied().find(|p| p.name() == s)
    }

    fn index(self) -> usize {
        match self {
            Point::QueueAdmit => 0,
            Point::WorkerExec => 1,
            Point::WorkerLoop => 2,
            Point::PoolDispatch => 3,
            Point::NetRead => 4,
            Point::NetWrite => 5,
            Point::ArtifactDecode => 6,
            Point::CommConnect => 7,
            Point::CommSend => 8,
            Point::CommRecv => 9,
        }
    }
}

/// What a rule injects when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwind the current thread (`panic!`).
    Panic,
    /// Sleep for the rule's `param` milliseconds.
    Delay,
    /// Surface an `io::Error` (connection-reset flavored).
    IoErr,
    /// Damage bytes in flight: truncate a reply frame / flip artifact
    /// bits, per the point's [`mangle`]/[`flip_bytes`] semantics.
    Corrupt,
}

impl FaultKind {
    fn from_name(s: &str) -> Option<FaultKind> {
        match s {
            "panic" => Some(FaultKind::Panic),
            "delay" => Some(FaultKind::Delay),
            "ioerr" => Some(FaultKind::IoErr),
            "corrupt" => Some(FaultKind::Corrupt),
            _ => None,
        }
    }
}

/// One parsed spec entry: fire `kind` at `point` with probability
/// `rate` per hit; `param` is the delay in milliseconds or the
/// corruption salt.
#[derive(Debug, Clone)]
pub struct Rule {
    pub point: Point,
    pub kind: FaultKind,
    pub rate: f64,
    pub param: u64,
}

/// A fault the active schedule decided to fire at some hit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fired {
    Panic,
    Delay(Duration),
    IoErr,
    /// Carries a per-fire salt so each corruption damages different
    /// bytes while staying reproducible.
    Corrupt(u64),
}

/// A seeded fault schedule: per-point hit counters plus the rule list.
/// `decide` is pure in `(seed, point, hit#)` — two schedules built
/// from the same spec and seed fire identically.
pub struct Schedule {
    seed: u64,
    rules: Vec<Rule>,
    hits: [AtomicU64; N_POINTS],
}

impl Schedule {
    /// Parse a spec string (see module docs for the grammar).
    pub fn parse(spec: &str, seed: u64) -> Result<Schedule, String> {
        let mut rules = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let parts: Vec<&str> = entry.split(':').collect();
            if parts.len() < 2 || parts.len() > 4 {
                return Err(format!(
                    "bad chaos rule '{entry}': expected point:kind[:rate[:param]]"
                ));
            }
            let point = Point::from_name(parts[0]).ok_or_else(|| {
                let valid: Vec<&str> = Point::ALL.iter().map(|p| p.name()).collect();
                format!(
                    "unknown injection point '{}' in '{entry}' (valid: {})",
                    parts[0],
                    valid.join(", ")
                )
            })?;
            let kind = FaultKind::from_name(parts[1]).ok_or_else(|| {
                format!(
                    "unknown fault kind '{}' in '{entry}' (valid: panic, delay, ioerr, corrupt)",
                    parts[1]
                )
            })?;
            let rate = if parts.len() > 2 {
                parts[2]
                    .parse::<f64>()
                    .map_err(|_| format!("bad rate '{}' in '{entry}'", parts[2]))?
            } else {
                1.0
            };
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("rate {rate} out of [0,1] in '{entry}'"));
            }
            let param = if parts.len() > 3 {
                parts[3]
                    .parse::<u64>()
                    .map_err(|_| format!("bad param '{}' in '{entry}'", parts[3]))?
            } else {
                5
            };
            rules.push(Rule { point, kind, rate, param });
        }
        if rules.is_empty() {
            return Err("empty chaos spec".to_string());
        }
        Ok(Schedule::new(rules, seed))
    }

    /// Build a schedule from already-parsed rules.
    pub fn new(rules: Vec<Rule>, seed: u64) -> Schedule {
        Schedule { seed, rules, hits: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one hit at `point` and decide whether a rule fires.
    /// First matching rule (spec order) whose hash clears its rate
    /// wins. Deterministic in `(seed, point, hit#)`.
    pub fn decide(&self, point: Point) -> Option<Fired> {
        let k = self.hits[point.index()].fetch_add(1, Ordering::Relaxed);
        for (ri, rule) in self.rules.iter().enumerate() {
            if rule.point != point {
                continue;
            }
            let h = splitmix64(
                self.seed
                    ^ ((point.index() as u64 + 1) << 56)
                    ^ ((ri as u64 + 1) << 48)
                    ^ k,
            );
            // Top 53 bits → uniform in [0, 1).
            let u = (h >> 11) as f64 / (1u64 << 53) as f64;
            if u < rule.rate {
                return Some(match rule.kind {
                    FaultKind::Panic => Fired::Panic,
                    FaultKind::Delay => Fired::Delay(Duration::from_millis(rule.param)),
                    FaultKind::IoErr => Fired::IoErr,
                    FaultKind::Corrupt => Fired::Corrupt(splitmix64(h ^ rule.param)),
                });
            }
        }
        None
    }
}

/// SplitMix64 — the crate's standard seedable hash for reproducible
/// pseudo-randomness (also used for retry jitter in `serve`).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Flip `1 + seed % 7` bits at seed-derived offsets. Used by the
/// `decode` corrupt fault and available to tests that want
/// reproducible artifact damage.
pub fn flip_bytes(seed: u64, buf: &mut [u8]) {
    if buf.is_empty() {
        return;
    }
    let n = 1 + (seed % 7) as usize;
    let mut h = seed;
    for _ in 0..n {
        h = splitmix64(h);
        let i = (h % buf.len() as u64) as usize;
        buf[i] ^= 1u8 << ((h >> 32) & 7);
    }
}

#[cfg(feature = "chaos")]
mod active {
    use super::Schedule;
    use std::sync::{Arc, OnceLock, RwLock};

    static ACTIVE: OnceLock<RwLock<Option<Arc<Schedule>>>> = OnceLock::new();

    /// The armed schedule. Initialized once from `NNL_CHAOS_SPEC` /
    /// `NNL_CHAOS_SEED` so `--features chaos` binaries can be driven
    /// purely from the environment; tests overwrite via
    /// `install`/`clear`.
    pub(super) fn cell() -> &'static RwLock<Option<Arc<Schedule>>> {
        ACTIVE.get_or_init(|| {
            let from_env = std::env::var("NNL_CHAOS_SPEC").ok().and_then(|spec| {
                let seed = std::env::var("NNL_CHAOS_SEED")
                    .ok()
                    .and_then(|v| v.parse().ok())
                    .unwrap_or(0);
                match Schedule::parse(&spec, seed) {
                    Ok(s) => Some(Arc::new(s)),
                    Err(e) => {
                        eprintln!("NNL_CHAOS_SPEC ignored: {e}");
                        None
                    }
                }
            });
            RwLock::new(from_env)
        })
    }
}

/// Arm `schedule` globally (replacing any active one). Chaos builds
/// only; tests sharing a process must serialize around this.
#[cfg(feature = "chaos")]
pub fn install(schedule: Schedule) {
    *active::cell().write().unwrap_or_else(|e| e.into_inner()) =
        Some(std::sync::Arc::new(schedule));
}

/// Disarm fault injection (chaos builds only).
#[cfg(feature = "chaos")]
pub fn clear() {
    *active::cell().write().unwrap_or_else(|e| e.into_inner()) = None;
}

/// Record a hit at `point` against the active schedule and return the
/// fault to inject, if any. This is THE gate: with the `chaos` feature
/// off it is an inlined `None`, so every helper below folds to nothing
/// and the injection points are provably free.
#[cfg(feature = "chaos")]
#[inline]
pub fn fired(point: Point) -> Option<Fired> {
    let schedule = active::cell().read().unwrap_or_else(|e| e.into_inner()).clone();
    schedule.and_then(|s| s.decide(point))
}

/// Chaos disabled: no schedule can exist, nothing ever fires.
#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub fn fired(_point: Point) -> Option<Fired> {
    None
}

/// Injection helper for compute-path points (`admit`, `exec`,
/// `worker`, `pool`): fires panics and delays; I/O-flavored kinds are
/// meaningless here and ignored.
#[inline]
pub fn disrupt(point: Point) {
    match fired(point) {
        Some(Fired::Panic) => panic!("chaos: injected panic at {}", point.name()),
        Some(Fired::Delay(d)) => std::thread::sleep(d),
        _ => {}
    }
}

/// Injection helper for I/O-path points: may inject a connection-reset
/// error, a delay, or a panic before the guarded operation runs.
#[inline]
pub fn io_gate(point: Point) -> std::io::Result<()> {
    match fired(point) {
        Some(Fired::Panic) => panic!("chaos: injected panic at {}", point.name()),
        Some(Fired::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(Fired::IoErr) => Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            format!("chaos: injected I/O error at {}", point.name()),
        )),
        _ => Ok(()),
    }
}

/// Injection helper for outbound frames: `corrupt` truncates the
/// payload to half its length — detectable damage (the receiver's
/// bounds-checked decoder reports a truncated frame) rather than
/// silent bit rot, so chaos tests can still assert the *values* of
/// successful replies. Other kinds behave as in [`io_gate`].
#[inline]
pub fn mangle(point: Point, buf: &mut Vec<u8>) -> std::io::Result<()> {
    match fired(point) {
        Some(Fired::Panic) => panic!("chaos: injected panic at {}", point.name()),
        Some(Fired::Delay(d)) => {
            std::thread::sleep(d);
            Ok(())
        }
        Some(Fired::IoErr) => Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionReset,
            format!("chaos: injected I/O error at {}", point.name()),
        )),
        Some(Fired::Corrupt(_)) => {
            let keep = buf.len() / 2;
            buf.truncate(keep);
            Ok(())
        }
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_full_grammar() {
        let s = Schedule::parse(
            "admit:delay:0.5:2, exec:panic:0.25, net.write:corrupt, decode:corrupt:1.0:9",
            7,
        )
        .expect("valid spec");
        assert_eq!(s.rules.len(), 4);
        assert_eq!(s.rules[0].point, Point::QueueAdmit);
        assert_eq!(s.rules[0].kind, FaultKind::Delay);
        assert_eq!(s.rules[0].param, 2);
        assert_eq!(s.rules[1].rate, 0.25);
        assert_eq!(s.rules[2].rate, 1.0);
        assert_eq!(s.rules[3].param, 9);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Schedule::parse("", 0).is_err());
        assert!(Schedule::parse("nosuchpoint:panic", 0).is_err());
        assert!(Schedule::parse("exec:meteor", 0).is_err());
        assert!(Schedule::parse("exec:panic:1.5", 0).is_err());
        assert!(Schedule::parse("exec:panic:0.5:xyz", 0).is_err());
        assert!(Schedule::parse("exec", 0).is_err());
    }

    #[test]
    fn same_seed_fires_identically() {
        let mk = || Schedule::parse("exec:panic:0.3,exec:delay:0.3:1,pool:ioerr:0.5", 1234)
            .expect("valid spec");
        let (a, b) = (mk(), mk());
        for _ in 0..512 {
            assert_eq!(a.decide(Point::WorkerExec), b.decide(Point::WorkerExec));
            assert_eq!(a.decide(Point::PoolDispatch), b.decide(Point::PoolDispatch));
            // A point with no rules never fires and never disturbs
            // other points' counters.
            assert_eq!(a.decide(Point::NetRead), None);
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a = Schedule::parse("exec:panic:0.5", 1).expect("valid spec");
        let b = Schedule::parse("exec:panic:0.5", 2).expect("valid spec");
        let fires = |s: &Schedule| -> Vec<bool> {
            (0..256).map(|_| s.decide(Point::WorkerExec).is_some()).collect()
        };
        assert_ne!(fires(&a), fires(&b));
    }

    #[test]
    fn rates_are_respected_roughly() {
        let s = Schedule::parse("exec:panic:0.25", 99).expect("valid spec");
        let n = 4096;
        let hits = (0..n).filter(|_| s.decide(Point::WorkerExec).is_some()).count();
        let frac = hits as f64 / n as f64;
        assert!((0.18..0.32).contains(&frac), "rate 0.25 produced {frac}");
    }

    #[test]
    fn flip_bytes_damages_and_reproduces() {
        let orig: Vec<u8> = (0..64u8).collect();
        let mut a = orig.clone();
        let mut b = orig.clone();
        flip_bytes(0xDEAD_BEEF, &mut a);
        flip_bytes(0xDEAD_BEEF, &mut b);
        assert_ne!(a, orig, "corruption must change bytes");
        assert_eq!(a, b, "same seed must damage identically");
    }
}
