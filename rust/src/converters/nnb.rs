//! NNP → NNB: the flat binary format for the C-runtime analogue
//! ("NNP to NNB (Binary format for NNabla C Runtime)", §3).
//!
//! Two wire versions share one structural encoding (string table +
//! inputs + outputs + layer records; every tensor reference is an
//! index into the string table — the fixed-width, pointer-free
//! encoding an embedded C runtime wants):
//!
//! ```text
//! v1  magic "NNB1" | structure | param blob (params.rs format, f32)
//! v2  magic "NNB2" | structure
//!     | calib:   u32 n | (u32 name_idx, f32 lo, f32 hi)*
//!     | qparams: u32 n | (u32 name_idx, u8 kind, ...)*
//!         kind 0 (f32):  u32 rank, u64 dims*, f32 data
//!         kind 1 (i8):   u8 channel_axis, u32 rank, u64 dims*,
//!                        u32 n_scales, f32 scales*, i8 data
//! ```
//!
//! NNB2 carries int8 weight blobs plus per-channel scales and the
//! activation calibration table — the ~4×-smaller artifact of the
//! quantized deployment path (`crate::quant`). Since the quantization
//! pipeline runs the compile-time graph optimizer first
//! (`nnp::passes`), NNB2 artifacts store the *optimized* definition
//! (BatchNorm folded into dense weights, no-ops elided); artifacts
//! written before the optimizer existed still load — their BN layers
//! fold at compile time and the folded weights re-quantize at load.
//! v1 images stay fully readable.
//!
//! Execution goes through [`NnbEngine`]: decode once, compile once
//! (f32 images into a [`CompiledNet`], v2 images into a
//! [`QuantizedNet`]), execute many — the embedded-runtime analogue
//! rides the same fast path as the serving stack, not the
//! per-call interpreter. Both decoders are hardened against truncated
//! or bit-flipped images: every length is bounds-checked before any
//! allocation, so malformed bytes fail with a clean `Err`.

use std::collections::HashMap;

use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use crate::nnp::params;
use crate::nnp::plan::{CompiledNet, InferencePlan};
use crate::quant::{ActRange, CalibTable, QParam, QTensor, QuantizedModel, QuantizedNet};
use crate::tensor::NdArray;
use crate::utils::json::Json;

struct StringTable {
    strings: Vec<String>,
    index: HashMap<String, u32>,
}

impl StringTable {
    fn new() -> Self {
        StringTable { strings: Vec::new(), index: HashMap::new() }
    }

    fn intern(&mut self, s: &str) -> u32 {
        if let Some(&i) = self.index.get(s) {
            return i;
        }
        let i = self.strings.len() as u32;
        self.strings.push(s.to_string());
        self.index.insert(s.to_string(), i);
        i
    }
}

// --------------------------------------------------------------- encoding

/// The interned structural section, ready to serialize.
struct StructRecs {
    net_name: u32,
    inputs: Vec<(u32, Vec<usize>)>,
    outputs: Vec<u32>,
    /// (name, op, attrs_json, inputs, params, outputs)
    layers: Vec<(u32, u32, String, Vec<u32>, Vec<u32>, Vec<u32>)>,
}

fn intern_structure(st: &mut StringTable, net: &NetworkDef) -> StructRecs {
    let layers = net
        .layers
        .iter()
        .map(|l| {
            let name = st.intern(&l.name);
            let op = st.intern(l.op.name());
            let attrs = l.op.attrs_json().to_string();
            let ins: Vec<u32> = l.inputs.iter().map(|s| st.intern(s)).collect();
            let ps: Vec<u32> = l.params.iter().map(|s| st.intern(s)).collect();
            let outs: Vec<u32> = l.outputs.iter().map(|s| st.intern(s)).collect();
            (name, op, attrs, ins, ps, outs)
        })
        .collect();
    let inputs = net.inputs.iter().map(|t| (st.intern(&t.name), t.dims.clone())).collect();
    let outputs = net.outputs.iter().map(|o| st.intern(o)).collect();
    let net_name = st.intern(&net.name);
    StructRecs { net_name, inputs, outputs, layers }
}

/// Serialize the string table + structural records (identical between
/// v1 and v2). Call only after *all* interning is done.
fn write_structure(out: &mut Vec<u8>, st: &StringTable, recs: &StructRecs) {
    out.extend_from_slice(&(st.strings.len() as u32).to_le_bytes());
    for s in &st.strings {
        out.extend_from_slice(&(s.len() as u32).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
    out.extend_from_slice(&recs.net_name.to_le_bytes());
    out.extend_from_slice(&(recs.inputs.len() as u32).to_le_bytes());
    for (n, dims) in &recs.inputs {
        out.extend_from_slice(&n.to_le_bytes());
        out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for &d in dims {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
    }
    out.extend_from_slice(&(recs.outputs.len() as u32).to_le_bytes());
    for o in &recs.outputs {
        out.extend_from_slice(&o.to_le_bytes());
    }
    out.extend_from_slice(&(recs.layers.len() as u32).to_le_bytes());
    for (name, op, attrs, ins, ps, outs) in &recs.layers {
        out.extend_from_slice(&name.to_le_bytes());
        out.extend_from_slice(&op.to_le_bytes());
        out.extend_from_slice(&(attrs.len() as u32).to_le_bytes());
        out.extend_from_slice(attrs.as_bytes());
        for list in [ins, ps, outs] {
            out.extend_from_slice(&(list.len() as u32).to_le_bytes());
            for i in list {
                out.extend_from_slice(&i.to_le_bytes());
            }
        }
    }
}

/// Encode a network + f32 parameters into NNB (v1) bytes.
pub fn to_nnb(net: &NetworkDef, param_list: &[(String, NdArray)]) -> Vec<u8> {
    let mut st = StringTable::new();
    let recs = intern_structure(&mut st, net);
    let mut out = Vec::new();
    out.extend_from_slice(b"NNB1");
    write_structure(&mut out, &st, &recs);
    out.extend_from_slice(&params::save_params(param_list));
    out
}

/// Encode a quantized model into NNB2 bytes: structure + calibration
/// table + mixed f32/i8 parameter blobs.
pub fn to_nnb2(model: &QuantizedModel) -> Vec<u8> {
    let mut st = StringTable::new();
    let recs = intern_structure(&mut st, &model.net);
    let calib: Vec<(u32, ActRange)> = model
        .calib
        .ranges
        .iter()
        .map(|(name, r)| (st.intern(name), *r))
        .collect();
    let pnames: Vec<u32> = model.params.iter().map(|(n, _)| st.intern(n)).collect();

    let mut out = Vec::new();
    out.extend_from_slice(b"NNB2");
    write_structure(&mut out, &st, &recs);
    out.extend_from_slice(&(calib.len() as u32).to_le_bytes());
    for (idx, r) in &calib {
        out.extend_from_slice(&idx.to_le_bytes());
        out.extend_from_slice(&r.lo.to_le_bytes());
        out.extend_from_slice(&r.hi.to_le_bytes());
    }
    out.extend_from_slice(&(model.params.len() as u32).to_le_bytes());
    for (idx, (_, p)) in pnames.iter().zip(&model.params) {
        out.extend_from_slice(&idx.to_le_bytes());
        match p {
            QParam::Float(a) => {
                out.push(0u8);
                out.extend_from_slice(&(a.rank() as u32).to_le_bytes());
                for &d in a.dims() {
                    out.extend_from_slice(&(d as u64).to_le_bytes());
                }
                for &v in a.data() {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            QParam::Int8(q) => {
                out.push(1u8);
                out.push(q.channel_axis as u8);
                out.extend_from_slice(&(q.dims.len() as u32).to_le_bytes());
                for &d in &q.dims {
                    out.extend_from_slice(&(d as u64).to_le_bytes());
                }
                out.extend_from_slice(&(q.scales.len() as u32).to_le_bytes());
                for &s in &q.scales {
                    out.extend_from_slice(&s.to_le_bytes());
                }
                out.extend(q.data.iter().map(|&v| v as u8));
            }
        }
    }
    out
}

// --------------------------------------------------------------- decoding

fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
    if n > bytes.len() - *pos {
        return Err("truncated NNB".into());
    }
    let s = &bytes[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

fn read_u32(bytes: &[u8], pos: &mut usize) -> Result<u32, String> {
    Ok(u32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()))
}

fn read_u64(bytes: &[u8], pos: &mut usize) -> Result<u64, String> {
    Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
}

fn read_f32(bytes: &[u8], pos: &mut usize) -> Result<f32, String> {
    Ok(f32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()))
}

/// Read `rank` u64 dims and their (overflow-checked) element product.
fn read_dims(bytes: &[u8], pos: &mut usize, rank: usize) -> Result<(Vec<usize>, usize), String> {
    let mut dims = Vec::new();
    for _ in 0..rank {
        dims.push(read_u64(bytes, pos)? as usize);
    }
    let n = dims
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or("NNB tensor size overflows")?;
    Ok((dims, n))
}

/// Decode the structural section shared by v1/v2 (the magic has
/// already been consumed). Returns the network and the string table
/// (v2's trailing sections reference it).
fn decode_structure(
    bytes: &[u8],
    pos: &mut usize,
) -> Result<(NetworkDef, Vec<String>), String> {
    let n_strings = read_u32(bytes, pos)? as usize;
    // every string costs at least its 4-byte length prefix: reject
    // implausible counts before allocating anything
    if n_strings > bytes.len() / 4 {
        return Err("truncated NNB".into());
    }
    let mut strings = Vec::with_capacity(n_strings);
    for _ in 0..n_strings {
        let len = read_u32(bytes, pos)? as usize;
        strings.push(
            String::from_utf8(take(bytes, pos, len)?.to_vec()).map_err(|_| "bad string")?,
        );
    }
    let s = |i: u32| -> Result<String, String> {
        strings.get(i as usize).cloned().ok_or("string index out of range".into())
    };
    let net_name = s(read_u32(bytes, pos)?)?;
    let n_inputs = read_u32(bytes, pos)? as usize;
    let mut inputs = Vec::new();
    for _ in 0..n_inputs {
        let name = s(read_u32(bytes, pos)?)?;
        let rank = read_u32(bytes, pos)? as usize;
        let (dims, _) = read_dims(bytes, pos, rank)?;
        inputs.push(TensorDef { name, dims });
    }
    let n_outputs = read_u32(bytes, pos)? as usize;
    let mut outputs = Vec::new();
    for _ in 0..n_outputs {
        outputs.push(s(read_u32(bytes, pos)?)?);
    }
    let n_layers = read_u32(bytes, pos)? as usize;
    let mut layers = Vec::new();
    for _ in 0..n_layers {
        let name = s(read_u32(bytes, pos)?)?;
        let opname = s(read_u32(bytes, pos)?)?;
        let alen = read_u32(bytes, pos)? as usize;
        let attrs_str =
            String::from_utf8(take(bytes, pos, alen)?.to_vec()).map_err(|_| "bad attrs")?;
        let attrs = Json::parse(&attrs_str)?;
        let op = Op::from_name_attrs(&opname, &attrs)
            .ok_or(format!("unsupported function '{opname}' in NNB"))?;
        let mut lists: [Vec<String>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for list in &mut lists {
            let n = read_u32(bytes, pos)? as usize;
            for _ in 0..n {
                list.push(s(read_u32(bytes, pos)?)?);
            }
        }
        let [ins, ps, outs] = lists;
        layers.push(Layer { name, op, inputs: ins, params: ps, outputs: outs });
    }
    Ok((NetworkDef { name: net_name, inputs, outputs, layers }, strings))
}

/// Decode NNB (v1) bytes back into a network + f32 parameters.
pub fn from_nnb(bytes: &[u8]) -> Result<(NetworkDef, Vec<(String, NdArray)>), String> {
    if bytes.len() < 8 || &bytes[0..4] != b"NNB1" {
        return Err("not an NNB file".into());
    }
    let mut pos = 4usize;
    let (net, _) = decode_structure(bytes, &mut pos)?;
    let param_list = params::load_params(&bytes[pos..])?;
    Ok((net, param_list))
}

/// Decode NNB2 bytes back into a quantized model.
pub fn from_nnb2(bytes: &[u8]) -> Result<QuantizedModel, String> {
    if bytes.len() < 8 || &bytes[0..4] != b"NNB2" {
        return Err("not an NNB2 file".into());
    }
    let mut pos = 4usize;
    let (net, strings) = decode_structure(bytes, &mut pos)?;
    let s = |i: u32| -> Result<String, String> {
        strings.get(i as usize).cloned().ok_or("string index out of range".into())
    };
    let n_calib = read_u32(bytes, &mut pos)? as usize;
    let mut ranges = Vec::new();
    for _ in 0..n_calib {
        let name = s(read_u32(bytes, &mut pos)?)?;
        let lo = read_f32(bytes, &mut pos)?;
        let hi = read_f32(bytes, &mut pos)?;
        ranges.push((name, ActRange { lo, hi }));
    }
    let n_params = read_u32(bytes, &mut pos)? as usize;
    let mut qparams = Vec::new();
    for _ in 0..n_params {
        let name = s(read_u32(bytes, &mut pos)?)?;
        let kind = take(bytes, &mut pos, 1)?[0];
        let p = match kind {
            0 => {
                let rank = read_u32(bytes, &mut pos)? as usize;
                let (dims, n) = read_dims(bytes, &mut pos, rank)?;
                let byte_len = n.checked_mul(4).ok_or("NNB tensor size overflows")?;
                let raw = take(bytes, &mut pos, byte_len)?;
                let data: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                QParam::Float(NdArray::from_vec(&dims, data))
            }
            1 => {
                let channel_axis = take(bytes, &mut pos, 1)?[0] as usize;
                let rank = read_u32(bytes, &mut pos)? as usize;
                let (dims, n) = read_dims(bytes, &mut pos, rank)?;
                if channel_axis >= dims.len() {
                    return Err("NNB2 channel axis out of range".into());
                }
                let n_scales = read_u32(bytes, &mut pos)? as usize;
                if n_scales != dims[channel_axis] {
                    return Err("NNB2 scale count does not match channel dim".into());
                }
                let scale_bytes =
                    n_scales.checked_mul(4).ok_or("NNB tensor size overflows")?;
                let raw = take(bytes, &mut pos, scale_bytes)?;
                let scales: Vec<f32> = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                let data: Vec<i8> =
                    take(bytes, &mut pos, n)?.iter().map(|&b| b as i8).collect();
                QParam::Int8(QTensor { dims, channel_axis, data, scales })
            }
            k => return Err(format!("unknown NNB2 parameter kind {k}")),
        };
        qparams.push((name, p));
    }
    Ok(QuantizedModel { net, params: qparams, calib: CalibTable { ranges } })
}

/// A decoded NNB image of either version.
pub enum NnbImage {
    V1 { net: NetworkDef, params: Vec<(String, NdArray)> },
    V2(QuantizedModel),
}

/// Version-dispatching decoder.
pub fn load_nnb(bytes: &[u8]) -> Result<NnbImage, String> {
    if bytes.len() >= 4 && &bytes[0..4] == b"NNB2" {
        return Ok(NnbImage::V2(from_nnb2(bytes)?));
    }
    let (net, params) = from_nnb(bytes)?;
    Ok(NnbImage::V1 { net, params })
}

/// A decoded-and-compiled NNB image: the embedded C-runtime analogue,
/// now on the compiled-plan fast path. Decode + compile once
/// ([`NnbEngine::load`]), execute many ([`NnbEngine::run`]).
pub enum NnbEngine {
    F32(CompiledNet),
    Int8(QuantizedNet),
}

impl NnbEngine {
    pub fn load(bytes: &[u8]) -> Result<NnbEngine, String> {
        match load_nnb(bytes)? {
            NnbImage::V1 { net, params } => {
                let pm: HashMap<String, NdArray> = params.into_iter().collect();
                Ok(NnbEngine::F32(CompiledNet::compile(&net, &pm)?))
            }
            NnbImage::V2(model) => Ok(NnbEngine::Int8(QuantizedNet::compile(&model)?)),
        }
    }

    /// The serving-facing plan view.
    pub fn plan(&self) -> &dyn InferencePlan {
        match self {
            NnbEngine::F32(p) => p,
            NnbEngine::Int8(q) => q,
        }
    }

    /// Execute on named inputs.
    pub fn run(&self, inputs: &HashMap<String, NdArray>) -> Result<Vec<NdArray>, String> {
        self.plan().execute_named(inputs)
    }
}

/// Execute an NNB image directly (one-shot convenience): decode,
/// compile, run — v1 through the f32 plan, v2 through the int8 plan.
pub fn run_nnb(
    bytes: &[u8],
    inputs: &HashMap<String, NdArray>,
) -> Result<Vec<NdArray>, String> {
    NnbEngine::load(bytes)?.run(inputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::tests::sample_nnp;
    use crate::quant::{quantize_net, QuantConfig};
    use crate::tensor::Rng;

    #[test]
    fn nnb_roundtrip_structure_and_params() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        let (net, params) = from_nnb(&bytes).unwrap();
        assert_eq!(net, nnp.networks[0]);
        assert_eq!(params.len(), nnp.parameters.len());
        for ((n1, a1), (n2, a2)) in params.iter().zip(&nnp.parameters) {
            assert_eq!(n1, n2);
            assert_eq!(a1.data(), a2.data());
        }
    }

    #[test]
    fn nnb_executes_like_source_network() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[0., 1., 0.]));
        let nnb_out = run_nnb(&bytes, &inputs).unwrap();
        let src_out = nnp.execute("main_executor", &inputs).unwrap();
        assert_eq!(nnb_out[0].data(), src_out[0].data());
    }

    #[test]
    fn nnb_engine_compiles_once_and_answers_repeatedly() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        let engine = NnbEngine::load(&bytes).unwrap();
        assert_eq!(engine.plan().name(), "main");
        for i in 0..3 {
            let mut inputs = HashMap::new();
            inputs
                .insert("x".to_string(), NdArray::from_slice(&[1, 3], &[i as f32, 1., 0.]));
            let got = engine.run(&inputs).unwrap();
            let want = nnp.execute("main_executor", &inputs).unwrap();
            assert_eq!(got[0].data(), want[0].data());
        }
    }

    #[test]
    fn string_table_dedupes() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        // interning means the tensor name "y" appears once in the table;
        // a crude check: the serialized image stays compact
        let n_y = bytes.windows(1 + 4).filter(|w| w == b"\x01\x00\x00\x00y").count();
        assert!(n_y <= 1, "string 'y' interned more than once");
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_nnb(b"NOPE").is_err());
        assert!(from_nnb(b"NNB1").is_err()); // magic alone, no body
        assert!(from_nnb2(b"NNB2").is_err());
        assert!(load_nnb(b"NN").is_err());
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        assert!(from_nnb(&bytes[..bytes.len() / 2]).is_err());
    }

    fn quantized_sample() -> (QuantizedModel, Vec<u8>, Vec<u8>) {
        let nnp = sample_nnp();
        let net = &nnp.networks[0];
        let pm = nnp.param_map();
        let mut rng = Rng::new(4);
        let samples: Vec<Vec<NdArray>> =
            (0..4).map(|_| vec![rng.rand(&[1, 3], -1.0, 1.0)]).collect();
        let (model, _) = quantize_net(net, &pm, &samples, &QuantConfig::default()).unwrap();
        let v1 = to_nnb(net, &nnp.parameters);
        let v2 = to_nnb2(&model);
        (model, v1, v2)
    }

    #[test]
    fn nnb2_roundtrip_is_exact() {
        let (model, _, v2) = quantized_sample();
        let back = from_nnb2(&v2).unwrap();
        assert_eq!(back, model);
    }

    #[test]
    fn nnb2_executes_like_its_quantized_net() {
        let (model, _, v2) = quantized_sample();
        let engine = NnbEngine::load(&v2).unwrap();
        let qnet = QuantizedNet::compile(&model).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[0.5, -0.25, 1.0]));
        let got = engine.run(&inputs).unwrap();
        let want = InferencePlan::execute_named(&qnet, &inputs).unwrap();
        assert_eq!(got[0].data(), want[0].data());
    }

    #[test]
    fn nnb2_is_smaller_than_nnb1() {
        // a realistically-sized weight matrix (the sample net's 6
        // weights would drown in the fixed calib/scale overhead); the
        // ≥3x zoo-model claim is asserted in tests/quant_parity.rs
        let net = NetworkDef {
            name: "wide".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 64] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "fc".into(),
                op: Op::Affine,
                inputs: vec!["x".into()],
                params: vec!["fc/W".into(), "fc/b".into()],
                outputs: vec!["y".into()],
            }],
        };
        let mut rng = Rng::new(6);
        let mut pm = HashMap::new();
        pm.insert("fc/W".to_string(), rng.randn(&[64, 32], 1.0));
        pm.insert("fc/b".to_string(), rng.randn(&[32], 0.1));
        let samples: Vec<Vec<NdArray>> =
            (0..2).map(|_| vec![rng.rand(&[1, 64], -1.0, 1.0)]).collect();
        let (model, _) = quantize_net(&net, &pm, &samples, &QuantConfig::default()).unwrap();
        let v1_params = vec![
            ("fc/W".to_string(), pm["fc/W"].clone()),
            ("fc/b".to_string(), pm["fc/b"].clone()),
        ];
        let v1 = to_nnb(&net, &v1_params);
        let v2 = to_nnb2(&model);
        assert!(
            v2.len() * 3 <= v1.len(),
            "NNB2 ({} B) not >=3x smaller than NNB1 ({} B)",
            v2.len(),
            v1.len()
        );
    }

    #[test]
    fn nnb2_rejects_truncation_anywhere() {
        let (_, _, v2) = quantized_sample();
        for cut in [4, 9, v2.len() / 3, v2.len() / 2, v2.len() - 1] {
            assert!(from_nnb2(&v2[..cut]).is_err(), "cut at {cut} did not error");
        }
    }
}
