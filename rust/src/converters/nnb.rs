//! NNP → NNB: the flat binary format for the C-runtime analogue
//! ("NNP to NNB (Binary format for NNabla C Runtime)", §3).
//!
//! Layout (all little-endian):
//! ```text
//! magic "NNB1" | u32 n_strings | strings (u32 len + bytes)*
//! | u32 n_inputs  | (u32 name_idx, u32 rank, u64 dims*)*
//! | u32 n_outputs | u32 name_idx*
//! | u32 n_layers  | layer records
//! | param blob (params.rs format)
//! ```
//! Every tensor reference is an index into the string table — the
//! fixed-width, pointer-free encoding an embedded C runtime wants.
//! [`run_nnb`] executes the format directly, standing in for the C
//! runtime itself.

use std::collections::HashMap;

use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use crate::nnp::{interpreter, params};
use crate::tensor::NdArray;
use crate::utils::json::Json;

struct StringTable {
    strings: Vec<String>,
    index: HashMap<String, u32>,
}

impl StringTable {
    fn new() -> Self {
        StringTable { strings: Vec::new(), index: HashMap::new() }
    }

    fn intern(&mut self, s: &str) -> u32 {
        if let Some(&i) = self.index.get(s) {
            return i;
        }
        let i = self.strings.len() as u32;
        self.strings.push(s.to_string());
        self.index.insert(s.to_string(), i);
        i
    }
}

/// Encode a network + parameters into NNB bytes.
pub fn to_nnb(net: &NetworkDef, param_list: &[(String, NdArray)]) -> Vec<u8> {
    let mut st = StringTable::new();
    // intern everything first for a stable table
    let mut layer_recs: Vec<(u32, u32, String, Vec<u32>, Vec<u32>, Vec<u32>)> = Vec::new();
    for l in &net.layers {
        let name = st.intern(&l.name);
        let op = st.intern(l.op.name());
        let attrs = l.op.attrs_json().to_string();
        let ins: Vec<u32> = l.inputs.iter().map(|s| st.intern(s)).collect();
        let ps: Vec<u32> = l.params.iter().map(|s| st.intern(s)).collect();
        let outs: Vec<u32> = l.outputs.iter().map(|s| st.intern(s)).collect();
        layer_recs.push((name, op, attrs, ins, ps, outs));
    }
    let input_recs: Vec<(u32, Vec<usize>)> =
        net.inputs.iter().map(|t| (st.intern(&t.name), t.dims.clone())).collect();
    let output_recs: Vec<u32> = net.outputs.iter().map(|o| st.intern(o)).collect();
    let net_name = st.intern(&net.name);

    let mut out = Vec::new();
    out.extend_from_slice(b"NNB1");
    out.extend_from_slice(&(st.strings.len() as u32).to_le_bytes());
    for s in &st.strings {
        out.extend_from_slice(&(s.len() as u32).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
    out.extend_from_slice(&net_name.to_le_bytes());
    out.extend_from_slice(&(input_recs.len() as u32).to_le_bytes());
    for (n, dims) in &input_recs {
        out.extend_from_slice(&n.to_le_bytes());
        out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for &d in dims {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
    }
    out.extend_from_slice(&(output_recs.len() as u32).to_le_bytes());
    for o in &output_recs {
        out.extend_from_slice(&o.to_le_bytes());
    }
    out.extend_from_slice(&(layer_recs.len() as u32).to_le_bytes());
    for (name, op, attrs, ins, ps, outs) in &layer_recs {
        out.extend_from_slice(&name.to_le_bytes());
        out.extend_from_slice(&op.to_le_bytes());
        out.extend_from_slice(&(attrs.len() as u32).to_le_bytes());
        out.extend_from_slice(attrs.as_bytes());
        for list in [ins, ps, outs] {
            out.extend_from_slice(&(list.len() as u32).to_le_bytes());
            for i in list {
                out.extend_from_slice(&i.to_le_bytes());
            }
        }
    }
    out.extend_from_slice(&params::save_params(param_list));
    out
}

/// Decode NNB bytes back into a network + parameters.
pub fn from_nnb(bytes: &[u8]) -> Result<(NetworkDef, Vec<(String, NdArray)>), String> {
    if bytes.len() < 8 || &bytes[0..4] != b"NNB1" {
        return Err("not an NNB file".into());
    }
    let mut pos = 4usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
        if *pos + n > bytes.len() {
            return Err("truncated NNB".into());
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let u32_at = |pos: &mut usize| -> Result<u32, String> {
        Ok(u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()))
    };
    let n_strings = u32_at(&mut pos)? as usize;
    let mut strings = Vec::with_capacity(n_strings);
    for _ in 0..n_strings {
        let len = u32_at(&mut pos)? as usize;
        strings.push(
            String::from_utf8(take(&mut pos, len)?.to_vec()).map_err(|_| "bad string")?,
        );
    }
    let s = |i: u32| -> Result<String, String> {
        strings.get(i as usize).cloned().ok_or("string index out of range".into())
    };
    let net_name = s(u32_at(&mut pos)?)?;
    let n_inputs = u32_at(&mut pos)? as usize;
    let mut inputs = Vec::with_capacity(n_inputs);
    for _ in 0..n_inputs {
        let name = s(u32_at(&mut pos)?)?;
        let rank = u32_at(&mut pos)? as usize;
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize);
        }
        inputs.push(TensorDef { name, dims });
    }
    let n_outputs = u32_at(&mut pos)? as usize;
    let mut outputs = Vec::with_capacity(n_outputs);
    for _ in 0..n_outputs {
        outputs.push(s(u32_at(&mut pos)?)?);
    }
    let n_layers = u32_at(&mut pos)? as usize;
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let name = s(u32_at(&mut pos)?)?;
        let opname = s(u32_at(&mut pos)?)?;
        let alen = u32_at(&mut pos)? as usize;
        let attrs_str =
            String::from_utf8(take(&mut pos, alen)?.to_vec()).map_err(|_| "bad attrs")?;
        let attrs = Json::parse(&attrs_str)?;
        let op = Op::from_name_attrs(&opname, &attrs)
            .ok_or(format!("unsupported function '{opname}' in NNB"))?;
        let mut lists: [Vec<String>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for list in &mut lists {
            let n = u32_at(&mut pos)? as usize;
            for _ in 0..n {
                list.push(s(u32_at(&mut pos)?)?);
            }
        }
        let [ins, ps, outs] = lists;
        layers.push(Layer { name, op, inputs: ins, params: ps, outputs: outs });
    }
    let param_list = params::load_params(&bytes[pos..])?;
    Ok((NetworkDef { name: net_name, inputs, outputs, layers }, param_list))
}

/// Execute an NNB image directly — the embedded C-runtime analogue.
pub fn run_nnb(
    bytes: &[u8],
    inputs: &HashMap<String, NdArray>,
) -> Result<Vec<NdArray>, String> {
    let (net, param_list) = from_nnb(bytes)?;
    let pm: HashMap<String, NdArray> = param_list.into_iter().collect();
    interpreter::run(&net, inputs, &pm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::tests::sample_nnp;

    #[test]
    fn nnb_roundtrip_structure_and_params() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        let (net, params) = from_nnb(&bytes).unwrap();
        assert_eq!(net, nnp.networks[0]);
        assert_eq!(params.len(), nnp.parameters.len());
        for ((n1, a1), (n2, a2)) in params.iter().zip(&nnp.parameters) {
            assert_eq!(n1, n2);
            assert_eq!(a1.data(), a2.data());
        }
    }

    #[test]
    fn nnb_executes_like_source_network() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[0., 1., 0.]));
        let nnb_out = run_nnb(&bytes, &inputs).unwrap();
        let src_out = nnp.execute("main_executor", &inputs).unwrap();
        assert_eq!(nnb_out[0].data(), src_out[0].data());
    }

    #[test]
    fn string_table_dedupes() {
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        // interning means the tensor name "y" appears once in the table;
        // a crude check: the serialized image stays compact
        let n_y = bytes.windows(1 + 4).filter(|w| w == b"\x01\x00\x00\x00y").count();
        assert!(n_y <= 1, "string 'y' interned more than once");
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_nnb(b"NOPE").is_err());
        let nnp = sample_nnp();
        let bytes = to_nnb(&nnp.networks[0], &nnp.parameters);
        assert!(from_nnb(&bytes[..bytes.len() / 2]).is_err());
    }
}
