//! Function-support querying — the paper's §3: "If ONNX file contains
//! a function unsupported by Neural Network Libraries, it may cause
//! error in conversion, so users may use querying commands provided by
//! Neural Network Libraries to check whether it contains unsupported
//! function." Mirrors the published support-status matrix.

use crate::nnp::NetworkDef;

/// Conversion targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// ONNX-subset export.
    OnnxLite,
    /// NNB flat binary (C-runtime analogue).
    Nnb,
    /// Frozen-graph single file.
    Frozen,
    /// Generated Rust source.
    RsSource,
    /// The native NNP interpreter.
    Nnp,
}

impl Target {
    pub fn name(self) -> &'static str {
        match self {
            Target::OnnxLite => "onnx",
            Target::Nnb => "nnb",
            Target::Frozen => "frozen",
            Target::RsSource => "rs_source",
            Target::Nnp => "nnp",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "onnx" => Target::OnnxLite,
            "nnb" => Target::Nnb,
            "frozen" => Target::Frozen,
            "rs_source" | "rs" => Target::RsSource,
            "nnp" => Target::Nnp,
            _ => return None,
        })
    }
}

/// Is `function` (canonical op name) supported by `target`?
pub fn supports(target: Target, function: &str) -> bool {
    match target {
        // everything the IR can express runs in NNP / NNB / frozen
        // (they share the interpreter semantics)
        Target::Nnp | Target::Nnb | Target::Frozen => true,
        // the source generator is dense-path only; keep in sync with
        // `rs_source::supported` (pinned by a test below)
        Target::RsSource => matches!(
            function,
            "Affine" | "ReLU" | "LeakyReLU" | "Sigmoid" | "Tanh" | "Softmax" | "Identity"
                | "Dropout"
        ),
        // ONNX has no standard Swish op (NNabla's real converter hits
        // the same class of gaps — that is what the query tool is for);
        // the live-graph-only registry ops (losses, reductions, scalar
        // arithmetic, stop-gradient, broadcast) are likewise unmapped.
        // Keep this list in sync with `onnx_lite::to_onnx`.
        Target::OnnxLite => !matches!(
            function,
            "Swish"
                | "StopGradient"
                | "AddScalar"
                | "MulScalar"
                | "PowScalar"
                | "SquaredError"
                | "SigmoidCrossEntropy"
                | "SoftmaxCrossEntropy"
                | "SumAll"
                | "MeanAll"
                | "Sum"
                | "Mean"
                | "BroadcastTo"
        ),
    }
}

/// All functions in `net` unsupported by `target` — empty means the
/// conversion will succeed.
pub fn query_unsupported(net: &NetworkDef, target: Target) -> Vec<&'static str> {
    net.function_names().into_iter().filter(|f| !supports(target, f)).collect()
}

/// Human-readable support matrix for a network across all targets
/// (the CLI `nnl query` output).
pub fn support_report(net: &NetworkDef) -> String {
    let targets =
        [Target::Nnp, Target::OnnxLite, Target::Nnb, Target::Frozen, Target::RsSource];
    let mut s = format!("support matrix for network '{}':\n", net.name);
    s.push_str(&format!("{:<24}", "function"));
    for t in targets {
        s.push_str(&format!("{:>10}", t.name()));
    }
    s.push('\n');
    for f in net.function_names() {
        s.push_str(&format!("{f:<24}"));
        for t in targets {
            s.push_str(&format!("{:>10}", if supports(t, f) { "ok" } else { "NO" }));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, Op, TensorDef};

    fn swish_net() -> NetworkDef {
        NetworkDef {
            name: "m".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
            outputs: vec!["y".into()],
            layers: vec![
                Layer {
                    name: "s".into(),
                    op: Op::Swish,
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "r".into(),
                    op: Op::ReLU,
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                },
            ],
        }
    }

    #[test]
    fn query_finds_onnx_gap() {
        let net = swish_net();
        assert_eq!(query_unsupported(&net, Target::OnnxLite), vec!["Swish"]);
        assert!(query_unsupported(&net, Target::Nnb).is_empty());
        // the dense-only source generator has no Swish either
        assert_eq!(query_unsupported(&net, Target::RsSource), vec!["Swish"]);
    }

    #[test]
    fn report_marks_gaps() {
        let r = support_report(&swish_net());
        assert!(r.contains("Swish"));
        assert!(r.contains("NO"));
        assert!(r.contains("ReLU"));
    }

    #[test]
    fn onnx_support_list_matches_converter() {
        // `supports(OnnxLite, ..)` is a hand-maintained mirror of
        // `onnx_lite::to_onnx`'s match arms — pin them together over
        // every registry op so they cannot drift silently.
        use std::collections::HashMap;
        for op in crate::nnp::ir::tests::all_ops() {
            let net = NetworkDef {
                name: "probe".into(),
                inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 4] }],
                outputs: vec!["y".into()],
                layers: vec![Layer {
                    name: "l".into(),
                    op: op.clone(),
                    inputs: vec!["x".into()],
                    params: vec![],
                    outputs: vec!["y".into()],
                }],
            };
            let convertible =
                crate::converters::onnx_lite::to_onnx(&net, &HashMap::new()).is_ok();
            assert_eq!(
                supports(Target::OnnxLite, op.name()),
                convertible,
                "query/supports and onnx_lite::to_onnx disagree on '{}'",
                op.name()
            );
            assert_eq!(
                supports(Target::RsSource, op.name()),
                crate::converters::rs_source::supported(&op),
                "query/supports and rs_source::supported disagree on '{}'",
                op.name()
            );
        }
    }

    #[test]
    fn target_names_roundtrip() {
        for t in [Target::OnnxLite, Target::Nnb, Target::Frozen, Target::RsSource, Target::Nnp] {
            assert_eq!(Target::from_name(t.name()), Some(t));
        }
        assert_eq!(Target::from_name("coreml"), None);
    }
}
