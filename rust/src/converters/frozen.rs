//! NNP ⇄ frozen graph: a single self-contained inference file with all
//! parameters inlined as graph constants — the TensorFlow frozen-graph
//! analogue of §3 ("NNP to Tensorflow frozen graph", and the reverse
//! "checkpoint or frozen graph to NNP").
//!
//! Freezing also performs the classic deployment simplifications:
//! dropout layers are removed and identities folded, so the frozen
//! artifact is inference-only by construction.

use std::collections::HashMap;

use crate::nnp::ir::{NetworkDef, Op};
use crate::nnp::params;
use crate::tensor::NdArray;
use crate::utils::json::Json;

const MAGIC: &[u8; 4] = b"FRZ1";

/// A frozen graph: simplified network + inlined constants.
#[derive(Debug, Clone, PartialEq)]
pub struct FrozenGraph {
    pub net: NetworkDef,
    pub constants: Vec<(String, NdArray)>,
}

/// Freeze: inline the needed parameters and strip train-only layers.
pub fn freeze(net: &NetworkDef, param_map: &HashMap<String, NdArray>) -> Result<FrozenGraph, String> {
    let mut simplified = net.clone();
    // remove dropout/identity by rewiring their outputs to their inputs
    let mut rename: HashMap<String, String> = HashMap::new();
    simplified.layers.retain(|l| match l.op {
        Op::Dropout { .. } | Op::Identity => {
            rename.insert(l.outputs[0].clone(), l.inputs[0].clone());
            false
        }
        _ => true,
    });
    let resolve = |mut name: String, rename: &HashMap<String, String>| -> String {
        while let Some(r) = rename.get(&name) {
            name = r.clone();
        }
        name
    };
    for l in &mut simplified.layers {
        for i in &mut l.inputs {
            *i = resolve(i.clone(), &rename);
        }
    }
    for o in &mut simplified.outputs {
        *o = resolve(o.clone(), &rename);
    }
    // inline constants
    let mut constants = Vec::new();
    for p in simplified.param_names() {
        let a = param_map.get(&p).ok_or(format!("freeze: missing parameter '{p}'"))?;
        constants.push((p, a.clone()));
    }
    simplified.validate()?;
    Ok(FrozenGraph { net: simplified, constants })
}

/// Un-freeze back to NNP pieces (network + parameter list).
pub fn unfreeze(fg: &FrozenGraph) -> (NetworkDef, Vec<(String, NdArray)>) {
    (fg.net.clone(), fg.constants.clone())
}

/// Run a frozen graph.
pub fn run(
    fg: &FrozenGraph,
    inputs: &HashMap<String, NdArray>,
) -> Result<Vec<NdArray>, String> {
    let pm: HashMap<String, NdArray> = fg.constants.iter().cloned().collect();
    crate::nnp::interpreter::run(&fg.net, inputs, &pm)
}

/// Serialize (`FRZ1 | u64 header_len | network JSON | param blob`).
pub fn save_bytes(fg: &FrozenGraph) -> Vec<u8> {
    let header = fg.net.to_json().to_string().into_bytes();
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(header.len() as u64).to_le_bytes());
    out.extend_from_slice(&header);
    out.extend_from_slice(&params::save_params(&fg.constants));
    out
}

/// Deserialize.
pub fn load_bytes(bytes: &[u8]) -> Result<FrozenGraph, String> {
    if bytes.len() < 12 || &bytes[0..4] != MAGIC {
        return Err("not a frozen graph".into());
    }
    let hlen = u64::from_le_bytes(bytes[4..12].try_into().unwrap()) as usize;
    if 12 + hlen > bytes.len() {
        return Err("truncated frozen graph".into());
    }
    let net = NetworkDef::from_json(&Json::parse(
        std::str::from_utf8(&bytes[12..12 + hlen]).map_err(|_| "bad header")?,
    )?)?;
    let constants = params::load_params(&bytes[12 + hlen..])?;
    Ok(FrozenGraph { net, constants })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::ir::{Layer, TensorDef};
    use crate::nnp::tests::sample_nnp;

    fn net_with_dropout() -> (NetworkDef, HashMap<String, NdArray>) {
        let net = NetworkDef {
            name: "d".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3] }],
            outputs: vec!["out".into()],
            layers: vec![
                Layer {
                    name: "fc".into(),
                    op: Op::Affine,
                    inputs: vec!["x".into()],
                    params: vec!["W".into()],
                    outputs: vec!["h".into()],
                },
                Layer {
                    name: "drop".into(),
                    op: Op::Dropout { p: 0.5 },
                    inputs: vec!["h".into()],
                    params: vec![],
                    outputs: vec!["hd".into()],
                },
                Layer {
                    name: "act".into(),
                    op: Op::ReLU,
                    inputs: vec!["hd".into()],
                    params: vec![],
                    outputs: vec!["out".into()],
                },
            ],
        };
        let mut pm = HashMap::new();
        pm.insert("W".to_string(), NdArray::arange(&[3, 2]));
        (net, pm)
    }

    #[test]
    fn freeze_strips_dropout() {
        let (net, pm) = net_with_dropout();
        let fg = freeze(&net, &pm).unwrap();
        assert_eq!(fg.net.layers.len(), 2);
        assert!(fg.net.layers.iter().all(|l| !matches!(l.op, Op::Dropout { .. })));
        // the relu now reads the affine output directly
        assert_eq!(fg.net.layers[1].inputs[0], "h");
    }

    #[test]
    fn frozen_inference_matches_source() {
        let (net, pm) = net_with_dropout();
        let fg = freeze(&net, &pm).unwrap();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[1, 3], &[1., -1., 2.]));
        let a = crate::nnp::interpreter::run(&net, &inputs, &pm).unwrap();
        let b = run(&fg, &inputs).unwrap();
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn bytes_roundtrip() {
        let nnp = sample_nnp();
        let fg = freeze(&nnp.networks[0], &nnp.param_map()).unwrap();
        let back = load_bytes(&save_bytes(&fg)).unwrap();
        assert_eq!(back.net, fg.net);
        assert_eq!(back.constants.len(), fg.constants.len());
    }

    #[test]
    fn unfreeze_restores_nnp_pieces() {
        let nnp = sample_nnp();
        let fg = freeze(&nnp.networks[0], &nnp.param_map()).unwrap();
        let (net, params) = unfreeze(&fg);
        assert_eq!(net.outputs, nnp.networks[0].outputs);
        assert_eq!(params.len(), 2);
    }

    #[test]
    fn missing_param_fails_freeze() {
        let (net, _) = net_with_dropout();
        let err = freeze(&net, &HashMap::new()).unwrap_err();
        assert!(err.contains("missing parameter 'W'"));
    }
}
