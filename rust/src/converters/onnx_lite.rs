//! NNP ⇄ ONNX-subset converter. The in-memory [`OnnxModel`] follows
//! ONNX's structure (graph / nodes / initializers / value_info) with
//! standard ONNX op names and attributes, so the mapping layer is a
//! faithful miniature of the real NNabla↔ONNX converter, including its
//! failure mode on unsupported functions.

use std::collections::HashMap;

use crate::nnp::ir::{Layer, NetworkDef, Op, TensorDef};
use crate::nnp::params;
use crate::tensor::NdArray;
use crate::utils::json::Json;

/// An ONNX attribute value (subset).
#[derive(Debug, Clone, PartialEq)]
pub enum OnnxAttr {
    Int(i64),
    Float(f32),
    Ints(Vec<i64>),
}

/// An ONNX node.
#[derive(Debug, Clone, PartialEq)]
pub struct OnnxNode {
    pub op_type: String,
    pub name: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub attrs: Vec<(String, OnnxAttr)>,
}

impl OnnxNode {
    fn attr(&self, name: &str) -> Option<&OnnxAttr> {
        self.attrs.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    fn attr_ints(&self, name: &str) -> Option<Vec<i64>> {
        match self.attr(name)? {
            OnnxAttr::Ints(v) => Some(v.clone()),
            _ => None,
        }
    }

    fn attr_f(&self, name: &str) -> Option<f32> {
        match self.attr(name)? {
            OnnxAttr::Float(f) => Some(*f),
            _ => None,
        }
    }
}

/// An ONNX model (graph-level subset).
#[derive(Debug, Clone, PartialEq)]
pub struct OnnxModel {
    pub opset: i64,
    pub graph_name: String,
    pub inputs: Vec<TensorDef>,
    pub outputs: Vec<String>,
    pub initializers: Vec<(String, NdArray)>,
    pub nodes: Vec<OnnxNode>,
}

/// Error for functions with no ONNX mapping (`query` predicts these).
#[derive(Debug)]
pub struct UnsupportedFunction(pub String);

impl std::fmt::Display for UnsupportedFunction {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "function '{}' has no ONNX mapping", self.0)
    }
}

fn pair_ints(p: (usize, usize)) -> OnnxAttr {
    OnnxAttr::Ints(vec![p.0 as i64, p.1 as i64])
}

fn pads_attr(p: (usize, usize)) -> OnnxAttr {
    // ONNX pads = [begin_h, begin_w, end_h, end_w]
    OnnxAttr::Ints(vec![p.0 as i64, p.1 as i64, p.0 as i64, p.1 as i64])
}

/// NNP network + params → ONNX model.
pub fn to_onnx(
    net: &NetworkDef,
    param_map: &HashMap<String, NdArray>,
) -> Result<OnnxModel, UnsupportedFunction> {
    let mut nodes = Vec::new();
    let mut initializers = Vec::new();
    let mut init_param = |name: &str| -> String {
        if let Some(a) = param_map.get(name) {
            if !initializers.iter().any(|(n, _): &(String, NdArray)| n == name) {
                initializers.push((name.to_string(), a.clone()));
            }
        }
        name.to_string()
    };
    for l in &net.layers {
        let mut inputs = l.inputs.clone();
        for p in &l.params {
            inputs.push(init_param(p));
        }
        let (op_type, attrs): (&str, Vec<(String, OnnxAttr)>) = match &l.op {
            Op::Affine => {
                // Gemm(x, W, b): alpha=beta=1, no transpose
                ("Gemm", vec![])
            }
            Op::Convolution { stride, pad, dilation } => (
                "Conv",
                vec![
                    ("strides".into(), pair_ints(*stride)),
                    ("pads".into(), pads_attr(*pad)),
                    ("dilations".into(), pair_ints(*dilation)),
                ],
            ),
            Op::MaxPool { kernel, stride, pad } => (
                "MaxPool",
                vec![
                    ("kernel_shape".into(), pair_ints(*kernel)),
                    ("strides".into(), pair_ints(*stride)),
                    ("pads".into(), pads_attr(*pad)),
                ],
            ),
            Op::AvgPool { kernel, stride, pad, including_pad } => (
                "AveragePool",
                vec![
                    ("kernel_shape".into(), pair_ints(*kernel)),
                    ("strides".into(), pair_ints(*stride)),
                    ("pads".into(), pads_attr(*pad)),
                    ("count_include_pad".into(), OnnxAttr::Int(*including_pad as i64)),
                ],
            ),
            Op::GlobalAvgPool => ("GlobalAveragePool", vec![]),
            Op::ReLU => ("Relu", vec![]),
            Op::LeakyReLU { alpha } => ("LeakyRelu", vec![("alpha".into(), OnnxAttr::Float(*alpha))]),
            Op::Sigmoid => ("Sigmoid", vec![]),
            Op::Tanh => ("Tanh", vec![]),
            Op::Elu { alpha } => ("Elu", vec![("alpha".into(), OnnxAttr::Float(*alpha))]),
            Op::Swish => return Err(UnsupportedFunction("Swish".into())),
            Op::Gelu => ("Gelu", vec![]),
            Op::Softplus => ("Softplus", vec![]),
            Op::Softmax => ("Softmax", vec![("axis".into(), OnnxAttr::Int(-1))]),
            Op::LogSoftmax => ("LogSoftmax", vec![("axis".into(), OnnxAttr::Int(-1))]),
            Op::BatchNorm { eps } => {
                ("BatchNormalization", vec![("epsilon".into(), OnnxAttr::Float(*eps))])
            }
            Op::LayerNorm { eps } => {
                ("LayerNormalization", vec![("epsilon".into(), OnnxAttr::Float(*eps))])
            }
            Op::Add2 => ("Add", vec![]),
            Op::Sub2 => ("Sub", vec![]),
            Op::Mul2 => ("Mul", vec![]),
            Op::Div2 => ("Div", vec![]),
            Op::Neg => ("Neg", vec![]),
            Op::Exp => ("Exp", vec![]),
            Op::Log => ("Log", vec![]),
            Op::Concat { axis } => ("Concat", vec![("axis".into(), OnnxAttr::Int(*axis as i64))]),
            Op::Reshape { dims } => {
                ("Reshape", vec![("shape".into(), OnnxAttr::Ints(dims.clone()))])
            }
            Op::Transpose { axes } => (
                "Transpose",
                vec![(
                    "perm".into(),
                    OnnxAttr::Ints(axes.iter().map(|&a| a as i64).collect()),
                )],
            ),
            Op::Slice { axis, start, stop } => (
                "Slice",
                vec![
                    ("starts".into(), OnnxAttr::Ints(vec![*start as i64])),
                    ("ends".into(), OnnxAttr::Ints(vec![*stop as i64])),
                    ("axes".into(), OnnxAttr::Ints(vec![*axis as i64])),
                ],
            ),
            Op::Deconvolution { stride, pad } => (
                "ConvTranspose",
                vec![
                    ("strides".into(), pair_ints(*stride)),
                    ("pads".into(), pads_attr(*pad)),
                ],
            ),
            Op::Dropout { p } => ("Dropout", vec![("ratio".into(), OnnxAttr::Float(*p))]),
            Op::Embed => ("Gather", vec![("axis".into(), OnnxAttr::Int(0))]),
            Op::Identity => ("Identity", vec![]),
            // live-graph-only ops (losses, reductions, scalar arithmetic,
            // stop-gradient, broadcast) have no standard ONNX mapping —
            // exactly the gap class `converters::query` predicts
            other => return Err(UnsupportedFunction(other.name().to_string())),
        };
        nodes.push(OnnxNode {
            op_type: op_type.to_string(),
            name: l.name.clone(),
            inputs,
            outputs: l.outputs.clone(),
            attrs,
        });
    }
    Ok(OnnxModel {
        opset: 20,
        graph_name: net.name.clone(),
        inputs: net.inputs.clone(),
        outputs: net.outputs.clone(),
        initializers,
        nodes,
    })
}

/// ONNX model → NNP network + params.
pub fn from_onnx(
    model: &OnnxModel,
) -> Result<(NetworkDef, Vec<(String, NdArray)>), UnsupportedFunction> {
    let init_names: std::collections::HashSet<&str> =
        model.initializers.iter().map(|(n, _)| n.as_str()).collect();
    let mut layers = Vec::new();
    for n in &model.nodes {
        let pair = |a: Option<Vec<i64>>, def: (usize, usize)| -> (usize, usize) {
            a.map(|v| (v[0] as usize, v[1] as usize)).unwrap_or(def)
        };
        let pads = |a: Option<Vec<i64>>| -> (usize, usize) {
            a.map(|v| (v[0] as usize, v[1] as usize)).unwrap_or((0, 0))
        };
        let op = match n.op_type.as_str() {
            "Gemm" => Op::Affine,
            "Conv" => Op::Convolution {
                stride: pair(n.attr_ints("strides"), (1, 1)),
                pad: pads(n.attr_ints("pads")),
                dilation: pair(n.attr_ints("dilations"), (1, 1)),
            },
            "MaxPool" => Op::MaxPool {
                kernel: pair(n.attr_ints("kernel_shape"), (1, 1)),
                stride: pair(n.attr_ints("strides"), (1, 1)),
                pad: pads(n.attr_ints("pads")),
            },
            "AveragePool" => Op::AvgPool {
                kernel: pair(n.attr_ints("kernel_shape"), (1, 1)),
                stride: pair(n.attr_ints("strides"), (1, 1)),
                pad: pads(n.attr_ints("pads")),
                including_pad: matches!(n.attr("count_include_pad"), Some(OnnxAttr::Int(1))),
            },
            "GlobalAveragePool" => Op::GlobalAvgPool,
            "Relu" => Op::ReLU,
            "LeakyRelu" => Op::LeakyReLU { alpha: n.attr_f("alpha").unwrap_or(0.01) },
            "Sigmoid" => Op::Sigmoid,
            "Tanh" => Op::Tanh,
            "Elu" => Op::Elu { alpha: n.attr_f("alpha").unwrap_or(1.0) },
            "Gelu" => Op::Gelu,
            "Softplus" => Op::Softplus,
            "Softmax" => Op::Softmax,
            "LogSoftmax" => Op::LogSoftmax,
            "BatchNormalization" => Op::BatchNorm { eps: n.attr_f("epsilon").unwrap_or(1e-5) },
            "LayerNormalization" => Op::LayerNorm { eps: n.attr_f("epsilon").unwrap_or(1e-5) },
            "Add" => Op::Add2,
            "Sub" => Op::Sub2,
            "Mul" => Op::Mul2,
            "Div" => Op::Div2,
            "Neg" => Op::Neg,
            "Exp" => Op::Exp,
            "Log" => Op::Log,
            "Transpose" => {
                // ONNX's missing-perm default (reverse all dims) needs
                // the input rank, which the node alone doesn't carry —
                // reject rather than guess (our exporter always writes
                // `perm`).
                let perm = n
                    .attr_ints("perm")
                    .ok_or_else(|| UnsupportedFunction("Transpose without perm".into()))?;
                Op::Transpose { axes: perm.iter().map(|&a| a as usize).collect() }
            }
            "Slice" => {
                let starts = n.attr_ints("starts").unwrap_or_default();
                let ends = n.attr_ints("ends").unwrap_or_default();
                let axes = n.attr_ints("axes").unwrap_or_default();
                if starts.len() != 1 || ends.len() != 1 || axes.len() != 1 {
                    return Err(UnsupportedFunction("Slice (multi-axis)".into()));
                }
                // ONNX's negative ("from the end") indices would wrap
                // on an `as usize` cast — reject rather than corrupt
                if starts[0] < 0 || ends[0] < 0 || axes[0] < 0 {
                    return Err(UnsupportedFunction("Slice (negative indices)".into()));
                }
                Op::Slice {
                    axis: axes[0] as usize,
                    start: starts[0] as usize,
                    stop: ends[0] as usize,
                }
            }
            "ConvTranspose" => Op::Deconvolution {
                stride: pair(n.attr_ints("strides"), (1, 1)),
                pad: pads(n.attr_ints("pads")),
            },
            "Concat" => Op::Concat {
                axis: match n.attr("axis") {
                    Some(OnnxAttr::Int(a)) => *a as usize,
                    _ => 1,
                },
            },
            "Reshape" => Op::Reshape { dims: n.attr_ints("shape").unwrap_or_default() },
            "Dropout" => Op::Dropout { p: n.attr_f("ratio").unwrap_or(0.5) },
            "Gather" => Op::Embed,
            "Identity" => Op::Identity,
            other => return Err(UnsupportedFunction(other.to_string())),
        };
        // split node inputs into activations vs initializer params
        let (acts, params): (Vec<String>, Vec<String>) =
            n.inputs.iter().cloned().partition(|i| !init_names.contains(i.as_str()));
        layers.push(Layer { name: n.name.clone(), op, inputs: acts, params, outputs: n.outputs.clone() });
    }
    Ok((
        NetworkDef {
            name: model.graph_name.clone(),
            inputs: model.inputs.clone(),
            outputs: model.outputs.clone(),
            layers,
        },
        model.initializers.clone(),
    ))
}

// ---------------------------------------------------------------- file I/O

const MAGIC: &[u8; 5] = b"ONNXL";

fn attrs_to_json(attrs: &[(String, OnnxAttr)]) -> Json {
    Json::Arr(
        attrs
            .iter()
            .map(|(k, v)| {
                let (t, val) = match v {
                    OnnxAttr::Int(i) => ("int", Json::num(*i as f64)),
                    OnnxAttr::Float(f) => ("float", Json::num(*f as f64)),
                    OnnxAttr::Ints(is) => {
                        ("ints", Json::Arr(is.iter().map(|&i| Json::num(i as f64)).collect()))
                    }
                };
                Json::obj(vec![("name", Json::str(k.clone())), ("t", Json::str(t)), ("v", val)])
            })
            .collect(),
    )
}

fn attrs_from_json(j: &Json) -> Vec<(String, OnnxAttr)> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let name = e.get("name").as_str()?.to_string();
                    let v = match e.get("t").as_str()? {
                        "int" => OnnxAttr::Int(e.get("v").as_f64()? as i64),
                        "float" => OnnxAttr::Float(e.get("v").as_f64()? as f32),
                        "ints" => OnnxAttr::Ints(
                            e.get("v")
                                .as_arr()?
                                .iter()
                                .filter_map(|x| x.as_f64().map(|f| f as i64))
                                .collect(),
                        ),
                        _ => return None,
                    };
                    Some((name, v))
                })
                .collect()
        })
        .unwrap_or_default()
}

/// Serialize to `.onnxl` bytes.
pub fn save_bytes(model: &OnnxModel) -> Vec<u8> {
    let header = Json::obj(vec![
        ("opset", Json::num(model.opset as f64)),
        ("graph_name", Json::str(model.graph_name.clone())),
        (
            "inputs",
            Json::Arr(
                model
                    .inputs
                    .iter()
                    .map(|t| {
                        Json::obj(vec![
                            ("name", Json::str(t.name.clone())),
                            ("dims", Json::arr_of_usize(&t.dims)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("outputs", Json::Arr(model.outputs.iter().map(|o| Json::str(o.clone())).collect())),
        (
            "nodes",
            Json::Arr(
                model
                    .nodes
                    .iter()
                    .map(|n| {
                        Json::obj(vec![
                            ("op_type", Json::str(n.op_type.clone())),
                            ("name", Json::str(n.name.clone())),
                            (
                                "inputs",
                                Json::Arr(n.inputs.iter().map(|s| Json::str(s.clone())).collect()),
                            ),
                            (
                                "outputs",
                                Json::Arr(n.outputs.iter().map(|s| Json::str(s.clone())).collect()),
                            ),
                            ("attrs", attrs_to_json(&n.attrs)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let header_bytes = header.to_string().into_bytes();
    let blob = params::save_params(&model.initializers);
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(header_bytes.len() as u64).to_le_bytes());
    out.extend_from_slice(&header_bytes);
    out.extend_from_slice(&blob);
    out
}

/// Deserialize `.onnxl` bytes.
pub fn load_bytes(bytes: &[u8]) -> Result<OnnxModel, String> {
    if bytes.len() < 13 || &bytes[0..5] != MAGIC {
        return Err("not an ONNX-lite file".into());
    }
    let hlen = u64::from_le_bytes(bytes[5..13].try_into().unwrap()) as usize;
    if 13 + hlen > bytes.len() {
        return Err("truncated ONNX-lite header".into());
    }
    let header = Json::parse(
        std::str::from_utf8(&bytes[13..13 + hlen]).map_err(|_| "bad header utf8")?,
    )?;
    let initializers = params::load_params(&bytes[13 + hlen..])?;
    let inputs = header
        .get("inputs")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|t| {
            Some(TensorDef {
                name: t.get("name").as_str()?.to_string(),
                dims: t.get("dims").usize_arr()?,
            })
        })
        .collect();
    let outputs = header
        .get("outputs")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|o| o.as_str().map(String::from))
        .collect();
    let nodes = header
        .get("nodes")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|n| {
            let strs = |j: &Json| -> Vec<String> {
                j.as_arr()
                    .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                    .unwrap_or_default()
            };
            Some(OnnxNode {
                op_type: n.get("op_type").as_str()?.to_string(),
                name: n.get("name").as_str()?.to_string(),
                inputs: strs(n.get("inputs")),
                outputs: strs(n.get("outputs")),
                attrs: attrs_from_json(n.get("attrs")),
            })
        })
        .collect();
    Ok(OnnxModel {
        opset: header.get("opset").as_f64().unwrap_or(20.0) as i64,
        graph_name: header.get("graph_name").as_str().unwrap_or("graph").to_string(),
        inputs,
        outputs,
        initializers,
        nodes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nnp::interpreter;
    use crate::nnp::tests::sample_nnp;

    #[test]
    fn nnp_to_onnx_to_nnp_preserves_inference() {
        let nnp = sample_nnp();
        let net = &nnp.networks[0];
        let onnx = to_onnx(net, &nnp.param_map()).unwrap();
        assert_eq!(onnx.nodes[0].op_type, "Gemm");
        assert_eq!(onnx.initializers.len(), 2);

        let (net2, params2) = from_onnx(&onnx).unwrap();
        let pm: HashMap<String, NdArray> = params2.into_iter().collect();
        let mut inputs = HashMap::new();
        inputs.insert("x".to_string(), NdArray::from_slice(&[2, 3], &[1., 0., 0., 0., 2., 0.]));
        let a = interpreter::run(net, &inputs, &nnp.param_map()).unwrap();
        let b = interpreter::run(&net2, &inputs, &pm).unwrap();
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn swish_refused_with_clear_error() {
        use crate::nnp::ir::{Layer, Op};
        let mut nnp = sample_nnp();
        nnp.networks[0].layers.push(Layer {
            name: "sw".into(),
            op: Op::Swish,
            inputs: vec!["y".into()],
            params: vec![],
            outputs: vec!["z".into()],
        });
        let err = to_onnx(&nnp.networks[0], &nnp.param_map()).unwrap_err();
        assert!(err.to_string().contains("Swish"));
    }

    #[test]
    fn file_roundtrip() {
        let nnp = sample_nnp();
        let onnx = to_onnx(&nnp.networks[0], &nnp.param_map()).unwrap();
        let bytes = save_bytes(&onnx);
        let back = load_bytes(&bytes).unwrap();
        assert_eq!(back.nodes, onnx.nodes);
        assert_eq!(back.inputs, onnx.inputs);
        assert_eq!(back.outputs, onnx.outputs);
        assert_eq!(back.opset, onnx.opset);
        assert_eq!(back.initializers.len(), onnx.initializers.len());
        for ((n1, a1), (n2, a2)) in back.initializers.iter().zip(&onnx.initializers) {
            assert_eq!(n1, n2);
            assert_eq!(a1.data(), a2.data());
        }
    }

    #[test]
    fn unknown_onnx_op_rejected_on_import() {
        let model = OnnxModel {
            opset: 20,
            graph_name: "g".into(),
            inputs: vec![],
            outputs: vec![],
            initializers: vec![],
            nodes: vec![OnnxNode {
                op_type: "LSTM".into(),
                name: "l".into(),
                inputs: vec![],
                outputs: vec![],
                attrs: vec![],
            }],
        };
        let err = from_onnx(&model).unwrap_err();
        assert!(err.to_string().contains("LSTM"));
    }

    #[test]
    fn conv_attrs_roundtrip_through_onnx() {
        use crate::nnp::ir::{Layer, Op};
        let net = NetworkDef {
            name: "c".into(),
            inputs: vec![TensorDef { name: "x".into(), dims: vec![1, 3, 8, 8] }],
            outputs: vec!["y".into()],
            layers: vec![Layer {
                name: "conv".into(),
                op: Op::Convolution { stride: (2, 1), pad: (1, 2), dilation: (1, 1) },
                inputs: vec!["x".into()],
                params: vec!["W".into()],
                outputs: vec!["y".into()],
            }],
        };
        let mut pm = HashMap::new();
        pm.insert("W".to_string(), NdArray::zeros(&[4, 3, 3, 3]));
        let onnx = to_onnx(&net, &pm).unwrap();
        let (net2, _) = from_onnx(&onnx).unwrap();
        assert_eq!(net2.layers[0].op, net.layers[0].op);
    }
}
