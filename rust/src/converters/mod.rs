//! File-format converters (paper §3, Figure 2): NNP is the hub format,
//! and each converter maps it to/from a deployment format:
//!
//! - [`onnx_lite`] — ONNX subset, bidirectional (`NNP ⇄ ONNX`);
//! - [`nnb`] — NNB flat binary for the C-runtime analogue (`NNP → NNB`),
//!   in two versions — v1 (f32) and NNB2 (int8 weights + scales +
//!   calibration, see [`crate::quant`]) — executed through
//!   [`nnb::NnbEngine`] on the compiled-plan fast path;
//! - [`frozen`] — frozen-graph single file, params inlined as constants
//!   (`NNP → TF-frozen-graph` analogue), bidirectional;
//! - [`rs_source`] — standalone Rust source generation
//!   (`NNP → C source code` analogue);
//! - [`query`] — the unsupported-function querying commands the paper
//!   describes ("users may use querying commands ... to check whether
//!   it contains unsupported function").

pub mod frozen;
pub mod nnb;
pub mod onnx_lite;
pub mod query;
pub mod rs_source;
