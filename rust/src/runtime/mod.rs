//! The static-graph runtime: loads AOT-compiled HLO artifacts (emitted
//! once by `python/compile/aot.py`) and executes them through the PJRT
//! C API via the `xla` crate. This is the paper's "speed-optimized
//! backend" — the `cudnn` extension context of Listing 2 mapped to
//! XLA-CPU. Python never runs here.
//!
//! Requires the `pjrt` cargo feature (the `xla` crate links native XLA
//! libraries); without it [`StaticExecutable`] is a stub that reports
//! the backend unavailable and callers use the dynamic engine.

pub mod artifact;
pub mod executable;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use executable::StaticExecutable;
