//! `StaticExecutable`: one AOT-compiled HLO program, compiled once at
//! load time and executed many times from the training/serving hot
//! path. Wraps the PJRT CPU client of the `xla` crate.
//!
//! The `xla` crate links native XLA libraries and is not available in
//! offline builds, so the real implementation is gated behind the
//! `pjrt` cargo feature. Without it, [`StaticExecutable::load`] returns
//! a clean error and every caller falls back to (or skips to) the
//! dynamic tape engine — the framework's other backend.

use super::artifact::{ArtifactSpec, Manifest};

#[cfg(feature = "pjrt")]
mod imp {
    use anyhow::{anyhow, Context, Result};

    use super::{ArtifactSpec, Manifest};
    use crate::tensor::NdArray;

    /// A compiled artifact bound to a PJRT client.
    pub struct StaticExecutable {
        spec: ArtifactSpec,
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
    }

    impl StaticExecutable {
        /// Load + compile `name` from the manifest. Compilation happens
        /// once here; `execute` afterwards is pure run.
        pub fn load(manifest: &Manifest, name: &str) -> Result<Self> {
            let spec = manifest.get(name).map_err(|e| anyhow!(e))?.clone();
            let hlo_path = manifest.hlo_path(&spec);
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(&hlo_path)
                .with_context(|| format!("loading HLO text {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("compiling HLO")?;
            Ok(StaticExecutable { spec, client, exe })
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        /// Host → device: literal for one input tensor.
        fn literal_for(&self, spec_idx: usize, a: &NdArray) -> Result<xla::Literal> {
            let spec = &self.spec.inputs[spec_idx];
            anyhow::ensure!(
                a.dims() == spec.dims.as_slice(),
                "input '{}' shape {:?} != expected {:?}",
                spec.name,
                a.dims(),
                spec.dims
            );
            let lit = xla::Literal::vec1(a.data());
            let dims: Vec<i64> = a.dims().iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }

        /// Execute with inputs in manifest order (params..., data...).
        /// Returns outputs in manifest order.
        pub fn execute(&self, inputs: &[NdArray]) -> Result<Vec<NdArray>> {
            anyhow::ensure!(
                inputs.len() == self.spec.inputs.len(),
                "artifact '{}' takes {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .enumerate()
                .map(|(i, a)| self.literal_for(i, a))
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals)?;
            let tuple = result[0][0].to_literal_sync()?;
            self.unpack_outputs(tuple)
        }

        fn unpack_outputs(&self, tuple: xla::Literal) -> Result<Vec<NdArray>> {
            // jax lowers with return_tuple=True: always a tuple literal
            let parts = tuple.to_tuple()?;
            anyhow::ensure!(
                parts.len() == self.spec.outputs.len(),
                "artifact '{}' returned {} outputs, manifest declares {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
            parts
                .into_iter()
                .zip(&self.spec.outputs)
                .map(|(lit, ospec)| {
                    // convert (e.g. bf16 outputs) to f32 before reading back
                    let lit = lit.convert(xla::PrimitiveType::F32)?;
                    let v = lit.to_vec::<f32>()?;
                    anyhow::ensure!(
                        v.len() == ospec.size(),
                        "output '{}' has {} elems, expected {:?}",
                        ospec.name,
                        v.len(),
                        ospec.dims
                    );
                    Ok(NdArray::from_vec(&ospec.dims, v))
                })
                .collect()
        }

        /// Device info string (for logs / Console records).
        pub fn platform(&self) -> String {
            format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
        }
    }

    // SAFETY: PJRT CPU client handles are safe to move across threads
    // (the C API is documented thread-safe for execution); the wrapper
    // types only lack the auto-trait because they hold raw pointers.
    // Each serve worker owns its own executable, so ownership transfer
    // is the only cross-thread operation — no shared mutation occurs.
    unsafe impl Send for StaticExecutable {}
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{anyhow, bail, Result};

    use super::{ArtifactSpec, Manifest};
    use crate::tensor::NdArray;

    /// Stub: the `pjrt` feature is off, so the static backend reports
    /// itself unavailable instead of linking the `xla` crate.
    pub struct StaticExecutable {
        spec: ArtifactSpec,
    }

    impl StaticExecutable {
        pub fn load(manifest: &Manifest, name: &str) -> Result<Self> {
            // still validate the artifact reference so callers get the
            // most specific error first
            let _spec = manifest.get(name).map_err(|e| anyhow!(e))?;
            bail!(
                "static PJRT runtime unavailable for artifact '{name}': \
                 built without the `pjrt` cargo feature (use the dynamic engine instead)"
            )
        }

        pub fn spec(&self) -> &ArtifactSpec {
            &self.spec
        }

        pub fn execute(&self, _inputs: &[NdArray]) -> Result<Vec<NdArray>> {
            bail!("static PJRT runtime unavailable: built without the `pjrt` cargo feature")
        }

        pub fn platform(&self) -> String {
            "pjrt (disabled at build time)".to_string()
        }
    }
}

pub use imp::StaticExecutable;

#[cfg(test)]
mod tests {
    //! Integration tests against real artifacts live in
    //! `rust/tests/static_runtime.rs` (they need `make artifacts`).
    //! Here we test the error paths that don't need artifacts.

    use super::*;
    use std::path::Path;

    #[test]
    fn load_missing_artifact_fails_with_listing() {
        let m = Manifest::parse(Path::new("/tmp"), r#"{"artifacts": []}"#).unwrap();
        match StaticExecutable::load(&m, "ghost") {
            Ok(_) => panic!("expected error"),
            Err(e) => assert!(e.to_string().contains("no artifact 'ghost'")),
        }
    }
}
