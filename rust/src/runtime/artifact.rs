//! Artifact manifest: `artifacts/manifest.json` describes every AOT
//! variant — its HLO file, ordered input/output tensor specs, and the
//! parameter layout (names + init spec) so Rust can materialize the
//! exact initial parameters the JAX side would.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::tensor::{NdArray, Rng};
use crate::utils::json::Json;

/// One tensor signature in an artifact's calling convention.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            name: j.get("name").as_str()?.to_string(),
            dims: j.get("dims").usize_arr()?,
            dtype: j.get("dtype").as_str().unwrap_or("float32").to_string(),
        })
    }

    pub fn size(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-compiled variant (model × precision × batch).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Variant name, e.g. `resnet_mini_train_f32_b32`.
    pub name: String,
    /// HLO text file (relative to the manifest).
    pub hlo_file: String,
    /// Inputs in calling order: params first, then data tensors.
    pub inputs: Vec<TensorSpec>,
    /// Outputs in order: grads first (matching param order), then loss.
    pub outputs: Vec<TensorSpec>,
    /// Names of the leading `inputs` that are parameters.
    pub param_names: Vec<String>,
    /// Initializer spec per parameter: `(kind, scale)` where kind is
    /// `zeros | ones | normal | uniform` (seeded by the manifest seed).
    pub param_init: Vec<(String, f32)>,
    /// RNG seed used for parameter init.
    pub seed: u64,
}

impl ArtifactSpec {
    /// Materialize the initial parameters exactly as aot.py declared.
    pub fn init_params(&self) -> Vec<(String, NdArray)> {
        let mut rng = Rng::new(self.seed);
        self.param_names
            .iter()
            .zip(&self.param_init)
            .map(|(name, (kind, scale))| {
                let spec = self
                    .inputs
                    .iter()
                    .find(|t| &t.name == name)
                    .unwrap_or_else(|| panic!("param '{name}' missing from inputs"));
                let a = match kind.as_str() {
                    "zeros" => NdArray::zeros(&spec.dims),
                    "ones" => NdArray::ones(&spec.dims),
                    "normal" => rng.randn(&spec.dims, *scale),
                    "uniform" => rng.rand(&spec.dims, -*scale, *scale),
                    other => panic!("unknown init kind '{other}'"),
                };
                (name.clone(), a)
            })
            .collect()
    }

    /// Data (non-parameter) inputs, in calling order.
    pub fn data_inputs(&self) -> &[TensorSpec] {
        &self.inputs[self.param_names.len()..]
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("cannot read manifest: {e}"))?;
        Self::parse(dir, &text)
    }

    /// Default artifact location (`$NNL_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var("NNL_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
            // walk up from cwd to find an artifacts/ dir (tests run in
            // target subdirs)
            let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
            loop {
                let cand = d.join("artifacts");
                if cand.join("manifest.json").exists() {
                    return cand;
                }
                if !d.pop() {
                    return PathBuf::from("artifacts");
                }
            }
        })
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let j = Json::parse(text)?;
        let mut artifacts = HashMap::new();
        for a in j.get("artifacts").as_arr().ok_or("manifest missing artifacts")? {
            let specs = |key: &str| -> Vec<TensorSpec> {
                a.get(key)
                    .as_arr()
                    .map(|v| v.iter().filter_map(TensorSpec::from_json).collect())
                    .unwrap_or_default()
            };
            let name = a.get("name").as_str().ok_or("artifact missing name")?.to_string();
            let param_names: Vec<String> = a
                .get("param_names")
                .as_arr()
                .map(|v| v.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let param_init: Vec<(String, f32)> = a
                .get("param_init")
                .as_arr()
                .map(|v| {
                    v.iter()
                        .filter_map(|e| {
                            Some((
                                e.get("kind").as_str()?.to_string(),
                                e.get("scale").as_f64().unwrap_or(0.0) as f32,
                            ))
                        })
                        .collect()
                })
                .unwrap_or_default();
            if param_init.len() != param_names.len() {
                return Err(format!("artifact '{name}': param_init/param_names mismatch"));
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name,
                    hlo_file: a.get("hlo_file").as_str().ok_or("missing hlo_file")?.to_string(),
                    inputs: specs("inputs"),
                    outputs: specs("outputs"),
                    param_names,
                    param_init,
                    seed: a.get("seed").as_f64().unwrap_or(0.0) as u64,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec, String> {
        self.artifacts.get(name).ok_or_else(|| {
            let mut names: Vec<&String> = self.artifacts.keys().collect();
            names.sort();
            format!("no artifact '{name}'; available: {names:?}")
        })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.hlo_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {
          "name": "mlp_train_f32_b8",
          "hlo_file": "mlp_train_f32_b8.hlo.txt",
          "seed": 42,
          "param_names": ["w1", "b1"],
          "param_init": [
            {"kind": "normal", "scale": 0.05},
            {"kind": "zeros", "scale": 0}
          ],
          "inputs": [
            {"name": "w1", "dims": [4, 8], "dtype": "float32"},
            {"name": "b1", "dims": [8], "dtype": "float32"},
            {"name": "x", "dims": [8, 4], "dtype": "float32"},
            {"name": "y", "dims": [8], "dtype": "float32"}
          ],
          "outputs": [
            {"name": "g_w1", "dims": [4, 8], "dtype": "float32"},
            {"name": "g_b1", "dims": [8], "dtype": "float32"},
            {"name": "loss", "dims": [], "dtype": "float32"}
          ]
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.get("mlp_train_f32_b8").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.outputs.len(), 3);
        assert_eq!(a.param_names, vec!["w1", "b1"]);
        assert_eq!(a.data_inputs().len(), 2);
        assert_eq!(a.data_inputs()[0].name, "x");
    }

    #[test]
    fn init_params_deterministic_and_shaped() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let a = m.get("mlp_train_f32_b8").unwrap();
        let p1 = a.init_params();
        let p2 = a.init_params();
        assert_eq!(p1.len(), 2);
        assert_eq!(p1[0].1.dims(), &[4, 8]);
        assert_eq!(p1[0].1.data(), p2[0].1.data()); // deterministic
        assert_eq!(p1[1].1.sum_all(), 0.0); // zeros init
    }

    #[test]
    fn missing_artifact_lists_available() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err();
        assert!(err.contains("mlp_train_f32_b8"));
    }

    #[test]
    fn rejects_mismatched_init() {
        let bad = SAMPLE.replace(
            r#"{"kind": "normal", "scale": 0.05},
            {"kind": "zeros", "scale": 0}"#,
            r#"{"kind": "normal", "scale": 0.05}"#,
        );
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }
}
