//! Training orchestration — ties together graphs, solvers, mixed
//! precision, the communicator and monitors. Three paths, matching the
//! paper's backends:
//!
//! - [`train_dynamic`] — the define-by-run engine (`cpu` context);
//! - [`train_static`] — AOT HLO through PJRT (`xla` context,
//!   Listing 2's one-line switch decides which of these runs);
//! - [`train_distributed`] — N simulated devices, per-worker backward +
//!   `all_reduce` (Listing 3 / Figure 3).

use std::collections::HashMap;
use std::time::Instant;

use crate::comm::{plan_buckets, Collective, CommError, CommHub, Reducer};
use crate::context::{Backend, Context, TypeConfig};
use crate::data::DataSource;
use crate::functions as F;
use crate::graph::Variable;
use crate::mixed_precision::{LossScaler, MasterWeights};
use crate::models::{build_model, Gb};
use crate::monitor::{MonitorSeries, MonitorTimeElapsed};
use crate::parametric as PF;
use crate::runtime::{Manifest, StaticExecutable};
use crate::solvers::Solver;
use crate::tensor::{NdArray, DType};

/// Training configuration (the TrainingConfig + Optimizer messages).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// `sgd | momentum | adam`
    pub solver: String,
    /// None = FP-32; Some(scaler) = mixed precision (§3.3).
    pub loss_scale: Option<LossScalerKind>,
    pub val_batches: usize,
    pub seed: u64,
}

/// Loss-scaler construction spec (Listing 6's two modes).
#[derive(Debug, Clone)]
pub enum LossScalerKind {
    Fixed(f32),
    Dynamic { initial: f32, factor: f32, interval: usize },
}

impl LossScalerKind {
    fn build(&self) -> LossScaler {
        match self {
            LossScalerKind::Fixed(s) => LossScaler::fixed(*s),
            LossScalerKind::Dynamic { initial, factor, interval } => {
                LossScaler::dynamic(*initial, *factor, *interval)
            }
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 0.05,
            weight_decay: 0.0,
            solver: "momentum".into(),
            loss_scale: None,
            val_batches: 4,
            seed: 313,
        }
    }
}

/// Build the solver `cfg` names, or a clean error listing the options
/// — the validation entry for untrusted config (CLI flags, nntxt
/// Optimizer messages) so a typo surfaces as an error message, not a
/// panic mid-run.
pub fn try_make_solver(cfg: &TrainConfig) -> Result<Solver, String> {
    Ok(match cfg.solver.as_str() {
        "sgd" => Solver::sgd(cfg.lr),
        "momentum" => Solver::momentum(cfg.lr, 0.9),
        "adam" => Solver::adam(cfg.lr, 0.9, 0.999, 1e-8),
        other => {
            return Err(format!(
                "unknown solver '{other}' (available: sgd, momentum, adam)"
            ))
        }
    })
}

fn make_solver(cfg: &TrainConfig) -> Solver {
    try_make_solver(cfg).unwrap_or_else(|e| panic!("{e}"))
}

/// Outcome of a training run (feeds the Console trial records and the
/// table generators).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub losses: MonitorSeries,
    pub val_error: f32,
    pub wall_secs: f64,
    pub steps: usize,
    pub n_params: usize,
    pub macs: u64,
    pub backend: &'static str,
    pub overflow_skips: usize,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.tail_mean(10)
    }
}

// ------------------------------------------------------------- dynamic

/// Train a zoo model on the define-by-run engine.
pub fn train_dynamic(model: &str, data: &dyn DataSource, cfg: &TrainConfig) -> TrainReport {
    PF::clear_parameters();
    PF::seed_parameter_rng(cfg.seed);
    F::dropout::seed_dropout(cfg.seed ^ 0xD0);
    let half = Context::default().type_config == TypeConfig::Half;

    let batch0 = data.batch(0, 0, 1);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();

    // training graph (built once, re-executed per batch — Figure 1)
    let mut g = Gb::new(model, true);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let macs = g.macs();
    let y = Variable::new(&[bs, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let params = PF::get_parameters();
    let n_params: usize = params.iter().map(|(_, v)| v.size()).sum();

    // mixed precision: f32 masters behind bf16 working params
    let masters = if half { Some(MasterWeights::new(&params)) } else { None };
    let mut solver = make_solver(cfg);
    match &masters {
        Some(m) => solver.set_parameters(m.masters()),
        None => solver.set_parameters(&params),
    }
    let mut scaler = cfg.loss_scale.as_ref().map(|k| k.build());

    let mut losses = MonitorSeries::new("loss");
    let timer = MonitorTimeElapsed::new();
    let mut skips = 0usize;
    for step in 0..cfg.steps {
        let (bx, by) = data.batch(step, 0, 1);
        x.var.set_data(bx);
        y.set_data(by.reshape(&[bs, 1]));
        loss.forward();
        solver.zero_grad();
        for (_, p) in &params {
            p.zero_grad();
        }
        let scale = scaler.as_ref().map(|s| s.scale()).unwrap_or(1.0);
        loss.backward_with_scale(scale);
        if let Some(m) = &masters {
            m.pull_grads();
        }
        solver.weight_decay(cfg.weight_decay * scale);
        let applied = match &mut scaler {
            Some(s) => {
                let ok = s.step(&mut solver);
                if !ok {
                    skips += 1;
                }
                ok
            }
            None => {
                solver.update();
                true
            }
        };
        if applied {
            if let Some(m) = &masters {
                m.push_weights();
            }
        }
        losses.add(step, loss.item());
    }

    let val_error = evaluate_dynamic(model, data, cfg.val_batches);
    TrainReport {
        model: model.to_string(),
        losses,
        val_error,
        wall_secs: timer.total_secs(),
        steps: cfg.steps,
        n_params,
        macs,
        backend: if half { "cpu:half" } else { "cpu:float" },
        overflow_skips: skips,
    }
}

/// Validation error (argmax) of the current registry parameters, using
/// an eval-mode graph (running-stat BN, inert dropout).
///
/// The eval graph is traced and compiled **once** through the full O2
/// pass pipeline (`nnp::passes`: BN folded onto the running stats,
/// dropout elided, dense→ReLU chains fused), then executed per batch —
/// the same optimized serving path `nnl serve` runs, exercised here on
/// every training run. Training itself never sees the optimizer: the
/// tape records and differentiates the graph exactly as written (the
/// O0 contract). If the trace cannot compile, evaluation falls back to
/// forwarding the tape directly.
pub fn evaluate_dynamic(model: &str, data: &dyn DataSource, batches: usize) -> f32 {
    let batch0 = data.val_batch(0);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();
    let mut g = Gb::new(model, false);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let classes = data.classes();
    let def = g.finish(&[&logits]);
    let snapshot: std::collections::HashMap<String, NdArray> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let plan = crate::nnp::CompiledNet::compile(&def, &snapshot);
    let mut wrong = 0usize;
    let mut total = 0usize;
    for i in 0..batches {
        let (bx, by) = data.val_batch(i);
        let planned = plan.as_ref().ok().and_then(|p| {
            p.execute_positional(std::slice::from_ref(&bx)).ok().map(|mut o| o.remove(0))
        });
        let out = match planned {
            Some(o) => o,
            None => {
                // untraceable graph or a batch the plan rejects:
                // forward the tape directly, never abort a training run
                x.var.set_data(bx);
                logits.var.forward();
                logits.var.data()
            }
        };
        for b in 0..bs {
            let row = &out.data()[b * classes..(b + 1) * classes];
            // NaN-safe total ordering (shared with the serving path):
            // NaN logits count as a miss instead of panicking
            let pred = crate::tensor::ops::argmax(row);
            if pred != by.data()[b] as usize {
                wrong += 1;
            }
            total += 1;
        }
    }
    wrong as f32 / total as f32
}

// -------------------------------------------------------------- static

/// Train through an AOT artifact (PJRT). The artifact computes
/// `(params, x, y, loss_scale) -> (scaled grads, loss)`; solver,
/// weight decay and the loss-scaler state machine run in Rust.
pub fn train_static(
    manifest: &Manifest,
    artifact: &str,
    data: &dyn DataSource,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let exe = StaticExecutable::load(manifest, artifact)?;
    let spec = exe.spec().clone();
    let param_vars: Vec<(String, Variable)> = spec
        .init_params()
        .into_iter()
        .map(|(n, a)| (n.clone(), Variable::from_array(a, true)))
        .collect();
    let n_params: usize = param_vars.iter().map(|(_, v)| v.size()).sum();
    let mut solver = make_solver(cfg);
    solver.set_parameters(&param_vars);
    let mut scaler = cfg.loss_scale.as_ref().map(|k| k.build());

    let mut losses = MonitorSeries::new("loss");
    let timer = Instant::now();
    let mut skips = 0usize;
    for step in 0..cfg.steps {
        let (bx, by) = data.batch(step, 0, 1);
        let scale = scaler.as_ref().map(|s| s.scale()).unwrap_or(1.0);
        let mut inputs: Vec<NdArray> = param_vars.iter().map(|(_, v)| v.data()).collect();
        inputs.push(bx);
        inputs.push(by.reshape(&spec.data_inputs()[1].dims));
        inputs.push(NdArray::scalar(scale));
        let out = exe.execute(&inputs)?;
        for ((_, v), grad) in param_vars.iter().zip(&out[..param_vars.len()]) {
            v.set_grad(grad.clone());
        }
        solver.weight_decay(cfg.weight_decay * scale);
        match &mut scaler {
            Some(s) => {
                if !s.step(&mut solver) {
                    skips += 1;
                }
            }
            None => solver.update(),
        }
        losses.add(step, out.last().unwrap().item());
    }
    Ok(TrainReport {
        model: artifact.to_string(),
        losses,
        val_error: f32::NAN, // measured via the matching infer artifact where present
        wall_secs: timer.elapsed().as_secs_f64(),
        steps: cfg.steps,
        n_params,
        macs: 0,
        backend: "xla",
        overflow_skips: skips,
    })
}

/// Validation error through an inference artifact, given trained params.
pub fn evaluate_static(
    manifest: &Manifest,
    infer_artifact: &str,
    params: &[NdArray],
    data: &dyn DataSource,
    batches: usize,
) -> anyhow::Result<f32> {
    let exe = StaticExecutable::load(manifest, infer_artifact)?;
    let classes = data.classes();
    let mut wrong = 0usize;
    let mut total = 0usize;
    for i in 0..batches {
        let (bx, by) = data.val_batch(i);
        let bs = bx.dims()[0];
        let mut inputs: Vec<NdArray> = params.to_vec();
        inputs.push(bx);
        let out = exe.execute(&inputs)?;
        let logits = &out[0];
        for b in 0..bs {
            let row = &logits.data()[b * classes..(b + 1) * classes];
            let pred = crate::tensor::ops::argmax(row);
            if pred != by.data()[b] as usize {
                wrong += 1;
            }
            total += 1;
        }
    }
    Ok(wrong as f32 / total as f32)
}

// --------------------------------------------------------- distributed

/// Distributed-training knobs on top of [`TrainConfig`]: gradient
/// bucket size and backward/reduce overlap. Every rank of a job must
/// use identical values (a mismatch desynchronizes the collective
/// sequence and surfaces as a typed `CommError::Protocol`, not silent
/// corruption). Overlap on/off changes only *when* collectives are
/// issued, never their contents — updates are bit-identical either
/// way.
#[derive(Debug, Clone)]
pub struct DistConfig {
    /// Gradient bucket capacity in bytes (`comm::bucket`).
    pub bucket_bytes: usize,
    /// Fire each bucket's all-reduce from the backward-pass hook the
    /// moment its last gradient lands (true), or queue everything
    /// after backward completes (false — the baseline the bench
    /// compares against).
    pub overlap: bool,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig { bucket_bytes: crate::comm::bucket::DEFAULT_BUCKET_BYTES, overlap: true }
    }
}

/// Pack one bucket's gradients into a flat buffer, members in plan
/// order.
fn pack_bucket(members: &[usize], trainable: &[(String, Variable)]) -> Vec<f32> {
    let total: usize = members.iter().map(|&i| trainable[i].1.size()).sum();
    let mut out = Vec::with_capacity(total);
    for &i in members {
        let g = trainable[i].1.grad();
        out.extend_from_slice(g.data());
    }
    out
}

/// One rank's data-parallel training loop over any [`Collective`]
/// backend — threads ([`CommHub`]) or TCP processes
/// (`comm::NetCommunicator`). Listing 3's pattern, plus gradient
/// bucketing and (optionally) reduce/backward overlap driven by the
/// tape's completion hook. Every comm failure propagates as a typed
/// [`CommError`]; nothing in here panics on a dead peer.
pub fn train_worker<C, D>(
    model: &str,
    data: &D,
    cfg: &TrainConfig,
    dist: &DistConfig,
    comm: C,
    backend: &'static str,
) -> Result<TrainReport, CommError>
where
    C: Collective + 'static,
    D: DataSource + ?Sized,
{
    let rank = comm.rank();
    let world = comm.size();
    PF::clear_parameters();
    PF::seed_parameter_rng(cfg.seed); // same init everywhere
    F::dropout::seed_dropout(cfg.seed ^ rank as u64);

    let batch0 = data.batch(0, rank, world);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();
    let mut g = Gb::new(model, true);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let macs = g.macs();
    let y = Variable::new(&[bs, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let params = PF::get_parameters();
    let n_params: usize = params.iter().map(|(_, v)| v.size()).sum();

    // the communicator lives on a background thread from here on
    let red = Reducer::spawn(comm);

    // belt-and-braces weight sync (same seed should already agree) —
    // always exact f32 on the wire, even when gradients ride fp16
    {
        let mut flat: Vec<f32> = Vec::with_capacity(n_params);
        for (_, v) in &params {
            flat.extend_from_slice(v.data().data());
        }
        let synced = red.bcast_flat(flat)?;
        let mut off = 0;
        for (_, v) in &params {
            let n = v.size();
            v.set_data(NdArray::from_vec(&v.dims(), synced[off..off + n].to_vec()));
            off += n;
        }
    }

    let mut solver = make_solver(cfg);
    solver.set_parameters(&params);
    let trainable: Vec<(String, Variable)> = solver.parameters().to_vec();

    // bucket plan: identical on every rank (derived from sizes only)
    let sizes: Vec<usize> = trainable.iter().map(|(_, v)| v.size()).collect();
    let plan = plan_buckets(&sizes, dist.bucket_bytes);
    let mut bucket_of = vec![0usize; sizes.len()];
    for (b, members) in plan.iter().enumerate() {
        for &i in members {
            bucket_of[i] = b;
        }
    }
    let uid_to_idx: HashMap<usize, usize> =
        trainable.iter().enumerate().map(|(i, (_, v))| (v.uid(), i)).collect();

    let mut losses = MonitorSeries::new("loss");
    let timer = MonitorTimeElapsed::new();
    for step in 0..cfg.steps {
        let (bx, by) = data.batch(step, rank, world);
        x.var.set_data(bx);
        y.set_data(by.reshape(&[bs, 1]));
        loss.forward();
        solver.zero_grad();

        // bucketed backward: the hook fires when a parameter's grad is
        // final; a full bucket launches its all-reduce immediately
        // (overlap on) while backward keeps running. Fire order is
        // graph-determined — identical on every rank — so the
        // collective sequences line up.
        let mut remaining: Vec<usize> = plan.iter().map(|m| m.len()).collect();
        let mut fired = vec![false; plan.len()];
        let mut inflight = 0usize;
        let mut hook_err: Option<CommError> = None;
        red.begin_backward();
        loss.backward_with_hook(1.0, &mut |v| {
            if hook_err.is_some() {
                return;
            }
            if let Some(&i) = uid_to_idx.get(&v.uid()) {
                let b = bucket_of[i];
                remaining[b] -= 1;
                if remaining[b] == 0 && dist.overlap {
                    match red.reduce(b, pack_bucket(&plan[b], &trainable), true) {
                        Ok(()) => {
                            fired[b] = true;
                            inflight += 1;
                        }
                        Err(e) => hook_err = Some(e),
                    }
                }
            }
        });
        red.end_backward();
        if let Some(e) = hook_err {
            return Err(e);
        }
        // overlap off queues everything here; overlap on only flushes
        // buckets whose parameters never completed (e.g. unused in
        // this graph). Same buckets, same math, either way.
        for b in 0..plan.len() {
            if !fired[b] {
                red.reduce(b, pack_bucket(&plan[b], &trainable), true)?;
                inflight += 1;
            }
        }
        // drain results (FIFO) and scatter averaged grads back
        for _ in 0..inflight {
            let (b, vals) = red.next_reduced()?;
            let mut off = 0;
            for &i in &plan[b] {
                let (_, v) = &trainable[i];
                let n = v.size();
                v.set_grad(NdArray::from_vec(&v.dims(), vals[off..off + n].to_vec()));
                off += n;
            }
        }

        solver.weight_decay(cfg.weight_decay);
        solver.update();
        // step loss averaged across workers (Figure 3 curve)
        let mean_loss = red.gather(loss.item())?.iter().sum::<f32>() / world as f32;
        losses.add(step, mean_loss);
    }
    red.shutdown();
    let val_error = if rank == 0 { evaluate_dynamic(model, data, cfg.val_batches) } else { 0.0 };
    Ok(TrainReport {
        model: model.to_string(),
        losses,
        val_error,
        wall_secs: timer.total_secs(),
        steps: cfg.steps,
        n_params,
        macs,
        backend,
        overflow_skips: 0,
    })
}

/// Data-parallel training over `world` simulated devices (threads),
/// dynamic engine. Listing 3's pattern verbatim: per-worker backward,
/// `all_reduce` of gradients, identical updates everywhere. Returns
/// rank 0's report (loss averaged across workers per step).
pub fn train_distributed<D>(
    model: &'static str,
    data: D,
    cfg: &TrainConfig,
    world: usize,
) -> TrainReport
where
    D: DataSource + Clone + Send + 'static,
{
    train_distributed_opts(model, data, cfg, world, &DistConfig::default())
        .unwrap_or_else(|e| panic!("distributed training failed: {e}"))
}

/// [`train_distributed`] with explicit [`DistConfig`] and typed
/// errors (the bench toggles overlap through this).
pub fn train_distributed_opts<D>(
    model: &'static str,
    data: D,
    cfg: &TrainConfig,
    world: usize,
    dist: &DistConfig,
) -> Result<TrainReport, CommError>
where
    D: DataSource + Clone + Send + 'static,
{
    let mut hub = CommHub::new(world);
    let mut handles = Vec::new();
    for rank in 0..world {
        let comm = hub.communicator(rank)?;
        let data = data.clone();
        let cfg = cfg.clone();
        let dist = dist.clone();
        handles.push(std::thread::spawn(move || {
            Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float).with_device(rank));
            train_worker(model, &data, &cfg, &dist, comm, "cpu:distributed")
        }));
    }
    let reports: Result<Vec<TrainReport>, CommError> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    let mut reports = reports?;
    Ok(reports.remove(0))
}

/// Sequential simulation of the same `world`-way data-parallel step —
/// the *oracle* the multi-process integration tests compare against
/// bit-for-bit. One graph, one registry: each step forwards/backwards
/// every rank's shard in rank order, accumulates gradients into a
/// zero-initialized buffer in that same order, multiplies by
/// `1/world` and applies one update — exactly the fold both comm
/// backends implement, so an N-process TCP run must match this to the
/// bit (for models without per-rank randomness; dropout models
/// diverge by design since each rank draws its own masks).
pub fn train_distributed_reference<D>(
    model: &str,
    data: &D,
    cfg: &TrainConfig,
    world: usize,
) -> TrainReport
where
    D: DataSource + ?Sized,
{
    PF::clear_parameters();
    PF::seed_parameter_rng(cfg.seed);
    F::dropout::seed_dropout(cfg.seed);

    let batch0 = data.batch(0, 0, world);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();
    let mut g = Gb::new(model, true);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let macs = g.macs();
    let y = Variable::new(&[bs, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let params = PF::get_parameters();
    let n_params: usize = params.iter().map(|(_, v)| v.size()).sum();
    let mut solver = make_solver(cfg);
    solver.set_parameters(&params);
    let trainable: Vec<(String, Variable)> = solver.parameters().to_vec();

    let scale = 1.0 / world as f32;
    let mut losses = MonitorSeries::new("loss");
    let timer = MonitorTimeElapsed::new();
    for step in 0..cfg.steps {
        let mut acc: Vec<Vec<f32>> =
            trainable.iter().map(|(_, v)| vec![0.0f32; v.size()]).collect();
        let mut loss_sum = 0.0f32;
        for rank in 0..world {
            let (bx, by) = data.batch(step, rank, world);
            x.var.set_data(bx);
            y.set_data(by.reshape(&[bs, 1]));
            loss.forward();
            solver.zero_grad();
            loss.backward();
            for (j, (_, v)) in trainable.iter().enumerate() {
                let grad = v.grad();
                for (a, gv) in acc[j].iter_mut().zip(grad.data()) {
                    *a += *gv;
                }
            }
            loss_sum += loss.item();
        }
        for (j, (_, v)) in trainable.iter().enumerate() {
            let vals: Vec<f32> = acc[j].iter().map(|&a| a * scale).collect();
            v.set_grad(NdArray::from_vec(&v.dims(), vals));
        }
        solver.weight_decay(cfg.weight_decay);
        solver.update();
        losses.add(step, loss_sum / world as f32);
    }
    let val_error = evaluate_dynamic(model, data, cfg.val_batches);
    TrainReport {
        model: model.to_string(),
        losses,
        val_error,
        wall_secs: timer.total_secs(),
        steps: cfg.steps,
        n_params,
        macs,
        backend: "cpu:reference",
        overflow_skips: 0,
    }
}

// ------------------------------------------------------- param dumps

/// Serialize this thread's registry parameters (name-sorted, f32 bit
/// patterns) — the artifact the multi-process integration test
/// compares across ranks and against the sequential reference.
pub fn dump_registry_params(path: &str) -> std::io::Result<()> {
    let mut params = PF::get_parameters();
    params.sort_by(|a, b| a.0.cmp(&b.0));
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(b"NNLP");
    out.push(1); // version
    out.extend_from_slice(&(params.len() as u32).to_le_bytes());
    for (name, v) in &params {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let dims = v.dims();
        out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in &dims {
            out.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        let data = v.data();
        out.extend_from_slice(&(data.size() as u32).to_le_bytes());
        for val in data.data() {
            out.extend_from_slice(&val.to_bits().to_le_bytes());
        }
    }
    std::fs::write(path, out)
}

/// Parse a [`dump_registry_params`] file back into
/// `(name, dims, f32 bit patterns)` triples — bit patterns, so equality
/// really is bit-for-bit.
pub fn read_params_dump(path: &str) -> std::io::Result<Vec<(String, Vec<usize>, Vec<u32>)>> {
    let bytes = std::fs::read(path)?;
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    let mut pos = 0usize;
    let mut take = |n: usize| -> std::io::Result<std::ops::Range<usize>> {
        if pos + n > bytes.len() {
            return Err(bad("truncated params dump"));
        }
        pos += n;
        Ok(pos - n..pos)
    };
    let u32_at = |r: std::ops::Range<usize>| {
        u32::from_le_bytes(bytes[r].try_into().expect("4 bytes")) as usize
    };
    if &bytes[take(4)?] != b"NNLP" || bytes[take(1)?.start] != 1 {
        return Err(bad("bad params dump header"));
    }
    let count = {
        let r = take(4)?;
        u32_at(r)
    };
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let name_len = {
            let r = take(4)?;
            u32_at(r)
        };
        if name_len > 4096 {
            return Err(bad("params dump name too long"));
        }
        let name = String::from_utf8(bytes[take(name_len)?].to_vec())
            .map_err(|_| bad("non-UTF8 name in params dump"))?;
        let ndim = {
            let r = take(4)?;
            u32_at(r)
        };
        if ndim > 16 {
            return Err(bad("params dump rank too large"));
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let r = take(4)?;
            dims.push(u32_at(r));
        }
        let elems = {
            let r = take(4)?;
            u32_at(r)
        };
        let r = take(elems * 4)?;
        let bits: Vec<u32> = bytes[r]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect();
        out.push((name, dims, bits));
    }
    Ok(out)
}

/// Quantize current registry parameters for a half-precision run.
pub fn quantize_registry(dtype: DType) {
    let params = PF::get_parameters();
    crate::mixed_precision::quantize_params(&params, dtype);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticImages;

    fn small_cfg(steps: usize) -> TrainConfig {
        TrainConfig { steps, lr: 0.05, val_batches: 2, ..Default::default() }
    }

    #[test]
    fn dynamic_mlp_learns_synthetic() {
        // mlp on flattened synthetic images: loss must halve, error
        // must beat chance decisively
        let data = SyntheticImages::new(4, 1, 8, 16, 3);
        // mlp takes [B, 64]: wrap with a flattening source
        #[derive(Clone)]
        struct Flat(SyntheticImages);
        impl crate::data::DataSource for Flat {
            fn batch(&self, i: usize, r: usize, w: usize) -> crate::data::Batch {
                let (x, y) = self.0.batch(i, r, w);
                let b = x.dims()[0];
                (x.reshape(&[b, 64]), y)
            }
            fn val_batch(&self, i: usize) -> crate::data::Batch {
                let (x, y) = self.0.val_batch(i);
                let b = x.dims()[0];
                (x.reshape(&[b, 64]), y)
            }
            fn input_dims(&self) -> Vec<usize> {
                vec![64]
            }
            fn classes(&self) -> usize {
                4
            }
        }
        let report = train_dynamic("mlp", &Flat(data), &small_cfg(60));
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first * 0.5, "{first} -> {}", report.final_loss());
        assert!(report.val_error < 0.5, "val error {}", report.val_error); // chance = 0.75
    }

    #[test]
    fn dynamic_mixed_precision_trains() {
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Half));
        let data = SyntheticImages::new(4, 3, 16, 8, 5);
        let mut cfg = small_cfg(25);
        cfg.loss_scale = Some(LossScalerKind::Dynamic { initial: 8.0, factor: 2.0, interval: 100 });
        let report = train_dynamic("resnet18", &data, &cfg);
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first, "half training diverged");
        assert_eq!(report.backend, "cpu:half");
    }

    #[test]
    fn unknown_solver_errs_cleanly_on_the_try_path() {
        let cfg = TrainConfig { solver: "adamw".into(), ..Default::default() };
        let err = try_make_solver(&cfg).unwrap_err();
        assert!(err.contains("unknown solver 'adamw'"), "{err}");
        assert!(err.contains("momentum"), "error must list the options: {err}");
        assert!(try_make_solver(&small_cfg(1)).is_ok());
    }

    #[test]
    fn distributed_matches_single_worker_gradient_math() {
        // 2 workers with lr/1: after same number of steps on disjoint
        // data, the loss still falls; and workers stay in sync (the
        // all_reduce property tests prove exact agreement)
        let data = SyntheticImages::new(4, 3, 16, 8, 7);
        let report = train_distributed("resnet18", data, &small_cfg(15), 2);
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first, "distributed diverged");
        assert_eq!(report.backend, "cpu:distributed");
    }

    /// Run `world` thread-backend workers with the given overlap
    /// setting and dump each rank's final registry to a file; returns
    /// the dump paths.
    fn run_workers_and_dump(
        data: &SyntheticImages,
        cfg: &TrainConfig,
        world: usize,
        overlap: bool,
        tag: &str,
    ) -> Vec<std::path::PathBuf> {
        let dist = DistConfig { overlap, ..Default::default() };
        let mut hub = CommHub::new(world);
        let mut handles = Vec::new();
        for rank in 0..world {
            let comm = hub.communicator(rank).expect("fresh rank");
            let data = data.clone();
            let cfg = cfg.clone();
            let dist = dist.clone();
            let path = std::env::temp_dir().join(format!("nnl_dist_test_{tag}_r{rank}.bin"));
            handles.push(std::thread::spawn(move || {
                train_worker("lenet", &data, &cfg, &dist, comm, "cpu:distributed")
                    .expect("train_worker");
                dump_registry_params(path.to_str().expect("utf8 path")).expect("dump worker");
                path
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    }

    #[test]
    fn distributed_lenet_is_bit_identical_to_sequential_reference() {
        // lenet: no dropout, no BN — the oracle model. The 2-worker
        // thread-backend run must reproduce the sequential simulation
        // of the same fold to the bit, with overlap on AND off.
        let cfg = TrainConfig { steps: 4, val_batches: 1, ..small_cfg(4) };
        let world = 2;
        let data = SyntheticImages::new(10, 1, 28, 8, 1);
        train_distributed_reference("lenet", &data, &cfg, world);
        let ref_path = std::env::temp_dir().join("nnl_dist_test_ref.bin");
        dump_registry_params(ref_path.to_str().expect("utf8 path")).expect("dump reference");
        let reference = read_params_dump(ref_path.to_str().unwrap()).expect("read reference");
        assert!(!reference.is_empty(), "reference dump has no parameters");

        for overlap in [true, false] {
            let tag = if overlap { "on" } else { "off" };
            for path in run_workers_and_dump(&data, &cfg, world, overlap, tag) {
                let got = read_params_dump(path.to_str().unwrap()).expect("read worker dump");
                assert_eq!(
                    got.len(),
                    reference.len(),
                    "param count mismatch (overlap={overlap})"
                );
                for ((gn, gd, gb), (rn, rd, rb)) in got.iter().zip(&reference) {
                    assert_eq!(gn, rn, "param order (overlap={overlap})");
                    assert_eq!(gd, rd, "dims of {gn} (overlap={overlap})");
                    assert_eq!(gb, rb, "{gn} not bit-identical (overlap={overlap})");
                }
                let _ = std::fs::remove_file(&path);
            }
        }
        let _ = std::fs::remove_file(&ref_path);
    }

    #[test]
    fn params_dump_rejects_truncation_and_garbage() {
        let dir = std::env::temp_dir();
        let good = dir.join("nnl_dump_roundtrip.bin");
        PF::clear_parameters();
        PF::seed_parameter_rng(5);
        let _ =
            PF::get_or_create_parameter("w", &[3, 2], |_| NdArray::full(&[3, 2], 1.5), true);
        dump_registry_params(good.to_str().unwrap()).expect("dump");
        let parsed = read_params_dump(good.to_str().unwrap()).expect("roundtrip");
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].0, "w");
        assert_eq!(parsed[0].1, vec![3, 2]);
        assert_eq!(parsed[0].2, vec![1.5f32.to_bits(); 6]);

        let bytes = std::fs::read(&good).expect("read dump");
        for cut in [0, 3, 5, 9, bytes.len() - 1] {
            let bad = dir.join(format!("nnl_dump_cut_{cut}.bin"));
            std::fs::write(&bad, &bytes[..cut]).expect("write truncated");
            assert!(
                read_params_dump(bad.to_str().unwrap()).is_err(),
                "truncation at {cut} must be a typed error"
            );
            let _ = std::fs::remove_file(&bad);
        }
        let garbage = dir.join("nnl_dump_garbage.bin");
        std::fs::write(&garbage, b"not a params dump at all").expect("write garbage");
        assert!(read_params_dump(garbage.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(&garbage);
        let _ = std::fs::remove_file(&good);
    }
}
