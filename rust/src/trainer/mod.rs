//! Training orchestration — ties together graphs, solvers, mixed
//! precision, the communicator and monitors. Three paths, matching the
//! paper's backends:
//!
//! - [`train_dynamic`] — the define-by-run engine (`cpu` context);
//! - [`train_static`] — AOT HLO through PJRT (`xla` context,
//!   Listing 2's one-line switch decides which of these runs);
//! - [`train_distributed`] — N simulated devices, per-worker backward +
//!   `all_reduce` (Listing 3 / Figure 3).

use std::time::Instant;

use crate::comm::CommHub;
use crate::context::{Backend, Context, TypeConfig};
use crate::data::DataSource;
use crate::functions as F;
use crate::graph::Variable;
use crate::mixed_precision::{LossScaler, MasterWeights};
use crate::models::{build_model, Gb};
use crate::monitor::{MonitorSeries, MonitorTimeElapsed};
use crate::parametric as PF;
use crate::runtime::{Manifest, StaticExecutable};
use crate::solvers::Solver;
use crate::tensor::{NdArray, DType};

/// Training configuration (the TrainingConfig + Optimizer messages).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub weight_decay: f32,
    /// `sgd | momentum | adam`
    pub solver: String,
    /// None = FP-32; Some(scaler) = mixed precision (§3.3).
    pub loss_scale: Option<LossScalerKind>,
    pub val_batches: usize,
    pub seed: u64,
}

/// Loss-scaler construction spec (Listing 6's two modes).
#[derive(Debug, Clone)]
pub enum LossScalerKind {
    Fixed(f32),
    Dynamic { initial: f32, factor: f32, interval: usize },
}

impl LossScalerKind {
    fn build(&self) -> LossScaler {
        match self {
            LossScalerKind::Fixed(s) => LossScaler::fixed(*s),
            LossScalerKind::Dynamic { initial, factor, interval } => {
                LossScaler::dynamic(*initial, *factor, *interval)
            }
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 0.05,
            weight_decay: 0.0,
            solver: "momentum".into(),
            loss_scale: None,
            val_batches: 4,
            seed: 313,
        }
    }
}

/// Build the solver `cfg` names, or a clean error listing the options
/// — the validation entry for untrusted config (CLI flags, nntxt
/// Optimizer messages) so a typo surfaces as an error message, not a
/// panic mid-run.
pub fn try_make_solver(cfg: &TrainConfig) -> Result<Solver, String> {
    Ok(match cfg.solver.as_str() {
        "sgd" => Solver::sgd(cfg.lr),
        "momentum" => Solver::momentum(cfg.lr, 0.9),
        "adam" => Solver::adam(cfg.lr, 0.9, 0.999, 1e-8),
        other => {
            return Err(format!(
                "unknown solver '{other}' (available: sgd, momentum, adam)"
            ))
        }
    })
}

fn make_solver(cfg: &TrainConfig) -> Solver {
    try_make_solver(cfg).unwrap_or_else(|e| panic!("{e}"))
}

/// Outcome of a training run (feeds the Console trial records and the
/// table generators).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub model: String,
    pub losses: MonitorSeries,
    pub val_error: f32,
    pub wall_secs: f64,
    pub steps: usize,
    pub n_params: usize,
    pub macs: u64,
    pub backend: &'static str,
    pub overflow_skips: usize,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.tail_mean(10)
    }
}

// ------------------------------------------------------------- dynamic

/// Train a zoo model on the define-by-run engine.
pub fn train_dynamic(model: &str, data: &dyn DataSource, cfg: &TrainConfig) -> TrainReport {
    PF::clear_parameters();
    PF::seed_parameter_rng(cfg.seed);
    F::dropout::seed_dropout(cfg.seed ^ 0xD0);
    let half = Context::default().type_config == TypeConfig::Half;

    let batch0 = data.batch(0, 0, 1);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();

    // training graph (built once, re-executed per batch — Figure 1)
    let mut g = Gb::new(model, true);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let macs = g.macs();
    let y = Variable::new(&[bs, 1], false);
    let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

    let params = PF::get_parameters();
    let n_params: usize = params.iter().map(|(_, v)| v.size()).sum();

    // mixed precision: f32 masters behind bf16 working params
    let masters = if half { Some(MasterWeights::new(&params)) } else { None };
    let mut solver = make_solver(cfg);
    match &masters {
        Some(m) => solver.set_parameters(m.masters()),
        None => solver.set_parameters(&params),
    }
    let mut scaler = cfg.loss_scale.as_ref().map(|k| k.build());

    let mut losses = MonitorSeries::new("loss");
    let timer = MonitorTimeElapsed::new();
    let mut skips = 0usize;
    for step in 0..cfg.steps {
        let (bx, by) = data.batch(step, 0, 1);
        x.var.set_data(bx);
        y.set_data(by.reshape(&[bs, 1]));
        loss.forward();
        solver.zero_grad();
        for (_, p) in &params {
            p.zero_grad();
        }
        let scale = scaler.as_ref().map(|s| s.scale()).unwrap_or(1.0);
        loss.backward_with_scale(scale);
        if let Some(m) = &masters {
            m.pull_grads();
        }
        solver.weight_decay(cfg.weight_decay * scale);
        let applied = match &mut scaler {
            Some(s) => {
                let ok = s.step(&mut solver);
                if !ok {
                    skips += 1;
                }
                ok
            }
            None => {
                solver.update();
                true
            }
        };
        if applied {
            if let Some(m) = &masters {
                m.push_weights();
            }
        }
        losses.add(step, loss.item());
    }

    let val_error = evaluate_dynamic(model, data, cfg.val_batches);
    TrainReport {
        model: model.to_string(),
        losses,
        val_error,
        wall_secs: timer.total_secs(),
        steps: cfg.steps,
        n_params,
        macs,
        backend: if half { "cpu:half" } else { "cpu:float" },
        overflow_skips: skips,
    }
}

/// Validation error (argmax) of the current registry parameters, using
/// an eval-mode graph (running-stat BN, inert dropout).
///
/// The eval graph is traced and compiled **once** through the full O2
/// pass pipeline (`nnp::passes`: BN folded onto the running stats,
/// dropout elided, dense→ReLU chains fused), then executed per batch —
/// the same optimized serving path `nnl serve` runs, exercised here on
/// every training run. Training itself never sees the optimizer: the
/// tape records and differentiates the graph exactly as written (the
/// O0 contract). If the trace cannot compile, evaluation falls back to
/// forwarding the tape directly.
pub fn evaluate_dynamic(model: &str, data: &dyn DataSource, batches: usize) -> f32 {
    let batch0 = data.val_batch(0);
    let bs = batch0.0.dims()[0];
    let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();
    let mut g = Gb::new(model, false);
    let x = g.input("x", &dims);
    let logits = build_model(&mut g, model, &x, data.classes());
    let classes = data.classes();
    let def = g.finish(&[&logits]);
    let snapshot: std::collections::HashMap<String, NdArray> =
        PF::get_parameters().into_iter().map(|(n, v)| (n, v.data())).collect();
    let plan = crate::nnp::CompiledNet::compile(&def, &snapshot);
    let mut wrong = 0usize;
    let mut total = 0usize;
    for i in 0..batches {
        let (bx, by) = data.val_batch(i);
        let planned = plan.as_ref().ok().and_then(|p| {
            p.execute_positional(std::slice::from_ref(&bx)).ok().map(|mut o| o.remove(0))
        });
        let out = match planned {
            Some(o) => o,
            None => {
                // untraceable graph or a batch the plan rejects:
                // forward the tape directly, never abort a training run
                x.var.set_data(bx);
                logits.var.forward();
                logits.var.data()
            }
        };
        for b in 0..bs {
            let row = &out.data()[b * classes..(b + 1) * classes];
            // NaN-safe total ordering (shared with the serving path):
            // NaN logits count as a miss instead of panicking
            let pred = crate::tensor::ops::argmax(row);
            if pred != by.data()[b] as usize {
                wrong += 1;
            }
            total += 1;
        }
    }
    wrong as f32 / total as f32
}

// -------------------------------------------------------------- static

/// Train through an AOT artifact (PJRT). The artifact computes
/// `(params, x, y, loss_scale) -> (scaled grads, loss)`; solver,
/// weight decay and the loss-scaler state machine run in Rust.
pub fn train_static(
    manifest: &Manifest,
    artifact: &str,
    data: &dyn DataSource,
    cfg: &TrainConfig,
) -> anyhow::Result<TrainReport> {
    let exe = StaticExecutable::load(manifest, artifact)?;
    let spec = exe.spec().clone();
    let param_vars: Vec<(String, Variable)> = spec
        .init_params()
        .into_iter()
        .map(|(n, a)| (n.clone(), Variable::from_array(a, true)))
        .collect();
    let n_params: usize = param_vars.iter().map(|(_, v)| v.size()).sum();
    let mut solver = make_solver(cfg);
    solver.set_parameters(&param_vars);
    let mut scaler = cfg.loss_scale.as_ref().map(|k| k.build());

    let mut losses = MonitorSeries::new("loss");
    let timer = Instant::now();
    let mut skips = 0usize;
    for step in 0..cfg.steps {
        let (bx, by) = data.batch(step, 0, 1);
        let scale = scaler.as_ref().map(|s| s.scale()).unwrap_or(1.0);
        let mut inputs: Vec<NdArray> = param_vars.iter().map(|(_, v)| v.data()).collect();
        inputs.push(bx);
        inputs.push(by.reshape(&spec.data_inputs()[1].dims));
        inputs.push(NdArray::scalar(scale));
        let out = exe.execute(&inputs)?;
        for ((_, v), grad) in param_vars.iter().zip(&out[..param_vars.len()]) {
            v.set_grad(grad.clone());
        }
        solver.weight_decay(cfg.weight_decay * scale);
        match &mut scaler {
            Some(s) => {
                if !s.step(&mut solver) {
                    skips += 1;
                }
            }
            None => solver.update(),
        }
        losses.add(step, out.last().unwrap().item());
    }
    Ok(TrainReport {
        model: artifact.to_string(),
        losses,
        val_error: f32::NAN, // measured via the matching infer artifact where present
        wall_secs: timer.elapsed().as_secs_f64(),
        steps: cfg.steps,
        n_params,
        macs: 0,
        backend: "xla",
        overflow_skips: skips,
    })
}

/// Validation error through an inference artifact, given trained params.
pub fn evaluate_static(
    manifest: &Manifest,
    infer_artifact: &str,
    params: &[NdArray],
    data: &dyn DataSource,
    batches: usize,
) -> anyhow::Result<f32> {
    let exe = StaticExecutable::load(manifest, infer_artifact)?;
    let classes = data.classes();
    let mut wrong = 0usize;
    let mut total = 0usize;
    for i in 0..batches {
        let (bx, by) = data.val_batch(i);
        let bs = bx.dims()[0];
        let mut inputs: Vec<NdArray> = params.to_vec();
        inputs.push(bx);
        let out = exe.execute(&inputs)?;
        let logits = &out[0];
        for b in 0..bs {
            let row = &logits.data()[b * classes..(b + 1) * classes];
            let pred = crate::tensor::ops::argmax(row);
            if pred != by.data()[b] as usize {
                wrong += 1;
            }
            total += 1;
        }
    }
    Ok(wrong as f32 / total as f32)
}

// --------------------------------------------------------- distributed

/// Data-parallel training over `world` simulated devices (threads),
/// dynamic engine. Listing 3's pattern verbatim: per-worker backward,
/// `all_reduce` of gradients, identical updates everywhere. Returns
/// rank 0's report (loss averaged across workers per step).
pub fn train_distributed<D>(
    model: &'static str,
    data: D,
    cfg: &TrainConfig,
    world: usize,
) -> TrainReport
where
    D: DataSource + Clone + Send + 'static,
{
    let mut hub = CommHub::new(world);
    let mut handles = Vec::new();
    for rank in 0..world {
        let comm = hub.communicator(rank);
        let data = data.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float).with_device(rank));
            PF::clear_parameters();
            PF::seed_parameter_rng(cfg.seed); // same init everywhere
            F::dropout::seed_dropout(cfg.seed ^ rank as u64);

            let batch0 = data.batch(0, rank, world);
            let bs = batch0.0.dims()[0];
            let dims: Vec<usize> = std::iter::once(bs).chain(data.input_dims()).collect();
            let mut g = Gb::new(model, true);
            let x = g.input("x", &dims);
            let logits = build_model(&mut g, model, &x, data.classes());
            let macs = g.macs();
            let y = Variable::new(&[bs, 1], false);
            let loss = F::mean_all(&F::softmax_cross_entropy(&logits.var, &y));

            let params = PF::get_parameters();
            let n_params: usize = params.iter().map(|(_, v)| v.size()).sum();
            // belt-and-braces weight sync (same seed should already agree)
            let mut weights: Vec<NdArray> = params.iter().map(|(_, v)| v.data()).collect();
            comm.bcast(&mut weights);
            for ((_, v), w) in params.iter().zip(weights) {
                v.set_data(w);
            }

            let mut solver = make_solver(&cfg);
            solver.set_parameters(&params);
            let mut losses = MonitorSeries::new("loss");
            let timer = MonitorTimeElapsed::new();
            for step in 0..cfg.steps {
                let (bx, by) = data.batch(step, rank, world);
                x.var.set_data(bx);
                y.set_data(by.reshape(&[bs, 1]));
                loss.forward();
                solver.zero_grad();
                loss.backward(); // Listing 3: loss.backward(clear_buffer=True)
                let trainable: Vec<(String, Variable)> = solver.parameters().to_vec();
                let mut grads: Vec<NdArray> =
                    trainable.iter().map(|(_, v)| v.grad()).collect();
                comm.all_reduce(&mut grads, true); // comm.all_reduce(params)
                for ((_, v), gr) in trainable.iter().zip(grads) {
                    v.set_grad(gr);
                }
                solver.weight_decay(cfg.weight_decay);
                solver.update();
                // step loss averaged across workers (Figure 3 curve)
                let mean_loss = comm.all_gather_scalar(loss.item()).iter().sum::<f32>()
                    / world as f32;
                losses.add(step, mean_loss);
            }
            let val_error =
                if rank == 0 { evaluate_dynamic(model, &data, cfg.val_batches) } else { 0.0 };
            TrainReport {
                model: model.to_string(),
                losses,
                val_error,
                wall_secs: timer.total_secs(),
                steps: cfg.steps,
                n_params,
                macs,
                backend: "cpu:distributed",
                overflow_skips: 0,
            }
        }));
    }
    let mut reports: Vec<TrainReport> =
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect();
    reports.remove(0)
}

/// Quantize current registry parameters for a half-precision run.
pub fn quantize_registry(dtype: DType) {
    let params = PF::get_parameters();
    crate::mixed_precision::quantize_params(&params, dtype);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticImages;

    fn small_cfg(steps: usize) -> TrainConfig {
        TrainConfig { steps, lr: 0.05, val_batches: 2, ..Default::default() }
    }

    #[test]
    fn dynamic_mlp_learns_synthetic() {
        // mlp on flattened synthetic images: loss must halve, error
        // must beat chance decisively
        let data = SyntheticImages::new(4, 1, 8, 16, 3);
        // mlp takes [B, 64]: wrap with a flattening source
        #[derive(Clone)]
        struct Flat(SyntheticImages);
        impl crate::data::DataSource for Flat {
            fn batch(&self, i: usize, r: usize, w: usize) -> crate::data::Batch {
                let (x, y) = self.0.batch(i, r, w);
                let b = x.dims()[0];
                (x.reshape(&[b, 64]), y)
            }
            fn val_batch(&self, i: usize) -> crate::data::Batch {
                let (x, y) = self.0.val_batch(i);
                let b = x.dims()[0];
                (x.reshape(&[b, 64]), y)
            }
            fn input_dims(&self) -> Vec<usize> {
                vec![64]
            }
            fn classes(&self) -> usize {
                4
            }
        }
        let report = train_dynamic("mlp", &Flat(data), &small_cfg(60));
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first * 0.5, "{first} -> {}", report.final_loss());
        assert!(report.val_error < 0.5, "val error {}", report.val_error); // chance = 0.75
    }

    #[test]
    fn dynamic_mixed_precision_trains() {
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Half));
        let data = SyntheticImages::new(4, 3, 16, 8, 5);
        let mut cfg = small_cfg(25);
        cfg.loss_scale = Some(LossScalerKind::Dynamic { initial: 8.0, factor: 2.0, interval: 100 });
        let report = train_dynamic("resnet18", &data, &cfg);
        Context::set_default(Context::new(Backend::Cpu, TypeConfig::Float));
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first, "half training diverged");
        assert_eq!(report.backend, "cpu:half");
    }

    #[test]
    fn unknown_solver_errs_cleanly_on_the_try_path() {
        let cfg = TrainConfig { solver: "adamw".into(), ..Default::default() };
        let err = try_make_solver(&cfg).unwrap_err();
        assert!(err.contains("unknown solver 'adamw'"), "{err}");
        assert!(err.contains("momentum"), "error must list the options: {err}");
        assert!(try_make_solver(&small_cfg(1)).is_ok());
    }

    #[test]
    fn distributed_matches_single_worker_gradient_math() {
        // 2 workers with lr/1: after same number of steps on disjoint
        // data, the loss still falls; and workers stay in sync (the
        // all_reduce property tests prove exact agreement)
        let data = SyntheticImages::new(4, 3, 16, 8, 7);
        let report = train_distributed("resnet18", data, &small_cfg(15), 2);
        let first = report.losses.points()[0].1;
        assert!(report.final_loss() < first, "distributed diverged");
        assert_eq!(report.backend, "cpu:distributed");
    }
}
