//! Monitors — the paper's Monitor message (§3.1) and the training-
//! status tracking Neural Network Console renders (§5.1). Series are
//! kept in memory and can be flushed to CSV for plotting (Figure 3's
//! loss curve comes out of these).

use std::fmt::Write as _;
use std::path::Path;
use std::time::Instant;

pub mod metrics;

/// A named scalar time-series (loss, error, lr, ...).
#[derive(Debug, Clone, Default)]
pub struct MonitorSeries {
    pub name: String,
    points: Vec<(usize, f32)>,
}

impl MonitorSeries {
    pub fn new(name: &str) -> Self {
        MonitorSeries { name: name.to_string(), points: Vec::new() }
    }

    pub fn add(&mut self, step: usize, value: f32) {
        self.points.push((step, value));
    }

    pub fn points(&self) -> &[(usize, f32)] {
        &self.points
    }

    pub fn last(&self) -> Option<f32> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Mean of the last `n` values (smoothed readout). An empty series
    /// reads 0.0, not NaN — dashboards and the serving `/stats` path
    /// consume this directly, and NaN poisons any aggregate it meets.
    pub fn tail_mean(&self, n: usize) -> f32 {
        if self.points.is_empty() || n == 0 {
            return 0.0;
        }
        let tail = &self.points[self.points.len().saturating_sub(n)..];
        tail.iter().map(|&(_, v)| v).sum::<f32>() / tail.len() as f32
    }

    /// CSV rendering (`step,value` rows with a header). Series names
    /// are user-controlled; names containing `,`, `"`, or newlines are
    /// quoted (with `"` doubled) so the header stays two columns.
    pub fn to_csv(&self) -> String {
        let mut s = format!("step,{}\n", csv_escape(&self.name));
        for (step, v) in &self.points {
            let _ = writeln!(s, "{step},{v}");
        }
        s
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_csv())
    }
}

/// Short alias used throughout the serving/metrics docs.
pub type Series = MonitorSeries;

/// RFC-4180 field escaping: quote when the value contains a comma,
/// quote, or line break, doubling any embedded quotes.
fn csv_escape(field: &str) -> String {
    if field.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

/// Wall-clock tracker (`MonitorTimeElapsed`).
#[derive(Debug)]
pub struct MonitorTimeElapsed {
    start: Instant,
    laps: Vec<(usize, f64)>,
}

impl Default for MonitorTimeElapsed {
    fn default() -> Self {
        Self::new()
    }
}

impl MonitorTimeElapsed {
    pub fn new() -> Self {
        MonitorTimeElapsed { start: Instant::now(), laps: Vec::new() }
    }

    pub fn lap(&mut self, step: usize) -> f64 {
        let t = self.start.elapsed().as_secs_f64();
        self.laps.push((step, t));
        t
    }

    pub fn total_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds per step over the last recorded span.
    pub fn secs_per_step(&self) -> f64 {
        match (self.laps.first(), self.laps.last()) {
            (Some(&(s0, t0)), Some(&(s1, t1))) if s1 > s0 => (t1 - t0) / (s1 - s0) as f64,
            _ => self.total_secs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_records_and_summarizes() {
        let mut m = MonitorSeries::new("loss");
        for i in 0..10 {
            m.add(i, 10.0 - i as f32);
        }
        assert_eq!(m.len(), 10);
        assert_eq!(m.last(), Some(1.0));
        assert!((m.tail_mean(2) - 1.5).abs() < 1e-6);
        assert!((m.tail_mean(100) - 5.5).abs() < 1e-6); // clamps to available
    }

    #[test]
    fn csv_format() {
        let mut m = MonitorSeries::new("err");
        m.add(0, 0.5);
        m.add(10, 0.25);
        let csv = m.to_csv();
        assert!(csv.starts_with("step,err\n"));
        assert!(csv.contains("10,0.25"));
    }

    #[test]
    fn time_monitor_laps() {
        let mut t = MonitorTimeElapsed::new();
        std::thread::sleep(std::time::Duration::from_millis(5));
        t.lap(0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let total = t.lap(10);
        assert!(total >= 0.01);
        assert!(t.secs_per_step() > 0.0);
    }

    #[test]
    fn empty_series_tail_is_zero_not_nan() {
        let m = MonitorSeries::new("x");
        assert_eq!(m.tail_mean(5), 0.0);
        assert_eq!(m.tail_mean(0), 0.0);
        assert!(m.is_empty());
    }

    #[test]
    fn csv_escapes_hostile_series_names() {
        let mut m = MonitorSeries::new("loss, val \"best\"");
        m.add(1, 0.5);
        let csv = m.to_csv();
        let header = csv.lines().next().unwrap();
        assert_eq!(header, "step,\"loss, val \"\"best\"\"\"");
        assert!(csv.contains("1,0.5"));
        // benign names stay unquoted
        assert!(MonitorSeries::new("loss").to_csv().starts_with("step,loss\n"));
    }
}
