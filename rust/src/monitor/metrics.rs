//! Live serving metrics — the `monitor` layer the network server
//! exports through its `/stats` protocol verb (ISSUE: framework
//! comparisons judge deployed stacks by measured latency/throughput,
//! so the numbers must come off the live path, not a benchmark rig).
//!
//! Everything here is lock-free on the record side: workers bump
//! atomics and atomic histogram buckets, and a snapshot is computed
//! only when someone asks (`/stats`, `nnl bench-serve`, shutdown
//! logs). One [`ModelMetrics`] lives for the whole lifetime of a
//! registry entry, *across* hot swaps, so p50/p99 and shed counts
//! describe the model as clients experienced it, not one plan
//! incarnation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::utils::json::Json;

/// Number of exponential (power-of-two nanosecond) latency buckets:
/// bucket `i` holds samples in `[2^i, 2^(i+1))` ns; bucket 39 tops out
/// above 500 s, far past any sane request.
const LAT_BUCKETS: usize = 40;

/// Linear batch-size buckets `1..=BATCH_BUCKETS`, with one overflow
/// bucket for anything larger.
const BATCH_BUCKETS: usize = 32;

/// A fixed-bucket exponential histogram over nanosecond samples.
/// `record` is a single relaxed fetch-add — safe from any worker —
/// and percentiles are interpolated inside the winning bucket.
pub struct Histogram {
    buckets: [AtomicU64; LAT_BUCKETS],
}

// derived Default stops at 32-element arrays
impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl Histogram {
    pub fn record_ns(&self, ns: u64) {
        // log2 bucket; ns == 0 lands in bucket 0
        let idx = (63 - ns.max(1).leading_zeros()) as usize;
        self.buckets[idx.min(LAT_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate `p`-quantile (0 < p <= 1) in milliseconds, linearly
    /// interpolated within the bucket that crosses the target rank.
    /// 0.0 on an empty histogram — the same NaN-free contract as
    /// [`super::MonitorSeries::tail_mean`].
    pub fn quantile_ms(&self, p: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = (1u64 << i) as f64;
                let hi = lo * 2.0;
                let frac = (target - seen) as f64 / c as f64;
                return (lo + (hi - lo) * frac) / 1e6;
            }
            seen += c;
        }
        0.0
    }
}

/// A linear histogram of executed batch sizes (rows per plan
/// execution) — the direct evidence of whether micro-batching engages
/// under load.
pub struct BatchHistogram {
    buckets: [AtomicU64; BATCH_BUCKETS + 1],
}

impl Default for BatchHistogram {
    fn default() -> Self {
        BatchHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl BatchHistogram {
    pub fn record(&self, rows: usize) {
        let idx = if rows == 0 || rows > BATCH_BUCKETS {
            BATCH_BUCKETS
        } else {
            rows - 1
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Non-empty `(batch_rows, count)` pairs; the overflow bucket
    /// reports as `BATCH_BUCKETS + 1`.
    pub fn nonzero(&self) -> Vec<(usize, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let c = b.load(Ordering::Relaxed);
                (c > 0).then_some((i + 1, c))
            })
            .collect()
    }
}

/// Per-model serving counters + distributions. One instance per
/// registry entry, shared by every plan incarnation hosted under that
/// name (hot swaps bump `swaps` and keep counting).
pub struct ModelMetrics {
    pub requests: AtomicU64,
    pub rows: AtomicU64,
    /// Plan executions (each may cover several requests).
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Requests refused by admission control (bounded queue full).
    pub shed: AtomicU64,
    /// Current bounded-queue depth (gauge).
    pub queue_depth: AtomicU64,
    /// Hot swaps performed under this name.
    pub swaps: AtomicU64,
    /// Request panics caught at a worker's `catch_unwind` boundary
    /// (each one answered with a typed `Internal` error).
    pub panics_caught: AtomicU64,
    /// Serve workers resurrected by supervision after a panic escaped
    /// per-request isolation.
    pub worker_restarts: AtomicU64,
    /// Requests shed *before* compute because their deadline expired
    /// while queued (answered with `DeadlineExceeded`).
    pub deadline_expired: AtomicU64,
    /// Client-side retries recorded by in-process `infer_with_retry`.
    pub retries: AtomicU64,
    pub exec_ns: AtomicU64,
    pub latency_ns: AtomicU64,
    pub latency: Histogram,
    pub batch_rows: BatchHistogram,
    started: Instant,
}

impl Default for ModelMetrics {
    fn default() -> Self {
        ModelMetrics {
            requests: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            panics_caught: AtomicU64::new(0),
            worker_restarts: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            latency_ns: AtomicU64::new(0),
            latency: Histogram::default(),
            batch_rows: BatchHistogram::default(),
            started: Instant::now(),
        }
    }
}

impl ModelMetrics {
    /// Record one finished request: enqueue-to-reply latency plus the
    /// error flag (workers call this from `finish`).
    pub fn record_request(&self, rows: usize, latency_ns: u64, err: bool) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.latency_ns.fetch_add(latency_ns, Ordering::Relaxed);
        self.latency.record_ns(latency_ns);
        if err {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one plan execution covering `rows` total rows.
    pub fn record_batch(&self, rows: usize, exec_ns: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
        self.batch_rows.record(rows);
    }

    /// Consistent point-in-time view (individual counters are relaxed;
    /// the snapshot is advisory, which is all monitoring needs).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let requests = self.requests.load(Ordering::Relaxed);
        let rows = self.rows.load(Ordering::Relaxed);
        let batches = self.batches.load(Ordering::Relaxed);
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            requests,
            rows,
            batches,
            errors: self.errors.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            swaps: self.swaps.load(Ordering::Relaxed),
            panics_caught: self.panics_caught.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            deadline_expired: self.deadline_expired.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            mean_batch_rows: rows as f64 / batches.max(1) as f64,
            mean_exec_ms: self.exec_ns.load(Ordering::Relaxed) as f64
                / 1e6
                / batches.max(1) as f64,
            mean_latency_ms: self.latency_ns.load(Ordering::Relaxed) as f64
                / 1e6
                / requests.max(1) as f64,
            p50_ms: self.latency.quantile_ms(0.50),
            p99_ms: self.latency.quantile_ms(0.99),
            rps: requests as f64 / secs,
            uptime_secs: secs,
            batch_dist: self.batch_rows.nonzero(),
        }
    }
}

/// What a `/stats` reply carries per model.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub rows: u64,
    pub batches: u64,
    pub errors: u64,
    pub shed: u64,
    pub queue_depth: u64,
    pub swaps: u64,
    pub panics_caught: u64,
    pub worker_restarts: u64,
    pub deadline_expired: u64,
    pub retries: u64,
    pub mean_batch_rows: f64,
    pub mean_exec_ms: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Requests per second over the metric's whole lifetime.
    pub rps: f64,
    pub uptime_secs: f64,
    pub batch_dist: Vec<(usize, u64)>,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Json {
        let dist = Json::Obj(
            self.batch_dist
                .iter()
                .map(|&(rows, c)| (rows.to_string(), Json::num(c as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("rows", Json::num(self.rows as f64)),
            ("batches", Json::num(self.batches as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("swaps", Json::num(self.swaps as f64)),
            ("panics_caught", Json::num(self.panics_caught as f64)),
            ("worker_restarts", Json::num(self.worker_restarts as f64)),
            ("deadline_expired", Json::num(self.deadline_expired as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("mean_batch_rows", Json::num(self.mean_batch_rows)),
            ("mean_exec_ms", Json::num(self.mean_exec_ms)),
            ("mean_latency_ms", Json::num(self.mean_latency_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("rps", Json::num(self.rps)),
            ("uptime_secs", Json::num(self.uptime_secs)),
            ("batch_size_distribution", dist),
        ])
    }
}

/// Process-global distributed-training counters, bumped from the
/// `comm::net` hot path (frame reads/writes, collective calls) and
/// the bucketed reducer (overlap accounting). Global rather than
/// per-communicator because the transport layer — frame I/O, writer
/// threads — has no communicator handy, and one process hosts exactly
/// one training rank.
#[derive(Default)]
pub struct CommCounters {
    /// Collective all-reduce invocations (one per bucket per step).
    pub allreduce_calls: AtomicU64,
    /// Framed bytes handed to the transport (headers included).
    pub bytes_sent: AtomicU64,
    /// Framed bytes read off the predecessor link.
    pub bytes_recv: AtomicU64,
    /// Communication-thread busy nanoseconds that overlapped a
    /// backward pass — the time bucketing actually hid.
    pub overlap_ns_hidden: AtomicU64,
    /// Ring receives that blocked > 1 ms waiting on a peer.
    pub ring_stalls: AtomicU64,
}

impl CommCounters {
    pub fn snapshot(&self) -> CommSnapshot {
        CommSnapshot {
            allreduce_calls: self.allreduce_calls.load(Ordering::Relaxed),
            bytes_sent: self.bytes_sent.load(Ordering::Relaxed),
            bytes_recv: self.bytes_recv.load(Ordering::Relaxed),
            overlap_ms_hidden: self.overlap_ns_hidden.load(Ordering::Relaxed) as f64 / 1e6,
            ring_stalls: self.ring_stalls.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of [`CommCounters`]; subtract two to get the
/// traffic of one benchmark run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommSnapshot {
    pub allreduce_calls: u64,
    pub bytes_sent: u64,
    pub bytes_recv: u64,
    pub overlap_ms_hidden: f64,
    pub ring_stalls: u64,
}

impl CommSnapshot {
    /// Counter deltas `self - earlier` (saturating, so a torn read
    /// never yields a bogus huge delta).
    pub fn since(&self, earlier: &CommSnapshot) -> CommSnapshot {
        CommSnapshot {
            allreduce_calls: self.allreduce_calls.saturating_sub(earlier.allreduce_calls),
            bytes_sent: self.bytes_sent.saturating_sub(earlier.bytes_sent),
            bytes_recv: self.bytes_recv.saturating_sub(earlier.bytes_recv),
            overlap_ms_hidden: (self.overlap_ms_hidden - earlier.overlap_ms_hidden).max(0.0),
            ring_stalls: self.ring_stalls.saturating_sub(earlier.ring_stalls),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("allreduce_calls", Json::num(self.allreduce_calls as f64)),
            ("bytes_sent", Json::num(self.bytes_sent as f64)),
            ("bytes_recv", Json::num(self.bytes_recv as f64)),
            ("overlap_ms_hidden", Json::num(self.overlap_ms_hidden)),
            ("ring_stalls", Json::num(self.ring_stalls as f64)),
        ])
    }
}

static COMM: CommCounters = CommCounters {
    allreduce_calls: AtomicU64::new(0),
    bytes_sent: AtomicU64::new(0),
    bytes_recv: AtomicU64::new(0),
    overlap_ns_hidden: AtomicU64::new(0),
    ring_stalls: AtomicU64::new(0),
};

/// The process-global comm counters.
pub fn comm() -> &'static CommCounters {
    &COMM
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let h = Histogram::default();
        // 99 fast samples (~1 ms) and one slow outlier (~1 s)
        for _ in 0..99 {
            h.record_ns(1_000_000);
        }
        h.record_ns(1_000_000_000);
        assert_eq!(h.count(), 100);
        let p50 = h.quantile_ms(0.5);
        let p99 = h.quantile_ms(0.99);
        let p100 = h.quantile_ms(1.0);
        assert!((0.5..4.0).contains(&p50), "p50 {p50}");
        assert!(p99 <= p100, "p99 {p99} p100 {p100}");
        assert!(p100 > 500.0, "outlier must surface at the tail: {p100}");
    }

    #[test]
    fn empty_histogram_is_zero_not_nan() {
        let h = Histogram::default();
        assert_eq!(h.quantile_ms(0.5), 0.0);
        assert_eq!(h.quantile_ms(0.99), 0.0);
    }

    #[test]
    fn batch_histogram_buckets() {
        let b = BatchHistogram::default();
        b.record(1);
        b.record(1);
        b.record(8);
        b.record(4096); // overflow bucket
        assert_eq!(b.nonzero(), vec![(1, 2), (8, 1), (BATCH_BUCKETS + 1, 1)]);
    }

    #[test]
    fn snapshot_math() {
        let m = ModelMetrics::default();
        m.record_batch(4, 2_000_000);
        for _ in 0..4 {
            m.record_request(1, 1_000_000, false);
        }
        m.shed.fetch_add(3, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests, 4);
        assert_eq!(s.shed, 3);
        assert_eq!(s.errors, 0);
        assert!((s.mean_batch_rows - 4.0).abs() < 1e-9);
        assert!((s.mean_exec_ms - 2.0).abs() < 1e-9);
        assert!((s.mean_latency_ms - 1.0).abs() < 1e-9);
        assert!(s.p50_ms > 0.0);
        let j = s.to_json();
        assert_eq!(j.get("requests").as_usize(), Some(4));
        assert!(j.get("batch_size_distribution").as_obj().is_some());
    }

    #[test]
    fn comm_snapshot_deltas_and_json() {
        let c = CommCounters::default();
        c.allreduce_calls.fetch_add(2, Ordering::Relaxed);
        c.bytes_sent.fetch_add(1000, Ordering::Relaxed);
        let before = c.snapshot();
        c.allreduce_calls.fetch_add(3, Ordering::Relaxed);
        c.bytes_sent.fetch_add(500, Ordering::Relaxed);
        c.bytes_recv.fetch_add(400, Ordering::Relaxed);
        c.overlap_ns_hidden.fetch_add(2_000_000, Ordering::Relaxed);
        c.ring_stalls.fetch_add(1, Ordering::Relaxed);
        let d = c.snapshot().since(&before);
        assert_eq!(d.allreduce_calls, 3);
        assert_eq!(d.bytes_sent, 500);
        assert_eq!(d.bytes_recv, 400);
        assert_eq!(d.ring_stalls, 1);
        assert!((d.overlap_ms_hidden - 2.0).abs() < 1e-9);
        let j = d.to_json();
        assert_eq!(j.get("bytes_sent").as_usize(), Some(500));
        assert_eq!(j.get("allreduce_calls").as_usize(), Some(3));
    }

    #[test]
    fn robustness_counters_flow_through_snapshot_and_json() {
        let m = ModelMetrics::default();
        m.panics_caught.fetch_add(2, Ordering::Relaxed);
        m.worker_restarts.fetch_add(1, Ordering::Relaxed);
        m.deadline_expired.fetch_add(3, Ordering::Relaxed);
        m.retries.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.panics_caught, 2);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.deadline_expired, 3);
        assert_eq!(s.retries, 5);
        let j = s.to_json();
        assert_eq!(j.get("panics_caught").as_usize(), Some(2));
        assert_eq!(j.get("worker_restarts").as_usize(), Some(1));
        assert_eq!(j.get("deadline_expired").as_usize(), Some(3));
        assert_eq!(j.get("retries").as_usize(), Some(5));
    }
}
