//! Learning-rate schedulers — the training recipes behind the paper's
//! §4 runs (warmup + cosine/step decay are what the referenced
//! imagenet-classification examples use).

/// A learning-rate schedule: step -> lr.
pub trait LrScheduler {
    fn lr_at(&self, step: usize) -> f32;

    /// Apply to a solver (call once per iteration).
    fn apply(&self, solver: &mut crate::solvers::Solver, step: usize) {
        solver.set_learning_rate(self.lr_at(step));
    }
}

/// Constant learning rate.
pub struct Constant(pub f32);

impl LrScheduler for Constant {
    fn lr_at(&self, _step: usize) -> f32 {
        self.0
    }
}

/// Linear warmup into a base schedule (large-batch distributed recipe).
pub struct Warmup<S: LrScheduler> {
    pub warmup_steps: usize,
    pub inner: S,
}

impl<S: LrScheduler> LrScheduler for Warmup<S> {
    fn lr_at(&self, step: usize) -> f32 {
        let base = self.inner.lr_at(step.max(self.warmup_steps));
        if step < self.warmup_steps {
            base * (step + 1) as f32 / self.warmup_steps as f32
        } else {
            self.inner.lr_at(step)
        }
    }
}

/// Step decay: lr * gamma^(step / period).
pub struct StepDecay {
    pub base: f32,
    pub gamma: f32,
    pub period: usize,
}

impl LrScheduler for StepDecay {
    fn lr_at(&self, step: usize) -> f32 {
        self.base * self.gamma.powi((step / self.period) as i32)
    }
}

/// Cosine annealing from `base` to `floor` over `total` steps.
pub struct Cosine {
    pub base: f32,
    pub floor: f32,
    pub total: usize,
}

impl LrScheduler for Cosine {
    fn lr_at(&self, step: usize) -> f32 {
        let t = (step.min(self.total)) as f32 / self.total.max(1) as f32;
        self.floor
            + 0.5 * (self.base - self.floor) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Constant(0.1);
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(1_000_000), 0.1);
    }

    #[test]
    fn warmup_ramps_linearly_then_hands_off() {
        let s = Warmup { warmup_steps: 10, inner: Constant(1.0) };
        assert!((s.lr_at(0) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(4) - 0.5).abs() < 1e-6);
        assert_eq!(s.lr_at(10), 1.0);
        assert_eq!(s.lr_at(100), 1.0);
    }

    #[test]
    fn step_decay_halves_per_period() {
        let s = StepDecay { base: 0.8, gamma: 0.5, period: 100 };
        assert_eq!(s.lr_at(0), 0.8);
        assert_eq!(s.lr_at(99), 0.8);
        assert_eq!(s.lr_at(100), 0.4);
        assert_eq!(s.lr_at(250), 0.2);
    }

    #[test]
    fn cosine_endpoints_and_monotonicity() {
        let s = Cosine { base: 1.0, floor: 0.1, total: 100 };
        assert!((s.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((s.lr_at(100) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(50) - 0.55).abs() < 1e-6);
        // monotone decreasing
        for w in (0..=100).collect::<Vec<_>>().windows(2) {
            assert!(s.lr_at(w[1]) <= s.lr_at(w[0]) + 1e-6);
        }
        // clamped past the horizon
        assert_eq!(s.lr_at(500), s.lr_at(100));
    }

    #[test]
    fn applies_to_solver() {
        let mut solver = crate::solvers::Solver::sgd(1.0);
        let s = StepDecay { base: 0.5, gamma: 0.1, period: 10 };
        s.apply(&mut solver, 0);
        assert_eq!(solver.learning_rate(), 0.5);
        s.apply(&mut solver, 25);
        assert!((solver.learning_rate() - 0.005).abs() < 1e-7);
    }

    #[test]
    fn warmup_cosine_composition() {
        let s = Warmup { warmup_steps: 5, inner: Cosine { base: 1.0, floor: 0.0, total: 100 } };
        assert!(s.lr_at(0) < s.lr_at(4));
        assert!(s.lr_at(99) < s.lr_at(10));
    }
}
